"""Train a ~100M-param smollm-family model for a few hundred steps on CPU —
the classical-architecture substrate end-to-end: config -> Model ->
microbatched train_step -> optimizer -> checkpoint.

The co-management connection: this is the same train_step the multi-pod
dry-run lowers for the production mesh; here it runs real steps at reduced
width on synthetic tokens.

Run:  PYTHONPATH=src python examples/transformer_train.py [--steps 200]
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.checkpoint import checkpoint
from repro.configs import base as cfg_base
from repro.data import pipeline
from repro.launch import steps
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_transformer.npz")
    args = ap.parse_args()

    # ~100M-scale variant of the assigned arch: full d_model, fewer layers
    cfg = cfg_base.get(args.arch).with_(
        n_layers=8, vocab=8192, microbatch=max(1, args.batch // 2),
        dtype="float32", remat=False)
    model = transformer.Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n = transformer.param_count(params)
    print(f"{args.arch} variant: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab} -> {n/1e6:.1f}M params")

    train_step, optimizer, _ = steps.make_train_step(cfg, global_batch=args.batch)
    opt_state = optimizer.init(params)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    losses, t0 = [], time.time()
    for i in range(args.steps):
        batch = {"tokens": pipeline.synthetic_tokens(i, args.batch, args.seq,
                                                     cfg.vocab)}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if i % 20 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tps = args.batch * args.seq * (i + 1) / dt
            print(f"step {i:4d}  loss {losses[-1]:.4f}  ({tps:,.0f} tok/s)")
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")

    checkpoint.save(args.ckpt, params, metadata={"step": args.steps,
                                                 "arch": args.arch})
    restored, meta = checkpoint.load(args.ckpt, like=params)
    same = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(restored)))
    print(f"checkpoint round-trip at step {meta['step']}: {'OK' if same else 'FAIL'}")
    os.remove(args.ckpt)


if __name__ == "__main__":
    main()
