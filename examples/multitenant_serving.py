"""Multi-tenant system demo: four concurrent clients with heterogeneous
circuit widths share four heterogeneous quantum workers (5/10/15/20 qubits)
under the co-Manager (Algorithm 2) — including a mid-run worker failure and
its 3-missed-heartbeats eviction + requeue recovery.  Driven through the
typed ``repro.api`` facade (``ClusterConfig`` + ``QuantumCluster.simulate``
replacing the loose ``SystemSimulation`` kwarg pile).

Run:  PYTHONPATH=src python examples/multitenant_serving.py
"""
from collections import Counter

from repro.api import ClusterConfig, QuantumCluster, SimulationConfig
from repro.comanager import tenancy
from repro.comanager.worker import WorkerConfig


def run(tenancy_mode: str, failures=None):
    jobs = [
        tenancy.JobSpec("alice-5q1l", 5, 1, 240, service_override=0.26),
        tenancy.JobSpec("bob-5q2l", 5, 2, 240, service_override=0.33),
        tenancy.JobSpec("carol-7q1l", 7, 1, 240, service_override=0.33),
        tenancy.JobSpec("dave-7q2l", 7, 2, 240, service_override=0.42),
    ]
    cluster = QuantumCluster(ClusterConfig(
        workers=tuple(WorkerConfig(f"w{i+1}", q, contention=0.5)
                      for i, q in enumerate((5, 10, 15, 20))),
        simulation=SimulationConfig(tenancy=tenancy_mode, fair_queue=True,
                                    classical_overhead=0.01),
    ))
    rep = cluster.simulate(jobs, worker_failures=failures or {})
    return cluster, rep


def main():
    print("=== multi-tenant vs single-tenant, 4 clients x 240 circuits ===")
    results = {}
    for mode in ("multi", "single_circuit"):
        sim, rep = run(mode)
        results[mode] = rep
        print(f"\n[{mode}] makespan {rep.makespan:.1f}s, "
              f"{rep.circuits_per_second:.1f} circuits/s")
        for cid, job in sorted(rep.jobs.items()):
            print(f"  {cid:12s} finished at {job.finish_time:7.1f}s "
                  f"({job.circuits_per_second:.2f} c/s)")
        spread = Counter(w for _, _, w in rep.assignments)
        print(f"  assignment spread: {dict(sorted(spread.items()))}")

    m, s = results["multi"], results["single_circuit"]
    print(f"\nmulti-tenancy system speedup: "
          f"{s.makespan / m.makespan:.2f}x on makespan, "
          f"{m.circuits_per_second / s.circuits_per_second:.2f}x on throughput")

    print("\n=== worker failure: w4 (20q) goes silent at t=30s ===")
    sim, rep = run("multi", failures={"w4": 30.0})
    ev = rep.evictions[0] if rep.evictions else None
    print(f"evicted: {ev} (3 missed heartbeats after t=30)")
    done = sum(1 for j in rep.jobs.values())
    print(f"all {done}/4 client jobs still completed "
          f"(requeued circuits rescheduled); makespan {rep.makespan:.1f}s")


if __name__ == "__main__":
    main()
