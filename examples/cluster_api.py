"""The unified public API: one ``QuantumCluster``, per-tenant ``Session``
handles, and the ``ExecutionBackend`` protocol over every executor family.

Three scenes:
  1. two tenants with different ``TenantPolicy``s stream circuits through
     session handles and share coalesced kernel launches;
  2. a training session's gradients are BIT-IDENTICAL to the pre-redesign
     ``GatewayRuntime.executor`` path (the facade is a front, not a fork);
  3. the same ``ShiftBank`` runs through backend adapters and the cost
     model explains what each family charges.

Run:  PYTHONPATH=src python examples/cluster_api.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import QuantumCluster, ClusterConfig, ServingConfig, TenantPolicy
from repro.core import quclassi, shift_rule
from repro.core.quclassi import QuClassiConfig


def serving_demo(cluster, cfg):
    print("=== tenant sessions: alice (tier 0, 500ms SLO) + bob (bulk) ===")
    alice = cluster.session("alice", TenantPolicy(priority=0, slo_ms=500.0, weight=2.0))
    bob = cluster.session("bob", TenantPolicy(priority=1))
    rng = np.random.default_rng(0)
    futures = []
    for _ in range(48):
        for sess in (alice, bob):
            theta = jnp.asarray(rng.uniform(0, np.pi, cfg.n_theta), jnp.float32)
            data = jnp.asarray(rng.uniform(0, np.pi, cfg.n_angles), jnp.float32)
            futures.append(sess.submit(cfg.spec, theta, data))
    alice.drain()
    assert all(f.done for f in futures)
    for sess in (alice, bob):
        t = sess.telemetry()
        print(f"  {sess.tenant:6s} completed={t['completed']} "
              f"p50={t['p50_latency_s']*1e3:.1f}ms")
    s = cluster.telemetry.summary()
    print(f"  {s['total_completed']} circuits in {s['batches']} launches, "
          f"lane fill {s['lane_fill']:.0%}")


def training_demo(cluster, cfg):
    print("\n=== session.train path == pre-redesign gateway path, bit for bit ===")
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(0, 1, (4, 8, 8)), jnp.float32)
    y = jnp.asarray([0, 1, 0, 1])
    params = quclassi.init_params(cfg, jax.random.PRNGKey(0))

    sess = cluster.session("trainer", bank_mode="materialized")
    loss_new, g_new, _ = quclassi.grad_shift(cfg, params, x, y,
                                             executor=sess.executor(cfg.spec))
    old = cluster.runtime.executor(cfg.spec, "trainer-legacy")
    loss_old, g_old, _ = quclassi.grad_shift(cfg, params, x, y, executor=old)
    diff = float(jnp.abs(g_new["theta"] - g_old["theta"]).max())
    assert diff == 0.0 and float(loss_new) == float(loss_old)
    print(f"  session grad == legacy gateway grad (max |diff| = {diff:.1f})")

    imp = cluster.session("trainer-imp")  # bank_mode auto -> implicit banks
    _, g_imp, _ = quclassi.grad_shift(cfg, params, x, y,
                                      executor=imp.executor(cfg.spec))
    err = float(jnp.abs(g_imp["theta"] - g_old["theta"]).max())
    print(f"  implicit shift-bank session matches to kernel tolerance "
          f"({err:.1e})")


def backend_demo(cluster, cfg):
    print("\n=== ExecutionBackend protocol over the executor families ===")
    rng = np.random.default_rng(2)
    theta = jnp.asarray(rng.uniform(0, np.pi, cfg.n_theta), jnp.float32)
    data = jnp.asarray(rng.uniform(0, np.pi, (96, cfg.n_angles)), jnp.float32)
    bank = shift_rule.build_shift_bank(theta, data)
    mat = bank.materialize()
    ref = None
    for kind in ("batched", "pooled", "multibank", "sharded", "mesh_spill"):
        with cluster.backend(kind, cfg.spec) as be:
            fids = np.asarray(be.run_bank(bank))
            if ref is None:
                ref = fids
            caps = be.capabilities()
            cm = be.cost_model()
            flags = "".join(
                c for c, on in zip("smxvp", (caps.shiftbank, caps.multibank,
                                             caps.sharded, caps.vmem_model,
                                             caps.mesh_spill)) if on)
            print(f"  {kind:10s} caps[{flags:5s}] "
                  f"implicit {cm.bank_cost_units(cfg.spec, bank):8.0f} units "
                  f"vs materialized {cm.bank_cost_units(cfg.spec, mat):8.0f} "
                  f"(max |diff vs batched| = {np.abs(fids - ref).max():.1e})")


def main():
    cfg = QuClassiConfig(qc=5, n_layers=1)
    config = ClusterConfig(serving=ServingConfig(target=128, deadline=0.25))
    with QuantumCluster(config) as cluster:
        serving_demo(cluster, cfg)
        training_demo(cluster, cfg)
        backend_demo(cluster, cfg)


if __name__ == "__main__":
    main()
