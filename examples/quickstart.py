"""Quickstart: the DQuLearn pipeline on one machine in ~a minute.

  1. build the paper's 5-qubit / 1-layer QuClassi circuit,
  2. segment an image into filter patches (Task Segmentation),
  3. run the SWAP-test fidelity through the fused Pallas kernel,
  4. take one parameter-shift gradient step and verify it against autodiff.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import circuits, quclassi, segmentation
from repro.core.quclassi import QuClassiConfig
from repro.data import mnist
from repro.kernels import ops

def main():
    # --- the subtask circuit -------------------------------------------------
    spec = circuits.build_quclassi_circuit(qc=5, n_layers=1)
    print(f"QuClassi circuit: {spec.n_qubits} qubits, {len(spec.ops)} gates, "
          f"{spec.n_theta} trainable params, {spec.n_data} data angles")

    # --- task segmentation (paper Fig 2): 8x8 image -> 3x3 patches of 4x4 ----
    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(1, 5, n_per_class=4, seed=0)
    patches = segmentation.segment(jnp.asarray(x), cfg.seg)
    print(f"segmentation: {x.shape} images -> {patches.shape} patches "
          f"(stride {cfg.seg.stride}, width {cfg.seg.filter_width})")

    # --- fused-kernel fidelity on a batch of circuits ------------------------
    key = jax.random.PRNGKey(0)
    theta = jax.random.uniform(key, (patches.shape[0] * patches.shape[1],
                                     spec.n_theta)) * jnp.pi
    angles = (patches.reshape(-1, 16)[:, :spec.n_data]) * jnp.pi
    fids = ops.vqc_fidelity(spec, theta, angles)
    print(f"kernel fidelities: shape {fids.shape}, "
          f"range [{float(fids.min()):.3f}, {float(fids.max()):.3f}]")

    # --- one parameter-shift training step ------------------------------------
    params = quclassi.init_params(cfg, key)
    xb, yb = jnp.asarray(x), jnp.asarray(y)
    loss_s, grads_s, _ = quclassi.grad_shift(cfg, params, xb, yb)
    loss_a, grads_a, _ = quclassi.grad_autodiff(cfg, params, xb, yb)
    gap = float(jnp.abs(grads_s["theta"] - grads_a["theta"]).max())
    print(f"parameter-shift loss {float(loss_s):.4f} "
          f"(autodiff {float(loss_a):.4f}), max grad gap {gap:.2e}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
