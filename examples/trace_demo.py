"""Observability demo: trace the Fig-6 multi-tenant workload end to end.

Four concurrent clients (5Q/1L, 5Q/2L, 7Q/1L, 7Q/2L) share four
heterogeneous workers (5/10/15/20 qubits) through the serving gateway on
the virtual clock.  Every circuit gets a lifecycle trace (submit -> admit
-> coalesced -> placed -> dispatched -> kernel_start -> complete), every
worker dispatch a busy-interval span, and the whole run exports as
Chrome-trace JSON: open ``trace_demo.json`` in https://ui.perfetto.dev to
see one timeline row per tenant and per worker.

Run:  PYTHONPATH=src python examples/trace_demo.py
"""
from repro.api import (
    ClusterConfig,
    ObservabilityConfig,
    QuantumCluster,
    SimulationConfig,
)
from repro.comanager import tenancy
from repro.comanager.worker import WorkerConfig
from repro.obs import CircuitTrace, validate_trace

CLIENTS = [("alice-5q1l", 5, 1, 0.26), ("bob-5q2l", 5, 2, 0.33),
           ("carol-7q1l", 7, 1, 0.33), ("dave-7q2l", 7, 2, 0.42)]


def main():
    jobs = [tenancy.JobSpec(cid, qc, nl, 120, service_override=svc)
            for cid, qc, nl, svc in CLIENTS]
    cluster = QuantumCluster(ClusterConfig(
        workers=tuple(WorkerConfig(f"w{i+1}", q, contention=0.5)
                      for i, q in enumerate((5, 10, 15, 20))),
        simulation=SimulationConfig(
            gateway=True, gateway_deadline=0.5, classical_overhead=0.01,
            # sample_rate < 1 keeps the ring small under real load; 1.0
            # here so the demo trace covers every circuit.
            observability=ObservabilityConfig(sample_rate=1.0),
        ),
    ))
    rep = cluster.simulate(jobs)
    tr = rep.trace

    print(f"=== Fig-6 workload: {rep.total_circuits} circuits, "
          f"makespan {rep.makespan:.1f}s ===")
    records = tr.buffer.records(CircuitTrace)
    bad = validate_trace(records)
    print(f"{len(records)} lifecycle records, {tr.open_traces} still open, "
          f"{len(bad)} well-formedness violations")

    print("\n-- where the time goes (share of end-to-end latency) --")
    stages = tr.stage_summary()
    for metric in ("queue_wait", "coalesce_wait", "place_wait",
                   "dispatch_lag", "kernel_wait", "execute"):
        share = stages.get(f"{metric}_share")
        snap = stages.get(metric)
        if share is None or snap is None:
            continue
        print(f"{metric:14s} p50={snap['p50']:8.4f}s p99={snap['p99']:8.4f}s "
              f"share={share:6.1%}")
    print(f"{'e2e':14s} p50={stages['e2e']['p50']:8.4f}s "
          f"p99={stages['e2e']['p99']:8.4f}s")

    print("\n-- worker occupancy --")
    for w, tl in sorted(tr.timelines.items()):
        s = tl.summary(horizon=rep.makespan)
        print(f"{w}: {s['spans']} dispatches, busy {s['busy_s']:.1f}s, "
              f"utilization {s['utilization']:.0%}")

    one = records[0]
    print(f"\n-- one circuit's lifecycle (tenant {one.tenant}, "
          f"seq {one.seq}) --")
    for stage, ts in one.stages:
        print(f"  {ts:8.3f}s  {stage}")

    tr.export_chrome_trace("trace_demo.json")
    print("\nwrote trace_demo.json — open it at https://ui.perfetto.dev "
          "(one row per tenant and per worker)")


if __name__ == "__main__":
    main()
