"""Federated DQL demo: 4 tenants train one QuClassi model without sharing
data, through the serving gateway on the virtual clock.

Three scenes:
  1. the happy path — 4 tenants, private MNIST shards, quorum-0.75 rounds
     via ``QuantumCluster.federated_session`` (this is also the CI smoke:
     2 rounds, 4 tenants, quorum 0.75, virtual clock);
  2. stragglers — a 10x slowdown fault on the wide workers makes the 7q
     tenants late; quorum + deadline rounds keep the cadence while the
     sync barrier pays the full straggler tax, and late updates fold in
     with the staleness discount;
  3. privacy knobs — pairwise-mask secure aggregation (the server only
     ever sums masked updates) and Gaussian DP noise with the epsilon
     ledger.

Run:  PYTHONPATH=src python examples/federated_dql.py
"""
import numpy as np

from repro.api import (
    FederatedConfig,
    QuantumCluster,
    SimulationConfig,
    TenantSpec,
)
from repro.comanager.faults import FaultSpec
from repro.core.quclassi import QuClassiConfig
from repro.data import mnist


def scene_1_happy_path(cluster):
    print("\n-- scene 1: 4 tenants, private shards, quorum-0.75 rounds")
    qcfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(3, 6, n_per_class=12, seed=0)
    (xtr, ytr), (xte, yte) = mnist.train_test_split(x, y)
    session = cluster.federated_session(
        ["alice", "bob", "carol", "dave"],
        FederatedConfig(n_rounds=2, quorum=0.75, seed=0),
        qcfg=qcfg,
        dataset=(xtr, ytr),
        eval_set=(xte, yte),
    )
    report = session.run()
    for rec in report.rounds:
        print(
            f"  round {rec.round_idx}: {len(rec.on_time)}/"
            f"{len(rec.participants)} on time in {rec.duration_s:.2f}s, "
            f"update norm {rec.update_norm:.4f}"
        )
    print(f"  accuracy by round: {[round(a, 3) for a in report.accuracy_by_round]}")
    tel = session.telemetry()
    rows = {r["client"]: r.get("federated") for r in tel["tenants"]}
    print(f"  gateway telemetry: rounds={tel['federated_rounds']}, "
          f"alice={rows['alice']}")
    return report


def scene_2_stragglers():
    print("\n-- scene 2: slow wide workers -> quorum rounds vs sync barrier")
    from repro.federated import run_federated

    params0 = {"theta": np.zeros((2, 8))}

    def update_fn(tenant, round_idx, params):
        g = np.random.default_rng(
            np.random.SeedSequence([round_idx] + [ord(c) for c in tenant])
        )
        return {k: 0.01 * g.standard_normal(np.shape(v))
                for k, v in params.items()}

    tenants = [
        TenantSpec("t5a", qc=5, n_layers=1, n_circuits=16),
        TenantSpec("t5b", qc=5, n_layers=2, n_circuits=16),
        TenantSpec("t7a", qc=7, n_layers=1, n_circuits=16),
        TenantSpec("t7b", qc=7, n_layers=2, n_circuits=16),
    ]
    faults = {
        w: FaultSpec(kind="slowdown", at=0.0, factor=10.0)
        for w in ("w2", "w3", "w4")
    }
    for label, kw in (
        ("sync barrier", dict(barrier=True)),
        ("quorum 0.5  ", dict(quorum=0.5)),
    ):
        cfg = FederatedConfig(n_rounds=4, seed=7, **kw)
        rep = run_federated(
            cfg, tenants, update_fn, params0,
            list(QuantumCluster().config.workers),
            gateway=True, worker_failures=dict(faults),
        )
        late = sum(c["late"] for c in rep.participation.values())
        print(
            f"  {label}: {rep.rounds_per_second:.3f} rounds/s, "
            f"straggler wait share {rep.quorum_wait_share:.0%}, "
            f"{late} late fold-ins"
        )


def scene_3_privacy():
    print("\n-- scene 3: secure aggregation + DP noise")
    from repro.federated import FederatedCoordinator

    params0 = {"theta": np.zeros(16)}
    rng = np.random.default_rng(1)
    updates = {t: {"theta": 0.1 * rng.standard_normal(16)}
               for t in ("a", "b", "c", "d")}
    finals = {}
    for secure in (False, True):
        co = FederatedCoordinator(
            FederatedConfig(n_rounds=1, secure_aggregation=secure, seed=5),
            params0,
        )
        co.begin_round(0, 0.0, list(updates))
        for t, u in updates.items():
            co.offer(t, u, 0.5)
        co.close_round(1.0)
        finals[secure] = co.params["theta"]
    gap = float(np.abs(finals[True] - finals[False]).max())
    print(f"  masked vs plain FedAvg max |diff| = {gap:.1e} (masks cancel)")

    co = FederatedCoordinator(
        FederatedConfig(n_rounds=3, dp_noise_multiplier=1.0, dp_clip=1.0,
                        dp_delta=1e-5, seed=5),
        params0,
    )
    for r in range(3):
        co.begin_round(r, float(r), list(updates))
        for t, u in updates.items():
            co.offer(t, u, r + 0.5)
        co.close_round(r + 1.0)
    print(f"  DP ledger after 3 noisy rounds: {co.accountant.summary(1e-5)}")


def main():
    # gateway-mode simulation: rounds flow through the serving gateway, so
    # its telemetry carries the federated participation counters.
    cluster = QuantumCluster(simulation=SimulationConfig(gateway=True))
    print(f"fleet: {[(w.worker_id, w.max_qubits) for w in cluster.config.workers]}")
    scene_1_happy_path(cluster)
    scene_2_stragglers()
    scene_3_privacy()
    print("\nfederated demo OK")


if __name__ == "__main__":
    main()
