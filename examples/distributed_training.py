"""End-to-end driver: train the paper's QuClassi classifier (1/5 digits)
with the DISTRIBUTED parameter-shift path — every gradient step's circuit
bank is scheduled by the co-Manager onto 4 quantum workers and executed by
the fused kernel per worker, exactly the paper's architecture (Fig 1).

Run:  PYTHONPATH=src python examples/distributed_training.py [--epochs 12]
"""
import argparse
import time

import jax
import numpy as np

from repro.comanager import dataplane, tenancy
from repro.comanager.simulation import SystemSimulation, homogeneous_workers
from repro.core import quclassi
from repro.core.quclassi import QuClassiConfig
from repro.core.trainer import train
from repro.data import mnist

N_WORKERS = 4


def comanaged_executor(cfg: QuClassiConfig, n_bank: int):
    """Build an executor whose worker assignment comes from an actual
    co-Manager run (Algorithm 2) over this bank."""
    jobs = [tenancy.JobSpec("client", cfg.qc, cfg.n_layers, n_bank,
                            service_override=0.05)]
    workers = homogeneous_workers(N_WORKERS, max_qubits=2 * cfg.qc)
    sim = SystemSimulation(workers, jobs)
    rep = sim.run()
    order = {f"w{i + 1}": i for i in range(N_WORKERS)}
    assignment = np.zeros(n_bank, int)
    payload = {t.task_id: t.payload for t in sim.manager.task_registry.values()}
    for (_, tid, wid) in rep.assignments:
        assignment[payload[tid]] = order[wid]
    counts = np.bincount(assignment, minlength=N_WORKERS)
    print(f"  co-Manager spread {n_bank} circuits over workers: {counts.tolist()}")
    return dataplane.worker_batched_executor(cfg.spec, assignment, N_WORKERS)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=8)
    args = ap.parse_args()

    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(1, 5, n_per_class=24, seed=0)
    (xtr, ytr), (xte, yte) = mnist.train_test_split(x, y)
    print(f"task 1/5: {len(ytr)} train, {len(yte)} test images")

    n_bank = quclassi.total_bank_circuits(cfg, args.batch_size) // cfg.n_classes
    executor = comanaged_executor(cfg, n_bank)

    t0 = time.time()
    rep = train(cfg, (xtr, ytr), (xte, yte), epochs=args.epochs,
                batch_size=args.batch_size, lr=0.05, optimizer="adam",
                grad_mode="shift", executor=executor,
                log=lambda s: print(f"  {s}"))
    print(f"final test accuracy: {rep.final_test_accuracy:.1%} "
          f"({time.time() - t0:.0f}s, "
          f"{sum(e.circuits_executed for e in rep.epochs)} circuits executed "
          f"across {N_WORKERS} workers)")


if __name__ == "__main__":
    main()
