"""Online serving gateway demo: streaming circuits from concurrent tenants
are coalesced across clients into lane-aligned Pallas mega-batches, placed by
the co-Manager, and executed on the fused VQC kernel — then the same gateway
drives a real QuClassi training step, and the async runtime overlaps kernel
execution across per-worker slots with priority tiers and latency SLOs.

Run:  PYTHONPATH=src python examples/gateway_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quclassi
from repro.core.quclassi import QuClassiConfig
from repro.data import mnist
from repro.serve import GatewayRuntime


def streaming_demo():
    """Two tenants submit interleaved; their circuits share kernel batches."""
    print("=== cross-tenant coalescing: alice + bob share mega-batches ===")
    cfg = QuClassiConfig(qc=5, n_layers=1)
    rt = GatewayRuntime(target=128, deadline=0.25)
    rt.gateway.register_client("alice", weight=2.0)   # alice paid for 2x share
    rt.gateway.register_client("bob", weight=1.0)

    rng = np.random.default_rng(0)
    futures = []
    now = rt.dispatcher.clock
    for i in range(96):                      # interleaved open-loop streams
        for cid in ("alice", "bob"):
            theta = jnp.asarray(rng.uniform(0, np.pi, cfg.n_theta), jnp.float32)
            data = jnp.asarray(rng.uniform(0, np.pi, cfg.n_angles), jnp.float32)
            futures.append(rt.gateway.submit(cid, cfg.spec, (theta, data), now()))
    rt.dispatcher.drain()

    for wid, n, clients in rt.dispatcher.batch_log:
        print(f"  batch of {n:3d} circuits -> {wid}  tenants={clients}")
    s = rt.telemetry.summary()
    print(f"  lane fill {s['lane_fill']:.0%}, "
          f"{s['total_completed']} circuits in {s['batches']} kernel launches")
    for t in s["tenants"]:
        print(f"  {t['client']:6s} p50={t['p50_latency_s']*1e3:.1f}ms "
              f"p99={t['p99_latency_s']*1e3:.1f}ms")
    assert all(f.done for f in futures)


def training_demo():
    """QuClassi training drives the real kernel through the gateway."""
    print("\n=== gateway-backed training (grad_shift via serve/) ===")
    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(3, 9, n_per_class=8, seed=0)
    x, y = jnp.asarray(x[:4]), jnp.asarray(y[:4])
    params = quclassi.init_params(cfg, jax.random.PRNGKey(0))

    rt = GatewayRuntime(target=128, deadline=0.5)
    ex = rt.executor(cfg.spec, "trainer")
    loss_gw, g_gw, _ = quclassi.grad_shift(cfg, params, x, y, executor=ex)
    loss_local, g_local, _ = quclassi.grad_shift(cfg, params, x, y)
    err = float(jnp.abs(g_gw["theta"] - g_local["theta"]).max())
    print(f"  loss via gateway {float(loss_gw):.6f} == local {float(loss_local):.6f}")
    print(f"  max |grad diff| = {err:.2e} (scheduling never changes the math)")
    print(f"  kernel launches: {len(rt.dispatcher.batch_log)}, "
          f"lane fill {rt.telemetry.lane_fill:.0%}")


def async_demo():
    """The async runtime: a tier-0 interactive tenant with a tight SLO rides
    the same worker pool as a tier-1 bulk tenant; batches execute on worker
    slots while admission continues, and futures resolve out of order."""
    print("\n=== async dispatcher: priority tiers + SLOs on a worker pool ===")
    cfg = QuClassiConfig(qc=5, n_layers=1)
    rng = np.random.default_rng(1)
    with GatewayRuntime(target=128, deadline=0.1, mode="async",
                        slots_per_worker=2) as rt:
        rt.gateway.register_client("bulk", priority=1)
        rt.gateway.register_client("interactive", priority=0, slo_ms=500.0)
        now = rt.dispatcher.clock
        futures = []
        for i in range(192):
            cid = "interactive" if i % 3 == 0 else "bulk"
            theta = jnp.asarray(rng.uniform(0, np.pi, cfg.n_theta), jnp.float32)
            data = jnp.asarray(rng.uniform(0, np.pi, cfg.n_angles), jnp.float32)
            futures.append(rt.gateway.submit(cid, cfg.spec, (theta, data), now()))
            rt.dispatcher.kick()
        rt.dispatcher.drain()
        assert all(f.done for f in futures)
        s = rt.telemetry.summary()
        for t in s["tenants"]:
            slo = (f" slo_attainment={t['slo_attainment']:.0%}"
                   if "slo_attainment" in t else "")
            print(f"  {t['client']:12s} p50={t['p50_latency_s']*1e3:.1f}ms "
                  f"p99={t['p99_latency_s']*1e3:.1f}ms{slo}")
        print(f"  {s['total_completed']} circuits in {s['batches']} launches, "
              f"lane fill {s['lane_fill']:.0%}")


if __name__ == "__main__":
    streaming_demo()
    training_demo()
    async_demo()
