"""Scale-storm demo: arrival storm -> knee -> calibrated admission control.

A scaled-down version of what ``benchmarks/scale_harness.py`` runs in CI:

1. generate a seeded 300-tenant storm (15% interactive / 55% batch / 30%
   bursty best-effort, with priority tiers, SLO classes and fair-share
   weights) from ``repro.scale.standard_populations``;
2. sweep offered load on the virtual clock to find the fleet's throughput
   knee — the highest operating point that still keeps up (efficiency
   >= 0.80) and holds the SLOs (attainment >= 0.99);
3. size the gateway's global weighted-fair admission cap at the knee via
   Little's law and replay a past-knee storm with and without it: the cap
   converts deep queueing past the knee into load shedding at submit,
   pinning the admitted circuits' p99 back to the knee's.

Everything runs on the virtual clock and is a pure function of the seed —
re-running this script reproduces every number bit-for-bit.

Run:  PYTHONPATH=src python examples/scale_storm.py
"""

from repro.scale import (
    WorkloadSpec,
    default_fleet,
    find_knee,
    standard_populations,
    sweep,
    verify_admission,
)

SPEC = WorkloadSpec(
    populations=standard_populations(300, rate_per_tenant=0.4, slo_scale=2.0),
    duration_s=10.0,
    seed=11,
)
LOADS = (0.5, 1.0, 2.0, 4.0, 6.0, 8.0)
FLEET = default_fleet(n_replicas=1)  # the paper's 5/10/15/20-qubit quartet


def main():
    trace = SPEC.generate()
    print(f"storm: {trace.summary()}")

    print(f"\nsweeping {len(LOADS)} offered-load points on the virtual clock...")
    points = sweep(SPEC, LOADS, workers=FLEET)
    for p in points:
        print(
            f"  load {p.load:g}: offered {p.offered_cps:7.1f} c/s -> "
            f"achieved {p.achieved_cps:7.1f} c/s  "
            f"eff {p.efficiency:.2f}  p99 {p.p99_latency_s:5.2f}s  "
            f"attainment {p.slo_attainment}"
        )

    report = find_knee(points)
    knee = report.knee
    print(
        f"\nknee: load {knee.load:g} -> {knee.achieved_cps:.0f} c/s at "
        f"p99 {knee.p99_latency_s:.2f}s (saturated={report.saturated})"
    )

    adm = verify_admission(SPEC, report, overload=1.6, workers=FLEET)
    print(
        f"\nadmission control at {adm['overload']:g}x the knee "
        f"(cap = {adm['max_system_pending']} outstanding circuits):"
    )
    print(
        f"  uncapped: attainment {adm['attainment_uncapped']}, "
        f"p99 {adm['p99_uncapped_s']:.2f}s"
    )
    print(
        f"  capped:   attainment {adm['attainment_admitted']}, "
        f"p99 {adm['p99_admitted_s']:.2f}s, "
        f"sheds {adm['reject_fraction']:.1%} at submit"
    )


if __name__ == "__main__":
    main()
