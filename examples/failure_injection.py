"""Elastic fleet demo: worker crashes, migration with bit-identical replay,
hedged dispatch, and live membership — on the REAL async dispatcher.

Four scenes:
  1. a worker hard-crashes mid-run: its circuit breaker trips, stranded
     batches migrate through the coalescer to the survivors, and every
     future resolves to exactly the value a fault-free run produces;
  2. a flaky worker drops attempts; in-place retries absorb the noise;
  3. live membership: drain a worker out of rotation, register a fresh one,
     and keep serving without a restart;
  4. the same crash schedule on the virtual clock (``SystemSimulation``) —
     one fault spec drives both worlds.

Run:  PYTHONPATH=src python examples/failure_injection.py
"""
import jax.numpy as jnp
import numpy as np

from repro.comanager.simulation import SystemSimulation, homogeneous_workers
from repro.comanager.tenancy import JobSpec
from repro.comanager.worker import WorkerConfig
from repro.core.quclassi import QuClassiConfig
from repro.kernels import ops as kops
from repro.serve import (
    FaultInjector,
    FaultSpec,
    FaultToleranceConfig,
    GatewayRuntime,
)

CFG = QuClassiConfig(qc=5, n_layers=1)


def rows(n, seed=0):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.uniform(0, np.pi, (n, CFG.n_theta)), jnp.float32)
    data = jnp.asarray(rng.uniform(0, np.pi, (n, CFG.n_angles)), jnp.float32)
    return theta, data


def submit_all(rt, theta, data, tenant="alice"):
    now = rt.dispatcher.clock
    futures = [
        rt.gateway.submit(tenant, CFG.spec, (theta[i], data[i]), now())
        for i in range(theta.shape[0])
    ]
    rt.dispatcher.kick()
    return futures


def crash_migration_demo():
    print("=== scene 1: worker crash -> breaker trip -> bit-identical "
          "migration ===")
    theta, data = rows(16)
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 10), WorkerConfig("w2", 10)],
        target=8, lanes=8, deadline=0.05, mode="async",
        fault_tolerance=FaultToleranceConfig(retry_limit=0,
                                             breaker_threshold=1),
        fault_injector=FaultInjector({"w1": FaultSpec(kind="crash", at=0.0)}),
    )
    try:
        futures = submit_all(rt, theta, data)
        rt.dispatcher.kick()
        got = jnp.stack([f.result(timeout=60.0) for f in futures])
        ref = kops.vqc_fidelity(CFG.spec, theta, data)
        assert np.array_equal(np.asarray(got), np.asarray(ref))
        s = rt.telemetry.summary()
        print(f"  w1 state={rt.dispatcher.fleet.state('w1')}, "
              f"{s['migrated_batches']} batches migrated, results "
              f"bit-identical to the fault-free run")
        print(f"  fleet events: {s['fleet']}")
    finally:
        rt.close()


def flaky_retry_demo():
    print("\n=== scene 2: flaky worker absorbed by in-place retries ===")
    theta, data = rows(16, seed=1)
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 10)],
        target=8, lanes=8, deadline=0.05, mode="async",
        fault_tolerance=FaultToleranceConfig(retry_limit=3,
                                             breaker_threshold=10),
        fault_injector=FaultInjector(
            {"w1": FaultSpec(kind="flaky", p=0.5, seed=3)}),
    )
    try:
        futures = submit_all(rt, theta, data)
        rt.dispatcher.kick()
        for f in futures:
            f.result(timeout=60.0)
        ev = rt.telemetry.summary()["fleet"]["w1"]
        print(f"  {ev['failures']} injected drops, {ev['retries']} retries, "
              f"all {len(futures)} circuits completed")
    finally:
        rt.close()


def live_membership_demo():
    print("\n=== scene 3: drain w1 out, register w3, keep serving ===")
    theta, data = rows(16, seed=2)
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 10), WorkerConfig("w2", 10)],
        target=8, lanes=8, deadline=0.05, mode="async",
    )
    try:
        for f in submit_all(rt, theta, data):
            f.result(timeout=60.0)
        rt.dispatcher.drain_worker("w1")
        rt.dispatcher.register_worker(WorkerConfig("w3", 15))
        for f in submit_all(rt, theta, data, tenant="bob"):
            f.result(timeout=60.0)
        print(f"  fleet now {rt.dispatcher.fleet.workers()}, "
              f"second wave served without a restart")
    finally:
        rt.close()


def virtual_clock_demo():
    print("\n=== scene 4: the same fault spec on the virtual clock ===")
    rep = SystemSimulation(
        homogeneous_workers(3, 10),
        [JobSpec("alice", qc=5, n_layers=1, n_circuits=40, submit_time=0.0),
         JobSpec("bob", qc=5, n_layers=1, n_circuits=40, submit_time=0.0)],
        gateway=True, gateway_deadline=0.2, heartbeat_period=0.5,
        worker_failures={"w1": FaultSpec(kind="crash_recover",
                                         at=0.05, recover_at=3.0)},
    ).run()
    s = rep.gateway_summary
    print(f"  {rep.total_circuits} circuits, makespan {rep.makespan:.2f}s, "
          f"{s.get('migrated_batches', 0)} batches migrated, "
          f"{len(rep.evictions)} eviction(s); all jobs finished: "
          f"{sorted(rep.jobs)}")


if __name__ == "__main__":
    crash_migration_demo()
    flaky_retry_demo()
    live_membership_demo()
    virtual_clock_demo()
