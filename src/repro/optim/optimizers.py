"""Pure-JAX optimizer substrate (pytree-generic, no external deps).

Used by both the quantum training loop (SGD, the paper's optimizer with
lr=1e-4..1e-3) and the classical architecture zoo (AdamW etc.).  API mirrors
optax: ``init(params) -> state``, ``update(grads, state, params) ->
(updates, state)``; ``apply_updates`` adds them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(lr: float | Callable[[jnp.ndarray], jnp.ndarray]) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        eta = lr(step) if callable(lr) else lr
        ups = jax.tree.map(lambda g: -eta * g, grads)
        return ups, {"step": step}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32), "m": _zeros_like_f32(params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        eta = lr(step) if callable(lr) else lr
        m = jax.tree.map(lambda m_, g: beta * m_ + g, state["m"], grads)
        if nesterov:
            ups = jax.tree.map(lambda m_, g: -eta * (beta * m_ + g), m, grads)
        else:
            ups = jax.tree.map(lambda m_: -eta * m_, m)
        return ups, {"step": step, "m": m}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """Adam / AdamW (decoupled decay when weight_decay > 0)."""
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _zeros_like_f32(params), "v": _zeros_like_f32(params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        eta = lr(step) if callable(lr) else lr
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -eta * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and p is not None:
                u = u - eta * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            ups = jax.tree.map(lambda m_, v_: upd(m_, v_, None), m, v)
        else:
            ups = jax.tree.map(upd, m, v, params)
        return ups, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


BY_NAME = {"sgd": sgd, "momentum": momentum, "adam": adam, "adamw": adamw}


def make(name: str, lr, **kw) -> Optimizer:
    return BY_NAME[name](lr, **kw)
