"""Synthetic MNIST-style digit data (offline container — no downloads).

Procedurally rendered digit glyphs with deterministic jitter/noise, matching
MNIST's role in the paper: a handwritten-digit binary-classification source
for pairs like 3/9, 3/8, 3/6, 1/5 (§IV-B).  Images are 8x8 in [0, 1] —
already at the downsampled scale the paper's 4x4-filter segmentation expects.
"""
from __future__ import annotations

import numpy as np

# 5x7 glyph bitmaps (classic font) — rows are strings, '#' = ink.
_GLYPHS = {
    0: [" ### ", "#   #", "#  ##", "# # #", "##  #", "#   #", " ### "],
    1: ["  #  ", " ##  ", "  #  ", "  #  ", "  #  ", "  #  ", " ### "],
    2: [" ### ", "#   #", "    #", "   # ", "  #  ", " #   ", "#####"],
    3: [" ### ", "#   #", "    #", "  ## ", "    #", "#   #", " ### "],
    4: ["   # ", "  ## ", " # # ", "#  # ", "#####", "   # ", "   # "],
    5: ["#####", "#    ", "#### ", "    #", "    #", "#   #", " ### "],
    6: [" ### ", "#    ", "#    ", "#### ", "#   #", "#   #", " ### "],
    7: ["#####", "    #", "   # ", "  #  ", " #   ", " #   ", " #   "],
    8: [" ### ", "#   #", "#   #", " ### ", "#   #", "#   #", " ### "],
    9: [" ### ", "#   #", "#   #", " ####", "    #", "    #", " ### "],
}


def _glyph_array(d: int) -> np.ndarray:
    g = _GLYPHS[d]
    return np.array([[1.0 if ch == "#" else 0.0 for ch in row] for row in g],
                    np.float32)  # (7, 5)


def render_digit(d: int, rng: np.random.Generator, size: int = 8,
                 noise: float = 0.15) -> np.ndarray:
    """One jittered, noisy digit image (size x size, values in [0, 1])."""
    canvas = np.zeros((size + 4, size + 4), np.float32)
    glyph = _glyph_array(d)
    # random sub-pixel-ish placement via integer jitter
    r0 = 2 + rng.integers(-1, 2)
    c0 = 2 + rng.integers(-1, 2) + (size - 5) // 2 - 1
    r0 = int(np.clip(r0, 0, canvas.shape[0] - 7))
    c0 = int(np.clip(c0, 0, canvas.shape[1] - 5))
    canvas[r0:r0 + 7, c0:c0 + 5] = np.maximum(canvas[r0:r0 + 7, c0:c0 + 5], glyph)
    # crop center to size, blur-ish by averaging shifted copies (ink spread)
    img = canvas[2:2 + size, 2:2 + size]
    spread = img.copy()
    spread[1:, :] = np.maximum(spread[1:, :], 0.4 * img[:-1, :])
    spread[:, 1:] = np.maximum(spread[:, 1:], 0.4 * img[:, :-1])
    spread = spread * rng.uniform(0.8, 1.0)
    spread += noise * rng.random(spread.shape).astype(np.float32) * 0.5
    return np.clip(spread, 0.0, 1.0).astype(np.float32)


def make_pair_dataset(digit_a: int, digit_b: int, n_per_class: int,
                      seed: int = 0, size: int = 8):
    """Binary dataset for the paper's A/B classification tasks.

    Returns (images (N, size, size) f32, labels (N,) int32 — 1 for digit_a,
    0 for digit_b), shuffled deterministically.
    """
    rng = np.random.default_rng(seed + 1000 * digit_a + digit_b)
    xs, ys = [], []
    for d, y in ((digit_a, 1), (digit_b, 0)):
        for _ in range(n_per_class):
            xs.append(render_digit(d, rng, size=size))
            ys.append(y)
    xs = np.stack(xs)
    ys = np.array(ys, np.int32)
    order = rng.permutation(len(ys))
    return xs[order], ys[order]


def train_test_split(images: np.ndarray, labels: np.ndarray, test_frac: float = 0.25):
    n_test = int(len(labels) * test_frac)
    return ((images[n_test:], labels[n_test:]),
            (images[:n_test], labels[:n_test]))
