"""Batching / sharding data pipeline (the "Data Cleaning" -> model feed path
of Fig 1, plus the classical-LM token pipeline for the architecture zoo).
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def clean(images: np.ndarray, clip_percentile: float = 99.5) -> np.ndarray:
    """Initial data cleaning (Fig 1): clamp extreme outliers, rescale to [0,1]."""
    hi = np.percentile(images, clip_percentile)
    x = np.clip(images, 0.0, hi) / max(hi, 1e-8)
    return x.astype(np.float32)


def batches(images: np.ndarray, labels: np.ndarray, batch_size: int,
            *, seed: int = 0, drop_remainder: bool = True,
            shuffle: bool = True) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Deterministic shuffled mini-batches."""
    n = len(labels)
    order = np.random.default_rng(seed).permutation(n) if shuffle else np.arange(n)
    end = n - (n % batch_size) if drop_remainder else n
    for i in range(0, end, batch_size):
        idx = order[i:i + batch_size]
        yield images[idx], labels[idx]


def synthetic_tokens(rng_seed: int, batch: int, seq_len: int, vocab: int):
    """Deterministic token batch for LM smoke tests / benchmarks."""
    rng = np.random.default_rng(rng_seed)
    toks = rng.integers(0, vocab, size=(batch, seq_len), dtype=np.int32)
    return jnp.asarray(toks)


def shard_batch(batch_arrays, mesh, axis: str = "data"):
    """Place host arrays onto the mesh, sharded along the batch axis."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    def put(x):
        spec = P(axis) if x.ndim == 1 else P(axis, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, batch_arrays)
