"""The multi-tenant system simulation: EventLoop + workers + CoManager.

Wires the paper's full runtime together on the virtual clock:
  * workers register at t=0 and send heartbeats every ``heartbeat_period``;
  * clients submit jobs (circuit banks) at their submit times;
  * the co-Manager drains the pending queue on every state change
    (submission / completion / heartbeat), per Algorithm 2;
  * completions loop results back to the classical side.

This is the engine behind every runtime figure reproduction
(benchmarks/: Fig 3, 4, 5, 6).

The typed front door is ``repro.api``: ``SimulationConfig`` carries this
constructor's kwarg pile and ``QuantumCluster.simulate`` forwards open
sessions' ``TenantPolicy``s into the per-tenant override maps below.
"""

from __future__ import annotations

import dataclasses

from repro.comanager.events import EventLoop
from repro.comanager.faults import normalize_failures
from repro.comanager.manager import CoManager
from repro.comanager.tenancy import JobResult, JobSpec, TaskIdAllocator
from repro.comanager.worker import CircuitTask, QuantumWorker, WorkerConfig


@dataclasses.dataclass
class SimulationReport:
    jobs: dict[str, JobResult]
    total_circuits: int
    makespan: float
    assignments: list
    evictions: list
    worker_busy_time: dict[str, float]
    #: mean over executed circuits of (1 - error_rate_w)^depth — the
    #: fraction of SWAP-test signal surviving depolarization (1.0 = ideal).
    fidelity_retention: float = 1.0
    #: circuits shed by gateway admission control (``Backpressure`` raised
    #: at submit under ``gateway_max_pending``/``gateway_max_system_pending``);
    #: they count as drained — never executed, never in ``jobs`` latencies.
    rejected: int = 0
    #: serve-gateway telemetry (per-tenant latency, lane-fill) when the
    #: simulation ran with gateway=True; None otherwise.
    gateway_summary: dict | None = None
    #: the gateway telemetry's ``TraceRecorder`` (lifecycle traces, worker
    #: timelines, Chrome-trace export) when the simulation ran with
    #: gateway=True; None otherwise.
    trace: object | None = None

    @property
    def circuits_per_second(self) -> float:
        return self.total_circuits / max(self.makespan, 1e-9)


def _validate_tenant_maps(jobs, *, worker_ids, worker_failures=None, **maps):
    """Reject per-tenant override maps that name unknown client ids.

    ``tenant_weights`` / ``tenant_priorities`` / ``tenant_slos_ms`` /
    ``arrivals`` keys must each be a submitted job's client id (a typo'd
    key used to pass silently — the override simply never applied);
    ``worker_failures`` keys must name configured workers."""
    known = {j.client_id for j in jobs}
    for name, mapping in maps.items():
        unknown = sorted(set(mapping or ()) - known)
        if unknown:
            raise ValueError(
                f"{name} refers to unknown client id(s) {unknown}; "
                f"known clients: {sorted(known)}"
            )
    bad_workers = sorted(set(worker_failures or ()) - worker_ids)
    if bad_workers:
        raise ValueError(
            f"worker_failures refers to unknown worker id(s) {bad_workers}; "
            f"known workers: {sorted(worker_ids)}"
        )
    # malformed fault specs (negative/NaN times, recover-before-fail, bad
    # probabilities) raise here, naming the offending worker id
    normalize_failures(worker_failures)


class SystemSimulation:
    def __init__(
        self,
        worker_cfgs: list[WorkerConfig],
        jobs: list[JobSpec],
        *,
        env: str = "ibmq",
        multi_tenant: bool = True,
        tenancy: str | None = None,
        policy: str = "cru",
        fidelity_floor: float = 0.0,
        eager_completion: bool = True,
        heartbeat_period: float = 5.0,
        assign_latency: float = 0.01,
        classical_overhead: float = 0.0,
        lockstep: bool = False,
        fair_queue: bool = False,
        run_until: float = 1e7,
        worker_failures: dict[str, float] | None = None,
        gateway: bool = False,
        gateway_target: int | None = None,
        gateway_deadline: float = 1.0,
        gateway_async: bool = False,
        gateway_max_pending: int | None = None,
        gateway_max_system_pending: int | None = None,
        gateway_max_pending_per_tier: dict[int, int] | None = None,
        tenant_weights: dict[str, float] | None = None,
        tenant_priorities: dict[str, int] | None = None,
        tenant_slos_ms: dict[str, float] | None = None,
        arrivals: dict[str, list[float]] | None = None,
        observability=None,
    ):
        """``assign_latency``: manager->worker dispatch cost per circuit.

        ``classical_overhead``: SERIAL per-circuit time on the classical
        manager (logical-circuit generation + quantum-state analysis).  The
        paper's runtime figures show strongly diminishing returns with more
        workers (5q/1L: 94.7s -> 73.1s for 1 -> 4 workers, not 4x) because the
        classical side — a single laptop/VM — processes every circuit
        serially.  Modeling it as a serial resource reproduces those curves;
        see benchmarks/runtime_uncontrolled.py for the calibration.

        ``lockstep``: reproduce the paper's Algorithm-1 dispatch loop
        ("for Circuit in cB: Result = Algorithm2(Circuit)"): the client sends
        one circuit per worker, then waits for the whole round to return
        before dispatching the next — round time ~ w*t_cl + t_q, which is
        exactly the diminishing-returns shape of Figs 3-5 (see
        benchmarks/calibration notes).

        ``classical_overhead`` is charged to a PER-CLIENT serial ledger: each
        client's classical process generates/analyzes its own circuits
        serially, which is the real bottleneck on the paper's classical side.

        ``worker_failures``: worker_id -> fault schedule.  The legacy float
        form (time at which the worker silently stops heartbeating, which
        exercises the 3-missed-heartbeats eviction path) still works; a
        ``FaultSpec`` — or a dict of its fields — selects a typed fault:
        ``crash`` (silent forever), ``crash_recover`` (re-registers at
        ``recover_at``, abandoning and requeueing anything it was running),
        ``slowdown`` (service times stretched by ``factor`` inside the
        window), ``flaky`` (each completion dropped-and-requeued with
        deterministic probability ``p``).  The same schedules drive the real
        dispatchers via ``repro.serve.fleet.FaultInjector``.

        ``gateway``: route submissions through the online serving gateway
        (repro.serve): circuits are admitted to per-client queues, dequeued
        weighted-fair, coalesced across tenants into lane-aligned mega-batches
        keyed by circuit structure, and each batch is ONE logical task for
        Algorithm 2 (demand = circuit width).  Batch execution follows the
        fused-kernel cost model: a batch of n compatible circuits takes
        ceil(n / LANES) service times (lanes execute in parallel), so packing
        LANES circuits into one dispatch costs one circuit's time — the
        coalescing throughput win, on the virtual clock.

        ``gateway_async``: virtual-clock counterpart of the real runtime's
        ``AsyncDispatcher`` worker pool.  The synchronous gateway charges
        every batch's dispatch overhead to ONE serial classical ledger (the
        pump thread executing batches inline), so a slow dispatch
        head-of-line-blocks all workers; async mode charges it to a
        PER-WORKER ledger — each worker's execution slot pipelines its own
        admissions — so in-flight batches on different workers overlap.

        ``tenant_priorities`` / ``tenant_slos_ms`` (gateway mode): strict
        scheduling tier (lower = first) and end-to-end latency SLO per
        client, forwarded to ``Gateway.register_client``; SLOs shorten the
        coalescer's flush deadline and arm deadline-miss accounting in the
        gateway telemetry (``slo_attainment`` in ``gateway_summary``).

        ``arrivals`` (gateway mode): client_id -> per-circuit arrival-time
        offsets (relative to the job's submit_time); circuits then stream in
        open-loop instead of arriving as one epoch-sized burst — the
        high-traffic serving stand-in used by benchmarks/gateway_throughput.

        ``gateway_max_pending`` / ``gateway_max_system_pending`` /
        ``gateway_max_pending_per_tier`` (gateway mode): per-tenant, global,
        and per-priority-tier admission caps.  A submission the
        gateway rejects (``Backpressure``) is counted in
        ``SimulationReport.rejected`` and drained — shed load, not executed
        work.  The global cap is the weighted-fair admission control the
        scale harness calibrates at the throughput knee
        (``repro.scale.knee``); both default to None (admit everything).

        Every per-tenant override map is validated against the submitted
        jobs' client ids (and ``worker_failures`` against the worker fleet):
        unknown keys raise ``ValueError`` instead of silently never applying.
        """
        _validate_tenant_maps(
            jobs,
            tenant_weights=tenant_weights,
            tenant_priorities=tenant_priorities,
            tenant_slos_ms=tenant_slos_ms,
            arrivals=arrivals,
            worker_failures=worker_failures,
            worker_ids={c.worker_id for c in worker_cfgs},
        )
        self.loop = EventLoop()
        self.manager = CoManager(
            multi_tenant=multi_tenant,
            tenancy=tenancy,
            eager_completion=eager_completion,
            policy=policy,
            fidelity_floor=fidelity_floor,
        )
        self.workers = {c.worker_id: QuantumWorker(c) for c in worker_cfgs}
        self.jobs = {j.client_id: j for j in jobs}
        self.env = env
        self.heartbeat_period = heartbeat_period
        self.assign_latency = assign_latency
        self.classical_overhead = classical_overhead
        self.lockstep = lockstep
        self.fair_queue = fair_queue  # round-robin across clients in the queue
        self._client_free: dict[str, float] = {}  # per-client serial CPU
        self._in_flight: dict[str, int] = {}  # per-client outstanding
        self.run_until = run_until
        self.failures = normalize_failures(worker_failures)
        self._flaky_attempts: dict[tuple[str, int], int] = {}
        self._recovery_scheduled: set[str] = set()

        self._remaining: dict[str, int] = {}
        self._results: dict[str, JobResult] = {}
        self._total = 0
        self.task_ids = TaskIdAllocator()  # per-simulation id space

        self.gateway = None
        self.gateway_async = gateway_async
        self.arrivals = arrivals or {}
        self.rejected = 0
        #: fired as ``cb(client_id, t)`` when a job's last circuit finishes —
        #: the hook round-structured controllers (repro.federated) ride to
        #: observe per-tenant update arrival times on the virtual clock.
        self.job_callbacks: list = []
        self._tenant_weights = dict(tenant_weights or {})
        self._tenant_priorities = dict(tenant_priorities or {})
        self._tenant_slos_ms = dict(tenant_slos_ms or {})
        if gateway:
            from repro.kernels.vqc_statevector import LANES
            from repro.serve.gateway import Backpressure, Gateway
            from repro.serve.metrics import Telemetry

            self.gw_lanes = LANES
            self._backpressure = Backpressure
            gw_kwargs = {}
            if gateway_max_pending is not None:
                gw_kwargs["max_pending"] = gateway_max_pending
            if gateway_max_pending_per_tier is not None:
                gw_kwargs["max_pending_per_tier"] = gateway_max_pending_per_tier
            self.gateway = Gateway(
                target=gateway_target or LANES,
                deadline=gateway_deadline,
                lanes=LANES,
                max_system_pending=gateway_max_system_pending,
                telemetry=Telemetry(lanes=LANES, observability=observability),
                **gw_kwargs,
            )
            for j in jobs:
                self.gateway.register_client(
                    j.client_id,
                    weight=(tenant_weights or {}).get(j.client_id, 1.0),
                    priority=(tenant_priorities or {}).get(j.client_id, 1),
                    slo_ms=(tenant_slos_ms or {}).get(j.client_id),
                )
            self._gw_batches: dict[int, object] = {}  # batch task_id -> batch
            self._gw_dispatched: set[int] = set()  # handed to a worker
            self._gw_flush_at: float | None = None

        lp = self.loop
        lp.on("register", self._on_register)
        lp.on("heartbeat", self._on_heartbeat)
        lp.on("submit", self._on_submit)
        lp.on("submit_circuit", self._on_submit_circuit)
        lp.on("gw_flush", self._on_gw_flush)
        lp.on("start", self._on_start)
        lp.on("complete", self._on_complete)
        lp.on("liveness", self._on_liveness)

    # ------------------------------------------------------------ handlers
    def _on_register(self, t: float, wid: str) -> None:
        w = self.workers[wid]
        for task in w.abandon(t):
            # crash_recover re-registration: the worker lost its in-memory
            # state, so anything it was running is requeued — unless the
            # liveness eviction already returned it to the queue, or a
            # replay finished elsewhere in the meantime
            if task.task_id in self.manager.completed_ids:
                continue
            if any(p.task_id == task.task_id for p in self.manager.pending):
                continue
            if any(
                task.task_id in v.in_flight
                for w2, v in self.manager.workers.items()
                if w2 != wid
            ):
                continue
            if self.gateway is not None and task.client_id == "__gw__":
                if task.task_id in self._gw_batches:
                    self._in_flight[task.client_id] -= 1
                    self._gw_requeue(t, task)
                continue
            self._in_flight[task.client_id] -= 1
            self.manager.submit(task)
        self.manager.register_worker(
            wid, w.max_qubits, w.cru(t), t, error_rate=w.cfg.error_rate
        )
        self.loop.schedule(t + self.heartbeat_period, "heartbeat", wid)
        self._drain(t)

    def _on_heartbeat(self, t: float, wid: str) -> None:
        f = self.failures.get(wid)
        if f is not None and f.crashed_between(t - self.heartbeat_period, t):
            # worker went silent: no report, no reschedule.  A crash_recover
            # schedules exactly one re-registration at its recovery time.
            if f.recover_at is not None and wid not in self._recovery_scheduled:
                self._recovery_scheduled.add(wid)
                self.loop.schedule(max(f.recover_at, t), "register", wid)
            return
        if self._all_done():
            return  # system idle: let the event loop drain
        w = self.workers[wid]
        self.manager.heartbeat(w.heartbeat_payload(t), t)
        self._drain(t)
        self.loop.schedule(t + self.heartbeat_period, "heartbeat", wid)

    def _on_liveness(self, t: float, _) -> None:
        self.manager.liveness_check(t, self.heartbeat_period)
        if self.gateway is not None:
            # batches requeued off an evicted worker go back through the
            # coalescer (re-coalesced), not straight back to Algorithm 2
            lost = [
                task
                for task in self.manager.pending
                if task.task_id in self._gw_dispatched
            ]
            if lost:
                self.manager.pending = [
                    task
                    for task in self.manager.pending
                    if task.task_id not in self._gw_dispatched
                ]
                for task in lost:
                    self._gw_requeue(t, task)
        self._drain(t)
        if not self._all_done():
            self.loop.schedule(t + self.heartbeat_period, "liveness", None)

    def _all_done(self) -> bool:
        jobs_submitted = len(self._remaining) == len(self.jobs)
        done = (
            jobs_submitted
            and not any(self._remaining.values())
            and not self.manager.pending
        )
        if done and self.gateway is not None:
            done = self.gateway.idle and not self._gw_batches
        return done

    def _on_submit(self, t: float, job: JobSpec) -> None:
        tasks = job.circuits(self.env, self.task_ids)
        self._remaining[job.client_id] = len(tasks)
        self._total += len(tasks)
        if self.gateway is not None:
            offsets = self.arrivals.get(job.client_id)
            if offsets is not None:
                # open-loop streaming: one admission event per circuit
                assert len(offsets) >= len(tasks), job.client_id
                for task, dt in zip(tasks, offsets):
                    self.loop.schedule(t + dt, "submit_circuit", task)
            else:
                for task in tasks:
                    self._gw_admit(t, task)
                self._gw_pump(t)
            return
        for task in tasks:
            self.manager.submit(task)
        self._drain(t)

    # -------------------------------------------------- gateway (serve/) path
    def _on_submit_circuit(self, t: float, task: CircuitTask) -> None:
        self._gw_admit(t, task)
        self._gw_pump(t)

    def _gw_admit(self, t: float, task: CircuitTask) -> None:
        key = (task.demand, task.service_time, task.depth)  # structural key
        try:
            self.gateway.submit(task.client_id, key, task, now=t)
        except self._backpressure:
            # admission control shed the circuit: it still counts as drained
            # (the job finishes with fewer executed circuits), never executed
            self.rejected += 1
            self._finish_one(task.client_id, t)

    def _gw_pump(self, t: float) -> None:
        """Coalesce admitted circuits; submit emitted batches to Algorithm 2
        as single lane-packed tasks; keep a flush timer armed for partials."""
        for batch in self.gateway.pump(t):
            self._gw_dispatch(t, batch)
        nd = self.gateway.next_deadline()
        if nd is not None and (
            self._gw_flush_at is None
            or nd < self._gw_flush_at - 1e-12
            or self._gw_flush_at <= t
        ):
            self._gw_flush_at = max(nd, t)
            self.loop.schedule(self._gw_flush_at, "gw_flush", None)
        self._drain(t)

    def _on_gw_flush(self, t: float, _) -> None:
        self._gw_flush_at = None
        self._gw_pump(t)

    def _gw_dispatch(self, t: float, batch) -> None:
        """One coalesced batch = one logical circuit-bank task: demand is the
        member circuits' width, service time is the fused-kernel cost
        ceil(n / LANES) * per-circuit time (lanes run in parallel)."""
        proto: CircuitTask = batch.members[0].payload
        n_passes = -(-batch.n // self.gw_lanes)
        bt = CircuitTask(
            task_id=next(self.task_ids),
            client_id="__gw__",
            demand=proto.demand,
            service_time=n_passes * proto.service_time,
            depth=proto.depth,
        )
        self._gw_batches[bt.task_id] = batch
        self.manager.submit(bt)

    def _gw_requeue(self, t: float, batch_task: CircuitTask) -> None:
        """An assigned batch came back (worker evicted / died before start):
        return its members to the coalescer so they are RE-coalesced —
        possibly merged with newer arrivals — rather than replayed as-is."""
        batch = self._gw_batches.pop(batch_task.task_id)
        self._gw_dispatched.discard(batch_task.task_id)
        self.gateway.requeue(batch, now=t)
        self._gw_pump(t)

    def _on_start(self, t: float, payload) -> None:
        task, wid = payload
        w = self.workers.get(wid)
        if w is None or task.demand > w.available_qubits:
            # worker died (or optimistic over-commit after eviction): requeue
            self._in_flight[task.client_id] -= 1
            if self.gateway is not None and task.task_id in self._gw_batches:
                self._gw_requeue(t, task)
            else:
                self.manager.submit(task)
            return
        finish = w.start(task, t)
        f = self.failures.get(wid)
        if f is not None:
            factor = f.slowdown_factor(t)
            if factor != 1.0:
                finish = t + (finish - t) * factor
                w.active[task.task_id].finish_time = finish
        if self.gateway is not None and task.task_id in self._gw_batches:
            tr = self.gateway.telemetry.trace
            if tr.enabled:
                batch = self._gw_batches[task.task_id]
                seqs = [m.seq for m in batch.members]
                tr.batch_stage(seqs, "dispatched", t, worker=wid)
                tr.batch_stage(seqs, "kernel_start", t)
                tr.worker_span(
                    wid,
                    t,
                    finish,
                    name=f"batch x{batch.n}",
                    args={
                        "members": batch.n,
                        "service_time": round(finish - t, 9),
                    },
                )
        self.loop.schedule(finish, "complete", (task, wid, t))

    def _on_complete(self, t: float, payload) -> None:
        task, wid, t_start = payload
        f = self.failures.get(wid)
        if f is not None and f.crashed_between(t_start, t):
            return  # worker died mid-execution: result never loops back
        if task.task_id in self.manager.completed_ids:
            return  # duplicate (requeued-then-finished-twice guard)
        w = self.workers[wid]
        if task.task_id not in w.active:
            return  # abandoned at a crash_recover re-registration
        if f is not None and f.kind == "flaky":
            key = (wid, task.task_id)
            attempt = self._flaky_attempts.get(key, 0)
            self._flaky_attempts[key] = attempt + 1
            if f.drops(task.task_id, attempt, t):
                # the execution happened but its result is garbage: release
                # the worker and requeue the task for another attempt
                w.finish(task.task_id, t)
                view = self.manager.workers.get(wid)
                if view is not None:
                    view.in_flight.pop(task.task_id, None)
                self._in_flight[task.client_id] -= 1
                if self.gateway is not None and task.task_id in self._gw_batches:
                    self._gw_requeue(t, task)
                else:
                    self.manager.submit(task)
                self._drain(t)
                return
        w.finish(task.task_id, t)
        self.manager.complete(wid, task, t)
        cid = task.client_id
        self._in_flight[cid] -= 1
        if self.gateway is not None and task.task_id in self._gw_batches:
            batch = self._gw_batches.pop(task.task_id)
            self._gw_dispatched.discard(task.task_id)
            self.gateway.complete(batch, None, t)
            for m in batch.members:
                self._finish_one(m.client_id, t)
        else:
            self._finish_one(cid, t)
        self._drain(t)

    def _finish_one(self, cid: str, t: float) -> None:
        self._remaining[cid] -= 1
        if self._remaining[cid] == 0:
            job = self.jobs[cid]
            self._results[cid] = JobResult(cid, job.n_circuits, job.submit_time, t)
            for cb in self.job_callbacks:
                cb(cid, t)

    def _drain(self, t: float) -> None:
        def launch(task, wid):
            # dispatch occupies the client's serial classical process first
            # (in gateway mode the ledger is the gateway's: one dispatch
            # cost per BATCH — the amortization that coalescing buys).
            # gateway_async splits that ledger PER WORKER: each worker's
            # execution slot pipelines its own dispatches, so batch dispatch
            # on one worker no longer head-of-line-blocks the others.
            cid = task.client_id
            ledger = cid
            if (
                self.gateway_async
                and self.gateway is not None
                and task.task_id in self._gw_batches
            ):
                ledger = f"{cid}/{wid}"
            free = max(self._client_free.get(ledger, 0.0), t) + self.classical_overhead
            self._client_free[ledger] = free
            self._in_flight[cid] = self._in_flight.get(cid, 0) + 1
            if self.gateway is not None and task.task_id in self._gw_batches:
                self._gw_dispatched.add(task.task_id)
                tr = self.gateway.telemetry.trace
                if tr.enabled:
                    tr.batch_stage(
                        (m.seq for m in self._gw_batches[task.task_id].members),
                        "placed",
                        t,
                        worker=wid,
                    )
            self.loop.schedule(free + self.assign_latency, "start", (task, wid))

        if self.lockstep:
            # round barrier: a client dispatches a new wave only when its
            # previous wave has fully returned (Algorithm 1's serial loop),
            # and at most one circuit per worker per wave.
            busy = {c for c, n in self._in_flight.items() if n > 0}
            placed = 0
            remaining = []
            used_workers: set[str] = set()
            for task in self.manager.pending:
                if task.client_id in busy:
                    remaining.append(task)
                    continue
                wid = self.manager.assign(task, t, exclude=used_workers)
                if wid is None:
                    remaining.append(task)
                    continue
                used_workers.add(wid)
                launch(task, wid)
                placed += 1
            self.manager.pending = remaining
        else:
            if self.fair_queue and self.manager.pending:
                self.manager.pending = _round_robin(self.manager.pending)
            self.manager.drain_pending(t, launch)

    # ---------------------------------------------------------------- run
    def submit_job(
        self,
        job: JobSpec,
        *,
        weight: float = 1.0,
        priority: int = 1,
        slo_ms: float | None = None,
    ) -> None:
        """Admit a job into a running (or not-yet-run) simulation.

        The constructor's job list is closed-world: every client is known at
        t=0 and its policy overrides are validated up front.  Round-structured
        controllers (the federated driver) instead open jobs as virtual time
        advances — a tenant's round-r local-training job is only knowable
        when round r-1 closes — so this entry point registers the job's
        gateway client with an explicit policy and schedules its submission
        at ``max(job.submit_time, now)``."""
        if job.client_id in self.jobs:
            raise ValueError(f"job {job.client_id!r} already submitted")
        self.jobs[job.client_id] = job
        if self.gateway is not None:
            self.gateway.register_client(
                job.client_id,
                weight=self._tenant_weights.get(job.client_id, weight),
                priority=self._tenant_priorities.get(job.client_id, priority),
                slo_ms=self._tenant_slos_ms.get(job.client_id, slo_ms),
            )
        self.loop.schedule(max(job.submit_time, self.loop.now), "submit", job)

    def start(self) -> None:
        """Schedule worker registrations, the liveness sweep, and every
        pre-declared job; the caller then drives ``loop.run`` itself (the
        federated driver interleaves round control events) and collects the
        report with ``finish()``.  ``run()`` remains the one-shot path."""
        for wid in self.workers:
            self.loop.schedule(0.0, "register", wid)
        self.loop.schedule(self.heartbeat_period, "liveness", None)
        for job in self.jobs.values():
            self.loop.schedule(job.submit_time, "submit", job)

    def run(self) -> SimulationReport:
        self.start()
        end = self.loop.run(until=self.run_until)
        return self.finish(end)

    def finish(self, end: float | None = None) -> SimulationReport:
        if end is None:
            end = self.loop.now
        makespan = max((r.finish_time for r in self._results.values()), default=end)
        # noise ledger: retention of each completed circuit on its worker
        rets, reg = [], self.manager.task_registry
        for _, tid, wid in self.manager.assignments:
            task, w = reg.get(tid), self.workers.get(wid)
            if task is not None and w is not None and tid in self.manager.completed_ids:
                rets.append((1.0 - w.cfg.error_rate) ** task.depth)
        return SimulationReport(
            jobs=dict(self._results),
            total_circuits=self._total,
            makespan=makespan,
            assignments=list(self.manager.assignments),
            evictions=list(self.manager.evictions),
            worker_busy_time={wid: w.busy_time for wid, w in self.workers.items()},
            fidelity_retention=(sum(rets) / len(rets)) if rets else 1.0,
            rejected=self.rejected,
            gateway_summary=(
                self.gateway.telemetry.summary() if self.gateway is not None else None
            ),
            trace=(
                self.gateway.telemetry.trace if self.gateway is not None else None
            ),
        )


def _round_robin(tasks):
    """Interleave the queue across clients (fair multi-client service),
    preserving each client's internal order."""
    by_client: dict[str, list] = {}
    order: list[str] = []
    for task in tasks:
        if task.client_id not in by_client:
            by_client[task.client_id] = []
            order.append(task.client_id)
        by_client[task.client_id].append(task)
    out, i = [], 0
    while any(by_client.values()):
        cid = order[i % len(order)]
        if by_client[cid]:
            out.append(by_client[cid].pop(0))
        i += 1
    return out


def homogeneous_workers(n: int, max_qubits: int, **kw) -> list[WorkerConfig]:
    return [
        WorkerConfig(worker_id=f"w{i + 1}", max_qubits=max_qubits, **kw)
        for i in range(n)
    ]
