"""The multi-tenant system simulation: EventLoop + workers + CoManager.

Wires the paper's full runtime together on the virtual clock:
  * workers register at t=0 and send heartbeats every ``heartbeat_period``;
  * clients submit jobs (circuit banks) at their submit times;
  * the co-Manager drains the pending queue on every state change
    (submission / completion / heartbeat), per Algorithm 2;
  * completions loop results back to the classical side.

This is the engine behind every runtime figure reproduction
(benchmarks/: Fig 3, 4, 5, 6).
"""
from __future__ import annotations

import dataclasses

from repro.comanager.events import EventLoop
from repro.comanager.manager import CoManager
from repro.comanager.tenancy import JobResult, JobSpec
from repro.comanager.worker import CircuitTask, QuantumWorker, WorkerConfig


@dataclasses.dataclass
class SimulationReport:
    jobs: dict[str, JobResult]
    total_circuits: int
    makespan: float
    assignments: list
    evictions: list
    worker_busy_time: dict[str, float]
    #: mean over executed circuits of (1 - error_rate_w)^depth — the
    #: fraction of SWAP-test signal surviving depolarization (1.0 = ideal).
    fidelity_retention: float = 1.0

    @property
    def circuits_per_second(self) -> float:
        return self.total_circuits / max(self.makespan, 1e-9)


class SystemSimulation:
    def __init__(self, worker_cfgs: list[WorkerConfig], jobs: list[JobSpec],
                 *, env: str = "ibmq", multi_tenant: bool = True,
                 tenancy: str | None = None, policy: str = "cru",
                 fidelity_floor: float = 0.0,
                 eager_completion: bool = True, heartbeat_period: float = 5.0,
                 assign_latency: float = 0.01, classical_overhead: float = 0.0,
                 lockstep: bool = False, fair_queue: bool = False,
                 run_until: float = 1e7,
                 worker_failures: dict[str, float] | None = None):
        """``assign_latency``: manager->worker dispatch cost per circuit.

        ``classical_overhead``: SERIAL per-circuit time on the classical
        manager (logical-circuit generation + quantum-state analysis).  The
        paper's runtime figures show strongly diminishing returns with more
        workers (5q/1L: 94.7s -> 73.1s for 1 -> 4 workers, not 4x) because the
        classical side — a single laptop/VM — processes every circuit
        serially.  Modeling it as a serial resource reproduces those curves;
        see benchmarks/runtime_uncontrolled.py for the calibration.

        ``lockstep``: reproduce the paper's Algorithm-1 dispatch loop
        ("for Circuit in cB: Result = Algorithm2(Circuit)"): the client sends
        one circuit per worker, then waits for the whole round to return
        before dispatching the next — round time ~ w*t_cl + t_q, which is
        exactly the diminishing-returns shape of Figs 3-5 (see
        benchmarks/calibration notes).

        ``classical_overhead`` is charged to a PER-CLIENT serial ledger: each
        client's classical process generates/analyzes its own circuits
        serially, which is the real bottleneck on the paper's classical side.

        ``worker_failures``: worker_id -> time at which it silently stops
        heartbeating (exercises the 3-missed-heartbeats eviction path)."""
        self.loop = EventLoop()
        self.manager = CoManager(multi_tenant=multi_tenant, tenancy=tenancy,
                                 eager_completion=eager_completion,
                                 policy=policy, fidelity_floor=fidelity_floor)
        self.workers = {c.worker_id: QuantumWorker(c) for c in worker_cfgs}
        self.jobs = {j.client_id: j for j in jobs}
        self.env = env
        self.heartbeat_period = heartbeat_period
        self.assign_latency = assign_latency
        self.classical_overhead = classical_overhead
        self.lockstep = lockstep
        self.fair_queue = fair_queue  # round-robin across clients in the queue
        self._client_free: dict[str, float] = {}  # per-client serial CPU
        self._in_flight: dict[str, int] = {}      # per-client outstanding
        self.run_until = run_until
        self.failures = worker_failures or {}

        self._remaining: dict[str, int] = {}
        self._results: dict[str, JobResult] = {}
        self._total = 0

        lp = self.loop
        lp.on("register", self._on_register)
        lp.on("heartbeat", self._on_heartbeat)
        lp.on("submit", self._on_submit)
        lp.on("start", self._on_start)
        lp.on("complete", self._on_complete)
        lp.on("liveness", self._on_liveness)

    # ------------------------------------------------------------ handlers
    def _on_register(self, t: float, wid: str) -> None:
        w = self.workers[wid]
        self.manager.register_worker(wid, w.max_qubits, w.cru(t), t,
                                     error_rate=w.cfg.error_rate)
        self.loop.schedule(t + self.heartbeat_period, "heartbeat", wid)

    def _on_heartbeat(self, t: float, wid: str) -> None:
        if wid in self.failures and t >= self.failures[wid]:
            return  # worker went silent: no report, no reschedule
        if self._all_done():
            return  # system idle: let the event loop drain
        w = self.workers[wid]
        self.manager.heartbeat(w.heartbeat_payload(t), t)
        self._drain(t)
        self.loop.schedule(t + self.heartbeat_period, "heartbeat", wid)

    def _on_liveness(self, t: float, _) -> None:
        self.manager.liveness_check(t, self.heartbeat_period)
        self._drain(t)
        if not self._all_done():
            self.loop.schedule(t + self.heartbeat_period, "liveness", None)

    def _all_done(self) -> bool:
        jobs_submitted = len(self._remaining) == len(self.jobs)
        return (jobs_submitted and not any(self._remaining.values())
                and not self.manager.pending)

    def _on_submit(self, t: float, job: JobSpec) -> None:
        tasks = job.circuits(self.env)
        self._remaining[job.client_id] = len(tasks)
        self._total += len(tasks)
        for task in tasks:
            self.manager.submit(task)
        self._drain(t)

    def _on_start(self, t: float, payload) -> None:
        task, wid = payload
        w = self.workers.get(wid)
        if w is None or task.demand > w.available_qubits:
            # worker died (or optimistic over-commit after eviction): requeue
            self._in_flight[task.client_id] -= 1
            self.manager.submit(task)
            return
        finish = w.start(task, t)
        self.loop.schedule(finish, "complete", (task, wid))

    def _on_complete(self, t: float, payload) -> None:
        task, wid = payload
        if wid in self.failures and t >= self.failures[wid]:
            return  # worker died mid-execution: result never loops back
        if task.task_id in self.manager.completed_ids:
            return  # duplicate (requeued-then-finished-twice guard)
        w = self.workers[wid]
        w.finish(task.task_id, t)
        self.manager.complete(wid, task, t)
        cid = task.client_id
        self._in_flight[cid] -= 1
        self._remaining[cid] -= 1
        if self._remaining[cid] == 0:
            job = self.jobs[cid]
            self._results[cid] = JobResult(cid, job.n_circuits, job.submit_time, t)
        self._drain(t)

    def _drain(self, t: float) -> None:
        def launch(task, wid):
            # dispatch occupies the client's serial classical process first
            cid = task.client_id
            free = max(self._client_free.get(cid, 0.0), t) + self.classical_overhead
            self._client_free[cid] = free
            self._in_flight[cid] = self._in_flight.get(cid, 0) + 1
            self.loop.schedule(free + self.assign_latency, "start", (task, wid))

        if self.lockstep:
            # round barrier: a client dispatches a new wave only when its
            # previous wave has fully returned (Algorithm 1's serial loop),
            # and at most one circuit per worker per wave.
            busy = {c for c, n in self._in_flight.items() if n > 0}
            placed = 0
            remaining = []
            used_workers: set[str] = set()
            for task in self.manager.pending:
                if task.client_id in busy:
                    remaining.append(task)
                    continue
                wid = self.manager.assign(task, t, exclude=used_workers)
                if wid is None:
                    remaining.append(task)
                    continue
                used_workers.add(wid)
                launch(task, wid)
                placed += 1
            self.manager.pending = remaining
        else:
            if self.fair_queue and self.manager.pending:
                self.manager.pending = _round_robin(self.manager.pending)
            self.manager.drain_pending(t, launch)

    # ---------------------------------------------------------------- run
    def run(self) -> SimulationReport:
        for wid in self.workers:
            self.loop.schedule(0.0, "register", wid)
        self.loop.schedule(self.heartbeat_period, "liveness", None)
        for job in self.jobs.values():
            self.loop.schedule(job.submit_time, "submit", job)
        end = self.loop.run(until=self.run_until)
        makespan = max((r.finish_time for r in self._results.values()), default=end)
        # noise ledger: retention of each completed circuit on its worker
        rets, reg = [], self.manager.task_registry
        for (_, tid, wid) in self.manager.assignments:
            task, w = reg.get(tid), self.workers.get(wid)
            if task is not None and w is not None and tid in self.manager.completed_ids:
                rets.append((1.0 - w.cfg.error_rate) ** task.depth)
        return SimulationReport(
            jobs=dict(self._results),
            total_circuits=self._total,
            makespan=makespan,
            assignments=list(self.manager.assignments),
            evictions=list(self.manager.evictions),
            worker_busy_time={wid: w.busy_time for wid, w in self.workers.items()},
            fidelity_retention=(sum(rets) / len(rets)) if rets else 1.0,
        )


def _round_robin(tasks):
    """Interleave the queue across clients (fair multi-client service),
    preserving each client's internal order."""
    by_client: dict[str, list] = {}
    order: list[str] = []
    for task in tasks:
        if task.client_id not in by_client:
            by_client[task.client_id] = []
            order.append(task.client_id)
        by_client[task.client_id].append(task)
    out, i = [], 0
    while any(by_client.values()):
        cid = order[i % len(order)]
        if by_client[cid]:
            out.append(by_client[cid].pop(0))
        i += 1
    return out


def homogeneous_workers(n: int, max_qubits: int, **kw) -> list[WorkerConfig]:
    return [WorkerConfig(worker_id=f"w{i+1}", max_qubits=max_qubits, **kw)
            for i in range(n)]
