"""Deterministic event-driven runtime for the co-Manager simulation.

The paper runs its control plane on wall-clock time (RPyC heartbeats every
5 s).  We reproduce the *semantics* on a virtual clock so every experiment is
exactly reproducible: events are ordered by (time, sequence number) and all
randomness is seeded.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable


@dataclasses.dataclass(order=True)
class _Entry:
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    payload: Any = dataclasses.field(compare=False)
    cancelled: bool = dataclasses.field(compare=False, default=False)


class EventLoop:
    """Min-heap virtual-time event loop."""

    def __init__(self):
        self._heap: list[_Entry] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.handlers: dict[str, Callable[[float, Any], None]] = {}
        self._stopped = False

    def schedule(self, time: float, kind: str, payload: Any = None) -> _Entry:
        if time < self.now - 1e-12:
            raise ValueError(f"cannot schedule in the past: {time} < {self.now}")
        e = _Entry(time, next(self._seq), kind, payload)
        heapq.heappush(self._heap, e)
        return e

    def cancel(self, entry: _Entry) -> None:
        entry.cancelled = True

    def on(self, kind: str, fn: Callable[[float, Any], None]) -> None:
        self.handlers[kind] = fn

    def stop(self) -> None:
        """Terminally stop the loop: ``run`` returns after the handler that
        called this (and any later ``run`` returns immediately).  Used by
        controllers layered on the simulation — e.g. the federated round
        driver, whose experiment ends at the final round close even when
        straggler jobs (a crashed worker's stalled tenant) would keep
        heartbeat events circulating forever."""
        self._stopped = True

    def run(self, until: float = float("inf"), max_events: int = 10_000_000) -> float:
        """Dispatch events in order until the heap drains, ``until`` is
        passed, or a handler calls ``stop()``."""
        n = 0
        while self._heap and n < max_events and not self._stopped:
            e = self._heap[0]
            if e.time > until:
                break
            heapq.heappop(self._heap)
            if e.cancelled:
                continue
            self.now = max(self.now, e.time)
            self.handlers[e.kind](self.now, e.payload)
            n += 1
        if n >= max_events:
            raise RuntimeError("event budget exhausted — likely a scheduling loop")
        return self.now

    @property
    def pending(self) -> int:
        return sum(1 for e in self._heap if not e.cancelled)
