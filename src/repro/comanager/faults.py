"""Typed worker-fault schedules shared by the virtual clock and the real
serving path.

``SystemSimulation.worker_failures`` historically mapped ``worker_id ->
crash time`` (the worker silently stops heartbeating at ``t``).  This
module generalizes that toggle into typed :class:`FaultSpec` schedules —
crash, crash-recover, slowdown, flaky — consumed identically by the
virtual-clock simulation and by the real dispatchers' ``FaultInjector``
(``repro.serve.fleet``), so every failure scenario is a cheap
deterministic regression test in both worlds.

Kept deliberately light (stdlib only): ``repro.api.config`` imports the
:class:`FaultToleranceConfig` knobs from here without pulling jax through
``repro.serve``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Union

FAULT_KINDS = ("crash", "crash_recover", "slowdown", "flaky")

#: Deterministic flaky-drop hash: Knuth-style multipliers combine the
#: (token, attempt, seed) triple, then a MurmurHash3 finalizer gives the
#: avalanche (attempt k and k+1 must draw independent values).  ``hash()``
#: is salted for strings, so it is never used here — flaky schedules stay
#: bit-reproducible across runs and platforms.
_MIX_A = 2654435761
_MIX_B = 40503
_MIX_C = 69069
_MASK64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    x &= _MASK64
    x ^= x >> 33
    x = (x * 0xFF51AFD7ED558CCD) & _MASK64
    x ^= x >> 33
    x = (x * 0xC4CEB9FE1A85EC53) & _MASK64
    x ^= x >> 33
    return x


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One worker's fault schedule.

    kind          one of :data:`FAULT_KINDS`
    at            fault onset time (simulation / dispatcher-relative s)
    recover_at    optional end of the fault window (crash_recover,
                  slowdown, flaky); ``None`` = faulty forever
    factor        slowdown multiplier on service time (kind="slowdown")
    p             per-attempt failure probability (kind="flaky")
    seed          salt for the deterministic flaky hash
    """

    kind: str = "crash"
    at: float = 0.0
    recover_at: Optional[float] = None
    factor: float = 1.0
    p: float = 0.0
    seed: int = 0

    # ------------------------------------------------------------ queries
    def active(self, t: float) -> bool:
        """Is the fault window open at time ``t``?"""
        if t < self.at:
            return False
        return self.recover_at is None or t < self.recover_at

    def crashed(self, t: float) -> bool:
        return self.kind in ("crash", "crash_recover") and self.active(t)

    def crashed_between(self, t0: float, t1: float) -> bool:
        """Did the crash window overlap ``[t0, t1]``?  Used by the virtual
        clock to drop results whose execution straddled a crash."""
        if self.kind not in ("crash", "crash_recover"):
            return False
        end = math.inf if self.recover_at is None else self.recover_at
        return self.at <= t1 and t0 < end

    def slowdown_factor(self, t: float) -> float:
        if self.kind != "slowdown" or not self.active(t):
            return 1.0
        return self.factor

    def drops(self, token: int, attempt: int, t: float) -> bool:
        """Deterministic flaky decision for ``(token, attempt)`` — e.g.
        (task_id, retry count).  Retries draw fresh hashes, so a flaky
        worker eventually succeeds."""
        if self.kind != "flaky" or self.p <= 0.0 or not self.active(t):
            return False
        x = _mix64(token * _MIX_A + attempt * _MIX_B + self.seed * _MIX_C + 12345)
        return x / (_MASK64 + 1) < self.p

    # --------------------------------------------------------- validation
    def validate(self, owner: str) -> None:
        """Raise ``ValueError`` naming ``owner`` (the worker id) on any
        malformed field."""
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"worker_failures[{owner!r}]: unknown fault kind "
                f"{self.kind!r} (expected one of {FAULT_KINDS})"
            )
        if not math.isfinite(self.at) or self.at < 0.0:
            raise ValueError(
                f"worker_failures[{owner!r}]: fault time {self.at!r} must "
                f"be finite and >= 0"
            )
        if self.recover_at is not None:
            if not math.isfinite(self.recover_at):
                raise ValueError(
                    f"worker_failures[{owner!r}]: recover_at "
                    f"{self.recover_at!r} must be finite"
                )
            if self.recover_at <= self.at:
                raise ValueError(
                    f"worker_failures[{owner!r}]: recover_at "
                    f"{self.recover_at!r} must be > fault time {self.at!r}"
                )
        if self.kind == "slowdown" and (
            not math.isfinite(self.factor) or self.factor <= 0.0
        ):
            raise ValueError(
                f"worker_failures[{owner!r}]: slowdown factor "
                f"{self.factor!r} must be finite and > 0"
            )
        if self.kind == "flaky" and not (0.0 <= self.p <= 1.0):
            raise ValueError(
                f"worker_failures[{owner!r}]: flaky probability "
                f"{self.p!r} must be in [0, 1]"
            )


FaultLike = Union[float, int, FaultSpec, Mapping]


def normalize_failures(
    worker_failures: Optional[Mapping[str, FaultLike]],
) -> dict[str, FaultSpec]:
    """Coerce a ``worker_failures`` map to ``{worker_id: FaultSpec}`` and
    validate it.  Accepts the legacy ``{wid: crash_time}`` float form, dict
    kwargs (``{"kind": "slowdown", "at": 2.0, "factor": 3.0}``), or
    ready-made :class:`FaultSpec` values.  Raises ``ValueError`` naming the
    offending worker id."""
    out: dict[str, FaultSpec] = {}
    for wid, value in (worker_failures or {}).items():
        if isinstance(value, FaultSpec):
            spec = value
        elif isinstance(value, Mapping):
            try:
                spec = FaultSpec(**value)
            except TypeError as exc:
                raise ValueError(
                    f"worker_failures[{wid!r}]: bad fault fields: {exc}"
                ) from None
        elif isinstance(value, (int, float)) and not isinstance(value, bool):
            spec = FaultSpec(kind="crash", at=float(value))
        else:
            raise ValueError(
                f"worker_failures[{wid!r}]: expected a crash time, a "
                f"FaultSpec, or a dict of FaultSpec fields, got "
                f"{type(value).__name__}"
            )
        spec.validate(str(wid))
        out[str(wid)] = spec
    return out


@dataclasses.dataclass(frozen=True)
class FaultToleranceConfig:
    """Dispatcher fault-tolerance knobs (``ServingConfig.fault_tolerance``).

    retry_limit         in-place retries of a failed batch on the same
                        worker before migrating (0 = migrate immediately)
    retry_backoff_s     base backoff between retries (doubles per attempt)
    hedge_k             hedged duplicate dispatch fires when a slot exceeds
                        ``hedge_k ×`` the ServiceModel EWMA estimate;
                        ``None`` disables hedging
    breaker_threshold   consecutive failures that trip a worker offline
    breaker_cooldown_s  offline hold before the half-open probation trial
    failure_alpha       EWMA smoothing for the per-worker failure rate
    """

    retry_limit: int = 1
    retry_backoff_s: float = 0.0
    hedge_k: Optional[float] = None
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 5.0
    failure_alpha: float = 0.25

    def __post_init__(self):
        if self.retry_limit < 0:
            raise ValueError("retry_limit must be >= 0")
        if self.retry_backoff_s < 0.0:
            raise ValueError("retry_backoff_s must be >= 0")
        if self.hedge_k is not None and self.hedge_k <= 0.0:
            raise ValueError("hedge_k must be > 0 (or None to disable)")
        if self.breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        if self.breaker_cooldown_s < 0.0:
            raise ValueError("breaker_cooldown_s must be >= 0")
        if not (0.0 < self.failure_alpha <= 1.0):
            raise ValueError("failure_alpha must be in (0, 1]")


__all__ = [
    "FAULT_KINDS",
    "FaultSpec",
    "FaultToleranceConfig",
    "normalize_failures",
]
