"""The quantum-classical co-Manager (paper Algorithm 2, line-by-line).

Four management modules:
  (1) co-Manager Initialization      — __init__ / bootstrap()
  (2) Quantum Worker Registration    — register_worker()      (lines 2-6)
  (3) Periodic Worker Management     — heartbeat() + liveness (lines 7-13)
  (4) Workload Assignment            — assign()               (lines 14-20)

Faithfulness notes:
* OR_w is recomputed from the heartbeat-reported active-circuit set
  (lines 8-9), AR_w = MR_w - OR_w (line 10), CRU_w(t+1) from the worker's
  "sys call" (line 11).
* A worker missing three consecutive heartbeats is evicted (lines 12-13).
* Assignment filters candidates by AR_w > D_c (STRICT inequality, as written
  on line 16), sorts ascending by most recent CRU (line 19) and returns the
  head (line 20).
* Between heartbeats the manager tracks its own assignments optimistically
  (it knows what it handed out) — otherwise it would over-commit a worker
  within one 5-second heartbeat period.  Completions are learned either
  eagerly (result return == completion, like the paper's RPC loop-back) or
  only at the next heartbeat (``eager_completion=False``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.comanager.worker import CircuitTask


@dataclasses.dataclass
class WorkerView:
    """The co-Manager's bookkeeping for one registered worker."""

    worker_id: str
    max_qubits: int  # MR_w
    reported_or: int = 0  # OR_w from last heartbeat
    reported_active: set = dataclasses.field(default_factory=set)
    cru: float = 0.0  # CRU_w(t) from last heartbeat
    last_heartbeat: float = 0.0
    missed_heartbeats: int = 0
    in_flight: dict = dataclasses.field(default_factory=dict)  # tid -> demand
    client_affinity: Optional[str] = None  # single-tenant mode ownership
    error_rate: float = 0.0  # beyond paper: reported gate error

    @property
    def occupied_qubits(self) -> int:
        return self.reported_or + sum(self.in_flight.values())

    @property
    def available_qubits(self) -> int:  # AR_w (line 10)
        return self.max_qubits - self.occupied_qubits


class CoManager:
    """``tenancy``:
    * "multi"          — circuits from any client co-reside on a worker up to
                         its qubit capacity (the paper's system);
    * "single_circuit" — one circuit occupies the entire machine at a time
                         ("one user occupies the entire machine while others
                         wait in a queue"), any client may use any machine
                         next — the Fig-6 single-tenant baseline;
    * "user_exclusive" — additionally a machine stays with one client until
                         that client's queue drains (IBM-Q-style account
                         exclusivity).
    """

    def __init__(
        self,
        *,
        eager_completion: bool = True,
        miss_limit: int = 3,
        multi_tenant: bool = True,
        tenancy: str | None = None,
        policy: str = "cru",
        fidelity_floor: float = 0.0,
    ):
        # (1) co-Manager Initialization (line 1)
        self.workers: dict[str, WorkerView] = {}  # W + MR dictionary
        self.pending: list[CircuitTask] = []  # client-submitted circuits
        self.miss_limit = miss_limit
        self.eager_completion = eager_completion
        if tenancy is None:
            tenancy = "multi" if multi_tenant else "user_exclusive"
        assert tenancy in ("multi", "single_circuit", "user_exclusive"), tenancy
        self.tenancy = tenancy
        self.multi_tenant = tenancy == "multi"
        # BEYOND PAPER: assignment policy.  "cru" = Algorithm 2 lines 18-19;
        # "noise_aware" sorts candidates by reported gate-error first (then
        # CRU) — addresses the paper's §V limitation #2.
        assert policy in ("cru", "noise_aware"), policy
        self.policy = policy
        # minimum acceptable (1-error)^depth per circuit: workers too noisy
        # for a given circuit DEPTH are not candidates (the circuit queues
        # for a cleaner machine instead) — runtime/fidelity trade-off knob.
        self.fidelity_floor = fidelity_floor
        self.assignments: list[tuple[float, int, str]] = []  # (t, task, worker)
        self.evictions: list[tuple[float, str]] = []
        self.task_registry: dict[int, CircuitTask] = {}
        self.completed_ids: set[int] = set()

    # ------------------------------------------------- (2) registration
    def register_worker(
        self,
        worker_id: str,
        max_qubits: int,
        cru: float,
        t: float,
        error_rate: float = 0.0,
    ) -> WorkerView:
        """Lines 2-6: join W; OR=0; AR=MR; record CRU."""
        v = WorkerView(
            worker_id=worker_id,
            max_qubits=max_qubits,
            cru=cru,
            last_heartbeat=t,
            error_rate=error_rate,
        )
        self.workers[worker_id] = v
        return v

    # --------------------------------------------- (3) periodic management
    def heartbeat(self, payload: dict, t: float) -> None:
        """Lines 7-11: recompute OR from the reported active set; AR; CRU."""
        v = self.workers.get(payload["worker_id"])
        if v is None:
            return  # stale heartbeat from an evicted worker
        active = payload["active"]
        completed = payload.get("completed", set())
        v.reported_or = sum(active.values())  # lines 8-9
        v.reported_active = set(active)
        # in-flight entries the worker now reports active (counted in OR) or
        # has finished are settled out of the optimistic ledger.
        v.in_flight = {
            tid: d
            for tid, d in v.in_flight.items()
            if tid not in active and tid not in completed
        }
        v.cru = payload["cru"]  # line 11
        v.error_rate = payload.get("error_rate", v.error_rate)
        v.last_heartbeat = t
        v.missed_heartbeats = 0
        self._maybe_release_affinity(v)

    def _maybe_release_affinity(self, v: WorkerView) -> None:
        """Single-tenant: free the machine once its client has drained."""
        if self.multi_tenant or v.client_affinity is None:
            return
        if v.occupied_qubits == 0 and not any(
            task.client_id == v.client_affinity for task in self.pending
        ):
            v.client_affinity = None

    def liveness_check(self, t: float, period: float) -> list[str]:
        """Lines 12-13: evict workers silent for ``miss_limit`` periods."""
        dead = []
        for wid, v in self.workers.items():
            missed = int((t - v.last_heartbeat) / period + 1e-9)
            v.missed_heartbeats = missed
            if missed >= self.miss_limit:
                dead.append(wid)
        for wid in dead:
            v = self.workers.pop(wid)
            self.evictions.append((t, wid))
            # circuits lost with the worker are requeued for reassignment
            lost = set(v.in_flight) | v.reported_active
            for tid in sorted(lost):
                task = self.task_registry.get(tid)
                if task is not None and tid not in self.completed_ids:
                    self.pending.insert(0, task)
        return dead

    # ------------------------------------------------- (4) workload assign
    def assign(
        self, task: CircuitTask, t: float, exclude: set | None = None
    ) -> Optional[str]:
        """Lines 14-20.  Returns the chosen worker id, or None (stays pending).

        ``exclude``: workers to skip for this call — used by the lockstep
        (Algorithm-1 round) dispatcher to hand at most one circuit per worker
        per round even while the CRU view is stale between heartbeats.

        Capacity predicate: the paper's pseudocode writes AR > D (strict), but
        its Fig 6 discussion ("worker-1, which only has 5 qubits, is useless
        to a 7-qubit circuit" — i.e. it IS usable by 5-qubit ones) requires
        exact fits to be schedulable, so we use AR >= D.

        Single-tenant baseline (multi_tenant=False) models the IBM-Q-style
        semantics the paper compares against: "one user occupies the entire
        machine while others wait in a queue" — at most one circuit resident
        per worker, and the worker stays with one client until its job drains.
        """
        held = None
        if self.tenancy == "user_exclusive":
            held = next(
                (
                    v
                    for v in self.workers.values()
                    if v.client_affinity == task.client_id
                ),
                None,
            )
        candidates = []
        for wid, v in self.workers.items():  # line 15
            if exclude and wid in exclude:
                continue
            if v.available_qubits >= task.demand:  # line 16 (see note)
                if (
                    self.policy == "noise_aware"
                    and self.fidelity_floor
                    and task.depth
                    and (1.0 - v.error_rate) ** task.depth < self.fidelity_floor
                ):
                    continue  # too noisy for this depth
                if not self.multi_tenant and v.occupied_qubits > 0:
                    continue  # machine fully occupied
                if self.tenancy == "user_exclusive":
                    if held is not None and v is not held:
                        continue  # one machine per client
                    if v.client_affinity not in (None, task.client_id):
                        continue  # others wait in queue
                candidates.append(v)  # line 17
        if not candidates:
            return None
        if self.policy == "noise_aware":
            candidates.sort(key=lambda v: (v.error_rate, v.cru, v.worker_id))
        else:
            candidates.sort(key=lambda v: (v.cru, v.worker_id))  # lines 18-19
        best = candidates[0]  # line 20
        best.in_flight[task.task_id] = task.demand
        if self.tenancy == "user_exclusive":
            best.client_affinity = task.client_id
        self.assignments.append((t, task.task_id, best.worker_id))
        return best.worker_id

    def complete(self, worker_id: str, task: CircuitTask, t: float) -> None:
        """Result looped back.  Eager mode frees capacity immediately."""
        self.completed_ids.add(task.task_id)
        v = self.workers.get(worker_id)
        if v is None:
            return
        if self.eager_completion:
            if task.task_id in v.in_flight:
                v.in_flight.pop(task.task_id)
            elif task.task_id in v.reported_active:
                # the last heartbeat counted it in OR; discount until refresh
                v.reported_active.discard(task.task_id)
                v.reported_or = max(0, v.reported_or - task.demand)
            self._maybe_release_affinity(v)

    # ------------------------------------------------------------- queue
    def submit(self, task: CircuitTask) -> None:
        self.task_registry[task.task_id] = task
        self.pending.append(task)

    def drain_pending(self, t: float, start_fn) -> int:
        """Try to place pending circuits (FIFO).  ``start_fn(task, wid)``
        actually launches the circuit.  Returns number placed."""
        placed = 0
        remaining: list[CircuitTask] = []
        for task in self.pending:
            wid = self.assign(task, t)
            if wid is None:
                remaining.append(task)
            else:
                start_fn(task, wid)
                placed += 1
        self.pending = remaining
        return placed
