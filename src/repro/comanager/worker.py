"""Quantum worker model (Algorithm 2 state: MR / AR / OR / CRU / AC).

A worker executes assigned circuits concurrently, subject to its qubit
capacity ``MR``.  Two execution backends:

* simulated service times — calibrated per-(qc, layers) rates so the paper's
  runtime figures can be reproduced deterministically on the virtual clock;
* real kernel execution — the worker's batch is handed to the fused Pallas
  VQC kernel (repro.kernels.ops), which is how the TPU data plane runs.

Contention model: quantum hardware executes co-resident circuits on disjoint
qubits truly concurrently, while the paper's *simulator* workers are
CPU-bound.  ``contention`` interpolates: the service time of a circuit that
starts with k other active circuits is scaled by (1 + contention * k).
"""

from __future__ import annotations

import dataclasses

#: paper-calibrated 1-worker processing speeds (circuits/sec) from Figs 3b/4b,
#: IBM-Q backends: (qc, n_layers) -> circuits per second.
PAPER_RATES_IBMQ = {
    (5, 1): 15.2,
    (5, 2): 6.2,
    (5, 3): 5.9,
    (7, 1): 12.4,
    (7, 2): 7.1,
    (7, 3): 4.4,
}
#: controlled-environment (GCP e2-medium) rates from Fig 5b.
PAPER_RATES_GCP = {
    (5, 1): 3.8,
    (5, 2): 3.0,
    (5, 3): 2.4,
    (7, 1): 3.0,
    (7, 2): 2.4,
    (7, 3): 1.9,
}


@dataclasses.dataclass(frozen=True)
class WorkerConfig:
    worker_id: str
    max_qubits: int  # MR_w
    speed: float = 1.0  # relative service-rate multiplier
    heartbeat_period: float = 5.0  # paper: "every 5 seconds"
    contention: float = 0.15  # co-residency slowdown factor
    base_load: float = 0.0  # external classical load (uncontrolled env)
    # BEYOND PAPER (their §V limitation #2): per-gate depolarizing error of
    # this machine.  A depth-g circuit's state is fully depolarized with
    # probability 1-(1-error_rate)**g, pulling the observed SWAP-test
    # fidelity toward 1/2.  0.0 = the paper's noiseless setting.
    error_rate: float = 0.0


@dataclasses.dataclass
class ActiveCircuit:
    task: "CircuitTask"
    start_time: float
    finish_time: float


@dataclasses.dataclass(frozen=True)
class CircuitTask:
    """One bank entry as the co-Manager sees it.

    ``demand`` is D_c in Algorithm 2 (qubit width); ``service_time`` is the
    1x-speed, zero-contention execution time; ``payload`` indexes the client
    job's (theta, data) bank row for real execution.
    """

    task_id: int
    client_id: str
    demand: int
    service_time: float
    payload: int = -1
    depth: int = 0  # gate count (noise-aware scheduling extension)

    def __post_init__(self):
        assert self.demand >= 1 and self.service_time > 0


class QuantumWorker:
    """Runtime state of one quantum worker."""

    def __init__(self, cfg: WorkerConfig):
        self.cfg = cfg
        self.active: dict[int, ActiveCircuit] = {}  # AC_w
        self.completed: list[int] = []
        self.busy_time = 0.0  # integral of n_active dt
        self._last_t = 0.0

    # ----------------------------------------------------------- resources
    @property
    def max_qubits(self) -> int:  # MR_w
        return self.cfg.max_qubits

    @property
    def occupied_qubits(self) -> int:  # OR_w = sum of D_c
        return sum(a.task.demand for a in self.active.values())

    @property
    def available_qubits(self) -> int:  # AR_w = MR_w - OR_w
        return self.max_qubits - self.occupied_qubits

    def cru(self, t: float) -> float:
        """Classical resource usage CRU_w(t): the sys_w 'system call'.

        Modeled as base external load + fraction of capacity occupied by
        concurrently executing circuits (a CPU-bound simulator's utilization
        tracks its resident circuit count).
        """
        util = len(self.active) / max(1, self.max_qubits // 5)
        return self.cfg.base_load + min(1.0, util)

    # ----------------------------------------------------------- execution
    def exec_time(self, task: CircuitTask) -> float:
        """Service time for ``task`` if started now (contention-scaled)."""
        k = len(self.active)
        return (task.service_time / self.cfg.speed) * (1.0 + self.cfg.contention * k)

    def start(self, task: CircuitTask, now: float) -> float:
        """Begin executing; returns the finish time to schedule."""
        if task.demand > self.available_qubits:
            raise RuntimeError(
                f"{self.cfg.worker_id}: demand {task.demand} > AR "
                f"{self.available_qubits}"
            )
        self._accumulate(now)
        finish = now + self.exec_time(task)
        self.active[task.task_id] = ActiveCircuit(task, now, finish)
        return finish

    def finish(self, task_id: int, now: float) -> CircuitTask:
        self._accumulate(now)
        ac = self.active.pop(task_id)
        self.completed.append(task_id)
        return ac.task

    def abandon(self, now: float) -> list[CircuitTask]:
        """Drop every resident circuit without completing it.

        Crash recovery: a worker that re-registers after a crash lost its
        in-memory state, so its active set is cleared (capacity returns,
        busy time accrues up to ``now``) and the orphaned tasks are handed
        back to the caller for requeueing.
        """
        self._accumulate(now)
        orphans = [ac.task for ac in self.active.values()]
        self.active.clear()
        return orphans

    def _accumulate(self, now: float) -> None:
        self.busy_time += len(self.active) * (now - self._last_t)
        self._last_t = now

    # --------------------------------------------------------------- noise
    def depolarization(self, depth: int) -> float:
        """lambda = P(state fully depolarized) for a depth-``depth`` circuit."""
        return 1.0 - (1.0 - self.cfg.error_rate) ** depth

    def observed_p0(self, ideal_p0: float, depth: int) -> float:
        """Global-depolarizing readout: P0 -> (1-l)*P0 + l/2."""
        lam = self.depolarization(depth)
        return (1.0 - lam) * ideal_p0 + lam * 0.5

    # ------------------------------------------------------------ heartbeat
    def heartbeat_payload(self, t: float) -> dict:
        """What w_i reports to the co-Manager every heartbeat period."""
        return {
            "worker_id": self.cfg.worker_id,
            "active": {tid: a.task.demand for tid, a in self.active.items()},
            "completed": set(self.completed),
            "cru": self.cru(t),
            "max_qubits": self.max_qubits,
            "error_rate": self.cfg.error_rate,
        }
