"""Multi-tenant client/job model.

A *client* submits a training job; a *job* expands into the circuit bank for
one epoch (or one gradient step) of its QuClassi workload.  The paper's
multi-tenant evaluation (Fig 6) runs four concurrent clients
(5Q/1L, 5Q/2L, 7Q/1L, 7Q/2L) against four heterogeneous workers
(5/10/15/20 qubits).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator

from repro.comanager.worker import CircuitTask, PAPER_RATES_GCP, PAPER_RATES_IBMQ


class TaskIdAllocator:
    """Per-runtime task-id source.

    Each ``SystemSimulation`` / serving gateway owns one of these, so two
    concurrently constructed runtimes can never interleave ids (the old
    module-global counter made task ids depend on construction order
    process-wide, which breaks multi-stream ingestion).
    """

    def __init__(self, start: int = 0):
        self._it = itertools.count(start)

    def __next__(self) -> int:
        return next(self._it)

    def __iter__(self) -> Iterator[int]:
        return self


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One client's training job for runtime experiments."""

    client_id: str
    qc: int  # circuit width (5 or 7)
    n_layers: int  # 1..3
    n_circuits: int  # bank size for the epoch
    submit_time: float = 0.0
    service_override: float | None = None  # quantum-side seconds/circuit

    def service_time(self, env: str = "ibmq") -> float:
        """Per-circuit 1-worker service time calibrated from the paper."""
        if self.service_override is not None:
            return self.service_override
        rates = PAPER_RATES_IBMQ if env == "ibmq" else PAPER_RATES_GCP
        return 1.0 / rates[(self.qc, self.n_layers)]

    def circuits(
        self, env: str = "ibmq", ids: Iterator[int] | None = None
    ) -> list[CircuitTask]:
        """Expand into the epoch's circuit bank.  ``ids`` is the owning
        runtime's task-id allocator (defaults to a fresh one, for callers
        that only ever build a single job)."""
        st = self.service_time(env)
        ids = ids if ids is not None else TaskIdAllocator()
        from repro.core import circuits as qcirc

        depth = len(qcirc.build_quclassi_circuit(self.qc, self.n_layers).ops)
        return [
            CircuitTask(
                task_id=next(ids),
                client_id=self.client_id,
                demand=self.qc,
                service_time=st,
                payload=i,
                depth=depth,
            )
            for i in range(self.n_circuits)
        ]


#: paper's per-epoch circuit counts (§IV-C): 5q -> 1440/2880/4320,
#: 7q -> 2016/4032/6048 for 1/2/3 layers.
PAPER_CIRCUIT_COUNTS = {
    (5, 1): 1440,
    (5, 2): 2880,
    (5, 3): 4320,
    (7, 1): 2016,
    (7, 2): 4032,
    (7, 3): 6048,
}


def paper_job(
    client_id: str,
    qc: int,
    n_layers: int,
    submit_time: float = 0.0,
    scale: float = 1.0,
) -> JobSpec:
    n = int(PAPER_CIRCUIT_COUNTS[(qc, n_layers)] * scale)
    return JobSpec(client_id, qc, n_layers, n, submit_time)


@dataclasses.dataclass
class JobResult:
    client_id: str
    n_circuits: int
    submit_time: float
    finish_time: float

    @property
    def makespan(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def circuits_per_second(self) -> float:
        return self.n_circuits / max(self.makespan, 1e-9)
