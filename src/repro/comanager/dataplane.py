"""Data plane: really execute circuit banks, per co-Manager assignment.

Two execution paths:

* ``worker_batched_executor`` — groups the bank rows assigned to each worker
  and runs each group through the fused Pallas VQC kernel.  This is the
  faithful "each worker executes its circuits" path; on one host the groups
  run sequentially, on a pod each worker's group lands on its mesh slice.

* ``sharded_executor`` — the TPU-native whole-bank path: the bank is sharded
  over the mesh's ``data`` axis with ``shard_map`` and every device runs the
  kernel on its shard.  This is what the production launcher uses and what
  the multi-pod dry-run lowers.

Both return fidelities in bank order, so ``shift_rule.assemble_gradient``
consumes them identically — scheduling never changes the math (the accuracy
experiments in the paper rely on exactly this property).

Both executors also accept IMPLICIT ``shift_rule.ShiftBank``s (call
``run(bank)``): the schedulable unit then becomes the (param, shift) group
and execution goes through the prefix-reuse kernel — same bank-order
results, a fraction of the gate applications and angle traffic.

Every factory here returns a ``declare``-d executor; the
``repro.api.backend`` adapters lift them into the ``ExecutionBackend``
protocol.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax.shard_map landed after 0.4.x (where it lives in experimental); the
# "skip varying-across-mesh checks" kwarg was renamed check_rep -> check_vma
# at a different point, so detect the kwarg itself, not just the symbol.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map
import inspect as _inspect

_SM_SKIP_CHECKS = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else {"check_rep": False}
)

from repro.api.capabilities import declare
from repro.core import shift_rule
from repro.core.sim import CircuitSpec
from repro.kernels import ops as kops


def worker_batched_executor(
    spec: CircuitSpec, assignment: Sequence[int], n_workers: int
):
    """Executor that mimics per-worker execution.

    Materialized banks: ``assignment[i] = worker index for bank row i``.
    Rows are grouped per worker and executed as one fused-kernel batch each;
    results come back in bank order via ONE inverse-permutation gather
    (rather than a per-worker scatter loop of ``out.at[rows].set``, which
    built n_workers intermediate arrays).

    Implicit ``ShiftBank``s (``run(bank)``): the schedulable unit becomes the
    (param, shift) GROUP — ``assignment[g] = worker index for bank group g``
    (length ``bank.n_groups``) — and each worker executes its groups as one
    prefix-reuse kernel call over the whole sample batch, so the co-Manager
    distributes suffix-replay subtasks instead of materialized rows.
    """
    import numpy as np

    assignment = np.asarray(assignment)
    # stable grouping permutation: rows sorted by worker, ties in bank order,
    # so each worker's group preserves its clients' submission order.
    order = np.argsort(assignment, kind="stable")
    inverse = np.argsort(order, kind="stable")
    bounds = np.searchsorted(assignment[order], np.arange(n_workers + 1))
    inverse_j = jnp.asarray(inverse)

    def _run_rows(theta_bank: jnp.ndarray, data_bank: jnp.ndarray) -> jnp.ndarray:
        groups = []
        for w in range(n_workers):
            rows = order[bounds[w] : bounds[w + 1]]
            if rows.size == 0:
                continue
            groups.append(kops.vqc_fidelity(spec, theta_bank[rows], data_bank[rows]))
        return jnp.concatenate(groups)[inverse_j]

    def _run_shiftbank(bank: shift_rule.ShiftBank) -> jnp.ndarray:
        if len(assignment) != bank.n_groups:
            if len(assignment) == bank.n_circuits:
                # per-ROW assignment (legacy scheduling granularity): honor it
                # exactly by materializing — same per-worker row placement.
                mat = bank.materialize()
                return _run_rows(mat.theta, mat.data)
            raise ValueError(
                f"assignment must cover the bank's {bank.n_groups} groups or "
                f"{bank.n_circuits} rows, got {len(assignment)} entries"
            )
        outs = []
        for w in range(n_workers):
            grp = order[bounds[w] : bounds[w + 1]]
            if grp.size == 0:
                continue
            outs.append(
                kops.vqc_fidelity_shiftgroups(
                    spec,
                    bank.theta,
                    bank.data,
                    bank.four_term,
                    tuple(int(g) for g in grp),
                )
            )
        stacked = jnp.concatenate(outs, 0)[inverse_j]  # (n_groups, B)
        return stacked.reshape(-1)

    def run(theta_bank, data_bank=None):
        if isinstance(theta_bank, shift_rule.ShiftBank):
            return _run_shiftbank(theta_bank)
        return _run_rows(theta_bank, data_bank)

    return declare(run, shiftbank=True)


def round_robin_assignment(n_circuits: int, n_workers: int):
    """The degenerate scheduler baseline (no co-management).

    Also the group-assignment baseline for implicit banks (pass
    ``n_circuits = bank.n_groups``)."""
    return [i % n_workers for i in range(n_circuits)]


def worker_pool_executor(
    spec: CircuitSpec,
    assignment: Sequence[int],
    n_workers: int,
    max_threads: int | None = None,
):
    """``worker_batched_executor`` with OVERLAPPING per-worker execution.

    The sequential executor runs each worker's group one after another on
    the host — faithful to one device, but on a multi-worker host (or with
    XLA releasing the GIL during kernel execution) the groups can run
    concurrently, exactly like the async dispatcher's one-slot-per-worker
    pool.  Each worker's group is submitted to a thread pool; results gather
    in bank order, so gradients are bit-identical to the sequential path
    (scheduling never changes the math).

    Call ``run.close()`` to shut the pool down when the executor is retired
    (threads are created on demand, so an unused executor costs nothing).
    """
    import numpy as np
    from concurrent.futures import ThreadPoolExecutor

    assignment = np.asarray(assignment)
    order = np.argsort(assignment, kind="stable")
    inverse = np.argsort(order, kind="stable")
    bounds = np.searchsorted(assignment[order], np.arange(n_workers + 1))
    inverse_j = jnp.asarray(inverse)
    pool = ThreadPoolExecutor(
        max_workers=max_threads or n_workers, thread_name_prefix="dataplane-worker"
    )

    def _groups():
        for w in range(n_workers):
            rows = order[bounds[w] : bounds[w + 1]]
            if rows.size:
                yield w, rows

    def run(theta_bank, data_bank=None) -> jnp.ndarray:
        if isinstance(theta_bank, shift_rule.ShiftBank):
            bank = theta_bank
            if len(assignment) != bank.n_groups:
                if len(assignment) == bank.n_circuits:
                    # per-ROW assignment (legacy granularity): honor it by
                    # materializing, same as worker_batched_executor.
                    mat = bank.materialize()
                    return run(mat.theta, mat.data)
                raise ValueError(
                    f"assignment must cover the bank's {bank.n_groups} "
                    f"groups or {bank.n_circuits} rows, got "
                    f"{len(assignment)} entries"
                )
            futs = [
                pool.submit(
                    kops.vqc_fidelity_shiftgroups,
                    spec,
                    bank.theta,
                    bank.data,
                    bank.four_term,
                    tuple(int(g) for g in rows),
                )
                for _, rows in _groups()
            ]
            stacked = jnp.concatenate([f.result() for f in futs], 0)
            return stacked[inverse_j].reshape(-1)
        futs = [
            pool.submit(kops.vqc_fidelity, spec, theta_bank[rows], data_bank[rows])
            for _, rows in _groups()
        ]
        return jnp.concatenate([f.result() for f in futs])[inverse_j]

    run.close = lambda: pool.shutdown(wait=True)
    return declare(run, shiftbank=True)


def worker_multibank_executor(
    spec: CircuitSpec, assignment: Sequence[int], n_workers: int
):
    """Multi-bank scheduling: the schedulable unit is the (bank, group)
    subtask of a same-spec BANK SET.

    ``assignment[i]`` is the worker for flat subtask i, where subtasks
    enumerate every bank's groups in bank-major order (bank 0 groups
    0..G-1, bank 1 groups 0..G-1, ...).  Each worker executes ALL its
    subtasks — possibly spanning several banks — as ONE fused multi-bank
    prefix-reuse launch, so K co-scheduled tenant banks cost per-worker
    launches instead of K x per-worker launches.  Returns per-bank flat
    fidelity vectors in bank order (``run(banks) -> [f_0, f_1, ...]``) —
    ``shift_rule.assemble_gradient`` consumes each unchanged.
    """
    import numpy as np

    assignment = np.asarray(assignment)

    def run(banks: Sequence[shift_rule.ShiftBank]) -> list:
        if len({b.four_term for b in banks}) > 1:
            raise ValueError("banks in one fused set must share four_term")
        flat = [(bi, g) for bi, b in enumerate(banks) for g in range(b.n_groups)]
        if len(assignment) != len(flat):
            raise ValueError(
                f"assignment must cover the bank set's {len(flat)} "
                f"(bank, group) subtasks, got {len(assignment)} entries"
            )
        grids = [[None] * b.n_groups for b in banks]
        for w in range(n_workers):
            subtasks = [flat[i] for i in np.flatnonzero(assignment == w)]
            if not subtasks:
                continue
            w_banks, group_sets, slots = [], [], []
            index: dict[int, int] = {}
            for bi, g in subtasks:
                k = index.get(bi)
                if k is None:
                    k = index[bi] = len(w_banks)
                    w_banks.append(bi)
                    group_sets.append([])
                slots.append((k, len(group_sets[k])))
                group_sets[k].append(g)
            outs = kops.vqc_fidelity_shiftgroups_multibank(
                spec,
                tuple(banks[bi].theta for bi in w_banks),
                tuple(banks[bi].data for bi in w_banks),
                banks[0].four_term,
                tuple(tuple(gs) for gs in group_sets),
            )
            for (bi, g), (k, i) in zip(subtasks, slots):
                grids[bi][g] = outs[k][i]
        return [jnp.stack(rows, 0).reshape(-1) for rows in grids]

    return declare(run, multibank=True)


def sharded_executor(spec: CircuitSpec, mesh: Mesh, axis: str = "data"):
    """Whole-bank shard_map executor over one mesh axis.

    Materialized banks: pads the bank to a multiple of the axis size, shards
    rows, runs the fused kernel per device, gathers results.  Lowerable with
    ShapeDtypeStructs for the dry-run.

    Implicit ``ShiftBank``s (``run(bank)``): SAMPLES are sharded instead of
    materialized rows — every device runs the prefix-reuse kernel over its
    sample shard and produces all (param, shift) groups for it; the gathered
    (n_groups, B) grid flattens back to bank order.
    """
    n_shards = mesh.shape[axis]

    def _local(theta, data):
        return kops.vqc_fidelity(spec, theta, data)

    shard_fn = _shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=P(axis),
        # the Pallas interpret-mode call inside produces ShapeDtypeStructs
        # without vma annotations; skip the varying-across-mesh check.
        **_SM_SKIP_CHECKS,
    )

    shift_fns: dict[bool, Callable] = {}

    def _shift_fn(four_term: bool):
        if four_term not in shift_fns:

            def _local_shift(theta, data):
                return kops.vqc_fidelity_shiftgroups(spec, theta, data, four_term)

            shift_fns[four_term] = _shard_map(
                _local_shift,
                mesh=mesh,
                in_specs=(P(axis, None), P(axis, None)),
                out_specs=P(None, axis),
                **_SM_SKIP_CHECKS,
            )
        return shift_fns[four_term]

    group_fns: dict[tuple, Callable] = {}

    def _group_fn(four_term: bool, groups: tuple):
        key = (four_term, groups)
        if key not in group_fns:

            def _local_groups(theta, data):
                return kops.vqc_fidelity_shiftgroups(
                    spec, theta, data, four_term, groups
                )

            group_fns[key] = _shard_map(
                _local_groups,
                mesh=mesh,
                in_specs=(P(axis, None), P(axis, None)),
                out_specs=P(None, axis),
                **_SM_SKIP_CHECKS,
            )
        return group_fns[key]

    def run(theta_bank, data_bank=None) -> jnp.ndarray:
        if isinstance(theta_bank, shift_rule.ShiftBank):
            bank = theta_bank
            b = bank.n_samples
            pad = (-b) % n_shards
            t = jnp.pad(bank.theta, ((0, pad), (0, 0)))
            d = jnp.pad(bank.data, ((0, pad), (0, 0)))
            out = _shift_fn(bank.four_term)(t, d)  # (n_groups, B+pad)
            return out[:, :b].reshape(-1)
        c = theta_bank.shape[0]
        pad = (-c) % n_shards
        t = jnp.pad(theta_bank, ((0, pad), (0, 0)))
        d = jnp.pad(data_bank, ((0, pad), (0, 0)))
        return shard_fn(t, d)[:c]

    def run_banks(thetas, datas, four_term: bool, group_sets: tuple):
        """Fused multi-bank launch SHARDED over the mesh: per-bank
        LANES-padded lane segments concatenate, the union group set runs on
        every device's lane shard, and per-bank blocks slice back out —
        the contract of ``kops.vqc_fidelity_shiftgroups_multibank`` with
        the device mesh as the executor (the dispatcher's mega-batch spill
        path)."""
        union = tuple(sorted({g for gs in group_sets for g in gs}))
        theta_cat, data_cat, segments = kops._pack_banks(thetas, datas)
        lanes = theta_cat.shape[0]
        pad = (-lanes) % n_shards
        theta_cat = jnp.pad(theta_cat, ((0, pad), (0, 0)))
        data_cat = jnp.pad(data_cat, ((0, pad), (0, 0)))
        out = jnp.clip(_group_fn(four_term, union)(theta_cat, data_cat), 0.0, 1.0)
        row = {g: i for i, g in enumerate(union)}
        return tuple(
            jnp.stack([out[row[g], off : off + b] for g in gs], axis=0)
            for (off, b), gs in zip(segments, group_sets)
        )

    run.run_banks = run_banks
    return declare(run, shiftbank=True, sharded=True)


class MeshSpillExecutor:
    """Whole-mesh escape hatch for mega-batches that fit no single worker.

    A coalesced batch too wide (qubit count above every worker's register
    capacity) or too deep (statevector tile over the per-worker VMEM model)
    is routed HERE instead of failing fast: row batches shard their lanes
    across the mesh's ``data`` axis, shift-group bank sets run the fused
    multi-bank kernel with lane segments sharded the same way.  Per-spec
    sharded executors are built lazily and cached — a long-lived dispatcher
    pays the shard_map trace once per circuit structure.
    """

    def __init__(self, mesh: Mesh | None = None, axis: str = "data"):
        if mesh is None:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh()
        self.mesh = mesh
        self.axis = axis
        self._per_spec: dict[CircuitSpec, Callable] = {}

    def _executor(self, spec: CircuitSpec):
        if spec not in self._per_spec:
            self._per_spec[spec] = sharded_executor(spec, self.mesh, self.axis)
        return self._per_spec[spec]

    def rows(self, spec: CircuitSpec, theta_bank, data_bank):
        """(C, P), (C, D) -> (C,) fidelities, lanes sharded over the mesh."""
        return self._executor(spec)(theta_bank, data_bank)

    def banks(
        self,
        spec: CircuitSpec,
        thetas,
        datas,
        four_term: bool,
        group_sets: tuple,
    ):
        """Fused multi-bank bank-set execution sharded over the mesh."""
        return self._executor(spec).run_banks(thetas, datas, four_term, group_sets)


def bank_shardings(mesh: Mesh, axis: str = "data"):
    """in_shardings for (theta_bank, data_bank) under pjit."""
    s = NamedSharding(mesh, P(axis, None))
    return (s, s)
