"""Data plane: really execute circuit banks, per co-Manager assignment.

Two execution paths:

* ``worker_batched_executor`` — groups the bank rows assigned to each worker
  and runs each group through the fused Pallas VQC kernel.  This is the
  faithful "each worker executes its circuits" path; on one host the groups
  run sequentially, on a pod each worker's group lands on its mesh slice.

* ``sharded_executor`` — the TPU-native whole-bank path: the bank is sharded
  over the mesh's ``data`` axis with ``shard_map`` and every device runs the
  kernel on its shard.  This is what the production launcher uses and what
  the multi-pod dry-run lowers.

Both return fidelities in bank order, so ``shift_rule.assemble_gradient``
consumes them identically — scheduling never changes the math (the accuracy
experiments in the paper rely on exactly this property).

Both executors also accept IMPLICIT ``shift_rule.ShiftBank``s (call
``run(bank)``): the schedulable unit then becomes the (param, shift) group
and execution goes through the prefix-reuse kernel — same bank-order
results, a fraction of the gate applications and angle traffic.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax.shard_map landed after 0.4.x (where it lives in experimental); the
# "skip varying-across-mesh checks" kwarg was renamed check_rep -> check_vma
# at a different point, so detect the kwarg itself, not just the symbol.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map
import inspect as _inspect

_SM_SKIP_CHECKS = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else {"check_rep": False})

from repro.core import shift_rule
from repro.core.sim import CircuitSpec
from repro.kernels import ops as kops


def worker_batched_executor(spec: CircuitSpec, assignment: Sequence[int],
                            n_workers: int):
    """Executor that mimics per-worker execution.

    Materialized banks: ``assignment[i] = worker index for bank row i``.
    Rows are grouped per worker and executed as one fused-kernel batch each;
    results come back in bank order via ONE inverse-permutation gather
    (rather than a per-worker scatter loop of ``out.at[rows].set``, which
    built n_workers intermediate arrays).

    Implicit ``ShiftBank``s (``run(bank)``): the schedulable unit becomes the
    (param, shift) GROUP — ``assignment[g] = worker index for bank group g``
    (length ``bank.n_groups``) — and each worker executes its groups as one
    prefix-reuse kernel call over the whole sample batch, so the co-Manager
    distributes suffix-replay subtasks instead of materialized rows.
    """
    import numpy as np
    assignment = np.asarray(assignment)
    # stable grouping permutation: rows sorted by worker, ties in bank order,
    # so each worker's group preserves its clients' submission order.
    order = np.argsort(assignment, kind="stable")
    inverse = np.argsort(order, kind="stable")
    bounds = np.searchsorted(assignment[order], np.arange(n_workers + 1))
    inverse_j = jnp.asarray(inverse)

    def _run_rows(theta_bank: jnp.ndarray, data_bank: jnp.ndarray) -> jnp.ndarray:
        groups = []
        for w in range(n_workers):
            rows = order[bounds[w]:bounds[w + 1]]
            if rows.size == 0:
                continue
            groups.append(kops.vqc_fidelity(spec, theta_bank[rows],
                                            data_bank[rows]))
        return jnp.concatenate(groups)[inverse_j]

    def _run_shiftbank(bank: shift_rule.ShiftBank) -> jnp.ndarray:
        if len(assignment) != bank.n_groups:
            if len(assignment) == bank.n_circuits:
                # per-ROW assignment (legacy scheduling granularity): honor it
                # exactly by materializing — same per-worker row placement.
                mat = bank.materialize()
                return _run_rows(mat.theta, mat.data)
            raise ValueError(
                f"assignment must cover the bank's {bank.n_groups} groups or "
                f"{bank.n_circuits} rows, got {len(assignment)} entries")
        outs = []
        for w in range(n_workers):
            grp = order[bounds[w]:bounds[w + 1]]
            if grp.size == 0:
                continue
            outs.append(kops.vqc_fidelity_shiftgroups(
                spec, bank.theta, bank.data, bank.four_term,
                tuple(int(g) for g in grp)))
        stacked = jnp.concatenate(outs, 0)[inverse_j]    # (n_groups, B)
        return stacked.reshape(-1)

    def run(theta_bank, data_bank=None):
        if isinstance(theta_bank, shift_rule.ShiftBank):
            return _run_shiftbank(theta_bank)
        return _run_rows(theta_bank, data_bank)

    run.accepts_shiftbank = True
    return run


def round_robin_assignment(n_circuits: int, n_workers: int):
    """The degenerate scheduler baseline (no co-management).

    Also the group-assignment baseline for implicit banks (pass
    ``n_circuits = bank.n_groups``)."""
    return [i % n_workers for i in range(n_circuits)]


def worker_pool_executor(spec: CircuitSpec, assignment: Sequence[int],
                         n_workers: int, max_threads: int | None = None):
    """``worker_batched_executor`` with OVERLAPPING per-worker execution.

    The sequential executor runs each worker's group one after another on
    the host — faithful to one device, but on a multi-worker host (or with
    XLA releasing the GIL during kernel execution) the groups can run
    concurrently, exactly like the async dispatcher's one-slot-per-worker
    pool.  Each worker's group is submitted to a thread pool; results gather
    in bank order, so gradients are bit-identical to the sequential path
    (scheduling never changes the math).

    Call ``run.close()`` to shut the pool down when the executor is retired
    (threads are created on demand, so an unused executor costs nothing).
    """
    import numpy as np
    from concurrent.futures import ThreadPoolExecutor

    assignment = np.asarray(assignment)
    order = np.argsort(assignment, kind="stable")
    inverse = np.argsort(order, kind="stable")
    bounds = np.searchsorted(assignment[order], np.arange(n_workers + 1))
    inverse_j = jnp.asarray(inverse)
    pool = ThreadPoolExecutor(max_workers=max_threads or n_workers,
                              thread_name_prefix="dataplane-worker")

    def _groups():
        for w in range(n_workers):
            rows = order[bounds[w]:bounds[w + 1]]
            if rows.size:
                yield w, rows

    def run(theta_bank, data_bank=None) -> jnp.ndarray:
        if isinstance(theta_bank, shift_rule.ShiftBank):
            bank = theta_bank
            if len(assignment) != bank.n_groups:
                if len(assignment) == bank.n_circuits:
                    # per-ROW assignment (legacy granularity): honor it by
                    # materializing, same as worker_batched_executor.
                    mat = bank.materialize()
                    return run(mat.theta, mat.data)
                raise ValueError(
                    f"assignment must cover the bank's {bank.n_groups} "
                    f"groups or {bank.n_circuits} rows, got "
                    f"{len(assignment)} entries")
            futs = [pool.submit(kops.vqc_fidelity_shiftgroups, spec,
                                bank.theta, bank.data, bank.four_term,
                                tuple(int(g) for g in rows))
                    for _, rows in _groups()]
            stacked = jnp.concatenate([f.result() for f in futs], 0)
            return stacked[inverse_j].reshape(-1)
        futs = [pool.submit(kops.vqc_fidelity, spec, theta_bank[rows],
                            data_bank[rows])
                for _, rows in _groups()]
        return jnp.concatenate([f.result() for f in futs])[inverse_j]

    run.accepts_shiftbank = True
    run.close = lambda: pool.shutdown(wait=True)
    return run


def sharded_executor(spec: CircuitSpec, mesh: Mesh, axis: str = "data"):
    """Whole-bank shard_map executor over one mesh axis.

    Materialized banks: pads the bank to a multiple of the axis size, shards
    rows, runs the fused kernel per device, gathers results.  Lowerable with
    ShapeDtypeStructs for the dry-run.

    Implicit ``ShiftBank``s (``run(bank)``): SAMPLES are sharded instead of
    materialized rows — every device runs the prefix-reuse kernel over its
    sample shard and produces all (param, shift) groups for it; the gathered
    (n_groups, B) grid flattens back to bank order.
    """
    n_shards = mesh.shape[axis]

    def _local(theta, data):
        return kops.vqc_fidelity(spec, theta, data)

    shard_fn = _shard_map(
        _local, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=P(axis),
        # the Pallas interpret-mode call inside produces ShapeDtypeStructs
        # without vma annotations; skip the varying-across-mesh check.
        **_SM_SKIP_CHECKS,
    )

    shift_fns: dict[bool, Callable] = {}

    def _shift_fn(four_term: bool):
        if four_term not in shift_fns:
            def _local_shift(theta, data):
                return kops.vqc_fidelity_shiftgroups(spec, theta, data,
                                                     four_term)
            shift_fns[four_term] = _shard_map(
                _local_shift, mesh=mesh,
                in_specs=(P(axis, None), P(axis, None)),
                out_specs=P(None, axis),
                **_SM_SKIP_CHECKS,
            )
        return shift_fns[four_term]

    def run(theta_bank, data_bank=None) -> jnp.ndarray:
        if isinstance(theta_bank, shift_rule.ShiftBank):
            bank = theta_bank
            b = bank.n_samples
            pad = (-b) % n_shards
            t = jnp.pad(bank.theta, ((0, pad), (0, 0)))
            d = jnp.pad(bank.data, ((0, pad), (0, 0)))
            out = _shift_fn(bank.four_term)(t, d)        # (n_groups, B+pad)
            return out[:, :b].reshape(-1)
        c = theta_bank.shape[0]
        pad = (-c) % n_shards
        t = jnp.pad(theta_bank, ((0, pad), (0, 0)))
        d = jnp.pad(data_bank, ((0, pad), (0, 0)))
        return shard_fn(t, d)[:c]

    run.accepts_shiftbank = True
    return run


def bank_shardings(mesh: Mesh, axis: str = "data"):
    """in_shardings for (theta_bank, data_bank) under pjit."""
    s = NamedSharding(mesh, P(axis, None))
    return (s, s)
