"""Trip-count-aware static analysis of optimized HLO text.

WHY: ``compiled.cost_analysis()`` counts a ``while`` body ONCE, regardless of
trip count (verified empirically: a scan of 10 matmuls reports the flops of
one).  Every model here scans over layer periods (and the train step scans
over microbatches), so raw cost_analysis under-reports flops/bytes/collective
traffic by 1-3 orders of magnitude.  This module parses ``compiled.as_text()``
into computations, extracts while-loop trip counts from their condition
computations, and walks the call graph multiplying costs by trip counts.

Cost model per instruction (HBM-level, fusion-aware):
  flops       : dot/convolution = 2 * prod(output_shape) * contraction size
                (counted INSIDE fused computations too — XLA fuses dots into
                output fusions);
  bytes       : for a top-level instruction, output bytes + operand bytes.
                A ``fusion`` op counts only its operands + outputs (fused
                interiors never touch HBM — that is what fusion means).
                parameter/constant/gte/tuple/bitcast count zero.
  collectives : output bytes of all-gather / all-reduce / reduce-scatter /
                all-to-all / collective-permute(+ -start variants), attributed
                to the computation they appear in (so loop collectives get
                multiplied by trip count).

This is a static-analysis approximation of XLA's own cost model, NOT a
simulator; its purpose is relative roofline terms, and it is validated
against hand-computable modules in tests/test_hlo_analyzer.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")

#: ops that move no HBM bytes themselves
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    shape_str: str            # full result type string (may be a tuple)
    operands: list
    raw: str

    def out_bytes(self) -> int:
        return shape_bytes(self.shape_str)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: dict
    root: Optional[str] = None

    def instr(self, name: str) -> Optional[Instr]:
        return self.instrs.get(name)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.coll_bytes * m,
                    {k: v * m for k, v in self.coll_by_kind.items()})


def shape_bytes(shape_str: str) -> int:
    """Total bytes of every typed array in a (possibly tuple) type string."""
    total = 0
    for m in _SHAPE_TOKEN.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_TOKEN.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


# --------------------------------------------------------------- parsing
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*{\s*$")
_INSTR_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE = re.compile(r"\s*([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w.\-]+)")


def _split_type_opcode(rest: str):
    """``rest`` starts at the result type.  Returns (type_str, opcode, tail)
    or None.  Handles tuple types with nested parens/braces and embedded
    ``/*index=N*/`` comments, and scalar types like ``bf16[2,3]{1,0}``."""
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rest[:i + 1]
                    m = _OPCODE.match(rest[i + 1:])
                    if not m:
                        return None
                    tail_start = i + 1 + m.end()
                    return type_str, m.group(1), rest[tail_start:]
        return None
    # scalar/array type: ends at whitespace not inside brackets
    depth = 0
    for i, ch in enumerate(rest):
        if ch in "[{":
            depth += 1
        elif ch in "]}":
            depth -= 1
        elif ch == " " and depth == 0:
            type_str = rest[:i]
            m = _OPCODE.match(rest[i:])
            if not m:
                return None
            return type_str, m.group(1), rest[i + m.end():]
    return None


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if not stripped:
            continue
        # computation headers have no " = " before the arrow (instruction
        # lines do); tuple params may contain /*index=N*/ comments, so test
        # for the spaced form only.
        if (stripped.endswith("{") and "->" in stripped
                and " = " not in stripped.split("->")[0]):
            m = _COMP_HDR.match(stripped.strip())
            if m:
                cur = Computation(m.group(1), {})
                comps[cur.name] = cur
                continue
        if stripped.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_HEAD.match(stripped)
        if not m:
            continue
        name = m.group(1)
        parts = _split_type_opcode(stripped[m.end():])
        if parts is None:
            continue
        shape_str, opcode, tail = parts
        # operand names: up to the closing paren of the operand list
        depth, end = 1, len(tail)
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        ops = _OPERAND.findall(tail[:end])
        inst = Instr(name, opcode, shape_str, ops, stripped)
        cur.instrs[name] = inst
        if stripped.lstrip().startswith("ROOT"):
            cur.root = name
    return comps


_CALLED = re.compile(r"(?:condition|body|to_apply|calls|branch_computations)="
                     r"(?:{([^}]*)}|%?([\w.\-]+))")
_TRIP_CONST = re.compile(r"constant\((\d+)\)")


def called_computations(instr: Instr) -> list[str]:
    out = []
    for m in _CALLED.finditer(instr.raw):
        if m.group(1) is not None:
            out += [x.strip().lstrip("%") for x in m.group(1).split(",")]
        else:
            out.append(m.group(2))
    return out


def while_parts(instr: Instr) -> tuple[Optional[str], Optional[str]]:
    cond = re.search(r"condition=%?([\w.\-]+)", instr.raw)
    body = re.search(r"body=%?([\w.\-]+)", instr.raw)
    return (cond.group(1) if cond else None, body.group(1) if body else None)


_KNOWN_TRIPS = re.compile(r'"known_trip_count":{"n":"(\d+)"}')


def trip_count(comps: dict, cond_name: str,
               while_instr: Optional[Instr] = None) -> int:
    """Loop bound: prefer the compiler's own ``known_trip_count`` backend
    config on the while op; fall back to the largest integer constant in the
    condition computation (scan lowers to ``compare(%induction, %constant),
    direction=LT`` with init 0, step 1)."""
    if while_instr is not None:
        m = _KNOWN_TRIPS.search(while_instr.raw)
        if m:
            return int(m.group(1))
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    consts = []
    for inst in comp.instrs.values():
        for m in _TRIP_CONST.finditer(inst.raw):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


# ------------------------------------------------------------- cost walk
def dot_flops(instr: Instr, comp: Computation, comps: dict) -> float:
    """2 * prod(out) * contracted size.  Contracted size from an operand's
    shape and the lhs_contracting_dims annotation."""
    out_dims = shape_dims(instr.shape_str)
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", instr.raw)
    lhs = _operand_shape(instr, 0, comp, comps)
    if m is None or lhs is None:
        return 2.0 * _prod(out_dims)
    contract = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(lhs):
            contract *= lhs[int(d)]
    # batch dims are shared between out and lhs; out already includes them
    return 2.0 * _prod(out_dims) * contract


def _prod(dims) -> float:
    p = 1.0
    for d in dims:
        p *= d
    return p


def _operand_shape(instr: Instr, idx: int, comp: Computation, comps: dict):
    if idx >= len(instr.operands):
        return None
    name = instr.operands[idx]
    target = comp.instr(name)
    if target is None:
        return None
    return shape_dims(target.shape_str)


def operand_bytes(instr: Instr, comp: Computation) -> int:
    total = 0
    for name in instr.operands:
        t = comp.instr(name)
        if t is not None:
            total += shape_bytes(t.shape_str)
    return total


def _flops_in_fusion(comp: Computation, comps: dict) -> float:
    f = 0.0
    for inst in comp.instrs.values():
        if inst.opcode in ("dot", "convolution"):
            f += dot_flops(inst, comp, comps)
        elif inst.opcode == "fusion":
            for c in called_computations(inst):
                if c in comps:
                    f += _flops_in_fusion(comps[c], comps)
    return f


def computation_cost(comps: dict, name: str, memo: dict) -> Cost:
    if name in memo:
        return memo[name]
    comp = comps[name]
    cost = Cost()
    for inst in comp.instrs.values():
        op = inst.opcode
        if op in _FREE_OPS:
            continue
        if op == "while":
            cond, body = while_parts(inst)
            trips = trip_count(comps, cond, inst) if cond else 1
            if body in comps:
                cost += computation_cost(comps, body, memo).scaled(trips)
            if cond in comps:
                cost += computation_cost(comps, cond, memo).scaled(trips)
            continue
        if op in ("conditional",):
            # count the most expensive branch once
            branches = [computation_cost(comps, c, memo)
                        for c in called_computations(inst) if c in comps]
            if branches:
                cost += max(branches, key=lambda c: c.flops + c.bytes)
            continue
        if op in ("call", "custom-call") :
            for c in called_computations(inst):
                if c in comps:
                    cost += computation_cost(comps, c, memo)
            cost.bytes += inst.out_bytes() + operand_bytes(inst, comp)
            continue
        if op == "fusion":
            dus_root = False
            for c in called_computations(inst):
                if c in comps:
                    cost.flops += _flops_in_fusion(comps[c], comps)
                    root = comps[c].instrs.get(comps[c].root or "")
                    if root is not None and root.opcode == "dynamic-update-slice":
                        dus_root = True
            if dus_root:
                # in-place scatter into a carried buffer (scan stacking):
                # the big buffer is aliased, traffic = the small operands
                # (the update slice) read + written, NOT the whole buffer.
                ob = [shape_bytes(comp.instrs[o].shape_str)
                      for o in inst.operands if o in comp.instrs]
                cost.bytes += 2 * (sum(ob) - max(ob)) if ob else 0
            else:
                cost.bytes += inst.out_bytes() + operand_bytes(inst, comp)
            continue

        base = op[:-len("-start")] if op.endswith("-start") else op
        if base in COLL_KINDS:
            b = inst.out_bytes()
            cost.coll_bytes += b
            cost.coll_by_kind[base] = cost.coll_by_kind.get(base, 0.0) + b
            cost.bytes += b + operand_bytes(inst, comp)
            continue
        if base.endswith("-done") or base in ("copy-start", "copy-done"):
            continue
        if op == "dynamic-update-slice":
            # in-place: read + write the update slice only (operand 1)
            upd = (shape_bytes(comp.instrs[inst.operands[1]].shape_str)
                   if len(inst.operands) > 1 and inst.operands[1] in comp.instrs
                   else inst.out_bytes())
            cost.bytes += 2 * upd
            continue
        if op in ("dot", "convolution"):
            cost.flops += dot_flops(inst, comp, comps)
        cost.bytes += inst.out_bytes() + operand_bytes(inst, comp)
    memo[name] = cost
    return cost


def analyze(hlo: str) -> Cost:
    """Whole-module cost, trip-count aware, starting from ENTRY."""
    comps = parse_module(hlo)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: computation named like main
        entry = next((n for n in comps if "main" in n), None)
    if entry is None:
        raise ValueError("could not find ENTRY computation")
    # computations reachable via fusions shouldn't be double counted; the
    # memoized walk only follows explicit calls from ENTRY.
    return computation_cost(comps, entry, {})
