"""Static 'profile' of a saved dry-run module: the top flops / bytes /
collective contributors, trip-count weighted — the §Perf iteration loop
reads this instead of a wall-clock trace (CPU container, TPU target).

Usage:
  PYTHONPATH=src python -m repro.roofline.profile_hlo \
      experiments/dryrun/smollm-360m__prefill_32k__16x16.hlo.gz [--top 15]
"""
from __future__ import annotations

import argparse
import gzip
import re

from repro.roofline import hlo_analyzer as H


def instruction_costs(hlo: str):
    """Yield (flops, bytes, coll_bytes, trips, computation, instr) rows."""
    comps = H.parse_module(hlo)
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
    entry = m.group(1)

    # multiplier per computation = product of trip counts on the call path
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop(0)
        comp = comps[name]
        for inst in comp.instrs.values():
            if inst.opcode == "while":
                cond, body = H.while_parts(inst)
                trips = H.trip_count(comps, cond, inst) if cond else 1
                for c in (body, cond):
                    if c in comps:
                        mult[c] = mult.get(c, 0.0) + mult[name] * trips
                        if c not in seen:
                            seen.add(c)
                            order.append(c)
            elif inst.opcode in ("call", "custom-call", "conditional"):
                for c in H.called_computations(inst):
                    if c in comps:
                        mult[c] = mult.get(c, 0.0) + mult[name]
                        if c not in seen:
                            seen.add(c)
                            order.append(c)

    rows = []
    for name, m_ in mult.items():
        comp = comps[name]
        for inst in comp.instrs.values():
            op = inst.opcode
            if op in H._FREE_OPS or op in ("while", "call", "conditional"):
                continue
            flops = bytes_ = coll = 0.0
            if op == "fusion":
                for c in H.called_computations(inst):
                    if c in comps:
                        flops += H._flops_in_fusion(comps[c], comps)
                dus = any(
                    comps[c].instrs.get(comps[c].root or "") is not None
                    and comps[c].instrs[comps[c].root].opcode == "dynamic-update-slice"
                    for c in H.called_computations(inst) if c in comps)
                if dus:
                    ob = [H.shape_bytes(comp.instrs[o].shape_str)
                          for o in inst.operands if o in comp.instrs]
                    bytes_ = 2 * (sum(ob) - max(ob)) if ob else 0
                else:
                    bytes_ = inst.out_bytes() + H.operand_bytes(inst, comp)
            elif op == "dynamic-update-slice":
                upd = (H.shape_bytes(comp.instrs[inst.operands[1]].shape_str)
                       if len(inst.operands) > 1 and inst.operands[1] in comp.instrs
                       else inst.out_bytes())
                bytes_ = 2 * upd
            else:
                base = op[:-6] if op.endswith("-start") else op
                if base in H.COLL_KINDS:
                    coll = inst.out_bytes()
                    bytes_ = coll + H.operand_bytes(inst, comp)
                elif base.endswith("-done") or base in ("copy-start", "copy-done"):
                    continue
                else:
                    if op in ("dot", "convolution"):
                        flops = H.dot_flops(inst, comp, comps)
                    bytes_ = inst.out_bytes() + H.operand_bytes(inst, comp)
            rows.append((flops * m_, bytes_ * m_, coll * m_, m_, name, inst))
    return rows


def describe(inst: H.Instr) -> str:
    meta = re.search(r'op_name="([^"]+)"', inst.raw)
    src = meta.group(1) if meta else ""
    return f"{inst.opcode:22s} {inst.shape_str[:46]:46s} {src[:70]}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--sort", choices=("bytes", "flops", "coll"), default="bytes")
    args = ap.parse_args()
    opener = gzip.open if args.path.endswith(".gz") else open
    with opener(args.path, "rt") as f:
        hlo = f.read()
    rows = instruction_costs(hlo)
    key = {"flops": 0, "bytes": 1, "coll": 2}[args.sort]
    rows.sort(key=lambda r: -r[key])
    tot = [sum(r[i] for r in rows) for i in range(3)]
    print(f"total: {tot[0]:.3e} flops, {tot[1]:.3e} bytes, {tot[2]:.3e} coll bytes")
    print(f"{'flops':>10s} {'bytes':>10s} {'coll':>10s} {'xtrips':>7s}  instruction")
    for fl, by, co, m_, comp, inst in rows[: args.top]:
        print(f"{fl:10.2e} {by:10.2e} {co:10.2e} {m_:7.0f}  {describe(inst)}")


if __name__ == "__main__":
    main()
