"""Re-run the HLO static analysis over saved dry-run artifacts (*.hlo.gz)
and refresh the corrected fields of the matching *.json records — lets the
analyzer iterate without re-compiling 80 modules.

Usage:  PYTHONPATH=src python -m repro.roofline.reanalyze [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.roofline import hlo_analyzer

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def reanalyze(mesh: str | None = None) -> int:
    pat = f"*__{mesh}.hlo.gz" if mesh else "*.hlo.gz"
    n = 0
    for hlo_path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pat))):
        json_path = hlo_path[: -len(".hlo.gz")] + ".json"
        if not os.path.exists(json_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            cost = hlo_analyzer.analyze(f.read())
        with open(json_path) as f:
            rec = json.load(f)
        rec["flops_per_device"] = cost.flops
        rec["bytes_accessed_per_device"] = cost.bytes
        rec["collective_bytes_per_device"] = cost.coll_bytes
        rec["collectives"] = {k: int(v) for k, v in cost.coll_by_kind.items()}
        with open(json_path, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
        print(f"[reanalyze] {os.path.basename(json_path)}: "
              f"flops {cost.flops:.2e}  bytes {cost.bytes:.2e}  "
              f"coll {cost.coll_bytes:.2e}")
    return n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    n = reanalyze(args.mesh)
    print(f"re-analyzed {n} records")


if __name__ == "__main__":
    main()
