"""Render the §Roofline table from experiments/dryrun/*.json.

Usage:  PYTHONPATH=src python -m repro.roofline.report [--mesh 16x16] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.roofline import analysis

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")

SHAPE_TOKENS = {"train_4k": ("train", 256 * 4096),
                "prefill_32k": ("prefill", 32 * 32768),
                "decode_32k": ("decode", 128),
                "long_500k": ("decode", 1)}


def load_records(mesh: str = "16x16") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def active_params(rec: dict) -> int:
    """Active-per-token params: from config when MoE, else total."""
    from repro.configs import base as cfg_base
    from repro.models import transformer
    import jax
    cfg = cfg_base.get(rec["arch"])
    model = transformer.Model(cfg)
    shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    return transformer.active_param_count(cfg, shapes)


def rows(mesh: str = "16x16", with_model_flops: bool = True) -> list[dict]:
    cache: dict[str, int] = {}
    out = []
    for rec in load_records(mesh):
        t = analysis.roofline_terms(rec)
        kind, n_tokens = SHAPE_TOKENS[rec["shape"]]
        row = {
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_ms": t["compute_s"] * 1e3,
            "memory_ms": t["memory_s"] * 1e3,
            "collective_ms": t["collective_s"] * 1e3,
            "dominant": t["dominant"],
            "hbm_gb_per_dev": rec["memory"]["temp_size_bytes"] / 1e9,
        }
        if with_model_flops:
            if rec["arch"] not in cache:
                cache[rec["arch"]] = active_params(rec)
            mf = analysis.model_flops(cache[rec["arch"]], n_tokens, kind)
            total_hlo = rec["flops_per_device"] * rec["chips"]
            row["model_flops"] = mf
            row["useful_ratio"] = mf / total_hlo if total_hlo else 0.0
        out.append(row)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", action="store_true", help="markdown table")
    args = ap.parse_args()
    table = rows(args.mesh)
    if args.md:
        print("| arch | shape | compute ms | memory ms | collective ms | "
              "dominant | HBM GB/dev | useful FLOP ratio |")
        print("|---|---|---|---|---|---|---|---|")
        for r in table:
            print(f"| {r['arch']} | {r['shape']} | {r['compute_ms']:.2f} | "
                  f"{r['memory_ms']:.2f} | {r['collective_ms']:.2f} | "
                  f"**{r['dominant']}** | {r['hbm_gb_per_dev']:.2f} | "
                  f"{r.get('useful_ratio', 0):.2f} |")
    else:
        hdr = ("arch", "shape", "compute_ms", "memory_ms", "collective_ms",
               "dominant", "hbm_gb_per_dev", "useful_ratio")
        print(",".join(hdr))
        for r in table:
            print(",".join(f"{r.get(k, '')}" if not isinstance(r.get(k), float)
                           else f"{r[k]:.3f}" for k in hdr))


if __name__ == "__main__":
    main()
