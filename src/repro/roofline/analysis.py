"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all PER-DEVICE seconds (XLA's
cost_analysis on the SPMD-partitioned module reports per-device numbers):

    compute_s    = flops_per_device / PEAK_FLOPS
    memory_s     = bytes_accessed_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW

Hardware constants (TPU v5e, per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

``collective_bytes`` parses the optimized HLO text: sums the output-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (cost_analysis does not attribute collective traffic).
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# matches e.g.:  %all-gather.5 = bf16[8,4096,1152]{2,1,0} all-gather(
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in optimized HLO text."""
    by_kind: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    counts: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for m in _OP_RE.finditer(hlo_text):
        tuple_shapes, single_shape, kind = m.group(1), m.group(2), m.group(3)
        shape_str = tuple_shapes if tuple_shapes is not None else single_shape
        b = _shape_bytes(shape_str)
        by_kind[kind] += b
        counts[kind] += 1
    return {"total": int(sum(by_kind.values())),
            "by_kind": {k: int(v) for k, v in by_kind.items() if v},
            "counts": {k: v for k, v in counts.items() if v}}


def roofline_terms(record: dict) -> dict:
    """record = dryrun JSON.  Returns the 3 terms + dominant + ratios."""
    compute_s = record["flops_per_device"] / PEAK_FLOPS
    memory_s = record["bytes_accessed_per_device"] / HBM_BW
    collective_s = record["collective_bytes_per_device"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get).replace("_s", "")
    bound_s = max(compute_s, memory_s, collective_s)
    return {**terms, "dominant": dominant, "bound_s": bound_s,
            "compute_fraction_of_bound": compute_s / bound_s if bound_s else 0.0}


def model_flops(n_params_active: int, n_tokens: int, kind: str) -> float:
    """6*N*D for training; 2*N*D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * n_tokens


def useful_compute_ratio(record: dict, n_params_active: int, n_tokens: int,
                         kind: str, chips: int) -> float:
    """MODEL_FLOPS / total compiled HLO FLOPs — catches remat/redundancy."""
    total_hlo = record["flops_per_device"] * chips
    if total_hlo <= 0:
        return 0.0
    return model_flops(n_params_active, n_tokens, kind) / total_hlo
