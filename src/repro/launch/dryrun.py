import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax import: the dry-run (and ONLY
#   the dry-run) builds the production meshes out of 512 host placeholders.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination with ShapeDtypeStruct inputs (no allocation), record
memory_analysis / cost_analysis / collective bytes for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import base as cfg_base
from repro.launch import partition, steps
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.sharding import axis_binding
from repro.roofline import analysis as roofline

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


# --------------------------------------------------------------- input specs
def cfg_for_shape(cfg, shape: cfg_base.InputShape):
    """Shape-conditioned config tweaks (documented in DESIGN.md §4):

    * long_500k on pure-attention archs -> sliding-window (8192) variant —
      the sub-quadratic requirement; SSM/hybrid run native; MLA keeps its
      full compressed-latent cache (linear memory).
    * decode shapes on MoE archs keep standard capacity routing.
    """
    if shape.name == "long_500k":
        has_ssm = any(k != "attn" for k in cfg.pattern)
        if cfg.mla is None and not (has_ssm and "attn" not in cfg.pattern):
            if cfg.sliding_window == 0:
                cfg = cfg.with_(sliding_window=8192)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, batch: int, seq_len: int) -> dict:
    if cfg.n_codebooks:
        return {"codes": _sds((batch, seq_len, cfg.n_codebooks), jnp.int32)}
    if cfg.n_prefix_embeds:
        return {"image_embeds": _sds((batch, cfg.n_prefix_embeds,
                                      cfg.prefix_embed_dim), jnp.float32),
                "tokens": _sds((batch, seq_len - cfg.n_prefix_embeds), jnp.int32)}
    return {"tokens": _sds((batch, seq_len), jnp.int32)}


def decode_specs(cfg, batch: int) -> dict:
    if cfg.n_codebooks:
        return {"codes": _sds((batch, 1, cfg.n_codebooks), jnp.int32)}
    return {"tokens": _sds((batch, 1), jnp.int32)}


def input_specs(arch: str, shape_name: str):
    """Public entry: ShapeDtypeStruct stand-ins for every model input."""
    cfg = cfg_for_shape(cfg_base.get(arch), cfg_base.INPUT_SHAPES[shape_name])
    shape = cfg_base.INPUT_SHAPES[shape_name]
    model = transformer.Model(cfg)
    if shape.kind in ("train", "prefill"):
        return {"batch": batch_specs(cfg, shape.global_batch, shape.seq_len)}
    caches = jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, shape.seq_len))
    return {"batch": decode_specs(cfg, shape.global_batch),
            "caches": caches,
            "pos": _sds((), jnp.int32)}


# ------------------------------------------------------------------ lowering
def lower_one(arch: str, shape_name: str, multi_pod: bool,
              overrides: dict | None = None):
    shape = cfg_base.INPUT_SHAPES[shape_name]
    cfg = cfg_for_shape(cfg_base.get(arch), shape)
    if overrides:
        cfg = cfg.with_(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    part = partition.Partitioner(mesh)
    binding = partition.logical_binding(mesh)
    model = transformer.Model(cfg)

    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(model.init_params, key)
    p_sh = part.param_shardings(params_shapes)

    if shape.kind == "train":
        train_step, optimizer, _ = steps.make_train_step(
            cfg, global_batch=shape.global_batch)
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        o_sh = part.opt_shardings(opt_shapes, params_shapes)
        b = batch_specs(cfg, shape.global_batch, shape.seq_len)
        b_sh = part.batch_shardings(b)
        with axis_binding(**binding):
            jitted = jax.jit(train_step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, part.replicated()),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shapes, opt_shapes, b)
    elif shape.kind == "prefill":
        prefill_step, _ = steps.make_prefill_step(cfg)
        b = batch_specs(cfg, shape.global_batch, shape.seq_len)
        b_sh = part.batch_shardings(b)
        with axis_binding(**binding):
            jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_shapes, b)
    else:  # decode
        serve_step, _ = steps.make_serve_step(cfg)
        b = decode_specs(cfg, shape.global_batch)
        caches = jax.eval_shape(
            lambda: model.init_caches(shape.global_batch, shape.seq_len))
        b_sh = part.batch_shardings(b)
        c_sh = part.cache_shardings(caches)
        with axis_binding(**binding):
            jitted = jax.jit(serve_step,
                             in_shardings=(p_sh, b_sh, c_sh, part.replicated()),
                             out_shardings=(part.replicated(), c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_shapes, b, caches,
                                   _sds((), jnp.int32))
    return lowered, cfg, params_shapes, mesh


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
            overrides: dict | None = None, variant: str = ""):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + (f"__{variant}" if variant else "")
    t0 = time.time()
    lowered, cfg, params_shapes, mesh = lower_one(arch, shape_name, multi_pod,
                                                  overrides)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = roofline.collective_bytes(hlo)
    # trip-count-aware static analysis: raw cost_analysis counts while bodies
    # ONCE (scan-over-layers + microbatch scan => up to 3 orders of magnitude
    # undercount); hlo_analyzer multiplies by known_trip_count.
    from repro.roofline import hlo_analyzer
    corrected = hlo_analyzer.analyze(hlo)

    n_chips = mesh.devices.size
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # trip-count-corrected, per device (partitioned-module shapes)
        "flops_per_device": corrected.flops,
        "bytes_accessed_per_device": corrected.bytes,
        "collective_bytes_per_device": corrected.coll_bytes,
        "collectives": {k: int(v) for k, v in corrected.coll_by_kind.items()},
        # raw XLA numbers (loop bodies counted once) for reference
        "raw_cost_analysis": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "collective_bytes_body_once": coll["total"],
        },
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "param_count": transformer.param_count(params_shapes),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{tag}.json"), "w") as f:
        json.dump(record, f, indent=1)
    with gzip.open(os.path.join(RESULTS_DIR, f"{tag}.hlo.gz"), "wt") as f:
        f.write(hlo)      # kept for §Perf iteration (collective inspection)
    if verbose:
        terms = roofline.roofline_terms(record)
        print(f"[dryrun] {tag}: compile {t_compile:.0f}s  "
              f"mem(temp) {record['memory']['temp_size_bytes']/1e9:.2f}GB  "
              f"compute {terms['compute_s']*1e3:.2f}ms  "
              f"memory {terms['memory_s']*1e3:.2f}ms  "
              f"collective {terms['collective_s']*1e3:.2f}ms  "
              f"-> {terms['dominant']}")
    return record


ALL_ARCHS = (
    "nemotron-4-340b", "phi-3-vision-4.2b", "granite-34b", "smollm-360m",
    "qwen3-4b", "granite-moe-3b-a800m", "musicgen-large", "xlstm-125m",
    "jamba-v0.1-52b", "deepseek-v3-671b",
)
ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    combos = []
    archs = ALL_ARCHS if (args.all or not args.arch) else (args.arch,)
    shapes = ALL_SHAPES if (args.all or not args.shape) else (args.shape,)
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]
    if args.multi_pod and not args.all:
        meshes = [True]
    for a in archs:
        for s in shapes:
            for m in meshes:
                combos.append((a, s, m))

    failures = []
    for a, s, m in combos:
        mesh_name = "2x16x16" if m else "16x16"
        out = os.path.join(RESULTS_DIR, f"{a}__{s}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(out):
            print(f"[dryrun] skip existing {a}__{s}__{mesh_name}")
            continue
        try:
            run_one(a, s, m)
        except Exception as e:  # noqa
            failures.append((a, s, mesh_name, repr(e)))
            print(f"[dryrun] FAIL {a}__{s}__{mesh_name}: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(combos)} dry-run combos compiled OK")


if __name__ == "__main__":
    main()
