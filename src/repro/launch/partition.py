"""Sharding rules: param/optimizer/batch/cache pytrees -> NamedShardings.

Baseline policy (§Perf iterates on this):
  * batch axis of inputs/activations -> ("pod", "data")      [data parallel]
  * weight matrices -> 2-D sharded: last dim over "model" (tensor parallel),
    second-to-last over "data" (FSDP-style) when divisible — this is what
    lets 340B/671B parameter + optimizer state fit 16 GB/chip.
  * MoE expert banks (L, E, in, out): E over "model" (expert parallel),
    `in` over "data".
  * small vectors (norms, biases) replicated.
  * decode caches: batch over ("pod","data") when divisible, else the cache
    LENGTH axis over "data" (context parallelism for long_500k's batch=1).

Divisibility is checked against the actual mesh; anything non-divisible is
left unsharded on that axis (correct, just less parallel).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes


def _divides(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


class Partitioner:
    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.model_n = mesh.shape.get("model", 1)
        self.data_n = mesh.shape.get("data", 1)
        self.batch_ax = batch_axes(mesh)
        self.batch_n = int(np.prod([mesh.shape[a] for a in self.batch_ax]))

    # ------------------------------------------------------------ weights
    def param_spec(self, path: str, shape: tuple[int, ...]) -> P:
        dims: list = [None] * len(shape)
        if len(shape) == 0:
            return P()
        is_block = path.startswith("blocks/")
        lead = 1 if is_block else 0          # blocks carry the period axis

        if "experts/" in path and len(shape) - lead == 3:
            e_i, in_i, out_i = lead, lead + 1, lead + 2
            if _divides(shape[e_i], self.model_n):
                dims[e_i] = "model"
            if _divides(shape[in_i], self.data_n):
                dims[in_i] = "data"
            return P(*dims)

        if path == "embed" or path.startswith("embed"):
            # (V, D) or (K, V, D): vocab-parallel
            v_i = len(shape) - 2
            if _divides(shape[v_i], self.model_n):
                dims[v_i] = "model"
            if _divides(shape[-1], self.data_n):
                dims[-1] = "data"
            return P(*dims)

        mat_dims = len(shape) - lead
        if mat_dims >= 2:
            if _divides(shape[-1], self.model_n):
                dims[-1] = "model"
            if _divides(shape[-2], self.data_n):
                dims[-2] = "data"
            return P(*dims)
        # 1-D vectors (norm scales, biases): replicate
        return P(*dims)

    def param_shardings(self, params_shapes) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
        out = []
        for path_keys, leaf in flat:
            path = "/".join(_k(k) for k in path_keys)
            out.append(NamedSharding(self.mesh, self.param_spec(path, leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def opt_shardings(self, opt_shapes, params_shapes):
        """Optimizer moments mirror the param specs; scalars replicate."""
        p_flat = {"/".join(_k(k) for k in p): l for p, l in
                  jax.tree_util.tree_flatten_with_path(params_shapes)[0]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shapes)
        out = []
        for path_keys, leaf in flat:
            path = "/".join(_k(k) for k in path_keys)
            # strip the leading m/ v/ to find the mirrored param
            sub = path.split("/", 1)[1] if "/" in path else ""
            if sub in p_flat and p_flat[sub].shape == leaf.shape:
                out.append(NamedSharding(self.mesh, self.param_spec(sub, leaf.shape)))
            else:
                out.append(NamedSharding(self.mesh, P()))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------- inputs
    def batch_spec(self, shape: tuple[int, ...]) -> P:
        dims: list = [None] * len(shape)
        if len(shape) and _divides(shape[0], self.batch_n):
            dims[0] = self.batch_ax if len(self.batch_ax) > 1 else self.batch_ax[0]
        return P(*dims)

    def batch_shardings(self, batch_shapes):
        return jax.tree.map(
            lambda l: NamedSharding(self.mesh, self.batch_spec(l.shape)),
            batch_shapes)

    def cache_spec(self, path: str, shape: tuple[int, ...]) -> P:
        """Cache leaves carry (period, B, ...) leading axes.

        Batch axis shards over ("pod","data") when divisible; the cache
        LENGTH/state axis (index 2: T for attention, d_inner for Mamba,
        d_model for sLSTM) additionally shards over "model" — sequence/
        context parallelism for decode, which keeps a 128x32k KV cache
        within HBM and turns full-cache reads into 1/16th reads + small
        softmax all-reduces.  With batch=1 (long_500k) the length axis
        takes every available mesh axis instead.
        """
        dims: list = [None] * len(shape)
        batch_dim = self.batch_ax if len(self.batch_ax) > 1 else self.batch_ax[0]
        if len(shape) >= 2 and _divides(shape[1], self.batch_n):
            dims[1] = batch_dim
            if len(shape) >= 3 and _divides(shape[2], self.model_n):
                dims[2] = "model"
            elif len(shape) >= 4 and _divides(shape[3], self.model_n):
                dims[3] = "model"
            return P(*dims)
        # batch not shardable: context-parallel over everything available
        all_axes = tuple(self.batch_ax) + ("model",)
        total = self.batch_n * self.model_n
        if len(shape) >= 3:
            if _divides(shape[2], total):
                dims[2] = all_axes
            elif _divides(shape[2], self.data_n):
                dims[2] = "data"
                if len(shape) >= 4 and _divides(shape[3], self.model_n):
                    dims[3] = "model"
        return P(*dims)

    def cache_shardings(self, cache_shapes):
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
        out = []
        for path_keys, leaf in flat:
            path = "/".join(_k(k) for k in path_keys)
            out.append(NamedSharding(self.mesh, self.cache_spec(path, leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def replicated(self):
        return NamedSharding(self.mesh, P())


def _k(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def logical_binding(mesh: Mesh) -> dict:
    """Logical-axis binding for models.sharding.axis_binding."""
    return {
        "__mesh__": mesh,
        "batch": batch_axes(mesh),
        "model": ("model",),
        "model_act": None,     # activations: keep d_model unsharded (baseline)
    }
