import os
import sys
if "--reduced" not in sys.argv and __name__ == "__main__":
    # full-config path lowers against the 512-placeholder production mesh;
    # must be set before jax initializes (reduced runs keep 1 real device).
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 50 --batch 8 --seq 64

``--reduced`` runs REAL steps on the local device(s) with the smoke-scale
config; without it, the full config is lowered + compiled against the
production mesh (dry-run semantics — this container has no TPU pod).
"""
import argparse
import time

import jax

from repro.configs import base as cfg_base
from repro.launch import steps
from repro.models import multimodal, transformer


def run_reduced(arch: str, steps_n: int, batch: int, seq: int,
                ckpt: str | None = None, log_every: int = 10) -> float:
    cfg = cfg_base.get(arch).reduced()
    model = transformer.Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n = transformer.param_count(params)
    print(f"[train] {arch} (reduced): {n/1e6:.1f}M params, "
          f"batch {batch} x seq {seq}")

    train_step, optimizer, _ = steps.make_train_step(cfg, global_batch=batch)
    opt_state = optimizer.init(params)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    losses, t0 = [], time.time()
    for i in range(steps_n):
        batch_data = multimodal.batch_for(cfg, batch, seq, seed=i)
        params, opt_state, loss = step_fn(params, opt_state, batch_data)
        losses.append(float(loss))
        if i % log_every == 0 or i == steps_n - 1:
            print(f"[train] step {i:4d}  loss {losses[-1]:.4f}")
    dt = time.time() - t0
    print(f"[train] {steps_n} steps in {dt:.1f}s "
          f"({batch * seq * steps_n / dt:,.0f} tok/s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if ckpt:
        from repro.checkpoint import checkpoint
        checkpoint.save(ckpt, params, metadata={"arch": arch, "step": steps_n})
        print(f"[train] checkpoint -> {ckpt}")
    return losses[-1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    if args.reduced:
        run_reduced(args.arch, args.steps, args.batch, args.seq, args.ckpt)
    else:
        print("[train] full config -> lowering against the production mesh "
              "(no TPU attached; dry-run)")
        from repro.launch import dryrun
        dryrun.run_one(args.arch, args.shape, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
