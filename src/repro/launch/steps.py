"""train_step / serve_step factories — the compiled units of the framework.

``make_train_step``: microbatched gradient accumulation (scan over
interleaved row-slices so every microbatch stays spread across the data
axis), f32 accumulators, grad clipping, optimizer update.

``make_serve_step``: one-token decode against a threaded KV/state cache.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.optim import optimizers as opt_mod


def make_loss_fn(model: transformer.Model) -> Callable:
    return lambda params, batch: model.loss(params, batch)


def _micro_split(batch: dict, n_micro: int) -> dict:
    """(B, ...) -> (n_micro, B/n_micro, ...) with INTERLEAVED rows, so each
    microbatch keeps rows on every data shard."""
    def f(a):
        b = a.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return jnp.swapaxes(a.reshape((b // n_micro, n_micro) + a.shape[1:]), 0, 1)
    return jax.tree.map(f, batch)


def make_train_step(cfg: ModelConfig, *, global_batch: int,
                    clip_norm: float = 1.0):
    model = transformer.Model(cfg)
    optimizer = opt_mod.make(cfg.optimizer, cfg.learning_rate)
    loss_fn = make_loss_fn(model)
    n_micro = max(1, global_batch // max(cfg.microbatch, 1))

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = _micro_split(batch, n_micro)

            def mb(acc, mbatch):
                g_acc, l_acc = acc
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), 0

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(mb, (g0, jnp.zeros((), jnp.float32)),
                                            micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
        if clip_norm:
            grads, gnorm = opt_mod.clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_mod.apply_updates(params, updates)
        return params, opt_state, loss

    return train_step, optimizer, model


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, batch, caches, pos) -> (logits, new_caches).
    ``batch`` holds the single new token; ``pos`` its absolute position."""
    model = transformer.Model(cfg)

    def serve_step(params, batch, caches, pos):
        return model.decode_step(params, batch, caches, pos)

    return serve_step, model


def make_prefill_step(cfg: ModelConfig):
    model = transformer.Model(cfg)

    def prefill_step(params, batch):
        logits, aux = model.prefill(params, batch)
        return logits

    return prefill_step, model
