"""Production mesh definitions (TPU v5e pods; host-device placeholders in
the dry-run).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax import, everything else
sees the real (single-CPU) topology.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_axis_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_shards: int | None = None):
    """All (or ``n_shards``) local devices on the ``data`` axis — the shape
    the ``repro.api`` sharded backends consume: circuit-bank lanes shard
    over ``data``, so a multi-device host parallelizes by default while the
    single-CPU container degenerates to ``make_host_mesh()``."""
    n = n_shards or len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
