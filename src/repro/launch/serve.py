import os
import sys
if "--reduced" not in sys.argv and __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
"""Serving launcher: batched prefill + cached decode.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --batch 4 --prompt-len 16 --gen 24

``--reduced`` serves the smoke-scale config with REAL batched requests on
the local device; without it, the full config's serve_step is lowered +
compiled against the production mesh (decode_32k semantics).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import base as cfg_base
from repro.launch import steps
from repro.models import multimodal, transformer


def run_reduced(arch: str, batch: int, prompt_len: int, gen: int) -> None:
    cfg = cfg_base.get(arch).reduced()
    model = transformer.Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    print(f"[serve] {arch} (reduced): batch {batch}, prompt {prompt_len}, "
          f"generating {gen} tokens/request")

    capacity = prompt_len + gen
    caches = model.init_caches(batch, capacity)
    serve_step, _ = steps.make_serve_step(cfg)
    step = jax.jit(serve_step, donate_argnums=(2,))

    # prefill by stepping the prompt through the cache (keeps one compiled
    # shape); real pods would use a fused prefill kernel.
    prompt = multimodal.decode_batch_for(cfg, batch)
    toks = {k: jnp.tile(v, (1, prompt_len) + (1,) * (v.ndim - 2))
            for k, v in prompt.items()}
    t0 = time.time()
    logits = None
    for t in range(prompt_len):
        tok_t = {k: v[:, t:t + 1] for k, v in toks.items()}
        logits, caches = step(params, tok_t, caches, jnp.int32(t))
    out_tokens = []
    for t in range(prompt_len, capacity):
        nxt = jnp.argmax(logits[..., -1, :], axis=-1).astype(jnp.int32)
        nxt = nxt.reshape(batch, 1, -1) if cfg.n_codebooks else nxt.reshape(batch, 1)
        key = "codes" if cfg.n_codebooks else "tokens"
        logits, caches = step(params, {key: nxt}, caches, jnp.int32(t))
        out_tokens.append(nxt)
    dt = time.time() - t0
    total = batch * capacity
    print(f"[serve] {total} cached decode steps in {dt:.1f}s "
          f"({total / dt:,.0f} tok/s incl. prefill); "
          f"sample continuation: {[int(x.reshape(-1)[0]) for x in out_tokens[:8]]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    if args.reduced:
        run_reduced(args.arch, args.batch, args.prompt_len, args.gen)
    else:
        print("[serve] full config -> lowering serve_step against the "
              "production mesh (dry-run)")
        from repro.launch import dryrun
        dryrun.run_one(args.arch, args.shape, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
