import os
if "dryrun" not in os.environ.get("XLA_FLAGS", ""):
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
"""Dry-run + roofline of the PAPER'S OWN workload on the production mesh:
a multi-tenant circuit bank (the parameter-shift subtasks of all concurrent
clients) executed across the 16x16 pod.

Baseline = the mechanical port: per-gate statevector simulation (one XLA op
chain per gate, statevector round-trips memory between gates) sharded over
all 256 chips.  Optimized = the fused Pallas VQC kernel (statevector lives
in VMEM for the whole circuit; HBM traffic is angles in, fidelity out),
whose traffic is analytic (interpret-mode lowering cannot express VMEM
residency).

Usage: PYTHONPATH=src python -m repro.launch.quantum_dryrun [--circuits N]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import circuits as qc, fidelity as fid
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis, hlo_analyzer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def lower_pergate(spec, n_circuits: int, mesh):
    """The paper-faithful data plane (per-gate sim), bank sharded over every
    chip (both mesh axes — circuits are embarrassingly parallel)."""
    sh = NamedSharding(mesh, P(("data", "model"), None))
    out_sh = NamedSharding(mesh, P(("data", "model")))

    def bank_fidelity(theta, data):
        return fid.fidelity_batch(spec, theta, data)

    theta = jax.ShapeDtypeStruct((n_circuits, spec.n_theta), jnp.float32)
    data = jax.ShapeDtypeStruct((n_circuits, spec.n_data), jnp.float32)
    return jax.jit(bank_fidelity, in_shardings=(sh, sh),
                   out_shardings=out_sh).lower(theta, data)


def kernel_traffic(spec, n_circuits: int, chips: int) -> dict:
    """Analytic HBM traffic of the fused kernel (per device): read the
    angle block, write the fidelity; the statevector never leaves VMEM."""
    c_local = n_circuits // chips
    read = (spec.n_theta + spec.n_data) * 4 * c_local
    write = 4 * c_local
    return {"bytes_per_device": read + write}


def pergate_state_traffic(spec, n_circuits: int, chips: int) -> dict:
    """What the baseline moves: state read+write per gate."""
    c_local = n_circuits // chips
    dim = 2 ** spec.n_qubits
    per_gate = 2 * 4 * dim * c_local * 2          # (re,im) f32, r+w
    return {"bytes_per_device": per_gate * len(spec.ops)}


def run(qc_width: int, n_layers: int, n_circuits: int, verbose=True):
    spec = qc.build_quclassi_circuit(qc_width, n_layers)
    mesh = make_production_mesh(multi_pod=False)
    chips = mesh.devices.size

    t0 = time.time()
    lowered = lower_pergate(spec, n_circuits, mesh)
    compiled = lowered.compile()
    cost = hlo_analyzer.analyze(compiled.as_text())
    t_compile = time.time() - t0

    base_mem_s = cost.bytes / analysis.HBM_BW
    base_cmp_s = cost.flops / analysis.PEAK_FLOPS
    analytic = pergate_state_traffic(spec, n_circuits, chips)
    kern = kernel_traffic(spec, n_circuits, chips)
    kern_mem_s = kern["bytes_per_device"] / analysis.HBM_BW

    rec = {
        "workload": f"vqc_bank_{qc_width}q{n_layers}L", "circuits": n_circuits,
        "chips": chips, "n_gates": len(spec.ops),
        "pergate": {
            "flops_per_device": cost.flops,
            "bytes_per_device": cost.bytes,
            "collective_bytes_per_device": cost.coll_bytes,
            "compute_ms": base_cmp_s * 1e3, "memory_ms": base_mem_s * 1e3,
            "analytic_state_bytes_per_device": analytic["bytes_per_device"],
        },
        "fused_kernel": {
            "bytes_per_device": kern["bytes_per_device"],
            "memory_ms": kern_mem_s * 1e3,
            "traffic_reduction_vs_pergate": cost.bytes / kern["bytes_per_device"],
        },
        "compile_s": round(t_compile, 1),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR,
                           f"quantum_bank__{qc_width}q{n_layers}L.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        print(f"[quantum-dryrun] {rec['workload']}: {n_circuits} circuits on "
              f"{chips} chips")
        print(f"  per-gate : compute {rec['pergate']['compute_ms']:.3f}ms  "
              f"memory {rec['pergate']['memory_ms']:.3f}ms  "
              f"(analyzer bytes {cost.bytes:.2e}, "
              f"analytic state traffic {analytic['bytes_per_device']:.2e})")
        print(f"  fused    : memory {rec['fused_kernel']['memory_ms']:.4f}ms  "
              f"({rec['fused_kernel']['traffic_reduction_vs_pergate']:.0f}x "
              f"less HBM traffic)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--circuits", type=int, default=1_048_576)
    ap.add_argument("--qc", type=int, default=7)
    ap.add_argument("--layers", type=int, default=3)
    args = ap.parse_args()
    run(args.qc, args.layers, args.circuits)


if __name__ == "__main__":
    main()
