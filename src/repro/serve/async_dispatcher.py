"""Async serving runtime: non-blocking dispatch with per-worker slots.

The synchronous ``Dispatcher`` executes every coalesced mega-batch inline on
the submitting thread, so one slow kernel launch head-of-line-blocks every
tenant — exactly the uncontrolled behavior the paper's co-Manager exists to
avoid.  ``AsyncDispatcher`` decouples the stages:

  * a PUMP THREAD moves admitted circuits through the weighted-fair
    scheduler and the coalescer, places emitted batches via Algorithm 2,
    and re-arms itself on the coalescer's next SLO/deadline flush;
  * a WORKER POOL executes placed batches — each registered worker owns
    ``slots_per_worker`` execution slots, one in-flight mega-batch each, so
    distinct workers (and slots) overlap kernel execution with admission,
    coalescing, and placement;
  * ``CircuitFuture``s resolve OUT OF ORDER as their batches finish; a
    batch that cannot currently be placed waits in a ready queue without
    blocking later batches that fit another worker.

Placement charges each batch's EWMA-predicted service seconds to the chosen
worker's CRU for the time it is outstanding (see ``repro.serve.dispatcher``),
so Algorithm 2 keeps steering work toward the least-loaded worker even
though completions now arrive asynchronously.

Locking: the gateway has its own re-entrant lock; this class guards its
scheduler state (ready queue, slot counts, co-Manager views) with one
condition variable.  The two are never held nested in the
gateway-then-condition order, so there is no lock-ordering cycle.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

from repro.comanager.manager import CoManager
from repro.comanager.worker import CircuitTask, WorkerConfig
from repro.serve.coalescer import CoalescedBatch
from repro.serve.dispatcher import (
    WORKER_VMEM_BYTES,
    Dispatcher,
    KernelFn,
    MultiBankKernelFn,
    ShiftKernelFn,
    batch_cost_units,
    batch_family,
    execute_batch,
    kernel_span_args,
)
from repro.serve.gateway import Gateway


class AsyncDispatcher(Dispatcher):
    """Non-blocking dispatcher: pump loop + per-worker execution pool."""

    def __init__(
        self,
        gateway: Gateway,
        workers: Sequence[WorkerConfig],
        *,
        manager: CoManager | None = None,
        kernel: KernelFn | None = None,
        shift_kernel: ShiftKernelFn | None = None,
        multibank_kernel: MultiBankKernelFn | None = None,
        mesh_spill: bool = True,
        spill_executor=None,
        worker_vmem_bytes: int = WORKER_VMEM_BYTES,
        evict_over_slo: bool = False,
        clock=time.perf_counter,
        slots_per_worker: int = 1,
    ):
        super().__init__(
            gateway,
            workers,
            manager=manager,
            kernel=kernel,
            shift_kernel=shift_kernel,
            multibank_kernel=multibank_kernel,
            mesh_spill=mesh_spill,
            spill_executor=spill_executor,
            worker_vmem_bytes=worker_vmem_bytes,
            clock=clock,
        )
        if slots_per_worker < 1:
            raise ValueError(f"slots_per_worker must be >= 1, got {slots_per_worker}")
        self.slots_per_worker = slots_per_worker
        #: preemptively evict ready-queue batches whose every member's SLO
        #: budget has fully elapsed (guaranteed misses): their futures
        #: resolve with DeadlineExceeded and the capacity serves work that
        #: can still make its deadline.  Off by default — eviction turns
        #: late results into errors, which only SLO-strict serving wants.
        self.evict_over_slo = evict_over_slo
        self._cv = threading.Condition()
        self._slot_free = {w.worker_id: slots_per_worker for w in workers}
        self._spill_slot_free = True  # one whole-mesh batch at a time
        self._ready: list[CoalescedBatch] = []
        self._in_flight = 0
        self._pumping = False  # a _pump_once holds popped-but-unqueued batches
        self._kicked = False
        self._stop = False
        self._errors: list[BaseException] = []
        self._pump_errors: list[BaseException] = []
        # +1 thread: the whole-mesh spill slot runs alongside full worker pools
        self._pool = ThreadPoolExecutor(
            max_workers=len(workers) * slots_per_worker + 1,
            thread_name_prefix="serve-slot",
        )
        self._pump_thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Launch the pump thread (idempotent)."""
        if self._pump_thread is not None and self._pump_thread.is_alive():
            return
        self._stop = False
        self._pump_thread = threading.Thread(
            target=self._pump_loop, name="serve-pump", daemon=True
        )
        self._pump_thread.start()

    def close(self) -> None:
        """Stop the pump thread and wait for in-flight batches to finish."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._pump_thread is not None:
            self._pump_thread.join(timeout=10.0)
            self._pump_thread = None
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "AsyncDispatcher":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def kick(self) -> None:
        """Wake the pump loop (call after submitting work)."""
        with self._cv:
            self._kicked = True
            self._cv.notify_all()

    # ----------------------------------------------------------- pump loop
    def _wait_timeout(self) -> float | None:
        """Seconds until the pump must wake for a deadline flush; a short
        safety poll while batches wait for capacity; None to sleep until
        kicked/notified."""
        nd = self.gateway.next_deadline()
        timeout = None
        with self._cv:
            if self._ready:
                timeout = 0.05
        if nd is not None:
            until = max(nd - self.clock(), 1e-3)
            timeout = until if timeout is None else min(timeout, until)
        return timeout

    def _pump_loop(self) -> None:
        while True:
            timeout = self._wait_timeout()
            with self._cv:
                if self._stop:
                    return
                if not self._kicked:
                    self._cv.wait(timeout)
                self._kicked = False
                if self._stop:
                    return
            try:
                self._pump_once()
            except Exception as exc:  # keep the loop alive; drain() raises it
                with self._cv:
                    self._pump_errors.append(exc)
                    self._cv.notify_all()

    def _pump_once(self) -> None:
        # _pumping marks the window where batches have been popped from the
        # gateway but not yet queued in _ready: drain() must not conclude
        # "quiesced" while their futures are still in limbo.
        with self._cv:
            self._pumping = True
        try:
            batches = self.gateway.pump(self.clock())
            with self._cv:
                self._ready.extend(batches)
        finally:
            with self._cv:
                self._pumping = False
                self._cv.notify_all()
        self._place_ready()

    def _expired(self, batch: CoalescedBatch, now: float) -> bool:
        """True when EVERY member's SLO budget has fully elapsed: the batch
        is a guaranteed miss for all of them, so executing it can only
        delay work that might still make its deadline.  A member without an
        SLO (best-effort) keeps the batch alive — its result is still
        wanted whenever it arrives."""
        saw_slo = False
        for m in batch.members:
            st = self.gateway.tenants.get(m.client_id)
            if st is None or st.slo_s is None:
                return False
            saw_slo = True
            if now <= m.arrival + st.slo_s:
                return False
        return saw_slo

    def _place_ready(self) -> None:
        """Try to place every ready batch; no head-of-line blocking — a
        batch that fits no worker right now is skipped, later batches may
        fit a different worker.  Oversized batches (register width or VMEM
        model above every worker) route to the whole-mesh spill slot;
        fully-over-SLO batches are preemptively evicted when enabled."""
        while True:
            now = self.clock()
            launch = spill = evict = None
            with self._cv:
                exclude = {w for w, free in self._slot_free.items() if free <= 0}
                for i, batch in enumerate(self._ready):
                    if self.evict_over_slo and self._expired(batch, now):
                        evict = self._ready.pop(i)
                        break
                    if self.mesh_spill and self._oversized(batch):
                        if not self._spill_slot_free:
                            continue  # mesh busy; later batches may fit workers
                        self._spill_slot_free = False
                        self._in_flight += 1
                        spill = self._ready.pop(i)
                        break
                    width = self._width(batch)
                    if not self.mesh_spill and width > self._max_width:
                        # spill disabled: the pre-spill contract — fail fast
                        # on register width only (a VMEM-model-heavy batch
                        # that fits a worker's register still executes there)
                        self._ready.pop(i)
                        err = RuntimeError(
                            f"no worker fits a {width}-qubit batch "
                            f"(largest worker: {self._max_width} qubits)"
                        )
                        self._errors.append(err)
                        self.gateway.fail(batch, err, now)
                        break
                    est = self._estimate_s(batch)
                    task = CircuitTask(
                        task_id=next(self.task_ids),
                        client_id="gateway",
                        demand=self._width(batch),
                        service_time=est,
                    )
                    wid = self.manager.assign(task, now, exclude=exclude)
                    if wid is None:
                        continue
                    self._ready.pop(i)
                    self._slot_free[wid] -= 1
                    self._in_flight += 1
                    self._charge(wid, est)
                    launch = (batch, task, wid, est)
                    break
                else:
                    return  # nothing placeable right now
            tr = self.gateway.telemetry.trace
            if evict is not None:
                self.gateway.evict(evict, now)
            elif spill is not None:
                if tr.enabled:
                    tr.batch_stage(
                        (m.seq for m in spill.members), "placed", now,
                        worker="mesh",
                    )
                self._pool.submit(self._run_spill, spill)
            elif launch is not None:
                if tr.enabled:
                    tr.batch_stage(
                        (m.seq for m in launch[0].members), "placed", now,
                        worker=launch[2],
                    )
                self._pool.submit(self._run, *launch)

    def _run_spill(self, batch: CoalescedBatch) -> None:
        """Spill-slot thread: execute one oversized batch on the whole
        device mesh, resolve its futures, release the spill slot."""
        tr = self.gateway.telemetry.trace
        t0 = self.clock()
        if tr.enabled:
            seqs = [m.seq for m in batch.members]
            tr.batch_stage(seqs, "dispatched", t0)
            tr.batch_stage(seqs, "kernel_start", t0)
        err: BaseException | None = None
        fids = None
        try:
            fids = execute_batch(batch, *self._spill_fns())
        except BaseException as exc:
            err = exc
        dt = self.clock() - t0
        now = self.clock()
        if err is None:
            if tr.enabled:
                tr.worker_span(
                    "mesh", t0, t0 + dt, kind="spill",
                    args=kernel_span_args(batch),
                )
            self.gateway.telemetry.service.update(
                ("spill", batch_family(batch)), batch_cost_units(batch), dt
            )
            self.gateway.telemetry.on_spill(batch.lane_count)
            self._record(batch)
            self.gateway.complete(batch, fids, now)
        else:
            self.gateway.fail(batch, err, now)
        with self._cv:
            self._spill_slot_free = True
            self._in_flight -= 1
            self.batch_log.append(
                ("mesh", batch.n, tuple(sorted(batch.clients())))
            )
            if err is not None:
                self._errors.append(err)
            self._kicked = True
            self._cv.notify_all()

    def _run(
        self, batch: CoalescedBatch, task: CircuitTask, wid: str, est: float
    ) -> None:
        """Worker-slot thread: execute one batch, resolve its futures (out
        of submission order relative to other batches), release the slot."""
        tr = self.gateway.telemetry.trace
        t0 = self.clock()
        if tr.enabled:
            seqs = [m.seq for m in batch.members]
            tr.batch_stage(seqs, "dispatched", t0)
            tr.batch_stage(seqs, "kernel_start", t0)
        err: BaseException | None = None
        fids = None
        try:
            fids = execute_batch(
                batch, self.kernel, self.shift_kernel, self.multibank_kernel
            )
        except BaseException as exc:
            err = exc
        dt = self.clock() - t0
        now = self.clock()
        if err is None:
            if tr.enabled:
                tr.worker_span(wid, t0, t0 + dt, args=kernel_span_args(batch))
            self._observe(batch, dt)
            self._record(batch)
            self.gateway.complete(batch, fids, now)
        else:
            self.gateway.fail(batch, err, now)
        # futures are resolved BEFORE the slot is released, so drain()'s
        # "no in-flight batches" implies "every future resolved".
        with self._cv:
            self.manager.complete(wid, task, now)
            self._charge(wid, -est)
            self._slot_free[wid] += 1
            self._in_flight -= 1
            self.batch_log.append((wid, batch.n, tuple(sorted(batch.clients()))))
            if err is not None:
                self._errors.append(err)
            self._kicked = True  # freed capacity: ready batches may now place
            self._cv.notify_all()

    # ------------------------------------------------------------- control
    def pump(self) -> int:
        """Non-blocking: wake the pump loop and return immediately."""
        self.kick()
        return 0

    def drain(self) -> int:
        """Force-flush partial buffers and block until the gateway is idle
        and every in-flight batch has resolved its futures.  Returns the
        number of batches executed while draining.  Raises the first pump-
        loop error instead of spinning forever on a wedged pump."""
        self.start()
        n0 = len(self.batch_log)
        while True:
            batches = self.gateway.flush(self.clock())
            with self._cv:
                if self._pump_errors:
                    raise self._pump_errors[0]
                self._ready.extend(batches)
                self._kicked = True
                self._cv.notify_all()
                quiesced = (
                    not self._ready
                    and self._in_flight == 0
                    and not self._pumping
                )
            if quiesced and self.gateway.idle:
                break
            with self._cv:
                self._cv.wait(0.02)
        return len(self.batch_log) - n0

    def absorb_backpressure(self) -> None:
        """Backpressure-retry hook: wake the pump, then wait briefly for a
        completion to free queue space — WITHOUT quiescing the whole runtime
        (the sync dispatcher has no choice but to drain inline; here a full
        drain would collapse the submission/execution overlap)."""
        self.kick()
        with self._cv:
            if self._pump_errors:
                raise self._pump_errors[0]
            self._cv.wait(0.05)

    @property
    def in_flight_batches(self) -> int:
        with self._cv:
            return self._in_flight

    @property
    def errors(self) -> list[BaseException]:
        with self._cv:
            return list(self._pump_errors) + list(self._errors)
