"""Async serving runtime: non-blocking dispatch with per-worker slots.

The synchronous ``Dispatcher`` executes every coalesced mega-batch inline on
the submitting thread, so one slow kernel launch head-of-line-blocks every
tenant — exactly the uncontrolled behavior the paper's co-Manager exists to
avoid.  ``AsyncDispatcher`` decouples the stages:

  * a PUMP THREAD moves admitted circuits through the weighted-fair
    scheduler and the coalescer, places emitted batches via Algorithm 2,
    and re-arms itself on the coalescer's next SLO/deadline flush;
  * a WORKER POOL executes placed batches — each registered worker owns
    ``slots_per_worker`` execution slots, one in-flight mega-batch each, so
    distinct workers (and slots) overlap kernel execution with admission,
    coalescing, and placement;
  * ``CircuitFuture``s resolve OUT OF ORDER as their batches finish; a
    batch that cannot currently be placed waits in a ready queue without
    blocking later batches that fit another worker.

Placement charges each batch's EWMA-predicted service seconds to the chosen
worker's CRU for the time it is outstanding (see ``repro.serve.dispatcher``),
so Algorithm 2 keeps steering work toward the least-loaded worker even
though completions now arrive asynchronously.

Locking: the gateway has its own re-entrant lock; this class guards its
scheduler state (ready queue, slot counts, co-Manager views) with one
condition variable.  The two are never held nested in the
gateway-then-condition order, so there is no lock-ordering cycle.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

from repro.comanager.faults import FaultToleranceConfig
from repro.comanager.manager import CoManager
from repro.comanager.worker import CircuitTask, WorkerConfig
from repro.serve.coalescer import CoalescedBatch
from repro.serve.dispatcher import (
    WORKER_VMEM_BYTES,
    Dispatcher,
    KernelFn,
    MultiBankKernelFn,
    ShiftKernelFn,
    batch_cost_units,
    batch_family,
    execute_batch,
    kernel_span_args,
)
from repro.serve.fleet import FaultInjector
from repro.serve.gateway import Gateway


class AsyncDispatcher(Dispatcher):
    """Non-blocking dispatcher: pump loop + per-worker execution pool."""

    #: ring-buffer capacity for execution errors kept for inspection — a
    #: long-lived dispatcher on a flaky fleet must not grow an unbounded
    #: error list; overflow increments ``errors_dropped`` instead.
    ERRORS_CAPACITY = 256

    def __init__(
        self,
        gateway: Gateway,
        workers: Sequence[WorkerConfig],
        *,
        manager: CoManager | None = None,
        kernel: KernelFn | None = None,
        shift_kernel: ShiftKernelFn | None = None,
        multibank_kernel: MultiBankKernelFn | None = None,
        mesh_spill: bool = True,
        spill_executor=None,
        worker_vmem_bytes: int = WORKER_VMEM_BYTES,
        evict_over_slo: bool = False,
        clock=time.perf_counter,
        slots_per_worker: int = 1,
        fault_tolerance: FaultToleranceConfig | None = None,
        fault_injector: FaultInjector | None = None,
    ):
        super().__init__(
            gateway,
            workers,
            manager=manager,
            kernel=kernel,
            shift_kernel=shift_kernel,
            multibank_kernel=multibank_kernel,
            mesh_spill=mesh_spill,
            spill_executor=spill_executor,
            worker_vmem_bytes=worker_vmem_bytes,
            clock=clock,
            fault_tolerance=fault_tolerance,
            fault_injector=fault_injector,
        )
        if slots_per_worker < 1:
            raise ValueError(f"slots_per_worker must be >= 1, got {slots_per_worker}")
        self.slots_per_worker = slots_per_worker
        #: preemptively evict ready-queue batches whose every member's SLO
        #: budget has fully elapsed (guaranteed misses): their futures
        #: resolve with DeadlineExceeded and the capacity serves work that
        #: can still make its deadline.  Off by default — eviction turns
        #: late results into errors, which only SLO-strict serving wants.
        self.evict_over_slo = evict_over_slo
        self._cv = threading.Condition()
        self._slot_free = {w.worker_id: slots_per_worker for w in workers}
        self._spill_slot_free = True  # one whole-mesh batch at a time
        self._ready: list[CoalescedBatch] = []
        self._in_flight = 0
        self._pumping = False  # a _pump_once holds popped-but-unqueued batches
        self._kicked = False
        self._stop = False
        self._errors: deque[BaseException] = deque(maxlen=self.ERRORS_CAPACITY)
        self._errors_dropped = 0
        self._pump_errors: list[BaseException] = []
        # in-flight runner registry for hedging and first-result-wins:
        # id(batch) -> {batch, outstanding, winner, wid, t0, est, hedged}
        self._runners: dict[int, dict] = {}
        # +1 thread: the whole-mesh spill slot runs alongside full worker pools
        self._pool = ThreadPoolExecutor(
            max_workers=len(workers) * slots_per_worker + 1,
            thread_name_prefix="serve-slot",
        )
        self._pump_thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Launch the pump thread (idempotent)."""
        if self._pump_thread is not None and self._pump_thread.is_alive():
            return
        self._stop = False
        self._pump_thread = threading.Thread(
            target=self._pump_loop, name="serve-pump", daemon=True
        )
        self._pump_thread.start()

    def close(self) -> None:
        """Stop the pump thread and wait for in-flight batches to finish."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._pump_thread is not None:
            self._pump_thread.join(timeout=10.0)
            self._pump_thread = None
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "AsyncDispatcher":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def kick(self) -> None:
        """Wake the pump loop (call after submitting work)."""
        with self._cv:
            self._kicked = True
            self._cv.notify_all()

    # ----------------------------------------------------------- pump loop
    def _wait_timeout(self) -> float | None:
        """Seconds until the pump must wake for a deadline flush; a short
        safety poll while batches wait for capacity; None to sleep until
        kicked/notified."""
        nd = self.gateway.next_deadline()
        timeout = None
        with self._cv:
            if self._ready:
                timeout = 0.05
            if self.ft.hedge_k is not None and self._runners:
                # hedging watches in-flight slots against the EWMA estimate
                timeout = 0.01 if timeout is None else min(timeout, 0.01)
        if nd is not None:
            until = max(nd - self.clock(), 1e-3)
            timeout = until if timeout is None else min(timeout, until)
        return timeout

    def _pump_loop(self) -> None:
        while True:
            timeout = self._wait_timeout()
            with self._cv:
                if self._stop:
                    return
                if not self._kicked:
                    self._cv.wait(timeout)
                self._kicked = False
                if self._stop:
                    return
            try:
                self._pump_once()
            except Exception as exc:  # keep the loop alive; drain() raises it
                with self._cv:
                    self._pump_errors.append(exc)
                    self._cv.notify_all()

    def _pump_once(self) -> None:
        # _pumping marks the window where batches have been popped from the
        # gateway but not yet queued in _ready: drain() must not conclude
        # "quiesced" while their futures are still in limbo.
        with self._cv:
            self._pumping = True
        try:
            batches = self.gateway.pump(self.clock())
            with self._cv:
                self._ready.extend(batches)
        finally:
            with self._cv:
                self._pumping = False
                self._cv.notify_all()
        self._place_ready()
        self._maybe_hedge()

    def _expired(self, batch: CoalescedBatch, now: float) -> bool:
        """True when EVERY member's SLO budget has fully elapsed: the batch
        is a guaranteed miss for all of them, so executing it can only
        delay work that might still make its deadline.  A member without an
        SLO (best-effort) keeps the batch alive — its result is still
        wanted whenever it arrives."""
        saw_slo = False
        for m in batch.members:
            st = self.gateway.tenants.get(m.client_id)
            if st is None or st.slo_s is None:
                return False
            saw_slo = True
            if now <= m.arrival + st.slo_s:
                return False
        return saw_slo

    def _place_ready(self) -> None:
        """Try to place every ready batch; no head-of-line blocking — a
        batch that fits no worker right now is skipped, later batches may
        fit a different worker.  Oversized batches (register width or VMEM
        model above every worker) route to the whole-mesh spill slot;
        fully-over-SLO batches are preemptively evicted when enabled."""
        while True:
            now = self.clock()
            launch = spill = evict = None
            with self._cv:
                exclude = {
                    w for w, free in self._slot_free.items() if free <= 0
                } | self.fleet.unplaceable(now)
                for i, batch in enumerate(self._ready):
                    if self.evict_over_slo and self._expired(batch, now):
                        evict = self._ready.pop(i)
                        break
                    if self.mesh_spill and self._oversized(batch):
                        if not self._spill_slot_free:
                            continue  # mesh busy; later batches may fit workers
                        self._spill_slot_free = False
                        self._in_flight += 1
                        spill = self._ready.pop(i)
                        break
                    width = self._width(batch)
                    if not self.mesh_spill and width > self._max_width:
                        # spill disabled: the pre-spill contract — fail fast
                        # on register width only (a VMEM-model-heavy batch
                        # that fits a worker's register still executes there)
                        self._ready.pop(i)
                        err = RuntimeError(
                            f"no worker fits a {width}-qubit batch "
                            f"(largest worker: {self._max_width} qubits)"
                        )
                        self._push_error_locked(err)
                        self.gateway.fail(batch, err, now)
                        break
                    est = self._estimate_s(batch)
                    task = CircuitTask(
                        task_id=next(self.task_ids),
                        client_id="gateway",
                        demand=self._width(batch),
                        service_time=est,
                    )
                    wid = self.manager.assign(task, now, exclude=exclude)
                    if wid is None:
                        continue
                    self._ready.pop(i)
                    self._slot_free[wid] -= 1
                    self._in_flight += 1
                    self._charge(wid, est)
                    self.fleet.on_dispatch(wid)
                    self._runners[id(batch)] = {
                        "batch": batch,
                        "outstanding": 1,
                        "winner": None,
                        "wid": wid,
                        "t0": now,
                        "est": est,
                        "hedged": False,
                    }
                    launch = (batch, task, wid, est)
                    break
                else:
                    return  # nothing placeable right now
            tr = self.gateway.telemetry.trace
            if evict is not None:
                self.gateway.evict(evict, now)
            elif spill is not None:
                if tr.enabled:
                    tr.batch_stage(
                        (m.seq for m in spill.members), "placed", now,
                        worker="mesh",
                    )
                self._pool.submit(self._run_spill, spill)
            elif launch is not None:
                if tr.enabled:
                    tr.batch_stage(
                        (m.seq for m in launch[0].members), "placed", now,
                        worker=launch[2],
                    )
                self._pool.submit(self._run, *launch)

    def _run_spill(self, batch: CoalescedBatch) -> None:
        """Spill-slot thread: execute one oversized batch on the whole
        device mesh, resolve its futures, release the spill slot."""
        tr = self.gateway.telemetry.trace
        t0 = self.clock()
        if tr.enabled:
            seqs = [m.seq for m in batch.members]
            tr.batch_stage(seqs, "dispatched", t0)
            tr.batch_stage(seqs, "kernel_start", t0)
        err: BaseException | None = None
        fids = None
        try:
            fids = execute_batch(batch, *self._spill_fns())
        except BaseException as exc:
            err = exc
        dt = self.clock() - t0
        now = self.clock()
        if err is None:
            if tr.enabled:
                tr.worker_span(
                    "mesh", t0, t0 + dt, kind="spill",
                    args=kernel_span_args(batch),
                )
            self.gateway.telemetry.service.update(
                ("spill", batch_family(batch)), batch_cost_units(batch), dt
            )
            self.gateway.telemetry.on_spill(batch.lane_count)
            self._record(batch)
            self.gateway.complete(batch, fids, now)
        else:
            self.gateway.fail(batch, err, now)
        with self._cv:
            self._spill_slot_free = True
            self._in_flight -= 1
            self.batch_log.append(
                ("mesh", batch.n, tuple(sorted(batch.clients())))
            )
            if err is not None:
                self._push_error_locked(err)
            self._kicked = True
            self._cv.notify_all()

    def _run(
        self,
        batch: CoalescedBatch,
        task: CircuitTask | None,
        wid: str,
        est: float,
        hedge: bool = False,
    ) -> None:
        """Worker-slot thread: execute one batch, resolve its futures (out
        of submission order relative to other batches), release the slot.

        Failure tolerance: a failed attempt retries in place (bounded by
        ``FaultToleranceConfig.retry_limit`` with exponential backoff), then
        the batch migrates to a surviving worker through the gateway's
        re-coalescing requeue.  With hedging, two runners may race on one
        batch: the first success claims it (resolving the futures exactly
        once) and the loser's result is discarded — kernel launches cannot
        be interrupted, so safe cancellation means the loser lands without
        side effects."""
        tel = self.gateway.telemetry
        tr = tel.trace
        seqs = [m.seq for m in batch.members]
        t0 = self.clock()
        if tr.enabled and not hedge:
            tr.batch_stage(seqs, "dispatched", t0)
            tr.batch_stage(seqs, "kernel_start", t0)
        err: BaseException | None = None
        fids = None
        attempts = 0
        while True:
            t0 = self.clock()
            err = None
            try:
                if self.fault_injector is not None:
                    self.fault_injector.check(wid, t0)
                fids = execute_batch(
                    batch, self.kernel, self.shift_kernel, self.multibank_kernel
                )
                if self.fault_injector is not None:
                    # mirror the simulation's slowdown fault in wall time
                    extra = (
                        self.fault_injector.slowdown_factor(wid, t0) - 1.0
                    ) * (self.clock() - t0)
                    if extra > 0:
                        time.sleep(extra)
            except BaseException as exc:
                err = exc
            if err is None:
                break
            now = self.clock()
            tripped = self.fleet.on_failure(wid, now)
            tel.on_worker_failure(wid)
            if tripped:
                tel.on_worker_offline(wid)
                if tr.enabled:
                    tr.batch_stage(seqs, "worker_offline", now, worker=wid)
            attempts += 1
            if (
                not hedge
                and attempts <= self.ft.retry_limit
                and self.fleet.retryable(wid, now)
            ):
                self.fleet.record_retry(wid)
                tel.on_worker_retry(wid)
                if tr.enabled:
                    tr.batch_stage(seqs, "retried", now, worker=wid)
                if self.ft.retry_backoff_s:
                    time.sleep(self.ft.retry_backoff_s * 2 ** (attempts - 1))
                continue
            break
        dt = self.clock() - t0
        now = self.clock()
        # settle against the (possibly hedged) runner set: the first
        # successful runner claims the batch, the LAST failed runner with
        # no winner owns migration/terminal failure.
        with self._cv:
            entry = self._runners.get(id(batch))
            if entry is not None:
                entry["outstanding"] -= 1
                last = entry["outstanding"] <= 0
                claimed = err is None and entry["winner"] is None
                if claimed:
                    entry["winner"] = wid
                winner_exists = entry["winner"] is not None
                if last:
                    self._runners.pop(id(batch), None)
            else:  # defensive: every launch registers an entry
                last, claimed, winner_exists = True, err is None, err is None
        migrated = False
        if claimed:
            if tr.enabled:
                tr.worker_span(wid, t0, t0 + dt, args=kernel_span_args(batch))
            self._observe(batch, dt)
            self._record(batch)
            self.gateway.complete(batch, fids, now)
        elif err is not None and last and not winner_exists:
            bad = self.fleet.unplaceable(now)
            with self._cv:
                survivors = [
                    w
                    for w, v in self.manager.workers.items()
                    if w != wid
                    and w not in bad
                    and v.max_qubits >= self._width(batch)
                ]
            if survivors:
                migrated = True
                self.fleet.record_migration(wid)
                tel.on_worker_migration(wid)
                if tr.enabled:
                    tr.batch_stage(seqs, "migrated", now, worker=wid)
                self.gateway.requeue(batch, now)
            else:
                self.gateway.fail(batch, err, now)
        if err is None:
            self.fleet.on_success(wid)
        # futures are resolved BEFORE the slot is released, so drain()'s
        # "no in-flight batches" implies "every future resolved".
        with self._cv:
            if task is not None:
                self.manager.complete(wid, task, now)
            self._charge(wid, -est)
            if wid in self._slot_free:  # the worker may have been drained
                self._slot_free[wid] += 1
            self._in_flight -= 1
            self.fleet.on_release(wid)
            if claimed or (err is not None and last and not winner_exists):
                self.batch_log.append(
                    (wid, batch.n, tuple(sorted(batch.clients())))
                )
            if err is not None and last and not winner_exists and not migrated:
                self._push_error_locked(err)
            self._kicked = True  # freed capacity: ready batches may now place
            self._cv.notify_all()

    def _maybe_hedge(self) -> None:
        """Hedged duplicate dispatch: an in-flight batch whose slot has
        exceeded ``hedge_k x`` its ServiceModel estimate is duplicated onto
        a free surviving worker; first result wins."""
        k = self.ft.hedge_k
        if k is None:
            return
        now = self.clock()
        launches = []
        with self._cv:
            for entry in self._runners.values():
                if entry["hedged"] or entry["winner"] is not None:
                    continue
                if now - entry["t0"] < k * max(entry["est"], 1e-9):
                    continue
                batch = entry["batch"]
                width = self._width(batch)
                wid2 = None
                for w in sorted(self._slot_free):
                    if w == entry["wid"] or self._slot_free[w] <= 0:
                        continue
                    v = self.manager.workers.get(w)
                    if v is None or v.max_qubits < width:
                        continue
                    if not self.fleet.placeable(w, now):
                        continue
                    wid2 = w
                    break
                if wid2 is None:
                    continue
                entry["hedged"] = True
                entry["outstanding"] += 1
                self._slot_free[wid2] -= 1
                self._in_flight += 1
                self._charge(wid2, entry["est"])
                self.fleet.on_dispatch(wid2)
                launches.append((batch, entry["wid"], wid2, entry["est"]))
        tel = self.gateway.telemetry
        tr = tel.trace
        for batch, straggler, wid2, est in launches:
            self.fleet.record_hedge(straggler)
            tel.on_worker_hedge(straggler)
            if tr.enabled:
                tr.batch_stage(
                    (m.seq for m in batch.members), "hedged", now, worker=wid2
                )
            self._pool.submit(self._run, batch, None, wid2, est, True)

    # ------------------------------------------------------------- control
    def pump(self) -> int:
        """Non-blocking: wake the pump loop and return immediately."""
        self.kick()
        return 0

    def drain(self) -> int:
        """Force-flush partial buffers and block until the gateway is idle
        and every in-flight batch has resolved its futures.  Returns the
        number of batches executed while draining.  Raises the first pump-
        loop error instead of spinning forever on a wedged pump."""
        self.start()
        n0 = len(self.batch_log)
        while True:
            batches = self.gateway.flush(self.clock())
            with self._cv:
                if self._pump_errors:
                    raise self._pump_errors[0]
                self._ready.extend(batches)
                self._kicked = True
                self._cv.notify_all()
                quiesced = (
                    not self._ready
                    and self._in_flight == 0
                    and not self._pumping
                )
            if quiesced and self.gateway.idle:
                break
            with self._cv:
                self._cv.wait(0.02)
        return len(self.batch_log) - n0

    def absorb_backpressure(self) -> None:
        """Backpressure-retry hook: wake the pump, then wait briefly for a
        completion to free queue space — WITHOUT quiescing the whole runtime
        (the sync dispatcher has no choice but to drain inline; here a full
        drain would collapse the submission/execution overlap)."""
        self.kick()
        with self._cv:
            if self._pump_errors:
                raise self._pump_errors[0]
            self._cv.wait(0.05)

    # ------------------------------------------------------ live membership
    def register_worker(self, worker: WorkerConfig) -> None:
        """Grow the fleet at runtime: the new worker gets its execution
        slots and becomes placeable on the next pump cycle."""
        # manager.workers is read under _cv by the pump and runner threads,
        # so membership mutations happen under the same lock
        with self._cv:
            super().register_worker(worker)
            self._slot_free[worker.worker_id] = self.slots_per_worker
            # grow the slot pool so the new worker's slots can actually run
            # concurrently (ThreadPoolExecutor spawns threads on demand up
            # to _max_workers, so raising the cap is safe at runtime)
            self._pool._max_workers += self.slots_per_worker
            self._kicked = True
            self._cv.notify_all()

    def drain_worker(self, worker_id: str, timeout: float = 30.0) -> None:
        """Live drain: stop placing on the worker, wait for its in-flight
        slots to land (results resolve, or migrate through the failure
        path), then remove it from the fleet."""
        deadline = time.monotonic() + timeout
        with self._cv:
            if worker_id not in self._slot_free:
                raise KeyError(f"unknown worker {worker_id!r}")
            self.fleet.mark_draining(worker_id)
            while self._slot_free[worker_id] < self.slots_per_worker:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"drain_worker({worker_id!r}): in-flight work did "
                        f"not land within {timeout}s"
                    )
                self._cv.wait(min(remaining, 0.05))
            del self._slot_free[worker_id]
            self._forget_worker(worker_id)
        self.kick()

    # ------------------------------------------------------------- metrics
    @property
    def in_flight_batches(self) -> int:
        with self._cv:
            return self._in_flight

    def _push_error_locked(self, err: BaseException) -> None:
        """Append to the bounded error ring (caller holds ``_cv``)."""
        if len(self._errors) == self._errors.maxlen:
            self._errors_dropped += 1
        self._errors.append(err)

    @property
    def errors(self) -> list[BaseException]:
        with self._cv:
            return list(self._pump_errors) + list(self._errors)

    @property
    def errors_dropped(self) -> int:
        """Errors evicted from the bounded ring (oldest-first overflow)."""
        with self._cv:
            return self._errors_dropped
