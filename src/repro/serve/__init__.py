"""Online serving layer: streaming circuit submissions from many tenants
-> priority-tiered weighted-fair admission -> cross-tenant lane-aligned
coalescing (SLO-aware flush deadlines) -> co-Manager placement -> fused
Pallas kernel execution, synchronously inline or async on a worker pool.

See ``gateway`` (admission / priority tiers / SLOs / backpressure),
``coalescer`` (structure-keyed mega-batch packing), ``dispatcher``
(placement + inline execution + EWMA cost model), ``async_dispatcher``
(pump loop + per-worker execution slots, out-of-order futures), ``metrics``
(per-tenant latency / throughput / lane-fill / SLO-attainment telemetry),
``fleet`` (worker health states, circuit breaker, fault injection).
"""
from repro.comanager.faults import FaultSpec, FaultToleranceConfig
from repro.serve.async_dispatcher import AsyncDispatcher
from repro.serve.coalescer import CoalescedBatch, Coalescer, PendingCircuit
from repro.serve.dispatcher import (
    WORKER_VMEM_BYTES,
    Dispatcher,
    GatewayRuntime,
    ShiftGroupKey,
    bank_partition,
    batch_cost_units,
    batch_vmem_bytes,
    execute_batch,
)
from repro.serve.fleet import (
    WORKER_STATES,
    FaultInjector,
    FleetHealth,
    InjectedWorkerFault,
    WorkerVitals,
)
from repro.serve.gateway import (
    SLO_FLUSH_FRACTION,
    Backpressure,
    CircuitFuture,
    DeadlineExceeded,
    Gateway,
)
from repro.serve.metrics import ServiceModel, Telemetry

__all__ = [
    "AsyncDispatcher",
    "Backpressure",
    "CircuitFuture",
    "CoalescedBatch",
    "Coalescer",
    "DeadlineExceeded",
    "Dispatcher",
    "FaultInjector",
    "FaultSpec",
    "FaultToleranceConfig",
    "FleetHealth",
    "Gateway",
    "GatewayRuntime",
    "InjectedWorkerFault",
    "PendingCircuit",
    "ServiceModel",
    "ShiftGroupKey",
    "SLO_FLUSH_FRACTION",
    "Telemetry",
    "WORKER_STATES",
    "WORKER_VMEM_BYTES",
    "WorkerVitals",
    "bank_partition",
    "batch_cost_units",
    "batch_vmem_bytes",
    "execute_batch",
]
