"""Online serving layer: streaming circuit submissions from many tenants
-> weighted-fair admission -> cross-tenant lane-aligned coalescing ->
co-Manager placement -> fused Pallas kernel execution.

See ``gateway`` (admission / fairness / backpressure), ``coalescer``
(structure-keyed mega-batch packing), ``dispatcher`` (placement + execution),
``metrics`` (per-tenant latency / throughput / lane-fill telemetry).
"""
from repro.serve.coalescer import CoalescedBatch, Coalescer, PendingCircuit
from repro.serve.dispatcher import Dispatcher, GatewayRuntime, ShiftGroupKey
from repro.serve.gateway import Backpressure, CircuitFuture, Gateway
from repro.serve.metrics import Telemetry

__all__ = [
    "Backpressure", "CircuitFuture", "CoalescedBatch", "Coalescer",
    "Dispatcher", "Gateway", "GatewayRuntime", "PendingCircuit",
    "ShiftGroupKey", "Telemetry",
]
