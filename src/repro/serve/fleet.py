"""Worker fleet health: per-worker state machine, circuit breaker, and
deterministic fault injection for the real dispatchers.

The paper's co-Manager "dynamically manages circuits according to the
runtime status of quantum workers"; this module is that runtime status.
Every worker carries a state in :data:`WORKER_STATES`:

    idle / busy      healthy; placeable
    probation        half-open breaker trial after the offline cooldown —
                     placeable, but one failure re-trips immediately
    offline          circuit breaker tripped (consecutive failures);
                     excluded from placement until the cooldown elapses
    draining         live-membership drain in progress: stop placing,
                     finish or migrate in-flight, then remove
    maintenance      operator-held out of rotation

plus an EWMA failure-rate signal and failure/retry/migration/hedge
counters surfaced through ``FleetHealth.snapshot()`` and
``Telemetry.summary()``.

:class:`FaultInjector` mirrors the simulation's typed fault schedules
(``repro.comanager.faults``) onto the real dispatchers: crash windows
raise :class:`InjectedWorkerFault` from the dispatch path, flaky workers
drop attempts deterministically, slowdowns stretch wall-clock execution —
so the same scenario runs under the virtual clock and against real
kernels.
"""

from __future__ import annotations

import dataclasses
import threading

from repro.comanager.faults import (
    FAULT_KINDS,
    FaultSpec,
    FaultToleranceConfig,
    normalize_failures,
)

WORKER_STATES = (
    "idle",
    "busy",
    "probation",
    "offline",
    "draining",
    "maintenance",
)

#: States a worker must be in to receive new placements.
_PLACEABLE = ("idle", "busy", "probation")


@dataclasses.dataclass
class WorkerVitals:
    """Mutable health record for one worker (guarded by FleetHealth's lock)."""

    state: str = "idle"
    failure_rate: float = 0.0
    consecutive_errors: int = 0
    busy_slots: int = 0
    offline_until: float = 0.0
    failures: int = 0
    successes: int = 0
    retries: int = 0
    migrations: int = 0
    hedges: int = 0
    trips: int = 0

    def snapshot(self) -> dict:
        return {
            "state": self.state,
            "failure_rate": round(self.failure_rate, 6),
            "consecutive_errors": self.consecutive_errors,
            "failures": self.failures,
            "successes": self.successes,
            "retries": self.retries,
            "migrations": self.migrations,
            "hedges": self.hedges,
            "offline_trips": self.trips,
        }


class FleetHealth:
    """Thread-safe worker health registry shared by both dispatchers."""

    def __init__(self, config: FaultToleranceConfig | None = None):
        self.config = config or FaultToleranceConfig()
        self._v: dict[str, WorkerVitals] = {}
        self._lock = threading.Lock()

    # ----------------------------------------------------------- membership
    def add(self, worker_id: str) -> None:
        with self._lock:
            self._v.setdefault(worker_id, WorkerVitals())

    def remove(self, worker_id: str) -> None:
        with self._lock:
            self._v.pop(worker_id, None)

    def workers(self) -> list[str]:
        with self._lock:
            return sorted(self._v)

    # ------------------------------------------------------------- queries
    def state(self, worker_id: str) -> str:
        with self._lock:
            v = self._v.get(worker_id)
            return v.state if v is not None else "offline"

    def placeable(self, worker_id: str, now: float) -> bool:
        """May new batches be placed on this worker?  Reading an expired
        offline window transitions it to the half-open probation state."""
        with self._lock:
            return self._placeable_locked(worker_id, now)

    def _placeable_locked(self, worker_id: str, now: float) -> bool:
        v = self._v.get(worker_id)
        if v is None:
            return False
        if v.state == "offline":
            if now >= v.offline_until:
                v.state = "probation"
                return True
            return False
        return v.state in _PLACEABLE

    def unplaceable(self, now: float) -> set[str]:
        """Workers to exclude from ``CoManager.assign`` this cycle."""
        with self._lock:
            return {
                w for w in self._v if not self._placeable_locked(w, now)
            }

    def retryable(self, worker_id: str, now: float) -> bool:
        """May a failed batch be retried in place on this worker?  Tripped
        or draining workers are not worth retrying — migrate instead."""
        with self._lock:
            v = self._v.get(worker_id)
            return v is not None and v.state in _PLACEABLE

    # ------------------------------------------------------------ outcomes
    def on_dispatch(self, worker_id: str) -> None:
        with self._lock:
            v = self._v.get(worker_id)
            if v is None:
                return
            v.busy_slots += 1
            if v.state == "idle":
                v.state = "busy"

    def on_release(self, worker_id: str) -> None:
        """One launch landed (success, terminal failure, or migration)."""
        with self._lock:
            v = self._v.get(worker_id)
            if v is None:
                return
            v.busy_slots = max(0, v.busy_slots - 1)
            if v.state == "busy" and v.busy_slots == 0:
                v.state = "idle"

    def on_success(self, worker_id: str) -> None:
        with self._lock:
            v = self._v.get(worker_id)
            if v is None:
                return
            alpha = self.config.failure_alpha
            v.successes += 1
            v.consecutive_errors = 0
            v.failure_rate *= 1.0 - alpha
            if v.state in ("probation", "offline"):
                # half-open trial passed: close the breaker
                v.state = "busy" if v.busy_slots else "idle"

    def on_failure(self, worker_id: str, now: float) -> bool:
        """Record one failed attempt; returns True when this failure trips
        the circuit breaker (worker just went offline)."""
        with self._lock:
            v = self._v.get(worker_id)
            if v is None:
                return False
            alpha = self.config.failure_alpha
            v.failures += 1
            v.consecutive_errors += 1
            v.failure_rate = v.failure_rate * (1.0 - alpha) + alpha
            if v.state in ("draining", "maintenance", "offline"):
                return False
            trip = (
                v.state == "probation"  # half-open trial failed: re-trip
                or v.consecutive_errors >= self.config.breaker_threshold
            )
            if trip:
                v.state = "offline"
                v.offline_until = now + self.config.breaker_cooldown_s
                v.trips += 1
            return trip

    def record_retry(self, worker_id: str) -> None:
        with self._lock:
            v = self._v.get(worker_id)
            if v is not None:
                v.retries += 1

    def record_migration(self, worker_id: str) -> None:
        with self._lock:
            v = self._v.get(worker_id)
            if v is not None:
                v.migrations += 1

    def record_hedge(self, worker_id: str) -> None:
        with self._lock:
            v = self._v.get(worker_id)
            if v is not None:
                v.hedges += 1

    # -------------------------------------------------------- state control
    def mark_draining(self, worker_id: str) -> None:
        with self._lock:
            v = self._v.get(worker_id)
            if v is not None:
                v.state = "draining"

    def mark_maintenance(self, worker_id: str) -> None:
        with self._lock:
            v = self._v.get(worker_id)
            if v is not None:
                v.state = "maintenance"

    def reactivate(self, worker_id: str) -> None:
        """Return a drained/maintenance/offline worker to rotation."""
        with self._lock:
            v = self._v.get(worker_id)
            if v is not None:
                v.state = "busy" if v.busy_slots else "idle"
                v.consecutive_errors = 0
                v.offline_until = 0.0

    def snapshot(self) -> dict:
        with self._lock:
            return {w: v.snapshot() for w, v in sorted(self._v.items())}


class InjectedWorkerFault(RuntimeError):
    """Raised by :class:`FaultInjector` in place of a real worker failure."""


class FaultInjector:
    """Deterministic fault schedule applied to the real dispatchers.

    Takes the same ``worker_failures`` map as ``SystemSimulation`` (legacy
    crash floats, dicts, or :class:`FaultSpec`).  Schedule times are
    relative to the dispatcher's start: the owning dispatcher calls
    :meth:`start` with its clock at construction, and :meth:`check` raises
    :class:`InjectedWorkerFault` whenever a dispatch lands in an open
    crash window or draws a flaky drop."""

    def __init__(self, worker_failures):
        self.schedule: dict[str, FaultSpec] = normalize_failures(worker_failures)
        self._t0: float | None = None
        self._attempts: dict[str, int] = {}
        self._lock = threading.Lock()

    def start(self, now: float) -> None:
        """Pin the schedule's t=0 to the dispatcher clock (first call wins)."""
        with self._lock:
            if self._t0 is None:
                self._t0 = now

    def _rel(self, now: float) -> float:
        with self._lock:
            if self._t0 is None:
                self._t0 = now
            return now - self._t0

    def check(self, worker_id: str, now: float) -> None:
        """Called on the dispatch path immediately before kernel execution."""
        spec = self.schedule.get(worker_id)
        if spec is None:
            return
        t = self._rel(now)
        if spec.crashed(t):
            raise InjectedWorkerFault(
                f"injected {spec.kind} on worker {worker_id} at t={t:.3f}s"
            )
        if spec.kind == "flaky":
            with self._lock:
                attempt = self._attempts.get(worker_id, 0)
                self._attempts[worker_id] = attempt + 1
            if spec.drops(0, attempt, t):
                raise InjectedWorkerFault(
                    f"injected flaky drop on worker {worker_id} "
                    f"(attempt {attempt}, p={spec.p})"
                )

    def slowdown_factor(self, worker_id: str, now: float) -> float:
        spec = self.schedule.get(worker_id)
        if spec is None:
            return 1.0
        return spec.slowdown_factor(self._rel(now))


__all__ = [
    "FAULT_KINDS",
    "FaultInjector",
    "FaultSpec",
    "FaultToleranceConfig",
    "FleetHealth",
    "InjectedWorkerFault",
    "WORKER_STATES",
    "WorkerVitals",
]
