"""Dispatcher: coalesced mega-batches -> co-Manager placement -> Pallas kernel.

One ``CoalescedBatch`` becomes ONE logical circuit-bank task for Algorithm 2:
its resource demand is the spec's qubit width (the co-resident lanes of a
fused kernel batch occupy one ``n_qubits``-wide register file slot on the
worker, not ``n * width`` qubits), so the existing capacity/CRU assignment
logic routes whole batches exactly as it routed single circuits.

This module is the *synchronous real-execution* runtime: execution happens
inline on the chosen worker's mesh slice (here: the local device) and
capacity is released immediately after.  The virtual-clock counterpart lives
in ``repro.comanager.simulation`` (``gateway=True``).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Sequence

import jax.numpy as jnp

from repro.comanager.manager import CoManager
from repro.comanager.tenancy import TaskIdAllocator
from repro.comanager.worker import CircuitTask, WorkerConfig
from repro.core.sim import CircuitSpec
from repro.kernels import ops as kops
from repro.serve.coalescer import CoalescedBatch
from repro.serve.gateway import Backpressure, Gateway
from repro.serve.metrics import Telemetry

#: kernel runner signature: (spec, theta (C,P), data (C,D)) -> fidelities (C,)
KernelFn = Callable[[CircuitSpec, jnp.ndarray, jnp.ndarray], jnp.ndarray]

#: shift-group runner: (spec, theta (B,P), data (B,D), four_term, groups)
#: -> per-group fidelities (len(groups), B)
ShiftKernelFn = Callable[[CircuitSpec, jnp.ndarray, jnp.ndarray, bool,
                          tuple], jnp.ndarray]

@dataclasses.dataclass(frozen=True)
class ShiftGroupKey:
    """Coalescing key for one implicit bank's (param, shift) group subtasks.

    All groups of one submitted ``ShiftBank`` share a key (they coalesce into
    joint prefix-reuse kernel launches); ``bank_token`` keeps different banks
    — different base angles — apart."""
    spec: CircuitSpec
    bank_token: int


class Dispatcher:
    def __init__(self, gateway: Gateway, workers: Sequence[WorkerConfig],
                 *, manager: CoManager | None = None,
                 kernel: KernelFn | None = None,
                 shift_kernel: ShiftKernelFn | None = None,
                 clock=time.perf_counter):
        self.gateway = gateway
        self.manager = manager or CoManager(multi_tenant=True)
        self.kernel = kernel or kops.vqc_fidelity
        self.shift_kernel = shift_kernel or kops.vqc_fidelity_shiftgroups
        # distinguishes shift-group submissions of different banks (different
        # base angles can never share a kernel launch, so they must not
        # coalesce); per-dispatcher so concurrent runtimes stay deterministic.
        self.bank_tokens = itertools.count()
        self.clock = clock
        self.task_ids = TaskIdAllocator()
        self.batch_log: list[tuple[str, int, tuple]] = []  # (worker, n, clients)
        for w in workers:
            self.manager.register_worker(w.worker_id, w.max_qubits,
                                         cru=w.base_load, t=self.clock(),
                                         error_rate=w.error_rate)

    # ----------------------------------------------------------- execution
    @staticmethod
    def _width(batch: CoalescedBatch) -> int:
        key = batch.key
        if isinstance(key, CircuitSpec):
            return key.n_qubits
        if isinstance(key, ShiftGroupKey):
            return key.spec.n_qubits
        raise TypeError(f"dispatcher batches must be keyed by CircuitSpec or "
                        f"ShiftGroupKey, got {type(key).__name__}")

    def run_batch(self, batch: CoalescedBatch) -> str:
        """Place one batch via Algorithm 2 and execute it on the spot."""
        now = self.clock()
        task = CircuitTask(task_id=next(self.task_ids), client_id="gateway",
                           demand=self._width(batch), service_time=1.0)
        wid = self.manager.assign(task, now)
        if wid is None:
            raise RuntimeError(
                f"no worker fits a {task.demand}-qubit batch "
                f"(capacities: {[v.max_qubits for v in self.manager.workers.values()]})")
        if isinstance(batch.key, ShiftGroupKey):
            # one prefix-reuse kernel launch computes every coalesced
            # (param, shift) group of this bank; member i gets its group's
            # (B,) fidelity row.
            spec = batch.key.spec
            bank = batch.members[0].payload[0]
            groups = tuple(int(m.payload[1]) for m in batch.members)
            rows = self.shift_kernel(spec, bank.theta, bank.data,
                                     bank.four_term, groups)
            fids = [rows[i] for i in range(len(batch.members))]
        else:
            spec: CircuitSpec = batch.key
            theta = jnp.stack([m.payload[0] for m in batch.members])
            data = jnp.stack([m.payload[1] for m in batch.members])
            fids = self.kernel(spec, theta, data)
        self.manager.complete(wid, task, self.clock())
        self.gateway.complete(batch, fids, self.clock())
        self.batch_log.append((wid, batch.n, tuple(sorted(batch.clients()))))
        return wid

    # ---------------------------------------------------------------- pump
    def pump(self) -> int:
        """Coalesce what's admitted; run every emitted batch.  Returns the
        number of batches executed."""
        batches = self.gateway.pump(self.clock())
        for b in batches:
            self.run_batch(b)
        return len(batches)

    def drain(self) -> int:
        """Force-flush partial buffers and run everything (end of a bank)."""
        batches = self.gateway.flush(self.clock())
        for b in batches:
            self.run_batch(b)
        return len(batches)


class GatewayRuntime:
    """Bundled gateway + dispatcher + telemetry for local serving.

    The unit the trainer and the benchmarks hold on to: multiple training
    clients share one runtime, and their circuit banks coalesce across
    tenants into shared kernel launches.
    """

    def __init__(self, workers: Sequence[WorkerConfig] | None = None, *,
                 target: int | None = None, deadline: float = 1.0,
                 kernel: KernelFn | None = None,
                 shift_kernel: ShiftKernelFn | None = None,
                 clock=time.perf_counter, **gateway_opts):
        if workers is None:
            workers = [WorkerConfig(f"w{i+1}", q)
                       for i, q in enumerate((5, 10, 15, 20))]
        self.telemetry = Telemetry()
        self.gateway = Gateway(target=target, deadline=deadline,
                               telemetry=self.telemetry, **gateway_opts)
        self.dispatcher = Dispatcher(self.gateway, workers, kernel=kernel,
                                     shift_kernel=shift_kernel, clock=clock)

    def executor(self, spec: CircuitSpec, client_id: str,
                 *, weight: float = 1.0):
        """A ``shift_rule.Executor`` that routes a circuit bank through the
        gateway row by row and gathers fidelities in submission order —
        ``shift_rule.assemble_gradient`` consumes the result unchanged."""
        if client_id not in self.gateway.tenants:
            self.gateway.register_client(client_id, weight=weight)

        def run(theta_bank: jnp.ndarray, data_bank: jnp.ndarray) -> jnp.ndarray:
            futures = []
            for i in range(theta_bank.shape[0]):
                while True:
                    try:
                        futures.append(self.gateway.submit(
                            client_id, spec, (theta_bank[i], data_bank[i]),
                            now=self.dispatcher.clock()))
                        break
                    except Backpressure:
                        # drain in-flight work, then the queue has room again
                        self.dispatcher.drain()
            self.dispatcher.drain()
            return jnp.stack([f.value for f in futures])

        return run

    def shift_executor(self, spec: CircuitSpec, client_id: str,
                       *, weight: float = 1.0):
        """A shift-aware ``shift_rule.Executor``: an implicit ``ShiftBank``
        enters the gateway as per-(param, shift) GROUP subtasks — 1 + 2P
        admissions instead of (1 + 2P) * B — which the coalescer packs into
        joint prefix-reuse kernel launches and the co-Manager places as
        whole-batch tasks.  Group fidelities come back in bank order, so
        ``shift_rule.assemble_gradient`` consumes them unchanged.

        Plain ``(theta_bank, data_bank)`` calls are also accepted and fall
        back to per-row submission, so the executor composes with every bank
        mode."""
        row_run = self.executor(spec, client_id, weight=weight)

        def run(bank, data_bank=None) -> jnp.ndarray:
            if data_bank is not None:
                return row_run(bank, data_bank)
            key = ShiftGroupKey(spec, next(self.dispatcher.bank_tokens))
            futures = []
            for g in range(bank.n_groups):
                while True:
                    try:
                        futures.append(self.gateway.submit(
                            client_id, key, (bank, g),
                            now=self.dispatcher.clock(),
                            lanes=bank.n_samples))
                        break
                    except Backpressure:
                        self.dispatcher.drain()
            self.dispatcher.drain()
            return jnp.concatenate([f.value for f in futures])

        run.accepts_shiftbank = True
        return run
