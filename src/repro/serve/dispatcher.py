"""Dispatcher: coalesced mega-batches -> co-Manager placement -> Pallas kernel.

One ``CoalescedBatch`` becomes ONE logical circuit-bank task for Algorithm 2:
its resource demand is the spec's qubit width (the co-resident lanes of a
fused kernel batch occupy one ``n_qubits``-wide register file slot on the
worker, not ``n * width`` qubits), so the existing capacity/CRU assignment
logic routes whole batches exactly as it routed single circuits.

Cost model: every batch carries an analytic work estimate
(``batch_cost_units`` — gate applications x padded kernel lanes; for
shift-group subtasks the TRUE prefix-reuse cost, including the suffix depth
the backward pass must cover) which the ``Telemetry.service`` EWMA converts
into predicted seconds.  The prediction becomes the task's ``service_time``
AND is charged to the assigned worker's CRU while the batch is outstanding,
so Algorithm 2's lowest-CRU-first choice routes new batches toward the
worker with the least predicted backlog.

This module is the *synchronous real-execution* runtime: execution happens
inline on the chosen worker's mesh slice (here: the local device) and
capacity is released immediately after.  The non-blocking counterpart with
a pump loop and per-worker execution slots is
``repro.serve.async_dispatcher.AsyncDispatcher``; the virtual-clock
counterpart lives in ``repro.comanager.simulation`` (``gateway=True``).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Sequence

import jax.numpy as jnp

from repro.api.capabilities import declare
from repro.comanager.faults import FaultToleranceConfig
from repro.comanager.manager import CoManager
from repro.comanager.tenancy import TaskIdAllocator
from repro.comanager.worker import CircuitTask, WorkerConfig
from repro.core.sim import CircuitSpec
from repro.kernels import ops as kops
from repro.kernels.vqc_statevector import (
    LANES,
    kernel_tb,
    shift_cost_info,
    shift_execution_info,
)
from repro.serve.coalescer import CoalescedBatch
from repro.serve.fleet import FaultInjector, FleetHealth
from repro.serve.gateway import Backpressure, Gateway
from repro.serve.metrics import Telemetry

#: kernel runner signature: (spec, theta (C,P), data (C,D)) -> fidelities (C,)
KernelFn = Callable[[CircuitSpec, jnp.ndarray, jnp.ndarray], jnp.ndarray]

#: shift-group runner: (spec, theta (B,P), data (B,D), four_term, groups)
#: -> per-group fidelities (len(groups), B)
ShiftKernelFn = Callable[
    [CircuitSpec, jnp.ndarray, jnp.ndarray, bool, tuple], jnp.ndarray
]

#: fused multi-bank runner: (spec, thetas, datas, four_term, group_sets)
#: -> per-bank (len(group_sets[k]), B_k) fidelity blocks
MultiBankKernelFn = Callable[[CircuitSpec, tuple, tuple, bool, tuple], tuple]


@dataclasses.dataclass(frozen=True)
class ShiftGroupKey:
    """Coalescing key for implicit-bank (param, shift) group subtasks.

    Keyed by circuit STRUCTURE only: group subtasks of *different* banks —
    different tenants, different base angles, different sample counts — of
    the same ``CircuitSpec`` and shift rule share a key and coalesce into
    joint multi-bank prefix-reuse launches (base angles are per-lane data
    of the fused kernel, so they never had to keep banks apart)."""

    spec: CircuitSpec
    four_term: bool = False


# --------------------------------------------------------- shared execution
def batch_spec(batch: CoalescedBatch) -> CircuitSpec:
    key = batch.key
    if isinstance(key, CircuitSpec):
        return key
    if isinstance(key, ShiftGroupKey):
        return key.spec
    raise TypeError(
        f"dispatcher batches must be keyed by CircuitSpec or "
        f"ShiftGroupKey, got {type(key).__name__}"
    )


def bank_partition(batch: CoalescedBatch):
    """Split a shift-group batch's members into per-bank subtask lists.

    Returns ``(banks, group_sets, slots)``: the distinct ``ShiftBank``s in
    first-appearance order, each bank's requested group tuple, and for every
    member its ``(bank_index, row_index)`` into the fused kernel's per-bank
    output blocks."""
    banks, group_sets, slots = [], [], []
    index: dict[int, int] = {}
    for m in batch.members:
        bank, g = m.payload
        k = index.get(id(bank))
        if k is None:
            k = index[id(bank)] = len(banks)
            banks.append(bank)
            group_sets.append([])
        slots.append((k, len(group_sets[k])))
        group_sets[k].append(int(g))
    return banks, [tuple(gs) for gs in group_sets], slots


def execute_batch(
    batch: CoalescedBatch,
    kernel: KernelFn,
    shift_kernel: ShiftKernelFn,
    multibank_kernel: MultiBankKernelFn | None = None,
) -> list:
    """Run one coalesced batch on the local device; returns one fidelity
    entry per member, in member (submission) order.  Shared by the sync and
    async dispatchers — batch composition never changes per-lane math, so
    both paths are bit-identical.

    Row batches are zero-padded up to a LANES multiple (shape bucketing):
    deadline flushes emit arbitrary partial sizes, and without bucketing
    every new size costs a fresh XLA compile — under the async pump, where
    partial flushes are routine, that recompile storm dwarfs the kernel
    time.  The pad lanes are dead weight the launch already paid for
    (``CoalescedBatch.padded``) and are sliced off before scatter-back."""
    if isinstance(batch.key, ShiftGroupKey):
        # ONE prefix-reuse kernel launch computes every coalesced
        # (param, shift) group of every bank in the batch; member i gets
        # its group's (B,) fidelity row of its bank's block.
        spec = batch.key.spec
        banks, group_sets, slots = bank_partition(batch)
        if len(banks) == 1:
            rows = shift_kernel(
                spec,
                banks[0].theta,
                banks[0].data,
                banks[0].four_term,
                group_sets[0],
            )
            return [rows[i] for _, i in slots]
        # per-bank lane bucketing BEFORE the jit boundary: deadline flushes
        # mix arbitrary sample counts, and without rounding each bank to a
        # LANES multiple every new (B_0, B_1, ...) combination would compile
        # a fresh fused kernel — the same recompile storm shape bucketing
        # fixed for row batches.  Pad lanes are per-lane-independent dead
        # weight; slice each member's row back to its bank's true width.
        def bucket(x):
            pad = (-x.shape[0]) % LANES
            return jnp.pad(x, ((0, pad), (0, 0))) if pad else x

        outs = (multibank_kernel or kops.vqc_fidelity_shiftgroups_multibank)(
            spec,
            tuple(bucket(b.theta) for b in banks),
            tuple(bucket(b.data) for b in banks),
            batch.key.four_term,
            tuple(group_sets),
        )
        return [outs[k][i][: banks[k].n_samples] for k, i in slots]
    spec: CircuitSpec = batch.key
    theta = jnp.stack([m.payload[0] for m in batch.members])
    data = jnp.stack([m.payload[1] for m in batch.members])
    n = len(batch.members)
    # bucketing to LANES (not the coalescer's possibly-smaller test-time lane
    # config) is free: the Pallas kernel's internal tile is >= LANES lanes
    # for ANY batch size, so the pad rows add zero kernel work while keeping
    # the number of distinct compiled shapes minimal.
    pad = (-n) % LANES
    if pad:
        theta = jnp.pad(theta, ((0, pad), (0, 0)))
        data = jnp.pad(data, ((0, pad), (0, 0)))
    fids = kernel(spec, theta, data)
    return [fids[i] for i in range(n)]


# ------------------------------------------------------- analytic cost model
def batch_family(batch: CoalescedBatch):
    """Service-model key: batches of one structural family share an EWMA."""
    if isinstance(batch.key, ShiftGroupKey):
        return ("shift", batch.key.spec)
    return batch.key


def batch_cost_units(batch: CoalescedBatch) -> float:
    """Analytic work units of one batch: gate applications x padded lanes.

    Row batches pay the full gate sequence over their padded lane tile.
    Shift-group batches pay the analytic cost of the path the ops layer
    will actually take (``kernels.shift_cost_info`` on the UNION group
    set): the fused prefix-reuse cost — data-register pass, forward pass,
    backward pass down to the shallowest anchor, and each variant's suffix
    replay (one gate for single-use parameters, the [first, last] span for
    multi-use ones) — over the sum of the banks' padded lane segments,
    since the fused launch computes the union groups for every lane; or,
    when no plan exists / replay is analytically dearer, the per-bank
    materialized fallback cost.
    """
    spec = batch_spec(batch)
    if not isinstance(batch.key, ShiftGroupKey):
        pad = batch.padded(LANES)
        return float(len(spec.ops) * pad)
    banks, group_sets, _ = bank_partition(batch)
    pad_b = sum(math.ceil(b.n_samples / LANES) * LANES for b in banks)
    union = tuple(sorted({g for gs in group_sets for g in gs}))
    cost = shift_cost_info(spec, batch.key.four_term, union)
    if not cost["use_implicit"]:
        # fallback materializes each bank's requested groups separately
        return float(
            len(spec.ops)
            * sum(
                len(gs) * math.ceil(b.n_samples / LANES) * LANES
                for b, gs in zip(banks, group_sets)
            )
        )
    return float(cost["gate_apps_implicit"] * pad_b)


# ------------------------------------------------------- worker VMEM model
#: modeled per-worker VMEM (one TPU core's worth): batches whose working
#: set exceeds it cannot run on a single worker and spill to the mesh.
WORKER_VMEM_BYTES = 16 * 1024 * 1024


def kernel_span_args(batch: CoalescedBatch) -> dict:
    """Trace-span payload for one batch's kernel launch: the shift-plan
    execution shape (``shift_execution_info`` — fused/spill/materialize,
    launches, tiles, VMEM footprint) for shift-group batches, the padded
    row-tile footprint otherwise.  Only computed when tracing is enabled."""
    spec = batch_spec(batch)
    if isinstance(batch.key, ShiftGroupKey):
        banks, group_sets, _ = bank_partition(batch)
        lanes = sum(math.ceil(b.n_samples / LANES) * LANES for b in banks)
        union = tuple(sorted({g for gs in group_sets for g in gs}))
        info = shift_execution_info(
            spec, lanes, four_term=batch.key.four_term, groups=union
        )
        args = {
            "kind": "shift",
            "mode": info["mode"],
            "launches": info["launches"],
            "n_tiles": info["n_tiles"],
            "vmem_bytes": info["vmem_bytes"],
            "banks": len(banks),
            "lanes": lanes,
            "members": batch.n,
        }
        if info["mode"] == "spill":
            # boundary-fetch shape of the double-buffered backward launch:
            # n_tiles fetches ping-ponging two VMEM buffers, all but the
            # first overlapping the previous tile's compute.
            args["spill_buffer_bytes"] = info["spill_buffer_bytes"]
            args["boundary_fetches"] = info["n_tiles"]
            args["overlap_ratio"] = info["overlap_ratio"]
        return args
    padded = batch.padded(LANES)
    return {
        "kind": "rows",
        "mode": "rows",
        "launches": 1,
        "vmem_bytes": 2 * 4 * (2**spec.n_qubits) * kernel_tb(padded),
        "lanes": padded,
        "members": batch.n,
    }


def batch_vmem_bytes(batch: CoalescedBatch) -> int:
    """Modeled single-worker VMEM working set of one coalesced batch.

    Row batches hold the full 2**n-dim statevector tile ((re, im) float32
    at the kernel's lane-tile width).  Shift-group batches hold the
    register-local checkpoint set — already bounded by the kernel's own
    depth-tile spilling, so ``shift_execution_info`` reports the post-spill
    footprint.  The dispatcher compares this against ``WORKER_VMEM_BYTES``
    to decide mesh spill (the whole-mesh path shards lanes, shrinking the
    per-device tile back under budget)."""
    spec = batch_spec(batch)
    if isinstance(batch.key, ShiftGroupKey):
        banks, group_sets, _ = bank_partition(batch)
        lanes = sum(math.ceil(b.n_samples / LANES) * LANES for b in banks)
        union = tuple(sorted({g for gs in group_sets for g in gs}))
        info = shift_execution_info(
            spec, lanes, four_term=batch.key.four_term, groups=union
        )
        return info["vmem_bytes"]
    tb = kernel_tb(batch.padded(LANES))
    return 2 * 4 * (2**spec.n_qubits) * tb


class Dispatcher:
    def __init__(
        self,
        gateway: Gateway,
        workers: Sequence[WorkerConfig],
        *,
        manager: CoManager | None = None,
        kernel: KernelFn | None = None,
        shift_kernel: ShiftKernelFn | None = None,
        multibank_kernel: MultiBankKernelFn | None = None,
        mesh_spill: bool = True,
        spill_executor=None,
        worker_vmem_bytes: int = WORKER_VMEM_BYTES,
        clock=time.perf_counter,
        fault_tolerance: FaultToleranceConfig | None = None,
        fault_injector: FaultInjector | None = None,
    ):
        self.gateway = gateway
        self.manager = manager or CoManager(multi_tenant=True)
        self.kernel = kernel or kops.vqc_fidelity
        self.shift_kernel = shift_kernel or kops.vqc_fidelity_shiftgroups
        self.multibank_kernel = (
            multibank_kernel or kops.vqc_fidelity_shiftgroups_multibank
        )
        #: route mega-batches that fit no single worker (register width or
        #: VMEM model) through the whole-mesh sharded executor instead of
        #: failing fast; disable to restore the strict fail-fast contract.
        self.mesh_spill = mesh_spill
        self.worker_vmem_bytes = worker_vmem_bytes
        self._spill = spill_executor  # built lazily when None
        self.clock = clock
        self.task_ids = TaskIdAllocator()
        self.batch_log: list[tuple[str, int, tuple]] = []  # (worker, n, clients)
        self._base_cru: dict[str, float] = {}
        self._outstanding_s: dict[str, float] = {}  # predicted queued seconds
        self.ft = fault_tolerance or FaultToleranceConfig()
        self.fleet = FleetHealth(self.ft)
        self.fault_injector = fault_injector
        if fault_injector is not None:
            fault_injector.start(self.clock())
        self._max_width = max((w.max_qubits for w in workers), default=0)
        for w in workers:
            self._register(w)

    # ------------------------------------------------------ live membership
    def _register(self, w: WorkerConfig) -> None:
        self.manager.register_worker(
            w.worker_id,
            w.max_qubits,
            cru=w.base_load,
            t=self.clock(),
            error_rate=w.error_rate,
        )
        self._base_cru[w.worker_id] = w.base_load
        self._outstanding_s[w.worker_id] = 0.0
        self.fleet.add(w.worker_id)

    def _recompute_max_width(self) -> None:
        self._max_width = max(
            (v.max_qubits for v in self.manager.workers.values()), default=0
        )

    def register_worker(self, worker: WorkerConfig) -> None:
        """Add a worker to the fleet at runtime; it becomes placeable on
        the next batch."""
        if worker.worker_id in self._base_cru:
            raise ValueError(f"worker {worker.worker_id!r} already registered")
        self._register(worker)
        self._max_width = max(self._max_width, worker.max_qubits)

    def drain_worker(self, worker_id: str, timeout: float = 30.0) -> None:
        """Remove a worker from the fleet: stop placing on it, let in-flight
        work land, then forget it.  The sync dispatcher has no cross-call
        in-flight work, so removal is immediate."""
        if worker_id not in self._base_cru:
            raise KeyError(f"unknown worker {worker_id!r}")
        self.fleet.mark_draining(worker_id)
        self._forget_worker(worker_id)

    def _forget_worker(self, worker_id: str) -> None:
        self.manager.workers.pop(worker_id, None)
        self._base_cru.pop(worker_id, None)
        self._outstanding_s.pop(worker_id, None)
        self.fleet.remove(worker_id)
        self._recompute_max_width()

    # ------------------------------------------------------ CRU cost model
    def _estimate_s(self, batch: CoalescedBatch) -> float:
        return self.gateway.telemetry.service.estimate(
            batch_family(batch), batch_cost_units(batch)
        )

    def _charge(self, wid: str, seconds: float) -> None:
        """Add/remove predicted outstanding work from a worker's CRU: the
        EWMA service estimate is the co-Manager's view of classical load."""
        self._outstanding_s[wid] = max(
            0.0, self._outstanding_s.get(wid, 0.0) + seconds
        )
        view = self.manager.workers.get(wid)
        if view is not None:
            view.cru = self._base_cru.get(wid, 0.0) + self._outstanding_s[wid]

    def _observe(self, batch: CoalescedBatch, seconds: float) -> None:
        self.gateway.telemetry.service.update(
            batch_family(batch), batch_cost_units(batch), seconds
        )

    # ----------------------------------------------------------- execution
    @staticmethod
    def _width(batch: CoalescedBatch) -> int:
        return batch_spec(batch).n_qubits

    def _oversized(self, batch: CoalescedBatch) -> bool:
        """No single worker can run this batch: register width above every
        worker's capacity, or working set over the per-worker VMEM model.
        Memoized on the batch — composition is immutable after coalescing,
        and the async ready-queue scan re-asks on every placement pass
        (often under its condition lock)."""
        verdict = getattr(batch, "_oversized_verdict", None)
        if verdict is None:
            verdict = (
                self._width(batch) > self._max_width
                or batch_vmem_bytes(batch) > self.worker_vmem_bytes
            )
            batch._oversized_verdict = verdict
        return verdict

    def _spill_executor(self):
        if self._spill is None:
            from repro.comanager.dataplane import MeshSpillExecutor

            self._spill = MeshSpillExecutor()
        return self._spill

    def _spill_fns(self):
        """(kernel, shift_kernel, multibank_kernel) triple backed by the
        whole-mesh spill executor, so ``execute_batch`` runs unchanged."""
        ex = self._spill_executor()
        return (
            lambda spec, t, d: ex.rows(spec, t, d),
            lambda spec, t, d, ft, gs: ex.banks(
                spec, (t,), (d,), ft, (tuple(gs),)
            )[0],
            lambda spec, ts, ds, ft, gss: ex.banks(spec, ts, ds, ft, gss),
        )

    def _record(self, batch: CoalescedBatch) -> None:
        """Per-launch telemetry shared by the sync and async paths."""
        if isinstance(batch.key, ShiftGroupKey):
            banks, _, _ = bank_partition(batch)
            self.gateway.telemetry.on_fused_launch(len(banks))

    def run_spilled(self, batch: CoalescedBatch) -> str:
        """Execute one oversized batch on the whole device mesh (no single
        worker is charged — the spill path is its own resource)."""
        tr = self.gateway.telemetry.trace
        t0 = self.clock()
        if tr.enabled:
            seqs = [m.seq for m in batch.members]
            tr.batch_stage(seqs, "placed", t0, worker="mesh")
            tr.batch_stage(seqs, "dispatched", t0)
            tr.batch_stage(seqs, "kernel_start", t0)
        fids = execute_batch(batch, *self._spill_fns())
        t1 = self.clock()
        if tr.enabled:
            tr.worker_span(
                "mesh", t0, t1, kind="spill", args=kernel_span_args(batch)
            )
        self.gateway.telemetry.service.update(
            ("spill", batch_family(batch)),
            batch_cost_units(batch),
            t1 - t0,
        )
        self.gateway.telemetry.on_spill(batch.lane_count)
        self._record(batch)
        self.gateway.complete(batch, fids, self.clock())
        self.batch_log.append(("mesh", batch.n, tuple(sorted(batch.clients()))))
        return "mesh"

    def run_batch(self, batch: CoalescedBatch) -> str:
        """Place one batch via Algorithm 2 and execute it on the spot,
        retrying in place on failure and then migrating the batch to a
        surviving worker through the gateway's re-coalescing requeue."""
        now = self.clock()
        if self.mesh_spill and self._oversized(batch):
            return self.run_spilled(batch)
        est = self._estimate_s(batch)
        task = CircuitTask(
            task_id=next(self.task_ids),
            client_id="gateway",
            demand=self._width(batch),
            service_time=est,
        )
        wid = self.manager.assign(task, now, exclude=self.fleet.unplaceable(now))
        if wid is None:
            if self.mesh_spill:
                return self.run_spilled(batch)
            caps = [v.max_qubits for v in self.manager.workers.values()]
            raise RuntimeError(
                f"no worker fits a {task.demand}-qubit batch (capacities: {caps})"
            )
        self._charge(wid, est)
        self.fleet.on_dispatch(wid)
        tel = self.gateway.telemetry
        tr = tel.trace
        seqs = [m.seq for m in batch.members]
        t0 = self.clock()
        if tr.enabled:
            tr.batch_stage(seqs, "placed", t0, worker=wid)
            tr.batch_stage(seqs, "dispatched", t0)
            tr.batch_stage(seqs, "kernel_start", t0)
        attempts = 0
        while True:
            t0 = self.clock()
            try:
                if self.fault_injector is not None:
                    self.fault_injector.check(wid, t0)
                fids = execute_batch(
                    batch, self.kernel, self.shift_kernel, self.multibank_kernel
                )
                break
            except Exception as exc:
                err = exc
            now = self.clock()
            tripped = self.fleet.on_failure(wid, now)
            tel.on_worker_failure(wid)
            if tripped:
                tel.on_worker_offline(wid)
                if tr.enabled:
                    tr.batch_stage(seqs, "worker_offline", now, worker=wid)
            attempts += 1
            if attempts <= self.ft.retry_limit and self.fleet.retryable(wid, now):
                self.fleet.record_retry(wid)
                tel.on_worker_retry(wid)
                if tr.enabled:
                    tr.batch_stage(seqs, "retried", now, worker=wid)
                if self.ft.retry_backoff_s:
                    time.sleep(self.ft.retry_backoff_s * 2 ** (attempts - 1))
                continue
            # out of retries: release the failed worker's capacity, then
            # migrate through the coalescer if any surviving worker fits
            self._charge(wid, -est)
            self.manager.complete(wid, task, now)
            self.fleet.on_release(wid)
            bad = self.fleet.unplaceable(now)
            survivors = [
                w
                for w, v in self.manager.workers.items()
                if w != wid and w not in bad and v.max_qubits >= task.demand
            ]
            if survivors:
                self.fleet.record_migration(wid)
                tel.on_worker_migration(wid)
                if tr.enabled:
                    tr.batch_stage(seqs, "migrated", now, worker=wid)
                self.gateway.requeue(batch, now)
                return wid
            self.gateway.fail(batch, err, now)
            raise err
        t1 = self.clock()
        if tr.enabled:
            tr.worker_span(wid, t0, t1, args=kernel_span_args(batch))
        self._observe(batch, t1 - t0)
        self._record(batch)
        self._charge(wid, -est)
        self.manager.complete(wid, task, self.clock())
        self.fleet.on_success(wid)
        self.fleet.on_release(wid)
        self.gateway.complete(batch, fids, self.clock())
        self.batch_log.append((wid, batch.n, tuple(sorted(batch.clients()))))
        return wid

    # ---------------------------------------------------------------- pump
    def pump(self) -> int:
        """Coalesce what's admitted; run every emitted batch.  Returns the
        number of batches executed."""
        batches = self.gateway.pump(self.clock())
        for b in batches:
            self.run_batch(b)
        return len(batches)

    def drain(self) -> int:
        """Force-flush partial buffers and run everything (end of a bank).
        Loops until the gateway is empty so batches migrated back through
        the coalescer after a worker failure are re-emitted and re-placed."""
        n = 0
        while True:
            batches = self.gateway.flush(self.clock())
            if not batches:
                return n
            for b in batches:
                self.run_batch(b)
            n += len(batches)

    # lifecycle no-ops so sync/async runtimes share a shutdown path
    def start(self) -> None:
        pass

    def kick(self) -> None:
        pass

    def close(self) -> None:
        pass

    def absorb_backpressure(self) -> None:
        """A tenant queue is full: inline execution is the only way the sync
        dispatcher frees it (the async override waits for a completion
        instead of quiescing everything)."""
        self.drain()


class GatewayRuntime:
    """Bundled gateway + dispatcher + telemetry for local serving.

    The unit the trainer and the benchmarks hold on to: multiple training
    clients share one runtime, and their circuit banks coalesce across
    tenants into shared kernel launches.

    ``mode``: "sync" executes each mega-batch inline on the submitting
    thread; "async" starts an ``AsyncDispatcher`` — a pump thread plus a
    per-worker execution pool (``slots_per_worker`` in-flight mega-batches
    per worker), so kernel execution overlaps with admission, coalescing,
    and placement, and futures resolve out of order.
    """

    def __init__(
        self,
        workers: Sequence[WorkerConfig] | None = None,
        *,
        target: int | None = None,
        deadline: float = 1.0,
        kernel: KernelFn | None = None,
        shift_kernel: ShiftKernelFn | None = None,
        multibank_kernel: MultiBankKernelFn | None = None,
        mesh_spill: bool = True,
        spill_executor=None,
        worker_vmem_bytes: int = WORKER_VMEM_BYTES,
        evict_over_slo: bool = False,
        clock=time.perf_counter,
        mode: str = "sync",
        slots_per_worker: int = 1,
        observability=None,
        fault_tolerance: FaultToleranceConfig | None = None,
        fault_injector: FaultInjector | None = None,
        **gateway_opts,
    ):
        if mode not in ("sync", "async"):
            raise ValueError(f"unknown mode {mode!r}")
        if workers is None:
            workers = [
                WorkerConfig(f"w{i + 1}", q) for i, q in enumerate((5, 10, 15, 20))
            ]
        self.mode = mode
        self.telemetry = Telemetry(observability=observability)
        self.gateway = Gateway(
            target=target,
            deadline=deadline,
            telemetry=self.telemetry,
            **gateway_opts,
        )
        common = dict(
            kernel=kernel,
            shift_kernel=shift_kernel,
            multibank_kernel=multibank_kernel,
            mesh_spill=mesh_spill,
            spill_executor=spill_executor,
            worker_vmem_bytes=worker_vmem_bytes,
            clock=clock,
            fault_tolerance=fault_tolerance,
            fault_injector=fault_injector,
        )
        if mode == "async":
            from repro.serve.async_dispatcher import AsyncDispatcher

            self.dispatcher = AsyncDispatcher(
                self.gateway,
                workers,
                slots_per_worker=slots_per_worker,
                evict_over_slo=evict_over_slo,
                **common,
            )
        else:
            if evict_over_slo:
                raise ValueError(
                    "evict_over_slo requires mode='async' "
                    "(the sync dispatcher has no ready queue)"
                )
            self.dispatcher = Dispatcher(self.gateway, workers, **common)
        # kernel profiling hook: shift-plan launches report their execution
        # shape (fused/spill/materialize) to this runtime's recorder for as
        # long as the runtime is open; restored on close so runtimes nest.
        self._prev_observer = None
        self._observer_installed = False
        if self.telemetry.trace.enabled:
            self._prev_observer = kops.set_launch_observer(
                self.telemetry.trace.on_kernel_launch
            )
            self._observer_installed = True
        self.dispatcher.start()

    def close(self) -> None:
        """Stop the pump thread and worker pool (async mode; sync no-op)."""
        if self._observer_installed:
            kops.set_launch_observer(self._prev_observer)
            self._observer_installed = False
        self.dispatcher.close()

    def __enter__(self) -> "GatewayRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def executor(
        self,
        spec: CircuitSpec,
        client_id: str,
        *,
        weight: float = 1.0,
        priority: int = 1,
        slo_ms: float | None = None,
    ):
        """A ``shift_rule.Executor`` that routes a circuit bank through the
        gateway row by row and gathers fidelities in submission order —
        ``shift_rule.assemble_gradient`` consumes the result unchanged.

        In async mode submission overlaps with execution: rows stream into
        the pump loop as they are admitted, and the final gather blocks on
        the out-of-order futures."""
        if client_id not in self.gateway.tenants:
            self.gateway.register_client(
                client_id, weight=weight, priority=priority, slo_ms=slo_ms
            )

        def run(theta_bank: jnp.ndarray, data_bank: jnp.ndarray) -> jnp.ndarray:
            futures = []
            for i in range(theta_bank.shape[0]):
                while True:
                    try:
                        futures.append(
                            self.gateway.submit(
                                client_id,
                                spec,
                                (theta_bank[i], data_bank[i]),
                                now=self.dispatcher.clock(),
                            )
                        )
                        break
                    except Backpressure:
                        # sync: drain in-flight work; async: wait for a
                        # completion to free queue space without quiescing
                        self.dispatcher.absorb_backpressure()
                self.dispatcher.kick()
            self.dispatcher.drain()
            return jnp.stack([f.value for f in futures])

        return run

    def shift_executor(
        self,
        spec: CircuitSpec,
        client_id: str,
        *,
        weight: float = 1.0,
        priority: int = 1,
        slo_ms: float | None = None,
    ):
        """A shift-aware ``shift_rule.Executor``: an implicit ``ShiftBank``
        enters the gateway as per-(param, shift) GROUP subtasks — 1 + 2P
        admissions instead of (1 + 2P) * B — which the coalescer packs into
        joint prefix-reuse kernel launches and the co-Manager places as
        whole-batch tasks.  Batches are keyed by circuit STRUCTURE
        (``ShiftGroupKey``), so concurrent tenants training the same spec
        fuse their banks' subtasks into shared multi-bank launches.  Group
        fidelities come back in bank order, so
        ``shift_rule.assemble_gradient`` consumes them unchanged.

        Plain ``(theta_bank, data_bank)`` calls are also accepted and fall
        back to per-row submission, so the executor composes with every bank
        mode."""
        row_run = self.executor(
            spec, client_id, weight=weight, priority=priority, slo_ms=slo_ms
        )

        def run(bank, data_bank=None) -> jnp.ndarray:
            if data_bank is not None:
                return row_run(bank, data_bank)
            key = ShiftGroupKey(spec, bank.four_term)
            futures = []
            for g in range(bank.n_groups):
                while True:
                    try:
                        futures.append(
                            self.gateway.submit(
                                client_id,
                                key,
                                (bank, g),
                                now=self.dispatcher.clock(),
                                lanes=bank.n_samples,
                            )
                        )
                        break
                    except Backpressure:
                        self.dispatcher.absorb_backpressure()
                self.dispatcher.kick()
            self.dispatcher.drain()
            return jnp.concatenate([f.value for f in futures])

        return declare(run, shiftbank=True)
