"""Dispatcher: coalesced mega-batches -> co-Manager placement -> Pallas kernel.

One ``CoalescedBatch`` becomes ONE logical circuit-bank task for Algorithm 2:
its resource demand is the spec's qubit width (the co-resident lanes of a
fused kernel batch occupy one ``n_qubits``-wide register file slot on the
worker, not ``n * width`` qubits), so the existing capacity/CRU assignment
logic routes whole batches exactly as it routed single circuits.

This module is the *synchronous real-execution* runtime: execution happens
inline on the chosen worker's mesh slice (here: the local device) and
capacity is released immediately after.  The virtual-clock counterpart lives
in ``repro.comanager.simulation`` (``gateway=True``).
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

import jax.numpy as jnp

from repro.comanager.manager import CoManager
from repro.comanager.tenancy import TaskIdAllocator
from repro.comanager.worker import CircuitTask, WorkerConfig
from repro.core.sim import CircuitSpec
from repro.kernels import ops as kops
from repro.serve.coalescer import CoalescedBatch
from repro.serve.gateway import Backpressure, Gateway
from repro.serve.metrics import Telemetry

#: kernel runner signature: (spec, theta (C,P), data (C,D)) -> fidelities (C,)
KernelFn = Callable[[CircuitSpec, jnp.ndarray, jnp.ndarray], jnp.ndarray]


class Dispatcher:
    def __init__(self, gateway: Gateway, workers: Sequence[WorkerConfig],
                 *, manager: CoManager | None = None,
                 kernel: KernelFn | None = None, clock=time.perf_counter):
        self.gateway = gateway
        self.manager = manager or CoManager(multi_tenant=True)
        self.kernel = kernel or kops.vqc_fidelity
        self.clock = clock
        self.task_ids = TaskIdAllocator()
        self.batch_log: list[tuple[str, int, tuple]] = []  # (worker, n, clients)
        for w in workers:
            self.manager.register_worker(w.worker_id, w.max_qubits,
                                         cru=w.base_load, t=self.clock(),
                                         error_rate=w.error_rate)

    # ----------------------------------------------------------- execution
    @staticmethod
    def _width(batch: CoalescedBatch) -> int:
        key = batch.key
        if isinstance(key, CircuitSpec):
            return key.n_qubits
        raise TypeError(f"dispatcher batches must be keyed by CircuitSpec, "
                        f"got {type(key).__name__}")

    def run_batch(self, batch: CoalescedBatch) -> str:
        """Place one batch via Algorithm 2 and execute it on the spot."""
        now = self.clock()
        task = CircuitTask(task_id=next(self.task_ids), client_id="gateway",
                           demand=self._width(batch), service_time=1.0)
        wid = self.manager.assign(task, now)
        if wid is None:
            raise RuntimeError(
                f"no worker fits a {task.demand}-qubit batch "
                f"(capacities: {[v.max_qubits for v in self.manager.workers.values()]})")
        spec: CircuitSpec = batch.key
        theta = jnp.stack([m.payload[0] for m in batch.members])
        data = jnp.stack([m.payload[1] for m in batch.members])
        fids = self.kernel(spec, theta, data)
        self.manager.complete(wid, task, self.clock())
        self.gateway.complete(batch, fids, self.clock())
        self.batch_log.append((wid, batch.n, tuple(sorted(batch.clients()))))
        return wid

    # ---------------------------------------------------------------- pump
    def pump(self) -> int:
        """Coalesce what's admitted; run every emitted batch.  Returns the
        number of batches executed."""
        batches = self.gateway.pump(self.clock())
        for b in batches:
            self.run_batch(b)
        return len(batches)

    def drain(self) -> int:
        """Force-flush partial buffers and run everything (end of a bank)."""
        batches = self.gateway.flush(self.clock())
        for b in batches:
            self.run_batch(b)
        return len(batches)


class GatewayRuntime:
    """Bundled gateway + dispatcher + telemetry for local serving.

    The unit the trainer and the benchmarks hold on to: multiple training
    clients share one runtime, and their circuit banks coalesce across
    tenants into shared kernel launches.
    """

    def __init__(self, workers: Sequence[WorkerConfig] | None = None, *,
                 target: int | None = None, deadline: float = 1.0,
                 kernel: KernelFn | None = None, clock=time.perf_counter,
                 **gateway_opts):
        if workers is None:
            workers = [WorkerConfig(f"w{i+1}", q)
                       for i, q in enumerate((5, 10, 15, 20))]
        self.telemetry = Telemetry()
        self.gateway = Gateway(target=target, deadline=deadline,
                               telemetry=self.telemetry, **gateway_opts)
        self.dispatcher = Dispatcher(self.gateway, workers, kernel=kernel,
                                     clock=clock)

    def executor(self, spec: CircuitSpec, client_id: str,
                 *, weight: float = 1.0):
        """A ``shift_rule.Executor`` that routes a circuit bank through the
        gateway row by row and gathers fidelities in submission order —
        ``shift_rule.assemble_gradient`` consumes the result unchanged."""
        if client_id not in self.gateway.tenants:
            self.gateway.register_client(client_id, weight=weight)

        def run(theta_bank: jnp.ndarray, data_bank: jnp.ndarray) -> jnp.ndarray:
            futures = []
            for i in range(theta_bank.shape[0]):
                while True:
                    try:
                        futures.append(self.gateway.submit(
                            client_id, spec, (theta_bank[i], data_bank[i]),
                            now=self.dispatcher.clock()))
                        break
                    except Backpressure:
                        # drain in-flight work, then the queue has room again
                        self.dispatcher.drain()
            self.dispatcher.drain()
            return jnp.stack([f.value for f in futures])

        return run
