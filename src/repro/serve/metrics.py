"""Serving-gateway telemetry.

Tracks, per tenant: submission/completion counts, rejected (backpressured)
submissions, end-to-end circuit latency (submit -> fidelity delivered), and
SLO attainment (completions within the tenant's registered deadline);
and, per coalesced batch: occupancy against the lane-padded kernel shape.

``ServiceModel`` is the EWMA per-spec service-time estimator: the dispatcher
reports each executed batch's measured wall time together with its analytic
work units (gate applications x padded lanes), and the model's estimates
feed the co-Manager's CRU cost model — a worker's classical-resource usage
rises by the *predicted* seconds of the batches queued on it, so Algorithm 2
steers new mega-batches toward the worker with the least outstanding work,
not just the fewest resident circuits.

``lane_fill`` is the headline packing metric: of the kernel lanes the data
plane actually paid for (batches are padded up to a multiple of ``LANES``),
what fraction carried a real client circuit?  1.0 = every lane useful;
a gateway flushing mostly-empty deadline batches under light load trends
toward ``1 / LANES``.

All clocks are caller-supplied floats (virtual seconds in the simulation,
``time.perf_counter()`` seconds in the real data plane), so the same
telemetry object serves both runtimes.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Hashable, Optional

from repro.obs.config import ObservabilityConfig
from repro.obs.histogram import LogHistogram
from repro.obs.trace import TraceRecorder, _key_str


@dataclasses.dataclass
class TenantStats:
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    #: circuits preemptively evicted after their SLO budget fully elapsed
    #: while waiting for placement (each also counts as an SLO miss).
    evicted: int = 0
    first_submit: float = float("inf")
    last_complete: float = 0.0
    #: streaming end-to-end latency histogram — O(1) memory per tenant no
    #: matter how many circuits complete (percentiles within one bucket
    #: width, i.e. a 1.25x relative factor, of exact).
    latencies: LogHistogram = dataclasses.field(default_factory=LogHistogram)
    #: end-to-end latency SLO in seconds (None = best-effort tenant).
    slo_s: float | None = None
    slo_misses: int = 0
    #: federated participation counters (repro.federated): updates that
    #: made a round's quorum, arrived after the deadline (folded or not),
    #: and updates dropped entirely (staleness limit / crashed tenant).
    fed_participated: int = 0
    fed_late: int = 0
    fed_dropped: int = 0

    @property
    def circuits_per_second(self) -> float:
        span = self.last_complete - self.first_submit
        return self.completed / max(span, 1e-9)

    @property
    def slo_attainment(self) -> float | None:
        """Fraction of resolved circuits delivered within the SLO (None: no
        SLO).  Evicted circuits resolved with an error still count against
        attainment — they were admitted and missed."""
        if self.slo_s is None:
            return None
        return 1.0 - self.slo_misses / max(self.completed + self.evicted, 1)

    def latency_percentile(self, q: float) -> float:
        return self.latencies.percentile(q)


class ServiceModel:
    """EWMA seconds-per-work-unit, keyed by batch family (the CircuitSpec or
    shift-group spec): ``estimate`` = ewma[key] * units, falling back to a
    global ewma (then ``default_s``) for keys never executed.  Thread-safe:
    the async dispatcher updates it from worker-pool threads."""

    def __init__(self, alpha: float = 0.25, default_s: float = 1.0):
        self.alpha = alpha
        self.default_s = default_s
        self._per_key: dict[Hashable, float] = {}
        self._updates: dict[Hashable, int] = {}
        self._global: float | None = None
        # EWMA of |predicted - measured| / measured per update, so placement
        # cost-model drift is visible in Telemetry.summary() instead of
        # silently steering Algorithm-2 decisions.
        self._rel_error: float | None = None
        self._lock = threading.Lock()

    def update(self, key: Hashable, units: float, seconds: float) -> None:
        if units <= 0 or seconds < 0:
            return
        per_unit = seconds / units
        with self._lock:
            old = self._per_key.get(key)
            if old is not None and seconds > 0:
                rel = abs(old * units - seconds) / seconds
                self._rel_error = (
                    rel
                    if self._rel_error is None
                    else self.alpha * rel + (1 - self.alpha) * self._rel_error
                )
            self._per_key[key] = (
                per_unit
                if old is None
                else self.alpha * per_unit + (1 - self.alpha) * old
            )
            self._updates[key] = self._updates.get(key, 0) + 1
            self._global = (
                per_unit
                if self._global is None
                else self.alpha * per_unit + (1 - self.alpha) * self._global
            )

    def estimate(self, key: Hashable, units: float) -> float:
        with self._lock:
            per_unit = self._per_key.get(key, self._global)
        if per_unit is None:
            return self.default_s
        return per_unit * units

    def snapshot(self) -> dict:
        """EWMA state for the metrics summary: per-spec seconds-per-unit
        (keys rendered with the trace layer's compact spec labels) and the
        running prediction error against measured wall time."""
        with self._lock:
            per_key: dict[str, dict] = {}
            for k, v in self._per_key.items():
                label = _key_str(k)
                if label in per_key:  # distinct specs, same shape label
                    n = 2
                    while f"{label}#{n}" in per_key:
                        n += 1
                    label = f"{label}#{n}"
                per_key[label] = {
                    "s_per_unit": v,
                    "updates": self._updates.get(k, 0),
                }
            out = {
                "alpha": self.alpha,
                "global_s_per_unit": self._global,
                "per_key": dict(sorted(per_key.items())),
            }
            if self._rel_error is not None:
                out["ewma_rel_error"] = round(self._rel_error, 4)
            return out


class Telemetry:
    def __init__(
        self,
        lanes: int = 128,
        observability: Optional[ObservabilityConfig] = None,
    ):
        self.lanes = lanes
        #: lifecycle tracing + worker timelines + stage histograms; the
        #: gateway/dispatchers record into it alongside these counters.
        self.trace = TraceRecorder(observability)
        self.tenants: dict[str, TenantStats] = {}
        self.batches = 0
        self.batched_circuits = 0
        self.padded_lanes = 0
        self.deadline_flushes = 0
        self.size_flushes = 0
        # fused shift-group launches: every executed ShiftGroupKey batch is
        # ONE prefix-reuse kernel launch; ``fused_banks`` counts the implicit
        # banks it covered (> batches when cross-bank fusion is happening —
        # the K x (1+2P) -> (1+2P) launch collapse the multi-bank path buys).
        self.fused_launches = 0
        self.fused_banks = 0
        self.multibank_launches = 0      # fused launches covering >= 2 banks
        # mesh spill: mega-batches too wide/deep for any single worker that
        # were routed through the sharded whole-mesh executor instead of
        # failing fast.
        self.mesh_spills = 0
        self.spilled_lanes = 0
        # failure recovery: batches sent back through the coalescer after a
        # worker failure or drain (each re-coalesces and re-places
        # bit-identically), plus per-worker fleet event counters.
        self.migrated_batches = 0
        self.migrated_circuits = 0
        self.worker_events: dict[str, dict[str, int]] = {}
        # federated aggregation rounds closed (repro.federated coordinator).
        self.federated_rounds = 0
        self.service = ServiceModel()

    def _tenant(self, client_id: str) -> TenantStats:
        return self.tenants.setdefault(client_id, TenantStats())

    def set_slo(self, client_id: str, slo_s: float | None) -> None:
        self._tenant(client_id).slo_s = slo_s

    # ------------------------------------------------------------- events
    def on_submit(self, client_id: str, now: float) -> None:
        s = self._tenant(client_id)
        s.submitted += 1
        s.first_submit = min(s.first_submit, now)

    def on_reject(self, client_id: str) -> None:
        self._tenant(client_id).rejected += 1

    def on_batch(
        self, n_lanes: int, *, padded: int | None = None, by_deadline: bool
    ) -> None:
        """``n_lanes``: kernel lanes the batch's members occupy — member
        count for row circuits, sum of bank sample widths for shift-group
        subtasks (``CoalescedBatch.lane_count``).  ``padded``: lanes the
        launch pays for (``CoalescedBatch.padded``); defaults to padding
        ``n_lanes`` once, which is only right for shared-row batches."""
        self.batches += 1
        self.batched_circuits += n_lanes
        if padded is None:
            padded = math.ceil(n_lanes / self.lanes) * self.lanes
        self.padded_lanes += padded
        if by_deadline:
            self.deadline_flushes += 1
        else:
            self.size_flushes += 1

    def on_fused_launch(self, n_banks: int) -> None:
        """One executed shift-group mega-batch = one fused kernel launch
        covering ``n_banks`` implicit banks' (param, shift) subtasks."""
        self.fused_launches += 1
        self.fused_banks += n_banks
        if n_banks > 1:
            self.multibank_launches += 1

    def on_spill(self, lanes: int) -> None:
        """One mega-batch routed through the whole-mesh spill executor."""
        self.mesh_spills += 1
        self.spilled_lanes += lanes

    def on_evict(self, client_id: str) -> None:
        """One circuit preemptively evicted past its SLO budget: counts as
        an SLO miss without a completion."""
        s = self._tenant(client_id)
        s.evicted += 1
        s.slo_misses += 1

    def on_requeue(self, n_members: int) -> None:
        """One batch migrated back through the coalescer (worker failure,
        drain, or simulated eviction) for re-coalescing and re-placement."""
        self.migrated_batches += 1
        self.migrated_circuits += n_members

    def _worker_events(self, worker_id: str) -> dict[str, int]:
        return self.worker_events.setdefault(
            worker_id,
            {
                "failures": 0,
                "retries": 0,
                "migrations": 0,
                "hedges": 0,
                "offline_trips": 0,
            },
        )

    def on_worker_failure(self, worker_id: str) -> None:
        self._worker_events(worker_id)["failures"] += 1

    def on_worker_retry(self, worker_id: str) -> None:
        self._worker_events(worker_id)["retries"] += 1

    def on_worker_migration(self, worker_id: str) -> None:
        self._worker_events(worker_id)["migrations"] += 1

    def on_worker_hedge(self, worker_id: str) -> None:
        self._worker_events(worker_id)["hedges"] += 1

    def on_worker_offline(self, worker_id: str) -> None:
        self._worker_events(worker_id)["offline_trips"] += 1

    def on_federated_update(self, client_id: str, status: str) -> None:
        """One federated-round outcome for ``client_id``: ``participated``
        (made quorum), ``late`` (arrived past the deadline — folded into the
        next round or discounted away), or ``dropped`` (never arrived /
        exceeded the staleness limit)."""
        s = self._tenant(client_id)
        if status == "participated":
            s.fed_participated += 1
        elif status == "late":
            s.fed_late += 1
        elif status == "dropped":
            s.fed_dropped += 1
        else:
            raise ValueError(
                f"unknown federated update status {status!r}; valid: "
                "participated / late / dropped"
            )

    def on_round_aggregated(self) -> None:
        """One federated aggregation round closed by the coordinator."""
        self.federated_rounds += 1

    def on_complete(self, client_id: str, submit_time: float, now: float) -> None:
        s = self._tenant(client_id)
        s.completed += 1
        s.last_complete = max(s.last_complete, now)
        latency = now - submit_time
        s.latencies.record(latency)
        if s.slo_s is not None and latency > s.slo_s + 1e-12:
            s.slo_misses += 1

    # ------------------------------------------------------------ summary
    @property
    def lane_fill(self) -> float:
        return self.batched_circuits / max(self.padded_lanes, 1)

    @property
    def mean_batch_occupancy(self) -> float:
        return self.batched_circuits / max(self.batches, 1)

    def tenant_summary(self, client_id: str) -> dict:
        s = self._tenant(client_id)
        out = {
            "client": client_id,
            "submitted": s.submitted,
            "completed": s.completed,
            "rejected": s.rejected,
            "p50_latency_s": round(s.latency_percentile(50), 4),
            "p99_latency_s": round(s.latency_percentile(99), 4),
            "circuits_per_second": round(s.circuits_per_second, 2),
        }
        if s.evicted:
            out["evicted"] = s.evicted
        if s.slo_s is not None:
            out["slo_s"] = s.slo_s
            out["slo_misses"] = s.slo_misses
            out["slo_attainment"] = round(s.slo_attainment, 4)
        if s.fed_participated or s.fed_late or s.fed_dropped:
            out["federated"] = {
                "participated": s.fed_participated,
                "late": s.fed_late,
                "dropped": s.fed_dropped,
            }
        return out

    def summary(self) -> dict:
        done = sum(s.completed for s in self.tenants.values())
        t0 = min((s.first_submit for s in self.tenants.values()), default=0.0)
        t1 = max((s.last_complete for s in self.tenants.values()), default=0.0)
        slo_done = sum(
            s.completed + s.evicted
            for s in self.tenants.values()
            if s.slo_s is not None
        )
        slo_misses = sum(s.slo_misses for s in self.tenants.values())
        evicted = sum(s.evicted for s in self.tenants.values())
        out = {
            "tenants": [self.tenant_summary(c) for c in sorted(self.tenants)],
            "total_completed": done,
            "circuits_per_second": round(done / max(t1 - t0, 1e-9), 2),
            "batches": self.batches,
            "mean_batch_occupancy": round(self.mean_batch_occupancy, 1),
            "lane_fill": round(self.lane_fill, 3),
            "size_flushes": self.size_flushes,
            "deadline_flushes": self.deadline_flushes,
        }
        if self.fused_launches:
            out["fused_launches"] = self.fused_launches
            out["fused_banks"] = self.fused_banks
            out["multibank_launches"] = self.multibank_launches
            out["banks_per_launch"] = round(
                self.fused_banks / self.fused_launches, 2
            )
        if self.mesh_spills:
            out["mesh_spills"] = self.mesh_spills
            out["spilled_lanes"] = self.spilled_lanes
        if evicted:
            out["evicted"] = evicted
        if self.migrated_batches:
            out["migrated_batches"] = self.migrated_batches
            out["migrated_circuits"] = self.migrated_circuits
        if self.worker_events:
            out["fleet"] = {
                w: dict(ev) for w, ev in sorted(self.worker_events.items())
            }
        if self.federated_rounds:
            out["federated_rounds"] = self.federated_rounds
        if slo_done:
            out["slo_misses"] = slo_misses
            out["slo_attainment"] = round(1.0 - slo_misses / slo_done, 4)
        if self.service._per_key or self.service._global is not None:
            out["service_model"] = self.service.snapshot()
        if self.trace.enabled and self.trace.events:
            out["observability"] = self.trace.summary()
        return out
