"""Serving-gateway telemetry.

Tracks, per tenant: submission/completion counts, rejected (backpressured)
submissions, and end-to-end circuit latency (submit -> fidelity delivered);
and, per coalesced batch: occupancy against the lane-padded kernel shape.

``lane_fill`` is the headline packing metric: of the kernel lanes the data
plane actually paid for (batches are padded up to a multiple of ``LANES``),
what fraction carried a real client circuit?  1.0 = every lane useful;
a gateway flushing mostly-empty deadline batches under light load trends
toward ``1 / LANES``.

All clocks are caller-supplied floats (virtual seconds in the simulation,
``time.perf_counter()`` seconds in the real data plane), so the same
telemetry object serves both runtimes.
"""
from __future__ import annotations

import dataclasses
import math


def _percentile(sorted_xs: list[float], q: float) -> float:
    """Nearest-rank percentile on an already-sorted list (no numpy needed
    on the hot path)."""
    if not sorted_xs:
        return float("nan")
    k = max(0, min(len(sorted_xs) - 1,
                   math.ceil(q / 100.0 * len(sorted_xs)) - 1))
    return sorted_xs[k]


@dataclasses.dataclass
class TenantStats:
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    first_submit: float = float("inf")
    last_complete: float = 0.0
    latencies: list = dataclasses.field(default_factory=list)

    @property
    def circuits_per_second(self) -> float:
        span = self.last_complete - self.first_submit
        return self.completed / max(span, 1e-9)

    def latency_percentile(self, q: float) -> float:
        return _percentile(sorted(self.latencies), q)


class Telemetry:
    def __init__(self, lanes: int = 128):
        self.lanes = lanes
        self.tenants: dict[str, TenantStats] = {}
        self.batches = 0
        self.batched_circuits = 0
        self.padded_lanes = 0
        self.deadline_flushes = 0
        self.size_flushes = 0

    def _tenant(self, client_id: str) -> TenantStats:
        return self.tenants.setdefault(client_id, TenantStats())

    # ------------------------------------------------------------- events
    def on_submit(self, client_id: str, now: float) -> None:
        s = self._tenant(client_id)
        s.submitted += 1
        s.first_submit = min(s.first_submit, now)

    def on_reject(self, client_id: str) -> None:
        self._tenant(client_id).rejected += 1

    def on_batch(self, n_lanes: int, *, padded: int | None = None,
                 by_deadline: bool) -> None:
        """``n_lanes``: kernel lanes the batch's members occupy — member
        count for row circuits, sum of bank sample widths for shift-group
        subtasks (``CoalescedBatch.lane_count``).  ``padded``: lanes the
        launch pays for (``CoalescedBatch.padded``); defaults to padding
        ``n_lanes`` once, which is only right for shared-row batches."""
        self.batches += 1
        self.batched_circuits += n_lanes
        if padded is None:
            padded = math.ceil(n_lanes / self.lanes) * self.lanes
        self.padded_lanes += padded
        if by_deadline:
            self.deadline_flushes += 1
        else:
            self.size_flushes += 1

    def on_complete(self, client_id: str, submit_time: float, now: float) -> None:
        s = self._tenant(client_id)
        s.completed += 1
        s.last_complete = max(s.last_complete, now)
        s.latencies.append(now - submit_time)

    # ------------------------------------------------------------ summary
    @property
    def lane_fill(self) -> float:
        return self.batched_circuits / max(self.padded_lanes, 1)

    @property
    def mean_batch_occupancy(self) -> float:
        return self.batched_circuits / max(self.batches, 1)

    def tenant_summary(self, client_id: str) -> dict:
        s = self._tenant(client_id)
        return {
            "client": client_id,
            "submitted": s.submitted,
            "completed": s.completed,
            "rejected": s.rejected,
            "p50_latency_s": round(s.latency_percentile(50), 4),
            "p99_latency_s": round(s.latency_percentile(99), 4),
            "circuits_per_second": round(s.circuits_per_second, 2),
        }

    def summary(self) -> dict:
        done = sum(s.completed for s in self.tenants.values())
        t0 = min((s.first_submit for s in self.tenants.values()),
                 default=0.0)
        t1 = max((s.last_complete for s in self.tenants.values()),
                 default=0.0)
        return {
            "tenants": [self.tenant_summary(c) for c in sorted(self.tenants)],
            "total_completed": done,
            "circuits_per_second": round(done / max(t1 - t0, 1e-9), 2),
            "batches": self.batches,
            "mean_batch_occupancy": round(self.mean_batch_occupancy, 1),
            "lane_fill": round(self.lane_fill, 3),
            "size_flushes": self.size_flushes,
            "deadline_flushes": self.deadline_flushes,
        }
