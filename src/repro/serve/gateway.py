"""Online serving gateway: admission, fairness, backpressure.

Streaming circuit submissions from many concurrent clients enter per-client
FIFO queues; a weighted-fair scheduler (stride scheduling: each dequeue
advances the client's virtual pass by ``1/weight``, the eligible client with
the smallest pass goes next) feeds the cross-tenant coalescer; the coalescer
emits lane-aligned mega-batches for the dispatcher.

Backpressure is two-level, both bounded per tenant:
  * ``max_pending``   — admission queue depth; a client that outruns the
    system gets ``Backpressure`` raised at ``submit`` (shed load / slow the
    stream) instead of growing memory without bound;
  * ``max_in_flight`` — circuits dequeued-but-not-completed; a client at its
    cap is skipped by the fair scheduler until results return, so one heavy
    tenant cannot monopolize the coalescer's buffers either.

The gateway is clock-agnostic: every entry point takes ``now`` (virtual
seconds under the simulation's event loop, ``time.perf_counter()`` in the
real data plane).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Hashable, Optional

from repro.serve.coalescer import Coalescer, CoalescedBatch, PendingCircuit
from repro.serve.metrics import Telemetry


class Backpressure(RuntimeError):
    """Raised when a tenant's admission queue is full."""


class CircuitFuture:
    """Single-assignment result slot for one submitted circuit."""

    __slots__ = ("client_id", "seq", "submit_time", "_value", "done")

    def __init__(self, client_id: str, seq: int, submit_time: float):
        self.client_id = client_id
        self.seq = seq
        self.submit_time = submit_time
        self._value = None
        self.done = False

    def set(self, value) -> None:
        assert not self.done, f"future {self.seq} resolved twice"
        self._value = value
        self.done = True

    @property
    def value(self):
        if not self.done:
            raise RuntimeError(f"circuit {self.seq} not completed yet")
        return self._value


@dataclasses.dataclass
class TenantState:
    weight: float = 1.0
    max_pending: int = 100_000
    max_in_flight: int = 100_000
    queue: deque = dataclasses.field(default_factory=deque)
    in_flight: int = 0
    vpass: float = 0.0    # stride-scheduling virtual pass


class Gateway:
    def __init__(self, *, target: int | None = None, deadline: float = 1.0,
                 lanes: int | None = None, max_pending: int = 100_000,
                 max_in_flight: int = 100_000,
                 telemetry: Telemetry | None = None):
        from repro.kernels.vqc_statevector import LANES
        lanes = lanes or LANES
        self.coalescer = Coalescer(target=target or lanes, deadline=deadline,
                                   lanes=lanes)
        self.telemetry = telemetry or Telemetry(lanes=lanes)
        self._defaults = dict(max_pending=max_pending,
                              max_in_flight=max_in_flight)
        self.tenants: dict[str, TenantState] = {}
        self._seq = 0

    # ---------------------------------------------------------- admission
    def register_client(self, client_id: str, *, weight: float = 1.0,
                        max_pending: int | None = None,
                        max_in_flight: int | None = None) -> TenantState:
        st = TenantState(
            weight=weight,
            max_pending=max_pending or self._defaults["max_pending"],
            max_in_flight=max_in_flight or self._defaults["max_in_flight"])
        # a late joiner starts at the current minimum virtual pass — not 0,
        # which would hand it absolute priority until it "caught up" with
        # tenants that have been served for a while.
        st.vpass = min((t.vpass for t in self.tenants.values()), default=0.0)
        self.tenants[client_id] = st
        return st

    def _tenant(self, client_id: str) -> TenantState:
        st = self.tenants.get(client_id)
        if st is None:
            st = self.register_client(client_id)
        return st

    def submit(self, client_id: str, key: Hashable, payload: Any,
               now: float, lanes: int = 1) -> CircuitFuture:
        """Admit one circuit.  Raises ``Backpressure`` at the queue bound.

        ``lanes``: kernel lanes the item occupies (1 for a row circuit; a
        shift-group subtask covers its bank's B sample lanes) — feeds the
        lane-fill telemetry, not admission accounting."""
        st = self._tenant(client_id)
        if len(st.queue) >= st.max_pending:
            self.telemetry.on_reject(client_id)
            raise Backpressure(
                f"{client_id}: {len(st.queue)} pending >= {st.max_pending}")
        fut = CircuitFuture(client_id, self._seq, now)
        st.queue.append(PendingCircuit(key=key, client_id=client_id,
                                       seq=self._seq, arrival=now,
                                       payload=payload, future=fut,
                                       lanes=lanes))
        self._seq += 1
        self.telemetry.on_submit(client_id, now)
        return fut

    # ------------------------------------------------- fair dequeue + pump
    def _next_client(self) -> Optional[str]:
        """Smallest-virtual-pass eligible client (weighted fair); ties break
        on client id for determinism.  One O(T) pass — this runs once per
        dequeued circuit."""
        best = None
        for cid, st in self.tenants.items():
            if not st.queue or st.in_flight >= st.max_in_flight:
                continue
            if best is None or (st.vpass, cid) < best:
                best = (st.vpass, cid)
        return best[1] if best else None

    def pump(self, now: float) -> list[CoalescedBatch]:
        """Move admitted circuits into the coalescer in weighted-fair order,
        then collect size-triggered and deadline-due batches."""
        batches: list[CoalescedBatch] = []
        while True:
            cid = self._next_client()
            if cid is None:
                break
            st = self.tenants[cid]
            item = st.queue.popleft()
            st.vpass += 1.0 / st.weight
            st.in_flight += 1
            batches.extend(self.coalescer.add(item))
        batches.extend(self.coalescer.flush_due(now))
        for b in batches:
            self.telemetry.on_batch(b.lane_count,
                                    padded=b.padded(self.coalescer.lanes),
                                    by_deadline=b.by_deadline)
        return batches

    def flush(self, now: float) -> list[CoalescedBatch]:
        """pump() then force-drain every partial buffer (end of a bank)."""
        batches = self.pump(now)
        forced = self.coalescer.flush_all(now)
        for b in forced:
            self.telemetry.on_batch(b.lane_count,
                                    padded=b.padded(self.coalescer.lanes),
                                    by_deadline=b.by_deadline)
        return batches + forced

    # ------------------------------------------------------------ results
    def complete(self, batch: CoalescedBatch, values, now: float) -> None:
        """Scatter one executed batch's fidelities back to its futures, in
        member (submission) order.  ``values`` may be None in clock-only
        runtimes (simulation) where there is no fidelity payload."""
        for i, m in enumerate(batch.members):
            st = self.tenants[m.client_id]
            st.in_flight = max(0, st.in_flight - 1)
            if m.future is not None:
                m.future.set(values[i] if values is not None else None)
            self.telemetry.on_complete(m.client_id, m.arrival, now)

    def requeue(self, batch: CoalescedBatch) -> None:
        """Return a failed (evicted-worker) batch for re-coalescing; the
        members keep their futures and original arrivals, so nothing is
        dropped and the deadline policy re-emits them promptly.  They remain
        counted in-flight: they never went back through admission."""
        self.coalescer.requeue(batch)

    # --------------------------------------------------------- inspection
    def next_deadline(self) -> Optional[float]:
        return self.coalescer.next_deadline()

    @property
    def idle(self) -> bool:
        """True when nothing is queued or buffered (in-flight may remain)."""
        return (self.coalescer.buffered == 0
                and all(not st.queue for st in self.tenants.values()))
