"""Online serving gateway: admission, fairness, backpressure, SLOs.

Streaming circuit submissions from many concurrent clients enter per-client
FIFO queues; a two-level scheduler feeds the cross-tenant coalescer:

  * strict PRIORITY tiers — a lower ``priority`` number is served strictly
    first; tier 0 (interactive/latency-critical) always preempts tier 1
    (batch training), which preempts tier 2, and so on;
  * weighted-fair STRIDE within a tier — each dequeue advances the client's
    virtual pass by ``1/weight``; the eligible client with the smallest pass
    goes next.

SLO-aware deadlines: a tenant registered with ``slo_ms`` gives every one of
its circuits a flush budget of ``SLO_FLUSH_FRACTION`` of the SLO (the rest
is reserved for placement + kernel execution); the coalescer flushes a
shared buffer at the MIN of its members' budgets, so one latency-sensitive
tenant pulls the whole cross-tenant batch forward.  Deadline misses are
counted per tenant in ``Telemetry`` (``slo_attainment``).

Backpressure is two-level, both bounded per tenant:
  * ``max_pending``   — admission queue depth; a client that outruns the
    system gets ``Backpressure`` raised at ``submit`` (shed load / slow the
    stream) instead of growing memory without bound;
  * ``max_in_flight`` — circuits dequeued-but-not-completed; a client at its
    cap is skipped by the fair scheduler until results return, so one heavy
    tenant cannot monopolize the coalescer's buffers either.

A third, GLOBAL bound arms calibrated admission control: with
``max_system_pending`` set (see ``repro.scale.knee.calibrate_admission`` —
knee throughput x knee p99 x slack, per Little's law), once the total
OUTSTANDING count (queued + dequeued-but-not-completed, i.e. every admitted
circuit still inside the system) reaches the cap, a submit is rejected when
the tenant already holds its weighted share of the cap (floored at one
circuit, so light interactive tenants retain liveness while the heavy
hitters above their share shed).  Past the saturation knee this converts
unbounded queueing — certain SLO misses — into prompt ``Backpressure``.

The gateway is clock-agnostic: every entry point takes ``now`` (virtual
seconds under the simulation's event loop, ``time.perf_counter()`` in the
real data plane).

Thread safety: all mutating entry points (``submit``, ``pump``, ``flush``,
``complete``, ``fail``, ``requeue``) take an internal re-entrant lock, so
the async dispatcher's pump loop and worker-pool completion threads can run
concurrently with user threads calling ``submit``.  ``CircuitFuture``
resolution is single-assignment behind that lock; ``CircuitFuture.result``
blocks on an event and is safe to call from any thread.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
from collections import deque
from typing import Any, Hashable, Optional

from repro.serve.coalescer import Coalescer, CoalescedBatch, PendingCircuit
from repro.serve.metrics import Telemetry

#: fraction of a tenant's latency SLO spent waiting in the coalescer; the
#: remainder is budget for placement + kernel execution + scatter-back.
SLO_FLUSH_FRACTION = 0.5


class Backpressure(RuntimeError):
    """Raised when a tenant's admission queue is full."""


class DeadlineExceeded(RuntimeError):
    """A circuit's full SLO budget elapsed before execution and it was
    preemptively evicted from the ready queue (load shedding: finishing it
    could only produce an already-missed result while delaying others)."""


class CircuitFuture:
    """Single-assignment result slot for one submitted circuit.

    Under the async dispatcher, futures resolve out of submission order from
    worker-pool threads: ``done``/``value`` stay cheap for polling loops, and
    ``result(timeout)`` blocks on an event for cross-thread waits.  A failed
    batch execution resolves its futures with ``set_error``; reading them
    re-raises the execution error in the waiting thread.
    """

    __slots__ = (
        "client_id",
        "seq",
        "submit_time",
        "_value",
        "_error",
        "done",
        "_event",
    )

    def __init__(self, client_id: str, seq: int, submit_time: float):
        self.client_id = client_id
        self.seq = seq
        self.submit_time = submit_time
        self._value = None
        self._error = None
        self.done = False
        self._event = threading.Event()

    def set(self, value) -> None:
        assert not self.done, f"future {self.seq} resolved twice"
        self._value = value
        self.done = True
        self._event.set()

    def set_error(self, exc: BaseException) -> None:
        assert not self.done, f"future {self.seq} resolved twice"
        self._error = exc
        self.done = True
        self._event.set()

    @property
    def value(self):
        if not self.done:
            raise RuntimeError(f"circuit {self.seq} not completed yet")
        if self._error is not None:
            raise self._error
        return self._value

    def result(self, timeout: float | None = None):
        """Block until resolved; returns the value or re-raises the batch's
        execution error."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"circuit {self.seq} not completed within {timeout}s"
            )
        return self.value


@dataclasses.dataclass
class TenantState:
    weight: float = 1.0
    priority: int = 1     # strict tier: lower value = served strictly first
    slo_s: Optional[float] = None  # end-to-end latency SLO (None: best-effort)
    max_pending: int = 100_000
    max_in_flight: int = 100_000
    queue: deque = dataclasses.field(default_factory=deque)
    in_flight: int = 0
    vpass: float = 0.0    # stride-scheduling virtual pass (within its tier)
    #: the (priority, vpass, cid) entry currently live in the scheduler heap
    #: for this tenant, or None; compared by IDENTITY so popped entries from
    #: an earlier registration can never masquerade as current.
    heap_key: Optional[tuple] = dataclasses.field(default=None, repr=False)


class Gateway:
    def __init__(
        self,
        *,
        target: int | None = None,
        deadline: float = 1.0,
        lanes: int | None = None,
        target_lanes: int | None = None,
        max_pending: int = 100_000,
        max_in_flight: int = 100_000,
        max_system_pending: int | None = None,
        max_pending_per_tier: dict[int, int] | None = None,
        telemetry: Telemetry | None = None,
    ):
        from repro.kernels.vqc_statevector import LANES
        lanes = lanes or LANES
        self.coalescer = Coalescer(
            target=target or lanes,
            deadline=deadline,
            lanes=lanes,
            target_lanes=target_lanes,
        )
        self.telemetry = telemetry or Telemetry(lanes=lanes)
        self._defaults = dict(max_pending=max_pending, max_in_flight=max_in_flight)
        self.max_system_pending = max_system_pending
        # per-priority-tier admission caps: the global weighted-fair cap
        # alone still lets a low-tier burst consume headroom a high tier
        # needs between refresh points; a tier cap bounds each tier's
        # outstanding circuits (queued + in flight) independently, shedding
        # weighted-fair WITHIN the tier.
        for tier, tier_cap in (max_pending_per_tier or {}).items():
            if tier_cap < 1:
                raise ValueError(
                    f"max_pending_per_tier[{tier}] must be >= 1, got {tier_cap}"
                )
        self.max_pending_per_tier = dict(max_pending_per_tier or {})
        self._tier_outstanding: dict[int, int] = {}
        self._tier_weight: dict[int, float] = {}
        self.tenants: dict[str, TenantState] = {}
        self._seq = 0
        # scheduler heap of (priority, vpass, cid): every ELIGIBLE tenant
        # (non-empty queue, below its in-flight cap) has exactly one entry
        # carrying its current pass; stale entries are invalidated lazily on
        # pop via the tenant's ``heap_key`` identity marker.  Makes the fair
        # dequeue O(log T) instead of an O(T) scan — the difference between
        # minutes and hours on a 10k-tenant storm.
        self._heap: list[tuple] = []
        self._pending_total = 0     # sum of all tenant queue depths
        self._inflight_total = 0    # sum of all tenant in-flight counts
        self._weight_total = 0.0    # sum of registered tenant weights
        # min vpass per priority tier, for O(1) late-joiner placement; an
        # entry goes None (dirty -> recompute on next use) when the tenant
        # that owned the minimum advances its pass.
        self._tier_vmin: dict[int, float | None] = {}
        # serializes queue/coalescer/telemetry mutation against the async
        # dispatcher's pump + completion threads; re-entrant because flush()
        # pumps and submit() may auto-register under the same lock.
        self._lock = threading.RLock()

    # ---------------------------------------------------------- admission
    def register_client(
        self,
        client_id: str,
        *,
        weight: float = 1.0,
        priority: int = 1,
        slo_ms: float | None = None,
        max_pending: int | None = None,
        max_in_flight: int | None = None,
    ) -> TenantState:
        """``priority``: strict scheduling tier (lower = first).  ``slo_ms``:
        end-to-end latency SLO; shortens the coalescer flush deadline for
        this tenant's circuits and arms deadline-miss accounting."""
        with self._lock:
            st = TenantState(
                weight=weight,
                priority=priority,
                slo_s=None if slo_ms is None else slo_ms / 1e3,
                max_pending=max_pending or self._defaults["max_pending"],
                max_in_flight=max_in_flight or self._defaults["max_in_flight"],
            )
            # a late joiner starts at the current minimum virtual pass OF ITS
            # TIER — not 0, which would hand it absolute priority within the
            # tier until it "caught up" with tenants served for a while.
            vmin = self._tier_vmin.get(priority)
            if vmin is None:
                vmin = min(
                    (t.vpass for t in self.tenants.values() if t.priority == priority),
                    default=0.0,
                )
            st.vpass = vmin
            self._tier_vmin[priority] = vmin  # joiner AT the min keeps it exact
            prev = self.tenants.get(client_id)
            if prev is not None:  # re-registration replaces the old state
                self._weight_total -= prev.weight
                self._pending_total -= len(prev.queue)
                self._inflight_total -= prev.in_flight
                self._tier_weight[prev.priority] -= prev.weight
                self._tier_outstanding[prev.priority] = self._tier_outstanding.get(
                    prev.priority, 0
                ) - (len(prev.queue) + prev.in_flight)
                if prev.priority != priority:
                    self._tier_vmin[prev.priority] = None
            self._weight_total += weight
            self._tier_weight[priority] = (
                self._tier_weight.get(priority, 0.0) + weight
            )
            self.tenants[client_id] = st
            self._mark_ready(client_id, st)
            self.telemetry.set_slo(client_id, st.slo_s)
            return st

    def _tenant(self, client_id: str) -> TenantState:
        st = self.tenants.get(client_id)
        if st is None:
            st = self.register_client(client_id)
        return st

    def submit(
        self, client_id: str, key: Hashable, payload: Any, now: float, lanes: int = 1
    ) -> CircuitFuture:
        """Admit one circuit.  Raises ``Backpressure`` at the queue bound.

        ``lanes``: kernel lanes the item occupies (1 for a row circuit; a
        shift-group subtask covers its bank's B sample lanes) — feeds the
        lane-fill telemetry, not admission accounting."""
        with self._lock:
            st = self._tenant(client_id)
            if len(st.queue) >= st.max_pending:
                self.telemetry.on_reject(client_id)
                self.telemetry.trace.circuit_reject(self._seq, client_id, key, now)
                raise Backpressure(
                    f"{client_id}: {len(st.queue)} pending >= {st.max_pending}"
                )
            cap = self.max_system_pending
            outstanding = self._pending_total + self._inflight_total
            if cap is not None and outstanding >= cap:
                # system saturated (every admitted circuit still inside it
                # counts — queued OR in flight): shed from tenants at/above
                # their weighted share of the cap (floored at one circuit,
                # so light tenants keep liveness while the hitters above
                # share take the hit).
                share = max(1.0, cap * st.weight / max(self._weight_total, 1e-9))
                mine = len(st.queue) + st.in_flight
                if mine + 1 > share:
                    self.telemetry.on_reject(client_id)
                    self.telemetry.trace.circuit_reject(
                        self._seq, client_id, key, now
                    )
                    raise Backpressure(
                        f"{client_id}: system at admission cap "
                        f"({outstanding} >= {cap}) and tenant above its "
                        f"weighted share ({mine} >= {share:.1f})"
                    )
            tier_cap = self.max_pending_per_tier.get(st.priority)
            if tier_cap is not None:
                tier_out = self._tier_outstanding.get(st.priority, 0)
                if tier_out >= tier_cap:
                    # tier saturated: shed weighted-fair WITHIN the tier
                    # (same floor-at-one rule as the global cap), so one
                    # tier's burst can never consume another tier's headroom
                    tier_w = max(self._tier_weight.get(st.priority, 0.0), 1e-9)
                    share = max(1.0, tier_cap * st.weight / tier_w)
                    mine = len(st.queue) + st.in_flight
                    if mine + 1 > share:
                        self.telemetry.on_reject(client_id)
                        self.telemetry.trace.circuit_reject(
                            self._seq, client_id, key, now
                        )
                        raise Backpressure(
                            f"{client_id}: tier {st.priority} at admission "
                            f"cap ({tier_out} >= {tier_cap}) and tenant "
                            f"above its weighted share ({mine} >= "
                            f"{share:.1f})"
                        )
            fut = CircuitFuture(client_id, self._seq, now)
            flush_by = (
                None
                if st.slo_s is None
                else now
                + min(self.coalescer.deadline, SLO_FLUSH_FRACTION * st.slo_s)
            )
            st.queue.append(
                PendingCircuit(
                    key=key,
                    client_id=client_id,
                    seq=self._seq,
                    arrival=now,
                    payload=payload,
                    future=fut,
                    lanes=lanes,
                    flush_by=flush_by,
                )
            )
            self._seq += 1
            self._pending_total += 1
            self._tier_outstanding[st.priority] = (
                self._tier_outstanding.get(st.priority, 0) + 1
            )
            self._mark_ready(client_id, st)
            self.telemetry.on_submit(client_id, now)
            self.telemetry.trace.circuit_submit(
                fut.seq, client_id, key, now, queue_depth=len(st.queue)
            )
            return fut

    # ------------------------------------------------- fair dequeue + pump
    def _mark_ready(self, cid: str, st: TenantState) -> None:
        """Arm the tenant's scheduler-heap entry if it is eligible for
        dequeue and has none live.  ``heap_key`` holds the live entry (by
        identity); priority/vpass only change while no entry is live, so a
        live entry always carries the tenant's current pass."""
        if st.heap_key is None and st.queue and st.in_flight < st.max_in_flight:
            entry = (st.priority, st.vpass, cid)
            st.heap_key = entry
            heapq.heappush(self._heap, entry)

    def _next_client(self) -> Optional[str]:
        """Two-level pick: strict priority tier first, then smallest virtual
        pass within the tier (weighted fair); ties break on client id for
        determinism.  O(log T) heap pop with lazy invalidation — entries
        that no longer match their tenant's ``heap_key`` (superseded) or
        whose tenant turned ineligible are discarded; every eligible tenant
        has a current entry, so the first live hit IS the global minimum,
        exactly what the old O(T) scan returned."""
        while self._heap:
            entry = heapq.heappop(self._heap)
            st = self.tenants.get(entry[2])
            if st is None or entry is not st.heap_key:
                continue  # stale: superseded or from a dead registration
            st.heap_key = None  # consumed; caller re-arms after the dequeue
            if st.queue and st.in_flight < st.max_in_flight:
                return entry[2]
            # current but ineligible (drained / at in-flight cap): drop it;
            # submit()/complete()/fail()/evict() re-arm on state change.
        return None

    def pump(self, now: float) -> list[CoalescedBatch]:
        """Move admitted circuits into the coalescer in priority-then-fair
        order, then collect size-triggered and deadline-due batches."""
        with self._lock:
            tr = self.telemetry.trace
            batches: list[CoalescedBatch] = []
            while True:
                cid = self._next_client()
                if cid is None:
                    break
                st = self.tenants[cid]
                item = st.queue.popleft()
                self._pending_total -= 1
                vmin = self._tier_vmin.get(st.priority)
                if vmin is not None and st.vpass <= vmin:
                    # the tier minimum may have advanced: recompute lazily
                    self._tier_vmin[st.priority] = None
                st.vpass += 1.0 / st.weight
                st.in_flight += 1
                self._inflight_total += 1
                self._mark_ready(cid, st)
                tr.circuit_stage(item.seq, "admit", now)
                batches.extend(self.coalescer.add(item))
            batches.extend(self.coalescer.flush_due(now))
            for b in batches:
                self.telemetry.on_batch(
                    b.lane_count,
                    padded=b.padded(self.coalescer.lanes),
                    by_deadline=b.by_deadline,
                )
                if tr.enabled:
                    tr.batch_stage((m.seq for m in b.members), "coalesced", now)
            tr.coalescer_sample(
                self.coalescer.buffered, self.coalescer.buffered_lanes
            )
            return batches

    def flush(self, now: float) -> list[CoalescedBatch]:
        """pump() then force-drain every partial buffer (end of a bank)."""
        with self._lock:
            batches = self.pump(now)
            forced = self.coalescer.flush_all(now)
            tr = self.telemetry.trace
            for b in forced:
                self.telemetry.on_batch(
                    b.lane_count,
                    padded=b.padded(self.coalescer.lanes),
                    by_deadline=b.by_deadline,
                )
                if tr.enabled:
                    tr.batch_stage((m.seq for m in b.members), "coalesced", now)
            return batches + forced

    # ------------------------------------------------------------ results
    def complete(self, batch: CoalescedBatch, values, now: float) -> None:
        """Scatter one executed batch's fidelities back to its futures, in
        member (submission) order.  ``values`` may be None in clock-only
        runtimes (simulation) where there is no fidelity payload."""
        with self._lock:
            for i, m in enumerate(batch.members):
                st = self.tenants[m.client_id]
                if st.in_flight > 0:
                    st.in_flight -= 1
                    self._inflight_total -= 1
                    self._tier_outstanding[st.priority] = (
                        self._tier_outstanding.get(st.priority, 1) - 1
                    )
                self._mark_ready(m.client_id, st)
                if m.future is not None:
                    m.future.set(values[i] if values is not None else None)
                self.telemetry.on_complete(m.client_id, m.arrival, now)
                self.telemetry.trace.circuit_end(m.seq, "complete", now)

    def fail(self, batch: CoalescedBatch, exc: BaseException, now: float) -> None:
        """Resolve a batch whose execution errored: every member future
        re-raises ``exc``; tenant in-flight accounting is released so the
        scheduler is not wedged by a poisoned batch."""
        with self._lock:
            for m in batch.members:
                st = self.tenants[m.client_id]
                if st.in_flight > 0:
                    st.in_flight -= 1
                    self._inflight_total -= 1
                    self._tier_outstanding[st.priority] = (
                        self._tier_outstanding.get(st.priority, 1) - 1
                    )
                self._mark_ready(m.client_id, st)
                if m.future is not None:
                    m.future.set_error(exc)
                self.telemetry.trace.circuit_end(m.seq, "fail", now)

    def evict(self, batch: CoalescedBatch, now: float) -> None:
        """Preemptively shed a batch whose members' SLO budgets fully
        elapsed before placement: every future resolves with
        ``DeadlineExceeded`` (already a guaranteed miss) and the misses are
        accounted per tenant, freeing the ready queue for work that can
        still make its deadline."""
        with self._lock:
            for m in batch.members:
                st = self.tenants[m.client_id]
                if st.in_flight > 0:
                    st.in_flight -= 1
                    self._inflight_total -= 1
                    self._tier_outstanding[st.priority] = (
                        self._tier_outstanding.get(st.priority, 1) - 1
                    )
                self._mark_ready(m.client_id, st)
                if m.future is not None:
                    m.future.set_error(
                        DeadlineExceeded(
                            f"circuit {m.seq} ({m.client_id}): SLO budget "
                            f"elapsed after {now - m.arrival:.3f}s in queue"
                        )
                    )
                self.telemetry.on_evict(m.client_id)
                self.telemetry.trace.circuit_end(m.seq, "evict", now)

    def requeue(self, batch: CoalescedBatch, now: float | None = None) -> None:
        """Return a failed (evicted-worker) batch for re-coalescing; the
        members keep their futures and original arrivals, so nothing is
        dropped and the deadline policy re-emits them promptly.  They remain
        counted in-flight: they never went back through admission."""
        with self._lock:
            self.coalescer.requeue(batch)
            self.telemetry.on_requeue(len(batch.members))
            tr = self.telemetry.trace
            if now is not None and tr.enabled:
                tr.batch_stage((m.seq for m in batch.members), "requeue", now)

    # --------------------------------------------------------- inspection
    def next_deadline(self) -> Optional[float]:
        with self._lock:
            return self.coalescer.next_deadline()

    @property
    def idle(self) -> bool:
        """True when nothing is queued or buffered (in-flight may remain).
        O(1) via the pending counter — this is polled once per completion,
        so an O(T) tenant scan would dominate storm-scale simulations."""
        with self._lock:
            return self.coalescer.buffered == 0 and self._pending_total == 0
