"""Cross-tenant circuit-bank coalescing.

The fused Pallas VQC kernel executes a *lane-aligned* batch of structurally
identical circuits (same gate sequence, per-lane angles) in one pass — a
tile of ``LANES`` (128) circuits costs roughly the same as one.  The
coalescer exploits that: circuits submitted by *different* tenants are keyed
by circuit structure and packed into shared mega-batches, so a worker
dispatch carries up to ``target`` circuits instead of one.

Flush policy is size-or-deadline:
  * size   — the moment a key's buffer reaches ``target`` circuits (a
             multiple of ``lanes``), a full batch is emitted;
  * deadline — a buffered circuit never waits longer than ``deadline``
             (bounded latency under light load: partial batches are emitted
             when their oldest member ages out).  An item may carry its own
             earlier ``flush_by`` (SLO-aware gateways set it from the
             tenant's latency SLO): a buffer's effective flush deadline is
             the MIN over its members, so one latency-sensitive circuit
             pulls the whole shared batch forward.

Keys are any hashable: the real data plane uses the ``CircuitSpec`` itself
(frozen dataclass — hash == structural identity), the virtual-clock
simulation uses ``(demand, service_time, depth)`` tuples.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Hashable, Optional

from repro.kernels.vqc_statevector import LANES


@dataclasses.dataclass
class PendingCircuit:
    """One admitted circuit waiting to be coalesced."""
    key: Hashable
    client_id: str
    seq: int              # gateway-wide admission sequence number
    arrival: float
    payload: Any          # (theta_row, data_row) | simulation CircuitTask
    future: Any = None    # CircuitFuture in the real data plane
    lanes: int = 1        # kernel lanes this item occupies (a shift-group
                          # subtask covers its bank's B sample lanes)
    flush_by: Optional[float] = None  # SLO-derived flush deadline; None ->
                                      # default (arrival + deadline)


@dataclasses.dataclass
class CoalescedBatch:
    """A lane-packable unit of work: all members share ``key``."""
    key: Hashable
    members: list[PendingCircuit]
    created: float
    by_deadline: bool = False

    @property
    def n(self) -> int:
        return len(self.members)

    @property
    def lane_count(self) -> int:
        """Kernel lanes the members actually occupy (== n for row circuits)."""
        return sum(m.lanes for m in self.members)

    def padded(self, lanes: int = LANES) -> int:
        """Lanes the kernel launch actually pays for.

        Row circuits share lane rows, so the batch pads once as a whole; a
        multi-lane member (shift-group subtask) gets its own kernel rows and
        pads its B sample lanes independently."""
        if all(m.lanes == 1 for m in self.members):
            return math.ceil(self.n / lanes) * lanes
        return sum(math.ceil(m.lanes / lanes) * lanes for m in self.members)

    @property
    def lane_fill(self) -> float:
        return self.lane_count / self.padded()

    def clients(self) -> set[str]:
        return {m.client_id for m in self.members}


class Coalescer:
    def __init__(
        self,
        *,
        target: int = LANES,
        deadline: float = 1.0,
        lanes: int = LANES,
        target_lanes: int | None = None,
    ):
        if target % lanes:
            raise ValueError(f"target {target} must be a multiple of lanes {lanes}")
        self.target = target
        self.deadline = deadline
        self.lanes = lanes
        #: optional LANE-weighted size trigger: a buffer whose members
        #: occupy this many kernel lanes flushes even below ``target``
        #: members.  Row circuits occupy one lane each, so member count is
        #: the right measure for them — but a shift-group subtask occupies
        #: its bank's B sample lanes, and a buffer of a few dozen such
        #: members can already be a multi-thousand-lane fused launch.
        self.target_lanes = target_lanes
        self._buffers: dict[Hashable, list[PendingCircuit]] = {}

    # ------------------------------------------------------------- intake
    def _size_due(self, buf: list[PendingCircuit]) -> int:
        """Members to emit for a size-triggered flush (0 = not due)."""
        if len(buf) >= self.target:
            return self.target
        if self.target_lanes is not None:
            filled = 0
            for i, m in enumerate(buf):
                filled += m.lanes
                if filled >= self.target_lanes:
                    return i + 1
        return 0

    def add(self, item: PendingCircuit) -> list[CoalescedBatch]:
        """Buffer one circuit; returns any size-triggered full batches."""
        buf = self._buffers.setdefault(item.key, [])
        buf.append(item)
        out = []
        while True:
            n = self._size_due(buf)
            if not n:
                break
            out.append(CoalescedBatch(item.key, buf[:n], created=item.arrival))
            del buf[:n]
        return out

    def requeue(self, batch: CoalescedBatch) -> None:
        """Return a failed batch's members to the FRONT of their buffer
        (eviction recovery).  Their original arrival times are kept, so the
        deadline policy flushes them promptly, possibly merged with newer
        arrivals — the batch is genuinely re-coalesced, not replayed."""
        buf = self._buffers.setdefault(batch.key, [])
        buf[:0] = batch.members

    def _due_at(self, buf: list[PendingCircuit]) -> float:
        """Effective flush deadline of one buffer: min over members of their
        SLO-derived ``flush_by`` (falling back to arrival + deadline)."""
        return min(
            m.arrival + self.deadline if m.flush_by is None else m.flush_by
            for m in buf
        )

    # -------------------------------------------------------------- flush
    def flush_due(self, now: float) -> list[CoalescedBatch]:
        """Emit partial batches whose flush deadline has passed (the oldest
        member aged out, or a member's SLO budget ran down)."""
        out = []
        for key, buf in self._buffers.items():
            if buf and now + 1e-12 >= self._due_at(buf):
                out.append(
                    CoalescedBatch(
                        key, buf[: self.target], created=now, by_deadline=True
                    )
                )
                del buf[: self.target]
        self._drop_empty()
        return out

    def flush_all(self, now: float) -> list[CoalescedBatch]:
        """Drain everything (end of a bank / shutdown)."""
        out = []
        for key, buf in self._buffers.items():
            while buf:
                out.append(
                    CoalescedBatch(
                        key, buf[: self.target], created=now, by_deadline=True
                    )
                )
                del buf[: self.target]
        self._drop_empty()
        return out

    def _drop_empty(self) -> None:
        """Retire emptied buffers: single-use keys (one per submitted
        ShiftBank) would otherwise accumulate forever and every pump scans
        the whole dict."""
        for key in [k for k, buf in self._buffers.items() if not buf]:
            del self._buffers[key]

    # ---------------------------------------------------------- inspection
    def next_deadline(self) -> Optional[float]:
        """Earliest time at which some buffered circuit must be flushed."""
        dues = [self._due_at(buf) for buf in self._buffers.values() if buf]
        return min(dues) if dues else None

    @property
    def buffered(self) -> int:
        return sum(len(b) for b in self._buffers.values())

    @property
    def buffered_lanes(self) -> int:
        """Lane-weighted buffer depth: kernel lanes the buffered circuits
        will occupy when flushed (== ``buffered`` for row circuits; a
        shift-group subtask weighs its bank's sample width).  The depth
        metric the observability layer samples each pump."""
        return sum(m.lanes for b in self._buffers.values() for m in b)

    def oldest_wait(self, now: float) -> float:
        """Age of the oldest buffered circuit (0.0 when empty)."""
        arrivals = [m.arrival for b in self._buffers.values() for m in b]
        return now - min(arrivals) if arrivals else 0.0
