"""Knee-point discovery + admission-control calibration.

``sweep`` replays one seeded storm recipe at increasing offered loads and
collects a ``SweepPoint`` per load: offered vs achieved throughput, the
population p99, SLO attainment, and the obs-layer congestion signals
(queue-depth p99, coalesce-wait share).  ``find_knee`` walks the curve and
locates the *throughput knee* — the last operating point where the system
still converts offered load into completions efficiently AND holds its
SLOs — plus the attainment cliff right past it.

``calibrate_admission`` then turns the knee into a policy: a Little's-law
global pending cap (knee throughput x knee p99 x slack) for the gateway's
weighted-fair admission control, so past-knee storms shed load at submit
instead of queueing into certain SLO misses.  ``verify_admission`` replays
a past-knee storm with the cap armed and reports the improvement.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from repro.scale.ergonomics import CumulativeTimer, IntervalTicker
from repro.scale.replay import ReplayResult, replay_sim
from repro.scale.workload import WorkloadSpec


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One operating point of the offered-load sweep."""

    load: float
    n_tenants: int
    offered_cps: float
    achieved_cps: float
    p99_latency_s: float
    slo_attainment: float | None
    reject_fraction: float
    queue_depth_p99: float | None
    coalesce_wait_share: float | None
    makespan_s: float

    @property
    def efficiency(self) -> float:
        """Achieved / offered throughput (1.0 = keeping up)."""
        return self.achieved_cps / max(self.offered_cps, 1e-9)

    def row(self) -> dict:
        return {
            "load": self.load,
            "offered_cps": round(self.offered_cps, 2),
            "achieved_cps": round(self.achieved_cps, 2),
            "efficiency": round(self.efficiency, 4),
            "p99_latency_s": round(self.p99_latency_s, 4),
            "slo_attainment": self.slo_attainment,
            "reject_fraction": round(self.reject_fraction, 4),
            "queue_depth_p99": self.queue_depth_p99,
            "coalesce_wait_share": self.coalesce_wait_share,
            "makespan_s": round(self.makespan_s, 3),
        }


def _point(load: float, res: ReplayResult) -> SweepPoint:
    return SweepPoint(
        load=load,
        n_tenants=res.n_tenants,
        offered_cps=res.offered_cps,
        achieved_cps=res.achieved_cps,
        p99_latency_s=res.p99_latency_s,
        slo_attainment=res.slo_attainment,
        reject_fraction=res.reject_fraction,
        queue_depth_p99=res.queue_depth_p99,
        coalesce_wait_share=res.coalesce_wait_share,
        makespan_s=res.makespan_s,
    )


def sweep(
    spec: WorkloadSpec,
    loads: Sequence[float],
    *,
    timer: CumulativeTimer | None = None,
    progress: Callable[[str], None] | None = None,
    tick_s: float = 5.0,
    **replay_kwargs,
) -> list[SweepPoint]:
    """Replay ``spec`` at each load multiplier (ascending), one seeded
    regeneration + virtual-clock replay per point."""
    timer = timer or CumulativeTimer()
    ticker = IntervalTicker(tick_s)
    points: list[SweepPoint] = []
    for load in sorted(loads):
        with timer.time("generate"):
            trace = spec.at_load(load).generate()
        with timer.time("replay"):
            res = replay_sim(trace, **replay_kwargs)
        points.append(_point(load, res))
        if progress is not None and ticker.tick():
            p = points[-1]
            progress(
                f"load {load:g}: offered {p.offered_cps:.0f} c/s -> "
                f"achieved {p.achieved_cps:.0f} c/s "
                f"(eff {p.efficiency:.2f}, p99 {p.p99_latency_s:.2f}s, "
                f"attainment {p.slo_attainment})"
            )
    return points


@dataclasses.dataclass(frozen=True)
class KneeReport:
    """The located knee + the cliff past it + the full curve."""

    knee: SweepPoint
    cliff: SweepPoint | None
    points: tuple[SweepPoint, ...]
    efficiency_floor: float
    attainment_floor: float

    @property
    def saturated(self) -> bool:
        """True when the sweep actually pushed past the knee (some point
        violated a floor) — a sweep that never saturates found no knee,
        only a lower bound."""
        return any(not self._healthy(p) for p in self.points)

    def _healthy(self, p: SweepPoint) -> bool:
        att_ok = (
            p.slo_attainment is None
            or p.slo_attainment >= self.attainment_floor
        )
        return p.efficiency >= self.efficiency_floor and att_ok

    def point_near_offered(self, offered_cps: float) -> SweepPoint:
        """The sweep point whose offered load is closest to the target."""
        return min(
            self.points, key=lambda p: abs(p.offered_cps - offered_cps)
        )

    def to_dict(self) -> dict:
        return {
            "knee": self.knee.row(),
            "cliff": self.cliff.row() if self.cliff is not None else None,
            "saturated": self.saturated,
            "efficiency_floor": self.efficiency_floor,
            "attainment_floor": self.attainment_floor,
            "sweep": [p.row() for p in self.points],
        }


def find_knee(
    points: Sequence[SweepPoint],
    *,
    efficiency_floor: float = 0.85,
    attainment_floor: float = 0.999,
) -> KneeReport:
    """Locate the knee on an ascending-load sweep.

    The knee is the HIGHEST offered-load point that still (a) converts at
    least ``efficiency_floor`` of its offered load into completions and
    (b) holds SLO attainment at or above ``attainment_floor``.  The cliff
    is the first point past the knee violating either floor (None when
    the sweep never saturates).
    """
    if not points:
        raise ValueError("cannot find a knee on an empty sweep")
    pts = sorted(points, key=lambda p: p.offered_cps)
    report = KneeReport(
        knee=pts[0],
        cliff=None,
        points=tuple(pts),
        efficiency_floor=efficiency_floor,
        attainment_floor=attainment_floor,
    )
    knee = None
    cliff = None
    for p in pts:
        if report._healthy(p):
            if cliff is None:
                knee = p
        elif cliff is None:
            cliff = p
    # a sweep already saturated at its first point: the knee is unknown
    # below the sweep range; report the first point as the (degenerate)
    # knee so downstream metrics stay defined.
    return dataclasses.replace(report, knee=knee or pts[0], cliff=cliff)


def calibrate_admission(
    knee: SweepPoint, *, slack: float = 0.5, floor: int = 64
) -> int:
    """Little's-law global outstanding cap from the knee operating point.

    Little's law says the healthy system holds ``achieved x mean-sojourn``
    circuits; we size from the knee's ``achieved x p99`` — a deliberate
    overstatement (p99 >> mean on a heavy-tailed mix) discounted by
    ``slack < 1``.  A standing backlog deeper than that can only add
    latency, never throughput: cap admission there, and the gateway sheds
    the excess at submit instead of queueing it into certain SLO misses.
    The default ``slack=0.5`` empirically pins the admitted circuits' p99
    back to the knee p99 under a 1.6x-knee storm (see the harness's
    ``admission`` section).
    """
    if slack <= 0:
        raise ValueError(f"slack must be positive, got {slack}")
    cap = int(math.ceil(knee.achieved_cps * knee.p99_latency_s * slack))
    return max(cap, floor)


def verify_admission(
    spec: WorkloadSpec,
    knee_report: KneeReport,
    *,
    overload: float = 1.6,
    slack: float = 0.5,
    **replay_kwargs,
) -> dict:
    """Replay a past-knee storm with and without the calibrated cap.

    Returns the calibrated cap plus both operating points; with the cap
    armed the gateway must actually shed load (``reject_fraction > 0``)
    and the admitted circuits' attainment must not degrade.
    """
    cap = calibrate_admission(knee_report.knee, slack=slack)
    load = knee_report.knee.load * overload
    trace = spec.at_load(load).generate()
    uncapped = replay_sim(trace, **replay_kwargs)
    capped = replay_sim(trace, max_system_pending=cap, **replay_kwargs)
    return {
        "max_system_pending": cap,
        "overload": overload,
        "load": round(load, 4),
        "offered_cps": round(trace.offered_cps, 2),
        "reject_fraction": round(capped.reject_fraction, 4),
        "rejected": capped.rejected,
        "attainment_admitted": capped.slo_attainment,
        "attainment_uncapped": uncapped.slo_attainment,
        "p99_admitted_s": round(capped.p99_latency_s, 4),
        "p99_uncapped_s": round(uncapped.p99_latency_s, 4),
        "achieved_cps": round(capped.achieved_cps, 2),
        "achieved_cps_uncapped": round(uncapped.achieved_cps, 2),
    }


__all__ = [
    "KneeReport",
    "SweepPoint",
    "calibrate_admission",
    "find_knee",
    "sweep",
    "verify_admission",
]
