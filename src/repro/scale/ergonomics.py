"""Experiment ergonomics for long-running harness sweeps.

Three small utilities (the mlfab-style experiment conveniences the ROADMAP
calls out) used by the scale harness's own telemetry — none of them touch
the virtual clock, so harness progress reporting never perturbs the
deterministic results it reports on:

* ``CumulativeTimer`` — named wall-clock accumulators (``with timer.time(
  "replay"): ...``); ``stats()`` reports count / total / mean per name, so
  a sweep's cost breakdown (generate vs replay vs aggregate) is one dict.
* ``IntervalTicker`` — rate-limits progress output: ``tick()`` returns
  True at most once per interval, so a 10k-tenant sweep logs a heartbeat
  line every few seconds instead of per event or not at all.
* ``config_diff`` — flat "key: old -> new" report between two config
  mappings, so every emitted artifact can say exactly how its run deviated
  from the defaults (the config-diff report idiom).
"""

from __future__ import annotations

import contextlib
import time
from typing import Any, Iterator, Mapping


class CumulativeTimer:
    """Named cumulative wall-clock timers.

    ``time(name)`` is a context manager accumulating into ``name``;
    ``add(name, seconds)`` records an externally measured duration.
    """

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._total: dict[str, float] = {}
        self._count: dict[str, int] = {}

    @contextlib.contextmanager
    def time(self, name: str) -> Iterator[None]:
        t0 = self._clock()
        try:
            yield
        finally:
            self.add(name, self._clock() - t0)

    def add(self, name: str, seconds: float) -> None:
        self._total[name] = self._total.get(name, 0.0) + seconds
        self._count[name] = self._count.get(name, 0) + 1

    def total(self, name: str) -> float:
        return self._total.get(name, 0.0)

    def stats(self) -> dict[str, dict[str, float]]:
        """``{name: {count, total_s, mean_s}}``, insertion-ordered."""
        return {
            name: {
                "count": self._count[name],
                "total_s": round(total, 6),
                "mean_s": round(total / self._count[name], 6),
            }
            for name, total in self._total.items()
        }


class IntervalTicker:
    """Fires at most once per ``interval_s`` of wall time.

    The first ``tick()`` always fires (so progress output starts
    immediately); subsequent calls fire only after the interval elapsed.
    """

    def __init__(self, interval_s: float, clock=time.monotonic):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be positive, got {interval_s}")
        self.interval_s = interval_s
        self._clock = clock
        self._last: float | None = None
        self.ticks = 0

    def tick(self, now: float | None = None) -> bool:
        now = self._clock() if now is None else now
        if self._last is not None and now - self._last < self.interval_s:
            return False
        self._last = now
        self.ticks += 1
        return True


def config_diff(
    base: Mapping[str, Any], current: Mapping[str, Any]
) -> list[str]:
    """Flat ``key: old -> new`` lines for every key that differs.

    Nested mappings recurse with dotted paths; keys present on one side
    only render as ``added``/``removed``.  Deterministic (sorted) so the
    report can live inside a trend-gated artifact.
    """
    lines: list[str] = []
    for key in sorted(set(base) | set(current)):
        if key not in current:
            lines.append(f"{key}: {base[key]!r} -> removed")
        elif key not in base:
            lines.append(f"{key}: added -> {current[key]!r}")
        elif isinstance(base[key], Mapping) and isinstance(
            current[key], Mapping
        ):
            lines.extend(
                f"{key}.{sub}"
                for sub in config_diff(base[key], current[key])
            )
        elif base[key] != current[key]:
            lines.append(f"{key}: {base[key]!r} -> {current[key]!r}")
    return lines


__all__ = ["CumulativeTimer", "IntervalTicker", "config_diff"]
