"""Trace replay: drive a generated storm against a runtime.

``replay_sim`` replays a ``Trace`` against the virtual clock
(``SystemSimulation`` with the serving gateway) — the 10k+-tenant path the
CI scale gate runs, deterministic down to the last event.  ``replay_real``
replays a (small) trace against real kernels through ``GatewayRuntime``,
the sanity check that the virtual-clock knee shape is not a simulation
artifact.

Both return a ``ReplayResult`` with the aggregates the knee finder
consumes: offered vs achieved throughput, the population-wide p99 (merged
from the per-tenant streaming histograms), SLO attainment, the reject
fraction under admission control, and the obs-layer signals (queue-depth
p99, coalesce-wait share of end-to-end latency).
"""

from __future__ import annotations

import dataclasses

from repro.comanager import tenancy
from repro.comanager.simulation import SystemSimulation
from repro.comanager.worker import PAPER_RATES_GCP, WorkerConfig
from repro.obs.config import ObservabilityConfig
from repro.obs.histogram import LogHistogram
from repro.scale.workload import Trace

#: same co-residency slowdown as the gateway benchmarks.
CONTENTION = 0.5


def default_fleet(n_replicas: int = 2) -> list[WorkerConfig]:
    """``n_replicas`` copies of the paper's heterogeneous 5/10/15/20-qubit
    quartet — 8 workers by default, the scale-harness reference fleet."""
    return [
        WorkerConfig(f"w{r * 4 + i + 1}", q, contention=CONTENTION)
        for r in range(n_replicas)
        for i, q in enumerate((5, 10, 15, 20))
    ]


@dataclasses.dataclass
class ReplayResult:
    """Aggregates of one replayed storm (all virtual-clock deterministic
    except the ``replay_real`` wall-clock fields)."""

    n_tenants: int
    submitted: int
    completed: int
    rejected: int
    offered_cps: float
    achieved_cps: float
    makespan_s: float
    p50_latency_s: float
    p99_latency_s: float
    slo_attainment: float | None
    queue_depth_p99: float | None
    coalesce_wait_share: float | None
    summary: dict
    report: object | None = None

    @property
    def reject_fraction(self) -> float:
        return self.rejected / max(self.submitted, 1)

    def row(self) -> dict:
        """Flat JSON-ready view (drops the raw summary/report handles)."""
        return {
            "n_tenants": self.n_tenants,
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "reject_fraction": round(self.reject_fraction, 4),
            "offered_cps": round(self.offered_cps, 2),
            "achieved_cps": round(self.achieved_cps, 2),
            "makespan_s": round(self.makespan_s, 3),
            "p50_latency_s": round(self.p50_latency_s, 4),
            "p99_latency_s": round(self.p99_latency_s, 4),
            "slo_attainment": self.slo_attainment,
            "queue_depth_p99": self.queue_depth_p99,
            "coalesce_wait_share": self.coalesce_wait_share,
        }


def merged_latency(telemetry) -> LogHistogram:
    """Population-wide end-to-end latency: fold every tenant's streaming
    histogram (same bucketing, so the merge keeps the error bound)."""
    out = LogHistogram()
    for stats in telemetry.tenants.values():
        out.merge(stats.latencies)
    return out


def replay_sim(
    trace: Trace,
    *,
    workers: list[WorkerConfig] | None = None,
    max_pending: int | None = None,
    max_system_pending: int | None = None,
    gateway_deadline: float = 0.25,
    gateway_async: bool = True,
    heartbeat_period: float = 5.0,
    classical_overhead: float = 0.002,
    assign_latency: float = 0.001,
    sample_rate: float = 0.05,
    run_until: float = 1e7,
    keep_report: bool = False,
) -> ReplayResult:
    """Replay ``trace`` on the virtual clock through the serving gateway.

    ``max_system_pending`` arms the gateway's weighted-fair global
    admission cap (None = admit everything, the uncalibrated default);
    rejected circuits are shed at submit and counted, never executed.
    ``sample_rate`` keeps lifecycle tracing O(1) at storm scale while the
    always-on histograms still see every circuit.
    """
    workers = default_fleet() if workers is None else workers
    jobs, arrivals = [], {}
    weights, priorities, slos = {}, {}, {}
    for t in trace.tenants:
        offs = trace.arrivals[t.tenant_id]
        jobs.append(
            tenancy.JobSpec(
                t.tenant_id,
                t.qc,
                t.n_layers,
                len(offs),
                service_override=1.0 / PAPER_RATES_GCP[(t.qc, t.n_layers)],
            )
        )
        arrivals[t.tenant_id] = offs
        weights[t.tenant_id] = t.weight
        priorities[t.tenant_id] = t.priority
        if t.slo_ms is not None:
            slos[t.tenant_id] = t.slo_ms
    sim = SystemSimulation(
        workers,
        jobs,
        gateway=True,
        gateway_async=gateway_async,
        gateway_deadline=gateway_deadline,
        gateway_max_pending=max_pending,
        gateway_max_system_pending=max_system_pending,
        arrivals=arrivals,
        tenant_weights=weights,
        tenant_priorities=priorities,
        tenant_slos_ms=slos or None,
        heartbeat_period=heartbeat_period,
        classical_overhead=classical_overhead,
        assign_latency=assign_latency,
        run_until=run_until,
        observability=ObservabilityConfig(sample_rate=sample_rate),
    )
    report = sim.run()
    summary = report.gateway_summary
    telemetry = sim.gateway.telemetry
    lat = merged_latency(telemetry)
    rejected = report.rejected
    completed = summary["total_completed"]
    makespan = max(report.makespan, 1e-9)
    recorder = telemetry.trace
    qd = recorder.queue_depth
    queue_depth_p99 = (
        round(qd.percentile(99), 2) if qd.count else None
    )
    stages = recorder.stage_summary()
    return ReplayResult(
        n_tenants=trace.n_tenants,
        submitted=trace.n_circuits,
        completed=completed,
        rejected=rejected,
        offered_cps=trace.offered_cps,
        achieved_cps=completed / makespan,
        makespan_s=report.makespan,
        p50_latency_s=lat.percentile(50) if lat.count else 0.0,
        p99_latency_s=lat.percentile(99) if lat.count else 0.0,
        slo_attainment=summary.get("slo_attainment"),
        queue_depth_p99=queue_depth_p99,
        coalesce_wait_share=stages.get("coalesce_wait_share"),
        summary=summary,
        report=report if keep_report else None,
    )


def replay_real(
    trace: Trace,
    *,
    mode: str = "async",
    slots_per_worker: int = 2,
    deadline: float = 0.1,
    target: int | None = None,
    max_system_pending: int | None = None,
) -> ReplayResult:
    """Replay a (small) trace against real kernels via ``GatewayRuntime``.

    Submissions stream in global arrival order (open loop, as fast as the
    gateway admits them); per-tenant policies ride along.  Wall-clock
    throughput is machine-dependent — report it, never gate it.
    """
    import time

    import jax.numpy as jnp
    import numpy as np

    from repro.core import circuits
    from repro.serve import Backpressure, GatewayRuntime

    specs: dict[tuple[int, int], object] = {}
    for t in trace.tenants:
        key = (t.qc, t.n_layers)
        if key not in specs:
            specs[key] = circuits.build_quclassi_circuit(*key)
    events = sorted(
        (off, t)
        for t in trace.tenants
        for off in trace.arrivals[t.tenant_id]
    )
    rng = np.random.default_rng(trace.seed)
    rt = GatewayRuntime(
        target=target,
        deadline=deadline,
        mode=mode,
        slots_per_worker=slots_per_worker,
        max_system_pending=max_system_pending,
    )
    rejected = 0
    try:
        for t in trace.tenants:
            rt.gateway.register_client(
                t.tenant_id,
                weight=t.weight,
                priority=t.priority,
                slo_ms=t.slo_ms,
            )
        for key, spec in specs.items():  # warm the per-spec kernel jits
            th = jnp.zeros((1, spec.n_theta), jnp.float32)
            da = jnp.zeros((1, spec.n_data), jnp.float32)
            rt.dispatcher.kernel(spec, th, da)
        t0 = time.perf_counter()
        futures = []
        for _, t in events:
            spec = specs[(t.qc, t.n_layers)]
            theta = jnp.asarray(
                rng.uniform(0, np.pi, (spec.n_theta,)), jnp.float32
            )
            data = jnp.asarray(
                rng.uniform(0, np.pi, (spec.n_data,)), jnp.float32
            )
            try:
                futures.append(
                    rt.gateway.submit(
                        t.tenant_id,
                        spec,
                        (theta, data),
                        now=rt.dispatcher.clock(),
                    )
                )
            except Backpressure:
                rejected += 1
            rt.dispatcher.kick()
        rt.dispatcher.drain()
        wall = time.perf_counter() - t0
        summary = rt.telemetry.summary()
        lat = merged_latency(rt.telemetry)
    finally:
        rt.close()
    completed = summary["total_completed"]
    return ReplayResult(
        n_tenants=trace.n_tenants,
        submitted=len(events),
        completed=completed,
        rejected=rejected,
        offered_cps=trace.offered_cps,
        achieved_cps=completed / max(wall, 1e-9),
        makespan_s=wall,
        p50_latency_s=lat.percentile(50) if lat.count else 0.0,
        p99_latency_s=lat.percentile(99) if lat.count else 0.0,
        slo_attainment=summary.get("slo_attainment"),
        queue_depth_p99=None,
        coalesce_wait_share=None,
        summary=summary,
    )


__all__ = [
    "CONTENTION",
    "ReplayResult",
    "default_fleet",
    "merged_latency",
    "replay_real",
    "replay_sim",
]
