"""Deterministic multi-tenant workload generation for scale storms.

The serving stack has only ever been benchmarked at 4 tenants x 300
Poisson arrivals — this module generates the other end of the spectrum:
thousands of tenants drawn from configurable *populations*, each with its
own arrival process (Poisson / bursty / heavy-tailed / diurnal), circuit
spec mix, priority tier, SLO class, and fair-share weight.  Everything is
seeded: the same ``WorkloadSpec`` always expands to the bit-identical
``Trace``, which is what lets the CI scale gate pin knee-point metrics.

The generated ``Trace`` is runtime-agnostic — ``repro.scale.replay`` turns
it into ``SystemSimulation`` inputs (virtual clock, 10k+ tenants) or real
``GatewayRuntime`` submissions (small mixes, real kernels).
"""

from __future__ import annotations

import dataclasses

import numpy as np

ARRIVAL_KINDS = ("poisson", "bursty", "heavy_tail", "diurnal")

#: circuit shapes with calibrated paper service rates (see
#: ``repro.comanager.worker.PAPER_RATES_GCP``): (qubits, layers).
KNOWN_SPECS = ((5, 1), (5, 2), (5, 3), (7, 1), (7, 2), (7, 3))


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """One tenant-level inter-arrival process, mean ``rate`` arrivals/sec.

    ``poisson``    — exponential inter-arrivals (the memoryless baseline).
    ``bursty``     — batch-Poisson: burst epochs arrive Poisson at
                     ``rate / mean_burst``; each epoch emits a geometric
                     number of circuits (mean ``mean_burst``) spaced
                     ``burst_spacing_s`` apart.  Mean rate stays ``rate``.
    ``heavy_tail`` — Lomax (Pareto-II) inter-arrivals with tail index
                     ``alpha`` (1 < alpha <= 2 has infinite variance:
                     long quiet gaps punctuated by dense runs), scaled so
                     the mean inter-arrival is ``1 / rate``.
    ``diurnal``    — inhomogeneous Poisson thinned against
                     ``rate * (1 + depth * sin(2 pi t / period_s))``: the
                     whole population ebbs and surges together.
    """

    kind: str = "poisson"
    rate: float = 1.0
    mean_burst: float = 8.0
    burst_spacing_s: float = 0.02
    alpha: float = 1.6
    period_s: float = 60.0
    depth: float = 0.8

    def __post_init__(self):
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(
                f"unknown arrival kind {self.kind!r}; valid: {ARRIVAL_KINDS}"
            )
        if self.rate <= 0:
            raise ValueError(f"rate must be positive, got {self.rate}")
        if self.mean_burst < 1.0:
            raise ValueError(
                f"mean_burst must be >= 1, got {self.mean_burst}"
            )
        if self.alpha <= 1.0:
            raise ValueError(
                f"alpha must be > 1 (finite mean), got {self.alpha}"
            )
        if not 0.0 <= self.depth < 1.0:
            raise ValueError(f"depth must be in [0, 1), got {self.depth}")
        if self.period_s <= 0:
            raise ValueError(
                f"period_s must be positive, got {self.period_s}"
            )

    def sample(
        self,
        rng: np.random.Generator,
        duration_s: float,
        rate: float | None = None,
    ) -> list[float]:
        """Arrival offsets in ``[0, duration_s)``, sorted ascending."""
        rate = self.rate if rate is None else rate
        n_cap = max(8, int(rate * duration_s * 4) + 16)
        if self.kind == "poisson":
            gaps = rng.exponential(1.0 / rate, n_cap)
        elif self.kind == "heavy_tail":
            scale = (self.alpha - 1.0) / rate
            gaps = rng.pareto(self.alpha, n_cap) * scale
        elif self.kind == "bursty":
            return self._sample_bursty(rng, duration_s, rate)
        else:  # diurnal: thinning against the sinusoidal envelope
            return self._sample_diurnal(rng, duration_s, rate)
        times = np.cumsum(gaps)
        return times[times < duration_s].tolist()

    def _sample_bursty(
        self, rng: np.random.Generator, duration_s: float, rate: float
    ) -> list[float]:
        epoch_rate = rate / self.mean_burst
        n_cap = max(4, int(epoch_rate * duration_s * 4) + 8)
        epochs = np.cumsum(rng.exponential(1.0 / epoch_rate, n_cap))
        epochs = epochs[epochs < duration_s]
        out: list[float] = []
        for t0 in epochs:
            size = 1 + rng.geometric(1.0 / self.mean_burst)
            for j in range(int(size)):
                t = t0 + j * self.burst_spacing_s
                if t < duration_s:
                    out.append(float(t))
        out.sort()  # long bursts can overrun the next epoch's start
        return out

    def _sample_diurnal(
        self, rng: np.random.Generator, duration_s: float, rate: float
    ) -> list[float]:
        rate_max = rate * (1.0 + self.depth)
        n_cap = max(8, int(rate_max * duration_s * 4) + 16)
        times = np.cumsum(rng.exponential(1.0 / rate_max, n_cap))
        times = times[times < duration_s]
        envelope = 1.0 + self.depth * np.sin(
            2.0 * np.pi * times / self.period_s
        )
        keep = rng.uniform(0.0, 1.0 + self.depth, times.shape) < envelope
        return times[keep].tolist()


@dataclasses.dataclass(frozen=True)
class TenantPopulation:
    """A cohort of tenants sharing an arrival process and SLO class.

    ``circuit_mix``: ``(qubits, layers, weight)`` rows — each tenant draws
    ONE circuit spec from the mix (a tenant trains one model), so spec
    diversity lives across the population.  ``rate_spread``: lognormal
    sigma of the per-tenant rate multiplier (0 = identical rates; 1.0 is a
    realistically skewed fleet where the busiest tenants dominate).
    ``priority`` / ``slo_ms`` / ``weight`` feed the gateway's strict tiers,
    deadline accounting, and weighted-fair scheduler.
    """

    name: str
    n_tenants: int
    arrival: ArrivalProcess
    circuit_mix: tuple[tuple[int, int, float], ...] = ((5, 1, 1.0),)
    priority: int = 1
    slo_ms: float | None = None
    weight: float = 1.0
    rate_spread: float = 0.0

    def __post_init__(self):
        if self.n_tenants < 1:
            raise ValueError(
                f"{self.name}: n_tenants must be >= 1, got {self.n_tenants}"
            )
        if not self.circuit_mix:
            raise ValueError(f"{self.name}: circuit_mix must be non-empty")
        for qc, nl, w in self.circuit_mix:
            if (qc, nl) not in KNOWN_SPECS:
                raise ValueError(
                    f"{self.name}: unknown circuit spec ({qc}q, {nl}l); "
                    f"calibrated specs: {list(KNOWN_SPECS)}"
                )
            if w <= 0:
                raise ValueError(
                    f"{self.name}: circuit_mix weight must be positive"
                )
        if self.rate_spread < 0:
            raise ValueError(
                f"{self.name}: rate_spread must be >= 0, got "
                f"{self.rate_spread}"
            )
        if self.slo_ms is not None and self.slo_ms <= 0:
            raise ValueError(
                f"{self.name}: slo_ms must be positive, got {self.slo_ms}"
            )
        if self.weight <= 0:
            raise ValueError(
                f"{self.name}: weight must be positive, got {self.weight}"
            )


@dataclasses.dataclass(frozen=True)
class TenantProfile:
    """One generated tenant: identity, circuit spec, and serving policy."""

    tenant_id: str
    population: str
    qc: int
    n_layers: int
    priority: int
    slo_ms: float | None
    weight: float
    rate: float  # realized mean arrivals/sec (after spread + load)


@dataclasses.dataclass
class Trace:
    """A generated storm: tenant profiles + per-tenant arrival offsets.

    ``arrivals[tenant_id]`` are offsets (seconds) into the storm window;
    tenants that drew zero arrivals in the window are omitted.
    """

    duration_s: float
    seed: int
    load: float
    tenants: list[TenantProfile]
    arrivals: dict[str, list[float]]

    @property
    def n_tenants(self) -> int:
        return len(self.tenants)

    @property
    def n_circuits(self) -> int:
        return sum(len(a) for a in self.arrivals.values())

    @property
    def offered_cps(self) -> float:
        return self.n_circuits / max(self.duration_s, 1e-9)

    def summary(self) -> dict:
        by_pop: dict[str, int] = {}
        for t in self.tenants:
            by_pop[t.population] = by_pop.get(t.population, 0) + 1
        return {
            "n_tenants": self.n_tenants,
            "n_circuits": self.n_circuits,
            "duration_s": self.duration_s,
            "offered_cps": round(self.offered_cps, 2),
            "load": self.load,
            "seed": self.seed,
            "tenants_by_population": dict(sorted(by_pop.items())),
        }


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A seeded storm recipe: populations + window + offered-load scale.

    ``load`` multiplies every tenant's arrival rate — the knob the knee
    sweep turns.  ``generate()`` is a pure function of the spec: the same
    (populations, duration, seed, load) always yields the same trace.
    """

    populations: tuple[TenantPopulation, ...]
    duration_s: float = 20.0
    seed: int = 0
    load: float = 1.0

    def __post_init__(self):
        if not self.populations:
            raise ValueError("populations must be non-empty")
        names = [p.name for p in self.populations]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate population names in {names}")
        if self.duration_s <= 0:
            raise ValueError(
                f"duration_s must be positive, got {self.duration_s}"
            )
        if self.load <= 0:
            raise ValueError(f"load must be positive, got {self.load}")
        if not isinstance(self.populations, tuple):
            object.__setattr__(self, "populations", tuple(self.populations))

    @property
    def n_tenants_nominal(self) -> int:
        return sum(p.n_tenants for p in self.populations)

    def at_load(self, load: float) -> "WorkloadSpec":
        return dataclasses.replace(self, load=load)

    def generate(self) -> Trace:
        rng = np.random.default_rng(self.seed)
        tenants: list[TenantProfile] = []
        arrivals: dict[str, list[float]] = {}
        for pop in self.populations:
            mix = np.asarray([w for _, _, w in pop.circuit_mix], float)
            mix /= mix.sum()
            for i in range(pop.n_tenants):
                tid = f"{pop.name}-{i:05d}"
                spec_i = int(rng.choice(len(pop.circuit_mix), p=mix))
                qc, nl, _ = pop.circuit_mix[spec_i]
                mult = 1.0
                if pop.rate_spread > 0:
                    sigma = pop.rate_spread
                    # mean-1 lognormal: the population's aggregate rate is
                    # load-invariant under spread
                    mult = float(
                        rng.lognormal(-0.5 * sigma * sigma, sigma)
                    )
                rate = pop.arrival.rate * mult * self.load
                offsets = pop.arrival.sample(rng, self.duration_s, rate)
                if not offsets:
                    continue  # silent tenant this window
                tenants.append(
                    TenantProfile(
                        tenant_id=tid,
                        population=pop.name,
                        qc=qc,
                        n_layers=nl,
                        priority=pop.priority,
                        slo_ms=pop.slo_ms,
                        weight=pop.weight,
                        rate=rate,
                    )
                )
                arrivals[tid] = offsets
        return Trace(
            duration_s=self.duration_s,
            seed=self.seed,
            load=self.load,
            tenants=tenants,
            arrivals=arrivals,
        )


def standard_populations(
    n_tenants: int,
    *,
    rate_per_tenant: float = 0.4,
    slo_scale: float = 1.0,
) -> tuple[TenantPopulation, ...]:
    """The canonical three-class storm mix at ``n_tenants`` total.

    15% interactive (tier 0, tight SLO, Poisson), 55% batch (tier 1,
    relaxed SLO, heavy-tailed), 30% bursty best-effort (tier 2, loose SLO,
    batch-Poisson bursts + diurnal surge).  ``rate_per_tenant`` sets the
    per-tenant mean arrival rate at load 1.0.
    """
    n_interactive = max(1, int(n_tenants * 0.15))
    n_bursty = max(1, int(n_tenants * 0.30))
    n_batch = max(1, n_tenants - n_interactive - n_bursty)
    return (
        TenantPopulation(
            name="interactive",
            n_tenants=n_interactive,
            arrival=ArrivalProcess(kind="poisson", rate=rate_per_tenant),
            circuit_mix=((5, 1, 3.0), (7, 1, 1.0)),
            priority=0,
            slo_ms=2000.0 * slo_scale,
            weight=4.0,
        ),
        TenantPopulation(
            name="batch",
            n_tenants=n_batch,
            arrival=ArrivalProcess(
                kind="heavy_tail", rate=rate_per_tenant, alpha=1.6
            ),
            circuit_mix=((5, 1, 2.0), (5, 2, 1.0), (7, 1, 2.0), (7, 2, 1.0)),
            priority=1,
            slo_ms=8000.0 * slo_scale,
            weight=1.0,
            rate_spread=0.8,
        ),
        TenantPopulation(
            name="bursty",
            n_tenants=n_bursty,
            arrival=ArrivalProcess(
                kind="bursty", rate=rate_per_tenant, mean_burst=6.0
            ),
            circuit_mix=((5, 1, 1.0), (7, 1, 1.0)),
            priority=2,
            slo_ms=16000.0 * slo_scale,
            weight=0.5,
        ),
    )


__all__ = [
    "ARRIVAL_KINDS",
    "ArrivalProcess",
    "KNOWN_SPECS",
    "TenantPopulation",
    "TenantProfile",
    "Trace",
    "WorkloadSpec",
    "standard_populations",
]
