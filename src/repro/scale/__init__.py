"""Trace-driven scale harness: storm generation, replay, knee discovery.

The pipeline the CI scale gate runs end to end:

1. ``workload`` — deterministic multi-population storm generator: thousands
   of tenants with diurnal / bursty / heavy-tailed arrival processes, mixed
   circuit specs, priority tiers, SLO classes and fair-share weights, all
   from one seed.
2. ``replay`` — drive a generated ``Trace`` against the virtual clock
   (``SystemSimulation``; 10k+-tenant runs) or against real kernels
   (``GatewayRuntime``; small mixes).
3. ``knee`` — sweep offered load, locate the throughput knee and the
   p99/attainment cliff from the obs-layer signals, and calibrate the
   gateway's weighted-fair admission cap at the knee.
4. ``ergonomics`` — the harness's own telemetry (cumulative timers,
   interval tickers, config-diff reports); wall-clock only, never touches
   the virtual clock.

``benchmarks/scale_harness.py`` wires the pipeline into ``BENCH_scale.json``
with baselines gated by ``benchmarks/check_trend.py``.
"""

from repro.scale.ergonomics import CumulativeTimer, IntervalTicker, config_diff
from repro.scale.knee import (
    KneeReport,
    SweepPoint,
    calibrate_admission,
    find_knee,
    sweep,
    verify_admission,
)
from repro.scale.replay import (
    ReplayResult,
    default_fleet,
    replay_real,
    replay_sim,
)
from repro.scale.workload import (
    ArrivalProcess,
    TenantPopulation,
    TenantProfile,
    Trace,
    WorkloadSpec,
    standard_populations,
)

__all__ = [
    "ArrivalProcess",
    "CumulativeTimer",
    "IntervalTicker",
    "KneeReport",
    "ReplayResult",
    "SweepPoint",
    "TenantPopulation",
    "TenantProfile",
    "Trace",
    "WorkloadSpec",
    "calibrate_admission",
    "config_diff",
    "default_fleet",
    "find_knee",
    "replay_real",
    "replay_sim",
    "standard_populations",
    "sweep",
    "verify_admission",
]
