"""Per-circuit lifecycle tracing + worker occupancy timelines.

The co-Manager "dynamically manages circuits according to the runtime
status of quantum workers" — this module is where that runtime status
becomes *visible*.  Three cooperating pieces:

* ``TraceRecorder`` — the hook surface the serving stack calls.  Every
  submitted circuit (deterministically sampled by admission sequence
  number) gets a ``CircuitTrace`` with timestamped stage transitions
  (``submit -> admit -> coalesced -> placed -> dispatched -> kernel_start
  -> complete/evict/fail``); every worker execution (real dispatcher slot
  or virtual-clock dispatch ledger) records a ``WorkerSpan``.  Stage
  transition latencies feed fixed-memory ``LogHistogram``s as they happen,
  so aggregate stage accounting survives ring-buffer eviction.
* ``TraceBuffer`` — bounded ring (O(1) append) holding finished records;
  ``export_chrome_trace()`` emits Chrome-trace/Perfetto JSON with one row
  per tenant and one per worker (async b/e span pairs, so overlapping
  circuits and co-resident worker tasks render correctly in
  ``ui.perfetto.dev``).
* ``WorkerTimeline`` — per-worker busy/spill interval accounting (O(1)
  memory: integrals + counters, not interval lists).

All clocks are caller-supplied floats — virtual seconds under the
simulation's event loop, ``time.perf_counter()`` seconds in the real data
plane — so the same recorder serves both runtimes, and a seeded simulation
exports a bit-identical trace (the golden-file test pins this).
"""
from __future__ import annotations

import dataclasses
import json
import threading
from collections import deque
from typing import Any, Iterable, Optional

from repro.obs.config import (
    FEDERATED_STAGES,
    LIFECYCLE_STAGES,
    RECOVERY_STAGES,
    ObservabilityConfig,
)
from repro.obs.histogram import LogHistogram

#: human-facing metric name for the latency *into* each stage (duration
#: since the previous recorded transition).
STAGE_METRICS = {
    "admit": "queue_wait",
    "coalesced": "coalesce_wait",
    "placed": "place_wait",
    "dispatched": "dispatch_lag",
    "kernel_start": "kernel_wait",
    "complete": "execute",
}

#: terminal transitions closing a circuit trace.
OUTCOMES = ("complete", "evict", "fail", "reject")

_HASH_MULT = 2654435761  # Knuth multiplicative hash (fits 32 bits)


@dataclasses.dataclass
class CircuitTrace:
    """Lifecycle record of one sampled circuit."""

    seq: int
    tenant: str
    key: str
    stages: list = dataclasses.field(default_factory=list)  # [(stage, ts)]
    worker: Optional[str] = None
    outcome: Optional[str] = None
    queue_depth: Optional[int] = None

    @property
    def start(self) -> float:
        return self.stages[0][1]

    @property
    def end(self) -> float:
        return self.stages[-1][1]

    def to_dict(self) -> dict:
        return {
            "seq": self.seq,
            "tenant": self.tenant,
            "key": self.key,
            "stages": [[s, t] for s, t in self.stages],
            "worker": self.worker,
            "outcome": self.outcome,
            "queue_depth": self.queue_depth,
        }


@dataclasses.dataclass
class RoundEvent:
    """One federated aggregation-round transition (``FEDERATED_STAGES``).

    Round-level, not circuit-level: a round's local-training circuits carry
    ordinary ``CircuitTrace`` records; these mark the coordinator's control
    decisions (round opened, update arrived on time / late, aggregate
    applied) so straggler waits are visible next to the data plane."""

    round_idx: int
    stage: str
    ts: float
    tenant: Optional[str] = None
    args: Optional[dict] = None

    def to_dict(self) -> dict:
        out = {"round": self.round_idx, "stage": self.stage, "ts": self.ts}
        if self.tenant is not None:
            out["tenant"] = self.tenant
        if self.args:
            out["args"] = dict(self.args)
        return out


@dataclasses.dataclass
class WorkerSpan:
    """One contiguous busy interval on a worker (or the mesh spill slot)."""

    span_id: int
    worker: str
    start: float
    end: float
    kind: str = "batch"  # batch | spill | circuit
    name: Optional[str] = None
    args: Optional[dict] = None


class WorkerTimeline:
    """Busy/spill occupancy accounting for one worker — O(1) memory.

    ``busy_s`` integrates span durations (co-resident spans double-count,
    matching ``QuantumWorker.busy_time``'s integral semantics); idle time
    is derived against the observed horizon at summary time."""

    __slots__ = (
        "worker_id",
        "busy_s",
        "spill_s",
        "n_spans",
        "first_start",
        "last_end",
        "by_kind",
    )

    def __init__(self, worker_id: str):
        self.worker_id = worker_id
        self.busy_s = 0.0
        self.spill_s = 0.0
        self.n_spans = 0
        self.first_start = float("inf")
        self.last_end = 0.0
        self.by_kind: dict[str, int] = {}

    def record(self, start: float, end: float, kind: str) -> None:
        dur = max(0.0, end - start)
        self.busy_s += dur
        if kind == "spill":
            self.spill_s += dur
        self.n_spans += 1
        self.by_kind[kind] = self.by_kind.get(kind, 0) + 1
        self.first_start = min(self.first_start, start)
        self.last_end = max(self.last_end, end)

    def summary(self, horizon: Optional[float] = None) -> dict:
        span = (
            (horizon if horizon is not None else self.last_end) - self.first_start
            if self.n_spans
            else 0.0
        )
        return {
            "worker": self.worker_id,
            "spans": self.n_spans,
            "busy_s": round(self.busy_s, 6),
            "spill_s": round(self.spill_s, 6),
            "idle_s": round(max(0.0, span - self.busy_s), 6),
            "utilization": round(self.busy_s / span, 4) if span > 0 else None,
            "by_kind": dict(sorted(self.by_kind.items())),
        }


class TraceBuffer:
    """Bounded ring of finished trace records; O(1) append, fixed memory."""

    def __init__(self, capacity: int = 65536):
        self._buf: deque = deque(maxlen=capacity)
        self.capacity = capacity
        self.appended = 0

    def append(self, rec) -> None:
        self.appended += 1
        self._buf.append(rec)

    @property
    def dropped(self) -> int:
        return self.appended - len(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self):
        return iter(self._buf)

    def records(self, kind=None) -> list:
        if kind is None:
            return list(self._buf)
        return [r for r in self._buf if isinstance(r, kind)]

    # -------------------------------------------------------------- export
    def export_chrome_trace(self, path: Optional[str] = None) -> dict:
        """Chrome-trace/Perfetto JSON: one process row per tenant and per
        worker; circuits and worker executions are async ``b``/``e`` span
        pairs (overlap-safe), queue depths are counter tracks.  Open the
        written file directly in https://ui.perfetto.dev."""
        circuits = self.records(CircuitTrace)
        spans = self.records(WorkerSpan)
        rounds = self.records(RoundEvent)
        tenants = sorted({c.tenant for c in circuits})
        workers = sorted({s.worker for s in spans})
        pid_of = {t: 1 + i for i, t in enumerate(tenants)}
        pid_of.update({("w", w): 1001 + i for i, w in enumerate(workers)})
        us = 1e6

        events: list[dict] = []
        for i, t in enumerate(tenants):
            events.append(_meta(pid_of[t], "process_name", name=f"tenant {t}"))
            events.append(_meta(pid_of[t], "process_sort_index", sort_index=i))
        for i, w in enumerate(workers):
            pid = pid_of[("w", w)]
            events.append(_meta(pid, "process_name", name=f"worker {w}"))
            events.append(_meta(pid, "process_sort_index", sort_index=100 + i))

        for c in circuits:
            pid = pid_of[c.tenant]
            # rejected submissions never consumed their sequence number, so
            # suffix their span id to avoid colliding with the admitted
            # circuit that did.
            cid = f"{c.seq}r" if c.outcome == "reject" else str(c.seq)
            name = f"{c.key} #{c.seq}"
            b_args: dict[str, Any] = {
                "stages": {s: round(ts, 9) for s, ts in c.stages}
            }
            if c.queue_depth is not None:
                b_args["queue_depth"] = c.queue_depth
            events.append(
                {
                    "ph": "b",
                    "cat": "circuit",
                    "id": cid,
                    "name": name,
                    "pid": pid,
                    "tid": 1,
                    "ts": c.start * us,
                    "args": b_args,
                }
            )
            events.append(
                {
                    "ph": "e",
                    "cat": "circuit",
                    "id": cid,
                    "name": name,
                    "pid": pid,
                    "tid": 1,
                    "ts": c.end * us,
                    "args": {"outcome": c.outcome, "worker": c.worker},
                }
            )
            if c.queue_depth is not None:
                events.append(
                    {
                        "ph": "C",
                        "name": "queue_depth",
                        "pid": pid,
                        "tid": 1,
                        "ts": c.start * us,
                        "args": {"depth": c.queue_depth},
                    }
                )

        for s in spans:
            pid = pid_of[("w", s.worker)]
            name = s.name or s.kind
            sid = f"s{s.span_id}"
            b = {
                "ph": "b",
                "cat": "exec",
                "id": sid,
                "name": name,
                "pid": pid,
                "tid": 1,
                "ts": s.start * us,
            }
            if s.args:
                b["args"] = s.args
            events.append(b)
            events.append(
                {
                    "ph": "e",
                    "cat": "exec",
                    "id": sid,
                    "name": name,
                    "pid": pid,
                    "tid": 1,
                    "ts": s.end * us,
                }
            )

        if rounds:
            # dedicated control-plane row, present only for federated runs
            # so non-federated golden traces stay byte-identical.
            fed_pid = 2001
            events.append(_meta(fed_pid, "process_name", name="federated rounds"))
            events.append(_meta(fed_pid, "process_sort_index", sort_index=200))
            for r in rounds:
                args: dict[str, Any] = {"round": r.round_idx}
                if r.tenant is not None:
                    args["tenant"] = r.tenant
                if r.args:
                    args.update(r.args)
                events.append(
                    {
                        "ph": "i",
                        "s": "p",
                        "cat": "round",
                        "name": f"{r.stage} r{r.round_idx}",
                        "pid": fed_pid,
                        "tid": 1,
                        "ts": r.ts * us,
                        "args": args,
                    }
                )

        trace = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(trace, f, indent=1, sort_keys=True)
        return trace


def _meta(pid: int, kind: str, **args) -> dict:
    return {"ph": "M", "name": kind, "pid": pid, "tid": 1, "ts": 0, "args": args}


class TraceRecorder:
    """The hook surface the serving stack (gateway, dispatchers, simulation,
    kernel wrappers) records into.  Hooks are cheap no-ops when disabled;
    when enabled, per-circuit records are sampled deterministically by
    sequence number while histograms and worker timelines stay always-on
    (they are O(1) memory).  Thread-safe: async dispatcher worker slots
    record concurrently with the pump thread."""

    def __init__(self, config: Optional[ObservabilityConfig] = None):
        self.config = config or ObservabilityConfig()
        self.enabled = self.config.enabled and self.config.sample_rate > 0.0
        self._threshold = int(self.config.sample_rate * (1 << 32))
        self._stage_ok = (
            None if self.config.stages is None else set(self.config.stages)
        )
        self.buffer = TraceBuffer(self.config.buffer_size)
        self._active: dict[int, CircuitTrace] = {}
        self._lock = threading.Lock()
        self.stage_hists: dict[str, LogHistogram] = {}
        self.e2e = LogHistogram()
        self.queue_depth = LogHistogram(v_min=0.5, growth=1.3, n_buckets=48)
        self.coalescer_depth = LogHistogram(v_min=0.5, growth=1.3, n_buckets=48)
        self.coalescer_lanes = LogHistogram(v_min=0.5, growth=1.3, n_buckets=64)
        self.timelines: dict[str, WorkerTimeline] = {}
        self.kernel_launches: dict[str, int] = {}
        self.round_counts: dict[str, int] = {}
        self.events = 0
        self._next_span = 0

    # ------------------------------------------------------------ sampling
    def sampled(self, seq: int) -> bool:
        """Deterministic per-circuit sampling decision (hash of the
        admission sequence number — identical across reruns and clocks)."""
        return (seq * _HASH_MULT) % (1 << 32) < self._threshold

    def _hist(self, name: str) -> LogHistogram:
        h = self.stage_hists.get(name)
        if h is None:
            h = self.stage_hists[name] = LogHistogram()
        return h

    # ----------------------------------------------------- circuit lifecycle
    def circuit_submit(
        self,
        seq: int,
        tenant: str,
        key,
        now: float,
        *,
        queue_depth: Optional[int] = None,
    ) -> None:
        if not self.enabled or not self.sampled(seq):
            return
        with self._lock:
            self.events += 1
            self._active[seq] = CircuitTrace(
                seq=seq,
                tenant=tenant,
                key=_key_str(key),
                stages=[("submit", now)],
                queue_depth=queue_depth,
            )
            if queue_depth is not None:
                self.queue_depth.record(queue_depth)

    def circuit_reject(self, seq: int, tenant: str, key, now: float) -> None:
        """Backpressure rejection: a zero-length trace closed on arrival."""
        if not self.enabled or not self.sampled(seq):
            return
        with self._lock:
            self.events += 1
            self.buffer.append(
                CircuitTrace(
                    seq=seq,
                    tenant=tenant,
                    key=_key_str(key),
                    stages=[("submit", now), ("reject", now)],
                    outcome="reject",
                )
            )

    def circuit_stage(
        self, seq: int, stage: str, now: float, worker: Optional[str] = None
    ) -> None:
        if not self.enabled:
            return
        with self._lock:
            rec = self._active.get(seq)
            if rec is None:
                return
            if self._stage_ok is not None and stage not in self._stage_ok:
                return
            self.events += 1
            metric = STAGE_METRICS.get(stage)
            if metric is not None:
                self._hist(metric).record(now - rec.stages[-1][1])
            rec.stages.append((stage, now))
            if worker is not None:
                rec.worker = worker

    def batch_stage(
        self,
        seqs: Iterable[int],
        stage: str,
        now: float,
        worker: Optional[str] = None,
    ) -> None:
        """Record one stage transition for every member of a batch."""
        if not self.enabled:
            return
        for seq in seqs:
            self.circuit_stage(seq, stage, now, worker)

    def circuit_end(self, seq: int, outcome: str, now: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            rec = self._active.pop(seq, None)
            if rec is None:
                return
            self.events += 1
            if outcome == "complete":
                self._hist("execute").record(now - rec.stages[-1][1])
            rec.stages.append((outcome, now))
            rec.outcome = outcome
            self.e2e.record(now - rec.start)
            self.buffer.append(rec)

    # ------------------------------------------------------- worker spans
    def worker_span(
        self,
        worker: str,
        start: float,
        end: float,
        *,
        kind: str = "batch",
        name: Optional[str] = None,
        args: Optional[dict] = None,
    ) -> None:
        """One busy interval on ``worker`` (kernel launch, simulated task,
        or mesh spill).  Feeds the occupancy timeline and the trace ring."""
        if not self.enabled:
            return
        with self._lock:
            self.events += 1
            tl = self.timelines.get(worker)
            if tl is None:
                tl = self.timelines[worker] = WorkerTimeline(worker)
            tl.record(start, end, kind)
            self.buffer.append(
                WorkerSpan(
                    span_id=self._next_span,
                    worker=worker,
                    start=start,
                    end=end,
                    kind=kind,
                    name=name,
                    args=args,
                )
            )
            self._next_span += 1

    # -------------------------------------------------- federated rounds
    def round_event(
        self,
        round_idx: int,
        stage: str,
        now: float,
        *,
        tenant: Optional[str] = None,
        args: Optional[dict] = None,
    ) -> None:
        """One federated-round transition (``FEDERATED_STAGES``): round-level
        control events from ``repro.federated`` — not tied to any circuit
        sequence number, so they bypass sampling (a handful per round) but
        respect the ``stages`` filter and the ring buffer like everything
        else."""
        if not self.enabled:
            return
        if stage not in FEDERATED_STAGES:
            raise ValueError(
                f"unknown federated stage {stage!r}; valid: "
                f"{list(FEDERATED_STAGES)}"
            )
        if self._stage_ok is not None and stage not in self._stage_ok:
            return
        with self._lock:
            self.events += 1
            self.round_counts[stage] = self.round_counts.get(stage, 0) + 1
            self.buffer.append(
                RoundEvent(
                    round_idx=round_idx,
                    stage=stage,
                    ts=now,
                    tenant=tenant,
                    args=args,
                )
            )

    def round_records(self) -> list[dict]:
        """Finished federated round events (oldest first)."""
        with self._lock:
            return [r.to_dict() for r in self.buffer.records(RoundEvent)]

    def coalescer_sample(self, members: int, lanes: int) -> None:
        """Coalescer buffer depth after one pump (member count and
        lane-weighted) — the queue the size-or-deadline policy drains."""
        if not self.enabled:
            return
        with self._lock:
            self.coalescer_depth.record(members)
            self.coalescer_lanes.record(lanes)

    def on_kernel_launch(self, info: dict) -> None:
        """Kernel-wrapper hook (``repro.kernels.ops.set_launch_observer``):
        counts shift-plan launches by execution mode (fused / spill /
        materialize), independent of any dispatcher."""
        if not self.enabled:
            return
        with self._lock:
            kind = info.get("mode", "unknown")
            self.kernel_launches[kind] = self.kernel_launches.get(kind, 0) + 1

    # ----------------------------------------------------------- summaries
    @property
    def open_traces(self) -> int:
        with self._lock:
            return len(self._active)

    def tenant_records(self, tenant: str) -> list[dict]:
        """Finished lifecycle records of one tenant (oldest first)."""
        with self._lock:
            return [
                r.to_dict()
                for r in self.buffer.records(CircuitTrace)
                if r.tenant == tenant
            ]

    def stage_summary(self) -> dict:
        """Aggregate stage-latency accounting: per-metric histogram stats
        plus each stage's share of total end-to-end latency."""
        with self._lock:
            out: dict[str, Any] = {}
            for metric in sorted(self.stage_hists):
                out[metric] = self.stage_hists[metric].snapshot()
            e2e_total = self.e2e.total
            if self.e2e.count:
                out["e2e"] = self.e2e.snapshot()
                for metric in sorted(self.stage_hists):
                    share = (
                        self.stage_hists[metric].total / e2e_total
                        if e2e_total > 0
                        else 0.0
                    )
                    out[f"{metric}_share"] = round(share, 4)
            return out

    def summary(self) -> dict:
        with self._lock:
            out = {
                "enabled": self.enabled,
                "sample_rate": self.config.sample_rate,
                "events": self.events,
                "records": len(self.buffer),
                "records_dropped": self.buffer.dropped,
                "open_traces": len(self._active),
            }
            if self.kernel_launches:
                out["kernel_launches"] = dict(sorted(self.kernel_launches.items()))
            if self.round_counts:
                out["rounds"] = dict(sorted(self.round_counts.items()))
            if self.queue_depth.count:
                out["queue_depth"] = self.queue_depth.snapshot()
            if self.coalescer_depth.count:
                out["coalescer_depth"] = self.coalescer_depth.snapshot()
                out["coalescer_lanes"] = self.coalescer_lanes.snapshot()
            if self.timelines:
                out["workers"] = {
                    w: tl.summary() for w, tl in sorted(self.timelines.items())
                }
        stages = self.stage_summary()
        if stages:
            out["stages"] = stages
        return out

    def export_chrome_trace(self, path: Optional[str] = None) -> dict:
        return self.buffer.export_chrome_trace(path)


def _key_str(key) -> str:
    """Compact, deterministic label for a coalescing key (CircuitSpec,
    ShiftGroupKey, simulation tuple, ...)."""
    spec = getattr(key, "spec", key)
    n_q = getattr(spec, "n_qubits", None)
    if n_q is not None:
        label = f"{n_q}q/{len(getattr(spec, 'ops', ()))}ops"
        if spec is not key:  # shift-group key
            label = f"shift:{label}"
        return label
    s = str(key)
    return s if len(s) <= 48 else s[:45] + "..."


def validate_trace(records: Iterable[CircuitTrace]) -> list[str]:
    """Well-formedness check used by tests and the demo: monotone stage
    timestamps, a terminal outcome on every record, eviction/fail spans
    closed.  Returns a list of violations (empty = well-formed)."""
    bad = []
    for r in records:
        ts = [t for _, t in r.stages]
        if any(b < a - 1e-9 for a, b in zip(ts, ts[1:])):
            bad.append(f"#{r.seq}: non-monotone stage timestamps {r.stages}")
        if r.outcome not in OUTCOMES:
            bad.append(f"#{r.seq}: no terminal outcome (stages {r.stages})")
        elif r.stages[-1][0] != r.outcome:
            bad.append(f"#{r.seq}: outcome {r.outcome} != last stage")
        names = [s for s, _ in r.stages]
        if names[0] != "submit":
            bad.append(f"#{r.seq}: trace does not open with submit")
        # recovery stages (retry / hedge / migrate / requeue) legitimately
        # send a circuit back through earlier pipeline stages, so the
        # order check only applies to untouched traces.
        order = {s: i for i, s in enumerate(LIFECYCLE_STAGES)}
        core = [s for s in names if s in order and s not in RECOVERY_STAGES]
        if not RECOVERY_STAGES.intersection(names) and any(
            order[b] < order[a] for a, b in zip(core, core[1:])
        ):
            bad.append(f"#{r.seq}: stages out of pipeline order {names}")
    return bad


__all__ = [
    "OUTCOMES",
    "STAGE_METRICS",
    "CircuitTrace",
    "TraceBuffer",
    "TraceRecorder",
    "WorkerSpan",
    "WorkerTimeline",
    "validate_trace",
]
