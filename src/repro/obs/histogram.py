"""Streaming log-bucketed histograms (HDR-style, fixed memory).

The serving telemetry used to keep raw per-completion latency lists — O(n)
memory per tenant forever, exactly what a gateway serving millions of
circuits cannot afford.  ``LogHistogram`` replaces them: a fixed array of
geometrically spaced buckets (``v_min * growth**i``), so memory is O(1)
regardless of sample count and any percentile is reconstructable to within
one bucket width (relative error <= ``growth`` — the tolerance the metrics
tests assert).

Values at or below ``v_min`` (including exact zeros — empty-queue depth
samples, sub-resolution latencies) land in a dedicated zero bucket; values
beyond the top bucket clamp into it (and are remembered exactly via
``max_seen``).  ``merge`` folds two same-shape histograms, so per-stage and
per-tenant histograms can be aggregated without losing the error bound.

Everything is pure Python over a fixed-size list: the recorder hot path is
one ``log`` + one list increment, no numpy import on the serving thread.
"""
from __future__ import annotations

import math


class LogHistogram:
    """Fixed-memory streaming histogram over log-spaced buckets.

    ``v_min``: lower edge of the first bucket (values <= v_min are "zero");
    ``growth``: geometric bucket width (1.25 -> <= 25% percentile error);
    ``n_buckets``: bucket count.  The defaults cover 1 us .. ~2e6 s, wide
    enough for stage latencies, end-to-end latencies, and queue depths.
    """

    __slots__ = (
        "v_min",
        "growth",
        "n_buckets",
        "_log_growth",
        "_log_vmin",
        "counts",
        "zeros",
        "count",
        "total",
        "min_seen",
        "max_seen",
    )

    def __init__(
        self, v_min: float = 1e-6, growth: float = 1.25, n_buckets: int = 128
    ):
        if v_min <= 0:
            raise ValueError(f"v_min must be positive, got {v_min}")
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        if n_buckets < 1:
            raise ValueError(f"n_buckets must be >= 1, got {n_buckets}")
        self.v_min = v_min
        self.growth = growth
        self.n_buckets = n_buckets
        self._log_growth = math.log(growth)
        self._log_vmin = math.log(v_min)
        self.counts = [0] * n_buckets
        self.zeros = 0  # samples <= v_min (incl. exact zeros)
        self.count = 0
        self.total = 0.0
        self.min_seen = math.inf
        self.max_seen = -math.inf

    # ------------------------------------------------------------- record
    def _bucket(self, v: float) -> int:
        i = int((math.log(v) - self._log_vmin) / self._log_growth)
        return min(max(i, 0), self.n_buckets - 1)

    def record(self, v: float, n: int = 1) -> None:
        """Add ``n`` observations of value ``v`` (O(1), no allocation)."""
        v = float(v)
        self.count += n
        self.total += v * n
        if v < self.min_seen:
            self.min_seen = v
        if v > self.max_seen:
            self.max_seen = v
        if v <= self.v_min:
            self.zeros += n
        else:
            self.counts[self._bucket(v)] += n

    # ------------------------------------------------------------ queries
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def bucket_bounds(self, i: int) -> tuple[float, float]:
        return (self.v_min * self.growth**i, self.v_min * self.growth ** (i + 1))

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile, reconstructed from bucket midpoints.

        Returns the geometric midpoint of the selected bucket, clamped to
        the exactly-tracked [min_seen, max_seen] envelope — always within
        one bucket width (x ``growth``) of the exact order statistic."""
        if not self.count:
            return float("nan")
        rank = max(1, min(self.count, math.ceil(q / 100.0 * self.count)))
        seen = self.zeros
        if rank <= seen:
            # all-zero bucket: the envelope is exact for min-side values
            return min(max(0.0, self.min_seen), self.v_min)
        for i, c in enumerate(self.counts):
            seen += c
            if rank <= seen:
                lo, hi = self.bucket_bounds(i)
                mid = math.sqrt(lo * hi)
                return min(max(mid, self.min_seen), self.max_seen)
        return self.max_seen  # unreachable when counts are consistent

    # ---------------------------------------------------------- aggregate
    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Fold ``other`` into self (shapes must match); returns self."""
        if (self.v_min, self.growth, self.n_buckets) != (
            other.v_min,
            other.growth,
            other.n_buckets,
        ):
            raise ValueError("cannot merge histograms with different bucketing")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.zeros += other.zeros
        self.count += other.count
        self.total += other.total
        self.min_seen = min(self.min_seen, other.min_seen)
        self.max_seen = max(self.max_seen, other.max_seen)
        return self

    def snapshot(self) -> dict:
        """Compact JSON-ready view: only non-empty buckets are listed."""
        out = {
            "count": self.count,
            "mean": round(self.mean, 6) if self.count else None,
            "min": self.min_seen if self.count else None,
            "max": self.max_seen if self.count else None,
            "p50": round(self.percentile(50), 6) if self.count else None,
            "p99": round(self.percentile(99), 6) if self.count else None,
        }
        buckets = {str(i): c for i, c in enumerate(self.counts) if c}
        if self.zeros:
            buckets["zero"] = self.zeros
        out["buckets"] = buckets
        return out

    def __repr__(self) -> str:
        return (
            f"LogHistogram(count={self.count}, mean={self.mean:.4g}, "
            f"buckets={sum(1 for c in self.counts if c)}/{self.n_buckets})"
        )


__all__ = ["LogHistogram"]
