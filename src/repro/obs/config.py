"""Typed configuration for the observability layer.

``ObservabilityConfig`` travels inside ``ServingConfig`` / ``SimulationConfig``
(repro.api) down to the ``Telemetry`` object every gateway owns, so one
dataclass controls tracing across the real dispatchers and the virtual-clock
simulation alike.  Validation happens at construction — a bad sampling rate
fails where it is written, not deep inside the serving hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

#: per-circuit lifecycle stages, in pipeline order.  ``submit`` opens the
#: trace; the terminal transition (complete / evict / fail / reject) closes
#: it and is always recorded for open traces regardless of stage filtering.
#: The recovery stages (retried / hedged / worker_offline / migrated /
#: requeue) revisit earlier pipeline stages by design — ``validate_trace``
#: relaxes its pipeline-order check for traces that contain them.
LIFECYCLE_STAGES = (
    "submit",
    "admit",
    "coalesced",
    "placed",
    "dispatched",
    "kernel_start",
    "retried",
    "hedged",
    "worker_offline",
    "migrated",
    "requeue",
)

#: stages recorded only on the failure-recovery path: their presence means
#: the circuit legitimately revisited earlier pipeline stages.
RECOVERY_STAGES = frozenset(
    {"retried", "hedged", "worker_offline", "migrated", "requeue"}
)

#: federated-round lifecycle stages (``repro.federated``): these are
#: ROUND-level events recorded via ``TraceRecorder.round_event`` — one per
#: aggregation-round transition, not per circuit — so they never appear
#: inside a ``CircuitTrace`` and are exempt from the pipeline-order check.
FEDERATED_STAGES = (
    "round_start",
    "update_received",
    "update_late",
    "round_aggregated",
)


@dataclasses.dataclass(frozen=True)
class ObservabilityConfig:
    """Tracing + metrics knobs.

    ``enabled``: master switch — False makes every recorder hook a cheap
    no-op (the sampling=0 fast path the gateway benchmark pins).
    ``sample_rate``: fraction of circuits that get a full lifecycle trace
    record (deterministic hash of the admission sequence number, so virtual
    -clock traces are reproducible); histograms and worker timelines are
    O(1) memory and always on while enabled.  ``buffer_size``: ring-buffer
    capacity for finished trace records and worker spans — memory stays
    bounded at millions of circuits.  ``stages``: optional subset of
    ``LIFECYCLE_STAGES`` to record (None = all).
    """

    enabled: bool = True
    sample_rate: float = 1.0
    buffer_size: int = 65536
    stages: Optional[tuple[str, ...]] = None

    def __post_init__(self):
        if not 0.0 <= self.sample_rate <= 1.0:
            raise ValueError(
                f"sample_rate must be in [0, 1], got {self.sample_rate}"
            )
        if self.buffer_size < 1:
            raise ValueError(
                f"buffer_size must be >= 1, got {self.buffer_size}"
            )
        if self.stages is not None:
            if not isinstance(self.stages, tuple):
                object.__setattr__(self, "stages", tuple(self.stages))
            valid = set(LIFECYCLE_STAGES) | set(FEDERATED_STAGES)
            unknown = sorted(set(self.stages) - valid)
            if unknown:
                raise ValueError(
                    f"unknown stage(s) {unknown}; valid stages: "
                    f"{list(LIFECYCLE_STAGES) + list(FEDERATED_STAGES)}"
                )

    @classmethod
    def disabled(cls) -> "ObservabilityConfig":
        return cls(enabled=False, sample_rate=0.0)


__all__ = [
    "FEDERATED_STAGES",
    "LIFECYCLE_STAGES",
    "RECOVERY_STAGES",
    "ObservabilityConfig",
]
