"""repro.obs — tracing + metrics for the multi-tenant serving stack.

One recorder serves both runtimes (virtual-clock simulation and real
kernel dispatchers) because every clock in the stack is a caller-supplied
float.  See ``trace.TraceRecorder`` for the hook surface and
``histogram.LogHistogram`` for the fixed-memory aggregation primitive.
"""
from repro.obs.config import (
    FEDERATED_STAGES,
    LIFECYCLE_STAGES,
    RECOVERY_STAGES,
    ObservabilityConfig,
)
from repro.obs.histogram import LogHistogram
from repro.obs.trace import (
    OUTCOMES,
    STAGE_METRICS,
    CircuitTrace,
    RoundEvent,
    TraceBuffer,
    TraceRecorder,
    WorkerSpan,
    WorkerTimeline,
    validate_trace,
)

__all__ = [
    "FEDERATED_STAGES",
    "LIFECYCLE_STAGES",
    "OUTCOMES",
    "RECOVERY_STAGES",
    "STAGE_METRICS",
    "CircuitTrace",
    "LogHistogram",
    "ObservabilityConfig",
    "RoundEvent",
    "TraceBuffer",
    "TraceRecorder",
    "WorkerSpan",
    "WorkerTimeline",
    "validate_trace",
]
