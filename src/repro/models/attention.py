"""Attention blocks: GQA/MQA (+qk_norm, sliding window) and DeepSeek MLA.

Shapes: x (B, S, D).  KV caches are explicit pytrees so ``serve_step`` can
thread them functionally.  All softmax/logit math is f32; projections run in
the model dtype (bf16 on TPU).

Cache layouts:
  GQA  : {"k": (B, T, KV, hd), "v": (B, T, KV, hd), "pos": ()} — T is the
         cache capacity (seq_len, or the sliding window for windowed archs,
         maintained as a ring buffer).
  MLA  : {"ckv": (B, T, kv_lora), "krope": (B, T, rope_dim), "pos": ()} —
         the compressed latent is cached once, NOT per head (that is the
         point of MLA: 576 floats/token instead of H*(nope+v)=32k).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import common
from repro.models.common import apply_rope, causal_mask, rms_norm, softmax_f32


# ----------------------------------------------------------------- params
def init_gqa_params(key, cfg: ModelConfig, dtype):
    hd = cfg.resolved_head_dim
    ks = common.keygen(key)
    p = {
        "wq": common.init_dense(next(ks), cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": common.init_dense(next(ks), cfg.d_model, cfg.kv_heads * hd, dtype),
        "wv": common.init_dense(next(ks), cfg.d_model, cfg.kv_heads * hd, dtype),
        "wo": common.init_dense(next(ks), cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_mla_params(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    ks = common.keygen(key)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": common.init_dense(next(ks), cfg.d_model, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": common.init_dense(next(ks), m.q_lora_rank, cfg.n_heads * qk_head, dtype),
        "wkv_a": common.init_dense(next(ks), cfg.d_model,
                                   m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": common.init_dense(next(ks), m.kv_lora_rank,
                                   cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim),
                                   dtype),
        "wo": common.init_dense(next(ks), cfg.n_heads * m.v_head_dim, cfg.d_model, dtype),
    }


# ------------------------------------------------------------- GQA apply
def _qk_normalize(q, k, params, cfg, eps):
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], eps)
        k = rms_norm(k, params["k_norm"], eps)
    return q, k


def gqa_attention(params, x, cfg: ModelConfig, *, positions=None):
    """Full (or sliding-window) causal self-attention over x (B, S, D)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.kv_heads
    g = h // kv
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    q, k = _qk_normalize(q, k, params, cfg, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bsigd,btid->bigst", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = scores + causal_mask(s, s, 0, cfg.sliding_window)[None, None, None]
    probs = softmax_f32(scores).astype(x.dtype)
    out = jnp.einsum("bigst,btid->bsigd", probs, v).reshape(b, s, h * hd)
    return out @ params["wo"]


def chunked_gqa_attention(params, x, cfg: ModelConfig, *, positions=None):
    """Flash-style causal attention: scan over KV chunks with an online
    softmax (running max / normalizer / accumulator), so the (B,H,S,S)
    score tensor is never materialized — per-step live memory is one
    (B, KV, G, Q, K) tile.  Numerically identical to gqa_attention.

    Fully-masked (future) KV chunks still execute (static shapes) but
    contribute zero; the causal skip is a compute win left to the Pallas
    variant — here the target is the HBM term, which this kills.
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.kv_heads
    g = h // kv
    ck = min(cfg.attention_chunk, s)
    assert s % ck == 0, (s, ck)
    n_chunks = s // ck
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    q, k = _qk_normalize(q, k, params, cfg, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    qg = q.reshape(b, n_chunks, ck, kv, g, hd) * (hd ** -0.5)
    kc = k.reshape(b, n_chunks, ck, kv, hd)
    vc = v.reshape(b, n_chunks, ck, kv, hd)

    def q_block(qi, q_tile):
        # q_tile: (b, ck, kv, g, hd); scan KV chunks with online softmax
        def kv_step(carry, kj_tiles):
            m_run, l_run, acc = carry
            kj, k_tile, v_tile = kj_tiles
            scores = jnp.einsum("bsigd,btid->bigst", q_tile,
                                k_tile).astype(jnp.float32)
            q_pos = qi * ck + jnp.arange(ck)[:, None]
            k_pos = kj * ck + jnp.arange(ck)[None, :]
            ok = k_pos <= q_pos
            if cfg.sliding_window:
                ok = ok & (k_pos > q_pos - cfg.sliding_window)
            scores = jnp.where(ok[None, None, None], scores, -1e30)
            m_new = jnp.maximum(m_run, scores.max(-1))          # (b,kv,g,ck)
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            pv = jnp.einsum("bigst,btid->bigsd", p.astype(x.dtype),
                            v_tile).astype(jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), 0

        m0 = jnp.full((b, kv, g, ck), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, g, ck), jnp.float32)
        a0 = jnp.zeros((b, kv, g, ck, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0),
             jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l_f[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1)                          # (b,ck,kv,g,hd)

    if cfg.attention_impl == "chunked_seqpar":
        # sequence parallelism: q-chunks spread over the (otherwise idle
        # during attention) "model" axis; K/V stay whole per device — XLA
        # all-gathers them once per layer.  Turns the per-device score-tile
        # traffic into 1/model_parallelism of the total.
        from repro.models.sharding import shard_hint
        qg = shard_hint(qg, "batch", "model", None, None, None, None)
        outs = jax.vmap(q_block, in_axes=(0, 1), out_axes=1)(
            jnp.arange(n_chunks), qg)
        outs = shard_hint(outs, "batch", "model", None, None, None, None)
        out = outs.reshape(b, s, h * hd).astype(x.dtype)
    else:
        outs = jax.lax.map(lambda args: q_block(*args),
                           (jnp.arange(n_chunks), jnp.moveaxis(qg, 1, 0)))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h * hd).astype(x.dtype)
    return out @ params["wo"]


def init_gqa_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    hd = cfg.resolved_head_dim
    t = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
    zeros = jnp.zeros((batch, t, cfg.kv_heads, hd), dtype)
    return {"k": zeros, "v": zeros}


def gqa_decode(params, x, cache, pos, cfg: ModelConfig):
    """One decode step. x (B, 1, D); pos () int32 = absolute position of the
    new token.  Returns (out (B,1,D), new_cache)."""
    b, s, d = x.shape
    assert s == 1
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.kv_heads
    g = h // kv
    t = cache["k"].shape[1]

    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, kv, hd)
    v = (x @ params["wv"]).reshape(b, 1, kv, hd)
    q, k = _qk_normalize(q, k, params, cfg, cfg.norm_eps)
    ppos = jnp.full((b, 1), pos, jnp.int32)
    q = apply_rope(q, ppos, cfg.rope_theta)
    k = apply_rope(k, ppos, cfg.rope_theta)

    slot = (pos % t) if cfg.sliding_window else pos   # ring buffer when windowed
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))

    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bigd,btid->bigt", qg, ck).astype(jnp.float32) * (hd ** -0.5)
    # valid slots: every filled position (serve_step decodes against a cache
    # pre-filled with seq_len context, so pos >= t for windowed rings).
    slot_idx = jnp.arange(t)
    if cfg.sliding_window:
        valid = (slot_idx <= pos) | jnp.full((t,), pos >= t)
    else:
        valid = slot_idx <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = softmax_f32(scores).astype(x.dtype)
    out = jnp.einsum("bigt,btid->bigd", probs, cv).reshape(b, 1, h * hd)
    return out @ params["wo"], {"k": ck, "v": cv}


# ------------------------------------------------------------- MLA apply
def _mla_dims(m: MLAConfig):
    return m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim


def mla_attention(params, x, cfg: ModelConfig, *, positions=None):
    """Training/prefill MLA (naive decompressed form)."""
    b, s, d = x.shape
    m = cfg.mla
    nope, rope_d, vd = _mla_dims(m)
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q_lat = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (q_lat @ params["wq_b"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ params["wkv_a"]                        # (B,S,kv_lora+rope)
    ckv = rms_norm(kv_a[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)

    kvb = (ckv @ params["wkv_b"]).reshape(b, s, h, nope + vd)
    k_nope, v = kvb[..., :nope], kvb[..., nope:]

    scale = (nope + rope_d) ** -0.5
    scores = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
              + jnp.einsum("bshd,btxd->bhst", q_rope,
                           jnp.broadcast_to(k_rope, (b, s, 1, rope_d))))
    scores = scores.astype(jnp.float32) * scale
    scores = scores + causal_mask(s, s, 0)[None, None]
    probs = softmax_f32(scores).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(b, s, h * vd)
    return out @ params["wo"]


def init_mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype)}


def mla_decode(params, x, cache, pos, cfg: ModelConfig):
    """One decode step with the ABSORBED latent form: attention runs in the
    kv_lora_rank space, so per-token cache is kv_lora+rope floats."""
    b, s, d = x.shape
    m = cfg.mla
    nope, rope_d, vd = _mla_dims(m)
    h = cfg.n_heads
    t = cache["ckv"].shape[1]

    q_lat = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (q_lat @ params["wq_b"]).reshape(b, 1, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ppos = jnp.full((b, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope, ppos, cfg.rope_theta)

    kv_a = x @ params["wkv_a"]
    ckv_new = rms_norm(kv_a[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kv_a[..., None, m.kv_lora_rank:], ppos, cfg.rope_theta)[:, :, 0]

    ckv = jax.lax.dynamic_update_slice(cache["ckv"],
                                       ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"],
                                         kr_new.astype(cache["krope"].dtype), (0, pos, 0))

    # absorb W^UK into the query: q_lat[b,h,r] = sum_d q_nope[b,h,d] * Wuk[r,h,d]
    wkv_b = params["wkv_b"].reshape(m.kv_lora_rank, h, nope + vd)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)          # (B,H,R)

    scale = (nope + rope_d) ** -0.5
    scores = (jnp.einsum("bhr,btr->bht", q_abs, ckv)
              + jnp.einsum("bhd,btd->bht", q_rope[:, 0], krope))
    scores = scores.astype(jnp.float32) * scale
    valid = jnp.arange(t) <= pos
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    probs = softmax_f32(scores).astype(x.dtype)
    ctx = jnp.einsum("bht,btr->bhr", probs, ckv)                    # latent ctx
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv).reshape(b, 1, h * vd)
    return out @ params["wo"], {"ckv": ckv, "krope": krope}
