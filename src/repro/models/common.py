"""Shared model substrate: norms, RoPE, initializers, embedding."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv).astype(dt) * scale.astype(dt)


def init_dense(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    s = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * s).astype(dtype)


def init_embed(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


def keygen(key):
    """Infinite deterministic key splitter."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def causal_mask(q_len: int, kv_len: int, q_offset, window: int = 0) -> jnp.ndarray:
    """(q_len, kv_len) additive mask.  ``q_offset`` = absolute position of
    query row 0 (traced OK).  ``window`` > 0 -> sliding-window causal."""
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    k_pos = jnp.arange(kv_len)[None, :]
    ok = k_pos <= q_pos
    if window:
        ok = ok & (k_pos > q_pos - window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def softmax_f32(logits: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    return jax.nn.softmax(logits.astype(jnp.float32), axis=axis)


def activation_fn(name: str):
    if name == "silu_gated" or name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                 # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE in f32. logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()
