"""Modality frontends — STUBS by explicit carve-out of the brief.

The [vlm] and [audio] architectures implement the TRANSFORMER BACKBONE; the
ViT/SigLIP vision tower and the EnCodec audio codec are not rebuilt.  These
helpers produce the precomputed embeddings / token grids the backbones
consume, with the right shapes and deterministic contents, for smoke tests,
examples and the dry-run input_specs().
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def vlm_batch(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0) -> dict:
    """Phi-3-vision style: ``image_embeds`` (B, P, clip_dim) precomputed patch
    features + text tokens filling the rest of the sequence."""
    p = cfg.n_prefix_embeds
    assert seq_len > p, (seq_len, p)
    rng = np.random.default_rng(seed)
    return {
        "image_embeds": jnp.asarray(
            rng.standard_normal((batch, p, cfg.prefix_embed_dim), np.float32) * 0.5),
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, seq_len - p), dtype=np.int32)),
    }


def audio_batch(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0) -> dict:
    """MusicGen style: EnCodec RVQ token grid (B, S, K) — one token per
    codebook per frame (we model the flattened/parallel pattern)."""
    rng = np.random.default_rng(seed)
    return {"codes": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, seq_len, cfg.n_codebooks),
                     dtype=np.int32))}


def text_batch(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, seq_len), dtype=np.int32))}


def batch_for(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0) -> dict:
    if cfg.n_codebooks:
        return audio_batch(cfg, batch, seq_len, seed)
    if cfg.n_prefix_embeds:
        return vlm_batch(cfg, batch, seq_len, seed)
    return text_batch(cfg, batch, seq_len, seed)


def decode_batch_for(cfg: ModelConfig, batch: int, seed: int = 0) -> dict:
    """The single new token fed to serve_step."""
    rng = np.random.default_rng(seed)
    if cfg.n_codebooks:
        return {"codes": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, 1, cfg.n_codebooks), np.int32))}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, 1), dtype=np.int32))}
