"""The language-model core: embeddings -> scanned block periods -> head.

Scan-over-periods keeps the lowered HLO one period long regardless of depth
(96-layer Nemotron compiles the same-sized module as 2-layer smoke configs),
which is what makes 80 full-size dry-run compiles tractable.

Handles every assigned family:
  dense/moe/hybrid/ssm : ModelConfig.pattern + MoEConfig.every
  vlm                  : precomputed patch embeddings + projector (stub
                         frontend per the brief) prepended to text tokens
  audio                : K codebook embeddings summed, K output heads
  deepseek MTP         : auxiliary next-next-token head (weight 0.3)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, common
from repro.models.common import cross_entropy, rms_norm
from repro.models.sharding import shard_hint


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        p = len(cfg.pattern)
        self.use_moe = tuple(cfg.is_moe_layer(j) for j in range(p))

    # ------------------------------------------------------------- params
    def init_params(self, key) -> dict:
        cfg = self.cfg
        ks = common.keygen(key)
        params: dict = {}
        if cfg.n_codebooks:
            params["embed"] = jnp.stack([
                common.init_embed(next(ks), cfg.vocab, cfg.d_model, self.dtype)
                for _ in range(cfg.n_codebooks)])
            params["heads"] = jnp.stack([
                common.init_dense(next(ks), cfg.d_model, cfg.vocab, self.dtype)
                for _ in range(cfg.n_codebooks)])
        else:
            params["embed"] = common.init_embed(next(ks), cfg.vocab, cfg.d_model,
                                                self.dtype)
            if not cfg.tie_embeddings:
                params["lm_head"] = common.init_dense(next(ks), cfg.d_model,
                                                      cfg.vocab, self.dtype)
        if cfg.n_prefix_embeds:
            params["projector"] = common.init_dense(
                next(ks), cfg.prefix_embed_dim, cfg.d_model, self.dtype)
        params["final_norm"] = jnp.ones((cfg.d_model,), self.dtype)
        if cfg.mtp_depth:
            params["mtp_proj"] = common.init_dense(next(ks), 2 * cfg.d_model,
                                                   cfg.d_model, self.dtype)
            params["mtp_norm"] = jnp.ones((cfg.d_model,), self.dtype)

        period_keys = jax.random.split(next(ks), cfg.n_periods)
        stacked = []
        for j, kind in enumerate(cfg.pattern):
            init_j = functools.partial(self._init_one_block, j, kind)
            stacked.append(jax.vmap(init_j)(
                jax.vmap(lambda k: jax.random.fold_in(k, j))(period_keys)))
        params["blocks"] = stacked
        return params

    def _init_one_block(self, j: int, kind: str, key):
        return blocks.init_block_params(key, kind, self.use_moe[j], self.cfg,
                                        self.dtype)

    # -------------------------------------------------------------- embed
    def embed_inputs(self, params, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.n_codebooks:
            codes = batch["codes"]                     # (B, S, K)
            x = jnp.zeros(codes.shape[:2] + (cfg.d_model,), self.dtype)
            for k in range(cfg.n_codebooks):
                x = x + jnp.take(params["embed"][k], codes[..., k], axis=0)
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.n_prefix_embeds and "image_embeds" in batch:
            prefix = (batch["image_embeds"].astype(self.dtype)
                      @ params["projector"])           # (B, P, D)
            x = jnp.concatenate([prefix, x], axis=1)
        return shard_hint(x, "batch", None, None)

    # ------------------------------------------------------------ forward
    def forward(self, params, x, *, caches=None, pos=None):
        """x (B,S,D) -> (hidden (B,S,D), aux, new_caches)."""
        cfg = self.cfg
        pattern = cfg.pattern
        decode = caches is not None

        def body(carry, scanned):
            x, aux = carry
            pp = scanned[0] if decode else scanned
            pc = scanned[1] if decode else [None] * len(pattern)
            new_c = []
            for j, kind in enumerate(pattern):
                x, a, nc = blocks.apply_block(pp[j], x, kind, self.use_moe[j],
                                              cfg, cache=pc[j], pos=pos)
                aux = aux + a
                new_c.append(nc)
            return (x, aux), (new_c if decode else 0)

        if cfg.remat and not decode:
            body = jax.checkpoint(body, prevent_cse=False)

        xs = (params["blocks"], caches) if decode else params["blocks"]
        (x, aux), out = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
        return x, aux, (out if decode else None)

    def hidden_to_logits(self, params, h):
        cfg = self.cfg
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        if cfg.n_codebooks:
            return jnp.einsum("bsd,kdv->bskv", h, params["heads"])
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = h @ head
        return shard_hint(logits, "batch", None, "model")

    # --------------------------------------------------------------- loss
    def loss(self, params, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        h, aux, _ = self.forward(params, x)
        logits = self.hidden_to_logits(params, h)
        if cfg.n_codebooks:
            codes = batch["codes"]
            ce = cross_entropy(logits[:, :-1].reshape(-1, cfg.vocab),
                               codes[:, 1:].reshape(-1))
        else:
            labels = batch["tokens"]
            pfx = cfg.n_prefix_embeds if "image_embeds" in batch else 0
            lg = logits[:, pfx:, :]                    # text region only
            ce = cross_entropy(lg[:, :-1], labels[:, 1:])
            if cfg.mtp_depth:
                # DeepSeek-style multi-token prediction: predict t+2 from
                # [h_t ; embed(tok_{t+1})] through a small projection.
                hh = h[:, pfx:, :]
                emb_next = jnp.take(params["embed"], labels[:, 1:], axis=0)
                z = jnp.concatenate([hh[:, :-1], emb_next], -1) @ params["mtp_proj"]
                z = rms_norm(z, params["mtp_norm"], cfg.norm_eps)
                head = (params["embed"].T if cfg.tie_embeddings
                        else params["lm_head"])
                mtp_logits = z[:, :-1] @ head
                ce = ce + 0.3 * cross_entropy(mtp_logits, labels[:, 2:])
        return ce + aux

    # -------------------------------------------------------------- decode
    def init_caches(self, batch: int, capacity: int):
        cfg = self.cfg
        out = []
        for j, kind in enumerate(cfg.pattern):
            c = blocks.init_block_cache(kind, cfg, batch, capacity, self.dtype)
            out.append(jax.tree.map(
                lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), c))
        return out

    def decode_step(self, params, batch: dict, caches, pos):
        """One-token decode: batch holds the NEW token; ``pos`` its position.
        Returns (logits (B, 1, V[,K]), new_caches)."""
        x = self.embed_inputs(params, batch)
        h, _, new_caches = self.forward(params, x, caches=caches, pos=pos)
        return self.hidden_to_logits(params, h), new_caches

    # ------------------------------------------------------------ prefill
    def prefill(self, params, batch: dict):
        """Full-sequence forward returning logits (no cache construction —
        examples re-feed tokens through decode_step for cached generation)."""
        x = self.embed_inputs(params, batch)
        h, aux, _ = self.forward(params, x)
        return self.hidden_to_logits(params, h), aux


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """Parameters touched per token (MoE counts top_k + shared experts)."""
    total = param_count(params)
    if cfg.moe is None:
        return total
    m = cfg.moe
    # subtract non-activated expert weight
    def expert_leaves(tree):
        return sum(int(p.size) for p in jax.tree.leaves(tree))
    inactive_frac = 1.0 - (m.top_k / m.n_experts)
    moe_layers = sum(cfg.is_moe_layer(j) for j in range(len(cfg.pattern))) \
        * cfg.n_periods
    per_layer_expert = 0
    # recompute from shapes: E * (in*ff [+gate] + ff*out)
    gated = cfg.activation.endswith("_gated")
    per_layer_expert = m.n_experts * m.d_ff_expert * cfg.d_model * (3 if gated else 2)
    return int(total - inactive_frac * per_layer_expert * moe_layers)
