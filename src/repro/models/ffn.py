"""Feed-forward blocks: gated (SwiGLU), plain GeLU, squared-ReLU (Nemotron)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common


def is_gated(activation: str) -> bool:
    return activation.endswith("_gated")


def init_ffn_params(key, d_model: int, d_ff: int, activation: str, dtype):
    ks = common.keygen(key)
    p = {"w_in": common.init_dense(next(ks), d_model, d_ff, dtype),
         "w_out": common.init_dense(next(ks), d_ff, d_model, dtype)}
    if is_gated(activation):
        p["w_gate"] = common.init_dense(next(ks), d_model, d_ff, dtype)
    return p


def ffn(params, x, activation: str):
    act = common.activation_fn(activation.replace("_gated", ""))
    h = x @ params["w_in"]
    if is_gated(activation):
        h = act(x @ params["w_gate"]) * h
    else:
        h = act(h)
    return h @ params["w_out"]
