"""Sub-quadratic sequence mixers: Mamba selective SSM and xLSTM cells.

All three mixers share one primitive: the diagonal linear recurrence
    h_t = a_t * h_{t-1} + b_t          (elementwise on the state)
computed CHUNKED over the sequence: a lax.scan over chunks carries the state;
within a chunk an associative scan materializes only (B, chunk, ...) — the
full (B, S, d_inner, d_state) tensor never exists.  This is the TPU-friendly
shape of the paper['s] recurrent-scan workloads (xlstm-125m, jamba).

Simplifications vs. the source papers (documented in DESIGN.md):
  * mLSTM uses log-space decay with per-row max stabilization inside each
    chunk (not the exact m_t running-max recursion across the whole
    sequence); normalizer lower-bounded at 1.
  * sLSTM keeps the exact sequential recurrence (lax.scan over steps) —
    there is no parallel form; that is the point of including it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import common


# ------------------------------------------------- chunked linear recurrence
def linear_recurrence_chunked(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t.  a, b: (B, S, ...), h0: (B, ...).

    Returns (h (B, S, ...), h_last (B, ...)).  Sequences that don't divide by
    ``chunk`` are zero-padded at the end (padded a=0 -> padded h=0, so
    ``h_last`` equals the true final state only when S % chunk == 0; the
    training path never consumes h_last).
    """
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2)
        a = jnp.pad(a, widths)
        b = jnp.pad(b, widths)
    S_p = S + pad
    n_chunks = S_p // chunk
    rest = a.shape[2:]
    a_c = a.reshape((B, n_chunks, chunk) + rest)
    b_c = b.reshape((B, n_chunks, chunk) + rest)
    del a, b

    def assoc(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    def step(h, ab):
        a_k, b_k = ab                                  # (B, chunk, ...)
        aa, bb = jax.lax.associative_scan(assoc, (a_k, b_k), axis=1)
        h_all = aa * h[:, None] + bb                   # (B, chunk, ...)
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(
        step, h0, (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(b_c, 1, 0)))
    h = jnp.moveaxis(h_chunks, 0, 1).reshape((B, S_p) + rest)[:, :S]
    return h, h_last


# ------------------------------------------------------------------- Mamba
def mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return d_inner, dt_rank, s.d_state


def init_mamba_params(key, cfg: ModelConfig, dtype):
    d_inner, dt_rank, n = mamba_dims(cfg)
    ks = common.keygen(key)
    # S4D-real initialization for A
    a_init = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                              (d_inner, n))
    return {
        "in_proj": common.init_dense(next(ks), cfg.d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(next(ks), (cfg.ssm.d_conv, d_inner), jnp.float32)
                   * (cfg.ssm.d_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_dtbc": common.init_dense(next(ks), d_inner, dt_rank + 2 * n, dtype),
        "dt_proj": common.init_dense(next(ks), dt_rank, d_inner, dtype, scale=dt_rank ** -0.5),
        "dt_bias": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": common.init_dense(next(ks), d_inner, cfg.d_model, dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x (B,S,C), w (K,C).  With ``state`` (B,K-1,C)
    performs a single-step update (S==1) and returns (y, new_state)."""
    k = w.shape[0]
    if state is not None:
        buf = jnp.concatenate([state, x], axis=1)       # (B, K, C)
        y = jnp.einsum("bkc,kc->bc", buf, w)[:, None] + b
        return y, buf[:, 1:]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    return y, None


def mamba_mixer(params, x, cfg: ModelConfig, *, state=None):
    """x (B,S,D) -> (y (B,S,D), new_state or None).

    ``state`` = {"h": (B, d_inner, N), "conv": (B, K-1, d_inner)} enables
    single-token decode (S == 1).
    """
    b_sz, s_len, _ = x.shape
    d_inner, dt_rank, n = mamba_dims(cfg)
    decode = state is not None

    xz = x @ params["in_proj"]
    xs, z = xz[..., :d_inner], xz[..., d_inner:]
    xs, conv_state = _causal_conv(xs, params["conv_w"], params["conv_b"],
                                  state["conv"] if decode else None)
    xs = jax.nn.silu(xs)

    dtbc = xs @ params["w_dtbc"]
    dt = jax.nn.softplus((dtbc[..., :dt_rank] @ params["dt_proj"]).astype(jnp.float32)
                         + params["dt_bias"])                       # (B,S,di)
    b_in = dtbc[..., dt_rank:dt_rank + n].astype(jnp.float32)       # (B,S,N)
    c_in = dtbc[..., dt_rank + n:].astype(jnp.float32)              # (B,S,N)

    a = jnp.exp(-jnp.exp(params["a_log"])[None, None] * dt[..., None])  # (B,S,di,N)
    bu = (dt * xs.astype(jnp.float32))[..., None] * b_in[:, :, None, :]

    if decode:
        h = state["h"] * a[:, 0] + bu[:, 0]                         # (B,di,N)
        y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0])[:, None]
        new_state = {"h": h, "conv": conv_state}
    else:
        h0 = jnp.zeros((b_sz, d_inner, n), jnp.float32)
        h_all, _ = linear_recurrence_chunked(a, bu, h0, cfg.ssm.chunk)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c_in)
        new_state = None

    y = (y + params["d_skip"] * xs.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"], new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype):
    d_inner, _, n = mamba_dims(cfg)
    return {"h": jnp.zeros((batch, d_inner, n), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, d_inner), dtype)}


# ------------------------------------------------------------------- mLSTM
def init_mlstm_params(key, cfg: ModelConfig, dtype):
    h = cfg.ssm.n_heads
    hd = cfg.d_model // h
    ks = common.keygen(key)
    return {
        "wq": common.init_dense(next(ks), cfg.d_model, cfg.d_model, dtype),
        "wk": common.init_dense(next(ks), cfg.d_model, cfg.d_model, dtype),
        "wv": common.init_dense(next(ks), cfg.d_model, cfg.d_model, dtype),
        "w_if": common.init_dense(next(ks), cfg.d_model, 2 * h, dtype, scale=0.02),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # forget-gate bias -> remember
        "w_gate": common.init_dense(next(ks), cfg.d_model, cfg.d_model, dtype),
        "wo": common.init_dense(next(ks), cfg.d_model, cfg.d_model, dtype),
    }


def mlstm_mixer(params, x, cfg: ModelConfig, *, state=None):
    """Matrix-memory LSTM: C_t = f_t C_{t-1} + i_t v_t k_t^T, y_t = C_t q_t.

    Training path: chunked — inter-chunk state carried exactly, intra-chunk
    computed as decay-masked linear attention in log space (f32).
    Decode path (state given): exact single-step recurrence.
    state = {"c": (B,H,dk,dv), "n": (B,H,dk)}.
    """
    b_sz, s_len, d = x.shape
    h = cfg.ssm.n_heads
    hd = d // h

    q = (x @ params["wq"]).reshape(b_sz, s_len, h, hd) * hd ** -0.5
    k = (x @ params["wk"]).reshape(b_sz, s_len, h, hd) * hd ** -0.5
    v = (x @ params["wv"]).reshape(b_sz, s_len, h, hd)
    gates = (x @ params["w_if"]).astype(jnp.float32).reshape(b_sz, s_len, 2, h)
    log_i = -jax.nn.softplus(-(gates[:, :, 0] + params["b_i"]))   # log sigmoid
    log_f = -jax.nn.softplus(-(gates[:, :, 1] + params["b_f"]))

    if state is not None:
        i_t, f_t = jnp.exp(log_i[:, 0]), jnp.exp(log_f[:, 0])     # (B,H)
        qh = q[:, 0].astype(jnp.float32)                          # (B,H,hd)
        kh = k[:, 0].astype(jnp.float32)
        vh = v[:, 0].astype(jnp.float32)
        c = state["c"] * f_t[..., None, None] + \
            i_t[..., None, None] * jnp.einsum("bhk,bhv->bhkv", kh, vh)
        n = state["n"] * f_t[..., None] + i_t[..., None] * kh
        num = jnp.einsum("bhkv,bhk->bhv", c, qh)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qh)), 1.0)
        y = (num / den[..., None]).reshape(b_sz, 1, d)
        new_state = {"c": c, "n": n}
    else:
        chunk = min(cfg.ssm.chunk, s_len)
        pad = (-s_len) % chunk
        if pad:
            # zero-pad the tail chunk: padded keys/values contribute nothing
            # (k=v=0), padded i-gates get -inf so they never write state.
            pw3 = ((0, 0), (0, pad), (0, 0), (0, 0))
            q, k, v = (jnp.pad(t, pw3) for t in (q, k, v))
            log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                            constant_values=-1e30)
            log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        s_p = s_len + pad
        nc = s_p // chunk
        qc = q.reshape(b_sz, nc, chunk, h, hd)
        kc = k.reshape(b_sz, nc, chunk, h, hd)
        vc = v.reshape(b_sz, nc, chunk, h, hd)
        li = log_i.reshape(b_sz, nc, chunk, h)
        lf = log_f.reshape(b_sz, nc, chunk, h)

        def step(carry, inp):
            c_st, n_st = carry                        # (B,H,dk,dv), (B,H,dk)
            qk, kk, vk, lik, lfk = inp                # (B, chunk, ...)
            cum_f = jnp.cumsum(lfk, axis=1)           # (B,chunk,H)
            # intra-chunk decay matrix: D[s,t] = exp(cumf_s - cumf_t + i_t), t<=s
            dmat = (cum_f[:, :, None] - cum_f[:, None, :]
                    + lik[:, None, :, :])             # (B,S,T,H)
            tri = jnp.tril(jnp.ones((chunk, chunk), bool))
            dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
            # stabilize rows against both intra max and inter decay
            m_row = jnp.maximum(jnp.max(dmat, axis=2), cum_f)      # (B,S,H)
            w_intra = jnp.exp(dmat - m_row[:, :, None])            # (B,S,T,H)
            scores = jnp.einsum("bshd,bthd->bsth", qk.astype(jnp.float32),
                                kk.astype(jnp.float32))
            y_intra = jnp.einsum("bsth,bthd->bshd", scores * w_intra,
                                 vk.astype(jnp.float32))
            n_intra = jnp.einsum("bsth,bthd->bshd", w_intra,
                                 kk.astype(jnp.float32))
            # inter-chunk: contribution of carried state
            w_inter = jnp.exp(cum_f - m_row)                       # (B,S,H)
            y_inter = jnp.einsum("bshd,bhdv->bshv", qk.astype(jnp.float32),
                                 c_st) * w_inter[..., None]
            n_inter = jnp.einsum("bshd,bhd->bsh", qk.astype(jnp.float32),
                                 n_st)[..., None] * w_inter[..., None]
            num = y_intra + y_inter
            den = jnp.abs(jnp.einsum("bshd,bshd->bsh", qk.astype(jnp.float32),
                                     n_intra)[..., None] + n_inter)
            y_k = num / jnp.maximum(den, jnp.exp(-m_row)[..., None])
            # exact state update to end of chunk
            tot_f = cum_f[:, -1]                                   # (B,H)
            decay_to_end = tot_f[:, None] - cum_f + lik            # (B,chunk,H)
            wk_end = jnp.exp(decay_to_end)
            c_new = c_st * jnp.exp(tot_f)[..., None, None] + \
                jnp.einsum("bthd,bthv,bth->bhdv", kk.astype(jnp.float32),
                           vk.astype(jnp.float32), wk_end)
            n_new = n_st * jnp.exp(tot_f)[..., None] + \
                jnp.einsum("bthd,bth->bhd", kk.astype(jnp.float32), wk_end)
            return (c_new, n_new), y_k

        c0 = jnp.zeros((b_sz, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b_sz, h, hd), jnp.float32)
        (_, _), ys = jax.lax.scan(
            step, (c0, n0),
            (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
             jnp.moveaxis(li, 1, 0), jnp.moveaxis(lf, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1).reshape(b_sz, s_p, h, hd)[:, :s_len]
        y = y.reshape(b_sz, s_len, d)
        new_state = None

    y = y.astype(x.dtype) * jax.nn.silu(x @ params["w_gate"])
    return y @ params["wo"], new_state


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype):
    h = cfg.ssm.n_heads
    hd = cfg.d_model // h
    return {"c": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32)}


# ------------------------------------------------------------------- sLSTM
def init_slstm_params(key, cfg: ModelConfig, dtype):
    h = cfg.ssm.n_heads
    hd = cfg.d_model // h
    ks = common.keygen(key)
    return {
        "w_in": common.init_dense(next(ks), cfg.d_model, 4 * cfg.d_model, dtype),
        # block-diagonal recurrent weights per head: (4, H, hd, hd)
        "r": (jax.random.normal(next(ks), (4, h, hd, hd), jnp.float32)
              * hd ** -0.5).astype(dtype),
        "b": jnp.concatenate([jnp.zeros((2 * cfg.d_model,), jnp.float32),
                              jnp.full((cfg.d_model,), 3.0, jnp.float32),
                              jnp.zeros((cfg.d_model,), jnp.float32)]),
        "wo": common.init_dense(next(ks), cfg.d_model, cfg.d_model, dtype),
    }


def slstm_mixer(params, x, cfg: ModelConfig, *, state=None):
    """Scalar-memory LSTM with recurrent block-diagonal connections.

    Exact sequential recurrence (z, i, f, o gates; stabilizer m):
      state = {"c","n","h","m"} each (B, D)-shaped f32 (h in model dtype).
    """
    b_sz, s_len, d = x.shape
    h_heads = cfg.ssm.n_heads
    hd = d // h_heads

    pre_all = (x @ params["w_in"]).astype(jnp.float32)  # (B,S,4D)

    def cell(carry, pre_t):
        c, n, hm, m = carry
        hr = hm.reshape(b_sz, h_heads, hd)
        rec = jnp.einsum("bhd,ghde->gbhe", hr.astype(params["r"].dtype),
                         params["r"]).astype(jnp.float32)
        rec = rec.reshape(4, b_sz, d)
        pre = pre_t.reshape(b_sz, 4, d).transpose(1, 0, 2) + rec + \
            params["b"].reshape(4, d)[:, None]
        z_t = jnp.tanh(pre[0])
        i_log = pre[1]
        f_log = -jax.nn.softplus(-pre[2])               # log sigmoid(f)
        o_t = jax.nn.sigmoid(pre[3])
        m_new = jnp.maximum(f_log + m, i_log)
        i_t = jnp.exp(i_log - m_new)
        f_t = jnp.exp(f_log + m - m_new)
        c_new = f_t * c + i_t * z_t
        n_new = jnp.maximum(f_t * n + i_t, 1e-6)
        h_new = o_t * (c_new / n_new)
        return (c_new, n_new, h_new.astype(x.dtype), m_new), h_new

    if state is None:
        zeros = jnp.zeros((b_sz, d), jnp.float32)
        carry = (zeros, zeros, jnp.zeros((b_sz, d), x.dtype), zeros)
    else:
        carry = (state["c"], state["n"], state["h"], state["m"])

    carry, hs = jax.lax.scan(cell, carry, jnp.moveaxis(pre_all, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)          # (B,S,D)
    new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]} \
        if state is not None else None
    return y @ params["wo"], new_state


def init_slstm_state(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    zeros = jnp.zeros((batch, d), jnp.float32)
    return {"c": zeros, "n": zeros, "h": jnp.zeros((batch, d), dtype), "m": zeros}
