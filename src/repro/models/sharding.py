"""Logical-axis sharding hints, resolved against the active mesh.

Models annotate activations with LOGICAL axes ("batch", "model", ...); the
launcher binds logical axes to mesh axes (e.g. batch -> ("pod", "data")).
Outside any binding the hints are no-ops, so the same model code runs in CPU
smoke tests and in the 512-chip dry-run unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

_BINDING: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "axis_binding", default=None)


@contextlib.contextmanager
def axis_binding(**logical_to_mesh):
    """e.g. axis_binding(batch=("pod", "data"), model=("model",))."""
    tok = _BINDING.set(logical_to_mesh)
    try:
        yield
    finally:
        _BINDING.reset(tok)


def shard_hint(x, *logical_axes):
    """with_sharding_constraint on logical axes; identity when unbound.

    ``logical_axes`` entries: logical axis name, None, or a tuple of names.
    The binding dict may carry a ``__mesh__`` entry (jax Mesh) so constraints
    resolve to NamedShardings without global mesh state.
    """
    binding = _BINDING.get()
    if binding is None or "__mesh__" not in binding:
        return x

    def resolve(a):
        if a is None:
            return None
        names = a if isinstance(a, tuple) else (a,)
        mesh_axes = []
        for n in names:
            m = binding.get(n)
            if m:
                mesh_axes.extend(m if isinstance(m, tuple) else (m,))
        if not mesh_axes:
            return None
        return tuple(mesh_axes) if len(mesh_axes) > 1 else mesh_axes[0]

    spec = P(*[resolve(a) for a in logical_axes])
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(binding["__mesh__"], spec))
