"""Block wiring: (mixer -> FFN/MoE) with pre-norm residuals, per layer kind.

A model is ``n_periods`` repetitions of a static ``pattern`` of blocks
(ModelConfig.pattern).  Uniform models have pattern=("attn",); Jamba's
period is 8 blocks (1 attn + 7 mamba, MoE on every 2nd); xLSTM alternates
mLSTM/sLSTM.  Scanning over periods keeps the lowered HLO one-period-sized.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, ffn as ffn_mod, moe as moe_mod, ssm
from repro.models.common import rms_norm
from repro.models.sharding import shard_hint


def init_block_params(key, kind: str, use_moe: bool, cfg: ModelConfig, dtype):
    ks = common.keygen(key)
    p = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    if kind == "attn":
        p["mixer"] = (attention.init_mla_params(next(ks), cfg, dtype) if cfg.mla
                      else attention.init_gqa_params(next(ks), cfg, dtype))
    elif kind == "mamba":
        p["mixer"] = ssm.init_mamba_params(next(ks), cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = ssm.init_mlstm_params(next(ks), cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = ssm.init_slstm_params(next(ks), cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.d_ff or use_moe:
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        if use_moe:
            p["ffn"] = moe_mod.init_moe_params(next(ks), cfg, dtype)
        else:
            p["ffn"] = ffn_mod.init_ffn_params(next(ks), cfg.d_model, cfg.d_ff,
                                               cfg.activation, dtype)
    return p


def apply_block(params, x, kind: str, use_moe: bool, cfg: ModelConfig, *,
                cache=None, pos=None):
    """-> (x, aux_loss, new_cache).  ``cache`` enables one-token decode."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    new_cache = None
    if kind == "attn":
        if cache is not None:
            fn = attention.mla_decode if cfg.mla else attention.gqa_decode
            out, new_cache = fn(params["mixer"], h, cache, pos, cfg)
        else:
            if cfg.mla:
                fn = attention.mla_attention
            elif cfg.attention_impl in ("chunked", "chunked_seqpar"):
                fn = attention.chunked_gqa_attention
            elif cfg.attention_impl == "flash":
                from repro.kernels.flash_attention import gqa_flash_attention
                fn = gqa_flash_attention
            else:
                fn = attention.gqa_attention
            out = fn(params["mixer"], h, cfg)
    elif kind == "mamba":
        out, new_cache = ssm.mamba_mixer(params["mixer"], h, cfg, state=cache)
    elif kind == "mlstm":
        out, new_cache = ssm.mlstm_mixer(params["mixer"], h, cfg, state=cache)
    elif kind == "slstm":
        out, new_cache = ssm.slstm_mixer(params["mixer"], h, cfg, state=cache)
    else:
        raise ValueError(kind)
    x = x + out
    x = shard_hint(x, "batch", None, "model_act")

    if "ffn" in params:
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        if use_moe:
            y, aux = moe_mod.moe_ffn(params["ffn"], h2, cfg)
        else:
            y = ffn_mod.ffn(params["ffn"], h2, cfg.activation)
        x = x + y
        x = shard_hint(x, "batch", None, "model_act")
    return x, aux, new_cache


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, capacity: int, dtype):
    if kind == "attn":
        if cfg.mla:
            return attention.init_mla_cache(cfg, batch, capacity, dtype)
        return attention.init_gqa_cache(cfg, batch, capacity, dtype)
    if kind == "mamba":
        return ssm.init_mamba_state(cfg, batch, dtype)
    if kind == "mlstm":
        return ssm.init_mlstm_state(cfg, batch, dtype)
    if kind == "slstm":
        return ssm.init_slstm_state(cfg, batch, dtype)
    raise ValueError(kind)
