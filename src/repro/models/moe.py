"""Mixture-of-Experts FFN with capacity-based token dispatch.

Design (TPU/GSPMD-friendly — everything static-shaped):
  * router: softmax top-k with optional always-on shared experts
    (DeepSeek-V3 style: 1 shared + 256 routed, top-8).
  * dispatch: scatter tokens into a per-expert capacity buffer
    (E, C, D) with position-in-expert computed by a cumulative count over
    the flattened token stream; tokens beyond capacity are DROPPED
    (their combine weight contributes nothing — standard Switch behavior).
  * experts: batched gated FFN over the leading E axis; E is sharded over
    the mesh "model" axis (expert parallelism) — the scatter/gather across
    the data->expert sharding boundary is where GSPMD emits the
    all-to-all traffic the roofline's collective term tracks.
  * load-balance auxiliary loss (Switch/DeepSeek): E * sum_e f_e * p_e.

The co-management connection (DESIGN.md §4): capacity-based expert dispatch
is the same bin-packing math as DQuLearn's qubit-capacity worker assignment —
demand (tokens) packed into capacity-bounded workers (experts), overflow
queued/dropped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import common, ffn as ffn_mod


def init_moe_params(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    ks = common.keygen(key)
    act = cfg.activation
    gated = ffn_mod.is_gated(act)

    def expert_bank(k, n):
        kk = common.keygen(k)
        p = {"w_in": _stack(next(kk), n, cfg.d_model, m.d_ff_expert, dtype),
             "w_out": _stack(next(kk), n, m.d_ff_expert, cfg.d_model, dtype)}
        if gated:
            p["w_gate"] = _stack(next(kk), n, cfg.d_model, m.d_ff_expert, dtype)
        return p

    n_bank = max(m.n_experts, m.pad_to)   # dead pad experts (never routed)
    p = {"router": common.init_dense(next(ks), cfg.d_model, m.n_experts, dtype,
                                     scale=0.02),
         "experts": expert_bank(next(ks), n_bank)}
    if m.n_shared_experts:
        p["shared"] = ffn_mod.init_ffn_params(
            next(ks), cfg.d_model, m.d_ff_expert * m.n_shared_experts, act, dtype)
    return p


def _stack(key, n, din, dout, dtype):
    return (jax.random.normal(key, (n, din, dout), jnp.float32)
            * din ** -0.5).astype(dtype)


def _expert_ffn(experts, xs, activation: str):
    """xs: (E, C, D) -> (E, C, D), batched over experts."""
    act = common.activation_fn(activation.replace("_gated", ""))
    h = jnp.einsum("ecd,edf->ecf", xs, experts["w_in"])
    if "w_gate" in experts:
        h = act(jnp.einsum("ecd,edf->ecf", xs, experts["w_gate"])) * h
    else:
        h = act(h)
    return jnp.einsum("ecf,efd->ecd", h, experts["w_out"])


def moe_ffn(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    e_bank = max(m.n_experts, m.pad_to)   # buffer/bank size incl. dead pads
    logits = (xt @ params["router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)     # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Capacity: Switch-style ceil(T*K*cf/E), lower-bounded; tokens past an
    # expert's capacity are dropped (combine weight 0).  ``dropless`` (or a
    # small token count, e.g. one-token decode) switches to capacity = T*K,
    # which can never drop — used where cached-decode must exactly match the
    # full forward pass, and by the correctness tests.
    if m.dropless or t * m.top_k <= 64:
        capacity = t * m.top_k
    else:
        capacity = max(8, -(-t * m.top_k * int(100 * m.capacity_factor)
                            // (100 * m.n_experts)))

    if m.dispatch == "per_k":
        # K scatters/gathers of (T, D) — never materializes the (T*K, D)
        # replicated-token payload (whose f32 backward gather dominated the
        # deepseek-v3 collective term).  Priority is k-major (all tokens'
        # 1st choices before any 2nd choice) vs flat's token-major; both are
        # deterministic FCFS variants.
        buf = jnp.zeros((e_bank, capacity + 1, d), x.dtype)
        counts = jnp.zeros((e_bank,), jnp.int32)
        slots, keeps = [], []
        for k in range(m.top_k):
            e_k = expert_idx[:, k]                            # (T,)
            oh = jax.nn.one_hot(e_k, e_bank, dtype=jnp.int32)
            pos = counts[e_k] + jnp.take_along_axis(
                jnp.cumsum(oh, axis=0) - oh, e_k[:, None], axis=1)[:, 0]
            counts = counts + oh.sum(0)
            keep_k = pos < capacity
            slot_k = jnp.where(keep_k, pos, capacity)
            buf = buf.at[e_k, slot_k].add(xt)                 # (T, D) payload
            slots.append(slot_k)
            keeps.append(keep_k)
        expert_out = _expert_ffn(params["experts"], buf[:, :capacity],
                                 cfg.activation)
        expert_out = jnp.pad(expert_out, ((0, 0), (0, 1), (0, 0)))
        y = jnp.zeros((t, d), x.dtype)
        for k in range(m.top_k):
            w_k = (gate_vals[:, k] * keeps[k]).astype(x.dtype)
            y = y + expert_out[expert_idx[:, k], slots[k]] * w_k[:, None]
        keep = jnp.stack(keeps, 1).reshape(-1)
        flat_e = expert_idx.reshape(-1)
    else:
        # position of each (token, k) within its expert: cumulative count
        # over the flattened (T*K,) stream — token-major FCFS priority.
        flat_e = expert_idx.reshape(-1)                       # (T*K,)
        onehot = jax.nn.one_hot(flat_e, e_bank, dtype=jnp.int32)
        pos_in_e = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                                       flat_e[:, None], axis=1)[:, 0]
        keep = pos_in_e < capacity
        slot = jnp.where(keep, pos_in_e, capacity)            # overflow slot

        # scatter tokens (with a spill row at index `capacity`)
        buf = jnp.zeros((e_bank, capacity + 1, d), x.dtype)
        tok_rep = jnp.repeat(xt, m.top_k, axis=0)             # (T*K, D)
        buf = buf.at[flat_e, slot].add(tok_rep)
        expert_out = _expert_ffn(params["experts"], buf[:, :capacity],
                                 cfg.activation)
        expert_out = jnp.pad(expert_out, ((0, 0), (0, 1), (0, 0)))

        gathered = expert_out[flat_e, slot]                   # (T*K, D)
        w = (gate_vals.reshape(-1) * keep).astype(x.dtype)
        y = (gathered * w[:, None]).reshape(t, m.top_k, d).sum(1)

    # load-balance aux loss: E * sum_e (fraction routed to e) * (mean prob e)
    f_e = jnp.zeros((e_bank,), jnp.float32).at[flat_e].add(
        keep.astype(jnp.float32))[: m.n_experts]
    f_e = f_e / jnp.maximum(f_e.sum(), 1.0)
    p_e = probs.mean(0)
    aux = m.n_experts * jnp.sum(f_e * p_e) * m.router_aux_weight

    if m.n_shared_experts:
        y = y + ffn_mod.ffn(params["shared"], xt, cfg.activation)
    return y.reshape(b, s, d), aux
