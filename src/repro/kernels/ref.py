"""Pure-jnp oracles for the Pallas kernels (no Pallas imports here).

Deliberately written independently of the kernel code paths: the oracle uses
the dense-matrix simulator from ``repro.core.sim`` (general k-qubit gate
contraction) while the kernel uses structured row-combination micro-ops, so
an agreement test covers both formulations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sim
from repro.core.sim import CircuitSpec


def vqc_state_ref(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray):
    """(C,P),(C,D) -> final state (re, im), each (C, 2**n)."""
    def one(t, d):
        return sim.run_circuit(spec, t, d)
    re, im = jax.vmap(one)(theta, data)
    return re, im


def vqc_p0_ref(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray) -> jnp.ndarray:
    """(C,P),(C,D) -> ancilla P(|0>) per circuit, (C,)."""
    re, im = vqc_state_ref(spec, theta, data)
    return sim.marginal_p0((re, im), qubit=0, n_qubits=spec.n_qubits)


def vqc_fidelity_ref(spec: CircuitSpec, theta, data) -> jnp.ndarray:
    return jnp.clip(2.0 * vqc_p0_ref(spec, theta, data) - 1.0, 0.0, 1.0)
