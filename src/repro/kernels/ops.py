"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to auto: real Mosaic lowering on TPU, interpret mode
on CPU (this container).  The wrappers are the executor used by the
co-Manager data plane and by ``shift_rule`` when kernel execution is on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sim import CircuitSpec
from repro.kernels import vqc_statevector as K


@functools.partial(jax.jit, static_argnums=(0, 3))
def vqc_p0(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray,
           tb: int = 4 * K.LANES) -> jnp.ndarray:
    return K.vqc_p0(spec, theta, data, tb=tb)


@functools.partial(jax.jit, static_argnums=(0,))
def vqc_fidelity(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray) -> jnp.ndarray:
    """Fused SWAP-test fidelity for a circuit bank: (C,P),(C,D) -> (C,)."""
    return jnp.clip(2.0 * K.vqc_p0(spec, theta, data) - 1.0, 0.0, 1.0)


@functools.partial(jax.jit, static_argnums=(0,))
def vqc_state(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray):
    return K.vqc_state(spec, theta, data)


def kernel_executor(spec: CircuitSpec):
    """shift_rule.Executor backed by the fused Pallas kernel."""
    return lambda theta_bank, data_bank: vqc_fidelity(spec, theta_bank, data_bank)


# ------------------------------------------------- shift-structured banks
@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def vqc_fidelity_shiftgroups(spec: CircuitSpec, theta: jnp.ndarray,
                             data: jnp.ndarray, four_term: bool = False,
                             groups: tuple[int, ...] | None = None) -> jnp.ndarray:
    """Shift-bank fidelities for the requested groups, (G, B).

    ``theta (B, P)`` / ``data (B, D)`` are the IMPLICIT bank — base angles
    only.  Uses the prefix-reuse kernel when the circuit matches the
    SWAP-test product structure; otherwise materializes just the requested
    groups and runs the standard fused kernel (same results, more work).
    """
    from repro.core import shift_rule
    if K.build_shift_plan(spec) is not None:
        return jnp.clip(
            K.vqc_shift_fidelity(spec, theta, data, four_term=four_term,
                                 groups=groups), 0.0, 1.0)
    descs = shift_rule.group_descriptors(theta.shape[1], four_term)
    if groups is None:
        groups = tuple(range(len(descs)))
    blocks = []
    for g in groups:
        j, s = descs[g]
        blocks.append(theta if j < 0 else theta.at[:, j].add(s))
    b = theta.shape[0]
    theta_bank = jnp.concatenate(blocks, 0)
    data_bank = jnp.tile(data, (len(groups), 1))
    return vqc_fidelity(spec, theta_bank, data_bank).reshape(len(groups), b)


@functools.partial(jax.jit, static_argnums=(0, 3))
def vqc_fidelity_shiftbank(spec: CircuitSpec, theta: jnp.ndarray,
                           data: jnp.ndarray, four_term: bool = False) -> jnp.ndarray:
    """Whole implicit bank -> flat (C,) fidelities in materialized-bank order."""
    return vqc_fidelity_shiftgroups(spec, theta, data, four_term).reshape(-1)


def shiftbank_executor(spec: CircuitSpec):
    """A ``shift_rule.Executor`` that consumes implicit ``ShiftBank``s
    directly (``accepts_shiftbank``) via the prefix-reuse kernel.  Also
    accepts plain ``(theta_bank, data_bank)`` calls — materialized banks run
    through the standard fused kernel, so the executor composes with every
    bank mode."""
    def run(bank, data_bank=None):
        if data_bank is not None:
            return vqc_fidelity(spec, bank, data_bank)
        return vqc_fidelity_shiftbank(spec, bank.theta, bank.data,
                                      bank.four_term)
    run.accepts_shiftbank = True
    return run
