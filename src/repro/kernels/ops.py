"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to auto: real Mosaic lowering on TPU, interpret mode
on CPU (this container).  The wrappers are the executor used by the
co-Manager data plane and by ``shift_rule`` when kernel execution is on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sim import CircuitSpec
from repro.kernels import vqc_statevector as K


@functools.partial(jax.jit, static_argnums=(0, 3))
def vqc_p0(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray,
           tb: int = 4 * K.LANES) -> jnp.ndarray:
    return K.vqc_p0(spec, theta, data, tb=tb)


@functools.partial(jax.jit, static_argnums=(0,))
def vqc_fidelity(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray) -> jnp.ndarray:
    """Fused SWAP-test fidelity for a circuit bank: (C,P),(C,D) -> (C,)."""
    return jnp.clip(2.0 * K.vqc_p0(spec, theta, data) - 1.0, 0.0, 1.0)


@functools.partial(jax.jit, static_argnums=(0,))
def vqc_state(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray):
    return K.vqc_state(spec, theta, data)


def kernel_executor(spec: CircuitSpec):
    """shift_rule.Executor backed by the fused Pallas kernel."""
    return lambda theta_bank, data_bank: vqc_fidelity(spec, theta_bank, data_bank)
