"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to auto: real Mosaic lowering on TPU, interpret mode
on CPU (this container).  The wrappers are the executor used by the
co-Manager data plane and by ``shift_rule`` when kernel execution is on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api.capabilities import declare
from repro.core.sim import CircuitSpec
from repro.kernels import vqc_statevector as K


@functools.partial(jax.jit, static_argnums=(0, 3))
def vqc_p0(
    spec: CircuitSpec,
    theta: jnp.ndarray,
    data: jnp.ndarray,
    tb: int = 4 * K.LANES,
) -> jnp.ndarray:
    return K.vqc_p0(spec, theta, data, tb=tb)


@functools.partial(jax.jit, static_argnums=(0,))
def vqc_fidelity(
    spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray
) -> jnp.ndarray:
    """Fused SWAP-test fidelity for a circuit bank: (C,P),(C,D) -> (C,)."""
    return jnp.clip(2.0 * K.vqc_p0(spec, theta, data) - 1.0, 0.0, 1.0)


@functools.partial(jax.jit, static_argnums=(0,))
def vqc_state(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray):
    return K.vqc_state(spec, theta, data)


def kernel_executor(spec: CircuitSpec):
    """shift_rule.Executor backed by the fused Pallas kernel."""
    return lambda theta_bank, data_bank: vqc_fidelity(spec, theta_bank, data_bank)


# ----------------------------------------------- kernel profiling observer
#: module-level launch observer: when set, every shift-plan launch entering
#: through the public wrappers reports its static ``shift_execution_info``
#: (mode fused/spill/materialize, launches, tiles, VMEM footprint) plus the
#: lane/bank shape.  The hook lives OUTSIDE the jit boundary — the public
#: shift wrappers below are plain Python around inner jit'd functions — so
#: it fires once per launch, not once per trace.  None (default) costs one
#: global read per launch.
_launch_observer = None


def set_launch_observer(fn):
    """Install ``fn(info: dict)`` as the shift-launch observer (None
    disables).  Returns the previous observer so callers can restore it."""
    global _launch_observer
    prev = _launch_observer
    _launch_observer = fn
    return prev


def _notify_launch(spec, n_lanes, four_term, groups, banks=1):
    obs = _launch_observer
    if obs is None:
        return
    info = dict(
        K.shift_execution_info(spec, n_lanes, four_term=four_term, groups=groups)
    )
    info["lanes"] = n_lanes
    info["banks"] = banks
    obs(info)
    if info["mode"] != "spill":
        return
    # spill path: one event per depth-tile launch segment (the summary event
    # above covers the forward launch), so traces show the double-buffered
    # backward sweep — tile order, which ping-pong boundary buffer each tile
    # fetches into, and whether that fetch overlapped the previous tile's
    # compute.  Total events = info["launches"].
    n_tiles = info["n_tiles"]
    for order in range(n_tiles):
        obs(
            {
                "mode": "spill_tile",
                "tile": n_tiles - 1 - order,  # tiles run deepest-first
                "tile_order": order,
                "buffer": order % 2,
                "boundary_bytes": info["spill_buffer_bytes"],
                "overlapped": order > 0,
                "lanes": n_lanes,
                "banks": banks,
            }
        )


# ------------------------------------------------- shift-structured banks
@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def _shiftgroups_jit(
    spec: CircuitSpec,
    theta: jnp.ndarray,
    data: jnp.ndarray,
    four_term: bool = False,
    groups: tuple[int, ...] | None = None,
) -> jnp.ndarray:
    from repro.core import shift_rule

    if K.use_shift_plan(spec, four_term, groups):
        return jnp.clip(
            K.vqc_shift_fidelity(spec, theta, data, four_term=four_term, groups=groups),
            0.0,
            1.0,
        )
    descs = shift_rule.group_descriptors(theta.shape[1], four_term)
    if groups is None:
        groups = tuple(range(len(descs)))
    blocks = []
    for g in groups:
        j, s = descs[g]
        blocks.append(theta if j < 0 else theta.at[:, j].add(s))
    b = theta.shape[0]
    theta_bank = jnp.concatenate(blocks, 0)
    data_bank = jnp.tile(data, (len(groups), 1))
    return vqc_fidelity(spec, theta_bank, data_bank).reshape(len(groups), b)


def vqc_fidelity_shiftgroups(
    spec: CircuitSpec,
    theta: jnp.ndarray,
    data: jnp.ndarray,
    four_term: bool = False,
    groups: tuple[int, ...] | None = None,
) -> jnp.ndarray:
    """Shift-bank fidelities for the requested groups, (G, B).

    ``theta (B, P)`` / ``data (B, D)`` are the IMPLICIT bank — base angles
    only.  Uses the prefix-reuse kernel when the circuit matches the
    SWAP-test product structure AND the analytic suffix-replay cost beats
    materializing the requested groups (``K.shift_cost_info`` — multi-use
    parameters replay their dependent span per variant, so deep reuse with
    a small group request can flip the decision); spills prefix checkpoints
    to HBM in depth tiles when the register is too wide for VMEM.
    Otherwise materializes just the requested groups and runs the standard
    fused kernel (same results, more work).
    """
    _notify_launch(spec, theta.shape[0], four_term, groups)
    return _shiftgroups_jit(spec, theta, data, four_term, groups)


def vqc_fidelity_shiftbank(
    spec: CircuitSpec,
    theta: jnp.ndarray,
    data: jnp.ndarray,
    four_term: bool = False,
) -> jnp.ndarray:
    """Whole implicit bank -> flat (C,) fidelities in materialized-bank order."""
    return vqc_fidelity_shiftgroups(spec, theta, data, four_term).reshape(-1)


def _pack_banks(thetas, datas):
    """Pad each bank's samples to a LANES multiple and concatenate along the
    lane axis.  Returns (theta_cat, data_cat, segments) with ``segments[k] =
    (lane_offset, n_samples_k)`` — static Python ints, so downstream slicing
    stays trace-free."""
    t_parts, d_parts, segments = [], [], []
    off = 0
    for t, d in zip(thetas, datas):
        b = t.shape[0]
        pad = (-b) % K.LANES
        t_parts.append(jnp.pad(t.astype(jnp.float32), ((0, pad), (0, 0))))
        d_parts.append(jnp.pad(d.astype(jnp.float32), ((0, pad), (0, 0))))
        segments.append((off, b))
        off += b + pad
    return (
        jnp.concatenate(t_parts, 0),
        jnp.concatenate(d_parts, 0),
        tuple(segments),
    )


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def _shiftgroups_multibank_jit(
    spec: CircuitSpec, thetas, datas, four_term: bool, group_sets: tuple
) -> tuple:
    union = tuple(sorted({g for gs in group_sets for g in gs}))
    if not K.use_shift_plan(spec, four_term, union):
        return tuple(
            _shiftgroups_jit(spec, t, d, four_term, gs)
            for t, d, gs in zip(thetas, datas, group_sets)
        )
    theta_cat, data_cat, segments = _pack_banks(thetas, datas)
    out = jnp.clip(
        K.vqc_shift_fidelity(
            spec, theta_cat, data_cat, four_term=four_term, groups=union
        ),
        0.0,
        1.0,
    )
    row = {g: i for i, g in enumerate(union)}
    return tuple(
        jnp.stack([out[row[g], off : off + b] for g in gs], axis=0)
        for (off, b), gs in zip(segments, group_sets)
    )


def vqc_fidelity_shiftgroups_multibank(
    spec: CircuitSpec, thetas, datas, four_term: bool, group_sets: tuple
) -> tuple:
    """FUSED multi-bank shift execution: K same-spec implicit banks in ONE
    prefix-reuse kernel launch.

    ``thetas`` / ``datas``: tuples of K per-bank base-angle arrays
    ((B_k, P), (B_k, D)); ``group_sets[k]``: the (param, shift) groups
    requested for bank k.  Each bank occupies its own LANES-padded lane
    segment of the launch; base angles are per-lane, so different banks
    (different thetas, even different sample counts) share the one
    data-register pass, checkpointed forward pass, and reversed-suffix
    backward pass — K x (1+2P) per-bank launches collapse to the union
    group set in ONE launch.  Returns a tuple of (len(group_sets[k]), B_k)
    fidelity blocks, each bit-identical per lane to the per-bank path.

    Circuits without the verified product structure — or whose suffix-replay
    cost for the union group set exceeds materializing it — fall back to
    per-bank materialized execution (correct, not fused).
    """
    if _launch_observer is not None:
        union = tuple(sorted({g for gs in group_sets for g in gs}))
        lanes = sum(t.shape[0] + (-t.shape[0]) % K.LANES for t in thetas)
        _notify_launch(spec, lanes, four_term, union, banks=len(thetas))
    return _shiftgroups_multibank_jit(spec, thetas, datas, four_term, group_sets)


def multibank_executor(spec: CircuitSpec):
    """A bank-set executor (declared ``multibank`` capability): runs a
    sequence of same-spec ``ShiftBank``s as one fused multi-bank launch and
    returns the per-bank flat fidelity vectors in bank order."""

    def run(banks):
        four = {b.four_term for b in banks}
        if len(four) > 1:
            raise ValueError("banks in one fused set must share four_term")
        outs = vqc_fidelity_shiftgroups_multibank(
            spec,
            tuple(b.theta for b in banks),
            tuple(b.data for b in banks),
            four.pop(),
            tuple(tuple(range(b.n_groups)) for b in banks),
        )
        return [o.reshape(-1) for o in outs]

    return declare(run, multibank=True)


def shiftbank_executor(spec: CircuitSpec):
    """A ``shift_rule.Executor`` that consumes implicit ``ShiftBank``s
    directly (declared ``shiftbank`` capability) via the prefix-reuse
    kernel.  Also accepts plain ``(theta_bank, data_bank)`` calls —
    materialized banks run through the standard fused kernel, so the
    executor composes with every bank mode."""

    def run(bank, data_bank=None):
        if data_bank is not None:
            return vqc_fidelity(spec, bank, data_bank)
        return vqc_fidelity_shiftbank(spec, bank.theta, bank.data, bank.four_term)

    return declare(run, shiftbank=True)
