"""Pallas TPU flash-attention kernel (forward) — §Perf hillclimb iteration
for the memory-bound prefill shapes.

Grid: (batch*heads, n_q_blocks, n_kv_blocks), kv innermost.  Each (head,
q-block) accumulates an online softmax across kv-blocks in VMEM scratch:

    m  (Qb,)      running row max
    l  (Qb,)      running normalizer
    acc(Qb, hd)   running weighted-value accumulator (f32)

HBM traffic per head: read Q once, K/V once per q-block pass, write O once —
no (S, S) score tensor ever leaves VMEM.  With Qb=Kb=512, hd<=128 the live
set is ~2.5 MB of VMEM per core, MXU-aligned (512x128 tiles).

Causality is enforced by masking inside the block; fully-future kv blocks
are masked to -inf and contribute nothing (compute skip is left to a
fancier index-map — the target term here is HBM bytes, not FLOPs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    block_q: int,
    block_k: int,
    causal: bool,
    window: int,
    n_kv_blocks: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (Qb, hd)
    k = k_ref[0].astype(jnp.float32)                     # (Kb, hd)
    v = v_ref[0].astype(jnp.float32)                     # (Kb, hd)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Qb, Kb)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window:
        ok = ok & (k_pos > q_pos - window)
    scores = jnp.where(ok, scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=1))      # (Qb,)
    p = jnp.exp(scores - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
):
    """q, k, v: (BH, S, hd) — batch*heads flattened, scale pre-applied.
    Returns (BH, S, hd).  GQA callers expand K/V across groups (or flatten
    (kv_head, group) into BH with repeated K/V refs)."""
    bh, s, hd = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q = s // block_q
    n_k = s // block_k
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        window=window,
        n_kv_blocks=n_k,
    )

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m
            pltpu.VMEM((block_q,), jnp.float32),       # l
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)


def gqa_flash_attention(params, x, cfg, *, positions=None):
    """Drop-in replacement for models.attention.gqa_attention using the
    Pallas kernel (attention_impl == "flash")."""
    from repro.models import attention as A
    from repro.models.common import apply_rope

    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.kv_heads
    g = h // kv
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    q, k = A._qk_normalize(q, k, params, cfg, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    # flatten (b, kv, g) -> BH; K/V repeat over groups
    qf = (q.reshape(b, s, kv, g, hd) * hd**-0.5).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(b * kv * g, s, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3)[:, :, None], g, 2).reshape(
        b * kv * g, s, hd
    )
    vf = jnp.repeat(v.transpose(0, 2, 1, 3)[:, :, None], g, 2).reshape(
        b * kv * g, s, hd
    )

    o = flash_attention(qf, kf, vf, causal=True, window=cfg.sliding_window)
    o = o.reshape(b, kv, g, s, hd).transpose(0, 3, 1, 2, 4).reshape(b, s, h * hd)
    return o @ params["wo"]


def flash_hbm_bytes(
    b, s, h, kv, hd, dtype_bytes: int = 2, block_q: int = 512
) -> int:
    """Analytic per-layer HBM traffic of the kernel: Q read once, K/V read
    once per q-block pass (grid revisits them), O written once."""
    n_q = s // block_q
    q_o = 2 * b * h * s * hd * dtype_bytes
    kv_reads = 2 * b * h * s * hd * dtype_bytes * n_q
    return q_o + kv_reads
