"""Fused VQC statevector Pallas kernel — the DQuLearn compute hot-spot.

The paper's data plane executes millions of *small* circuits (5–7 qubits,
10–20 gates): the circuit bank of parameter-shifted subtasks.  On a GPU/RPC
system each circuit is one round-trip; a mechanical port would launch one
XLA op per gate per circuit.  The TPU-native adaptation is to FUSE the whole
circuit — encoding rotations, variational layers, SWAP test, ancilla readout
— into ONE kernel over a VMEM-resident batch of statevectors:

  * layout: statevectors live as (2**n, TB) tiles — basis index on the
    sublane axis, circuit batch on the 128-wide lane axis.  Gate application
    is a 2x2 (or structured 4x4/8x8) linear combination of ROWS, vectorized
    across lanes; per-circuit angles become per-lane cos/sin vectors.
  * complex numbers are (re, im) float32 pairs (TPU has no complex MXU path).
  * the gate sequence is static Python (unrolled at trace time); angles are
    read from VMEM blocks of the banked parameters.
  * HBM traffic: read (P + D) * TB angle floats, write TB results.  The
    statevector NEVER touches HBM — it is created, evolved and measured in
    VMEM/VREGs.  Per-gate dispatch would move 2 * 4 * 2**n * TB bytes per
    gate; fusion removes all of it (see benchmarks/kernel_bench.py).

VMEM budget: state tile is 2 * 4 * 2**n * TB bytes — for the paper's 7-qubit
circuits and TB=512 that is 512 KB, far under the ~16 MB/core VMEM of a
TPU v5e.  Qubit counts up to ~12 fit comfortably (2 * 4 * 4096 * 128 = 4 MB
at TB=128); beyond that, shrink TB.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sim import CircuitSpec

LANES = 128  # TPU lane width; batch tiles are multiples of this.


# ----------------------------------------------------------- gate micro-ops
# Each helper operates on (re, im) arrays of shape (2**n, TB) and per-lane
# angle vectors of shape (TB,).  Qubit q is the q-th MOST significant bit of
# the basis (row) index, matching repro.core.sim.

def _split1(x: jnp.ndarray, q: int, n: int):
    """-> (x0, x1) halves along qubit q's bit; each (2**q, 2**(n-q-1), TB)."""
    tb = x.shape[-1]
    t = x.reshape(2 ** q, 2, 2 ** (n - q - 1), tb)
    return t[:, 0], t[:, 1]


def _merge1(x0, x1, q: int, n: int, tb: int):
    t = jnp.stack([x0, x1], axis=1)
    return t.reshape(2 ** n, tb)


def _rot1(re, im, q, n, c, s, kind):
    """Apply RX/RY/RZ with per-lane cos/sin (c, s) on qubit q."""
    tb = re.shape[-1]
    r0, r1 = _split1(re, q, n)
    i0, i1 = _split1(im, q, n)
    if kind == "ry":                      # [[c,-s],[s,c]] real
        nr0, ni0 = c * r0 - s * r1, c * i0 - s * i1
        nr1, ni1 = s * r0 + c * r1, s * i0 + c * i1
    elif kind == "rx":                    # [[c,-is],[-is,c]]
        nr0, ni0 = c * r0 + s * i1, c * i0 - s * r1
        nr1, ni1 = c * r1 + s * i0, c * i1 - s * r0
    elif kind == "rz":                    # diag(e^{-it/2}, e^{it/2})
        nr0, ni0 = c * r0 + s * i0, c * i0 - s * r0
        nr1, ni1 = c * r1 - s * i1, c * i1 + s * r1
    else:
        raise ValueError(kind)
    return (_merge1(nr0, nr1, q, n, tb), _merge1(ni0, ni1, q, n, tb))


def _split2(x, qa, qb, n):
    """-> 2x2 blocks b[ba][bb] over qubits qa < qb; each block
    (2**qa, 2**(qb-qa-1), 2**(n-qb-1), TB)."""
    tb = x.shape[-1]
    t = x.reshape(2 ** qa, 2, 2 ** (qb - qa - 1), 2, 2 ** (n - qb - 1), tb)
    return ((t[:, 0, :, 0], t[:, 0, :, 1]), (t[:, 1, :, 0], t[:, 1, :, 1]))


def _merge2(b, qa, qb, n, tb):
    t = jnp.stack([jnp.stack([b[0][0], b[0][1]], axis=2),
                   jnp.stack([b[1][0], b[1][1]], axis=2)], axis=1)
    return t.reshape(2 ** n, tb)


def _rot2(re, im, qa, qb, n, c, s, kind):
    """RYY / RZZ / CRY / CRZ with per-lane (c, s); qa < qb required."""
    tb = re.shape[-1]
    R = _split2(re, qa, qb, n)
    I = _split2(im, qa, qb, n)
    r00, r01, r10, r11 = R[0][0], R[0][1], R[1][0], R[1][1]
    i00, i01, i10, i11 = I[0][0], I[0][1], I[1][0], I[1][1]
    if kind == "rzz":    # diag phases: e^{-it/2} on |00>,|11>; e^{+it/2} on |01>,|10>
        nr00, ni00 = c * r00 + s * i00, c * i00 - s * r00
        nr11, ni11 = c * r11 + s * i11, c * i11 - s * r11
        nr01, ni01 = c * r01 - s * i01, c * i01 + s * r01
        nr10, ni10 = c * r10 - s * i10, c * i10 + s * r10
    elif kind == "ryy":  # couples (00,11) with +i s, (01,10) with -i s
        nr00, ni00 = c * r00 - s * i11, c * i00 + s * r11
        nr11, ni11 = c * r11 - s * i00, c * i11 + s * r00
        nr01, ni01 = c * r01 + s * i10, c * i01 - s * r10
        nr10, ni10 = c * r10 + s * i01, c * i10 - s * r01
    elif kind == "cry":  # RY on qb within qa=1 block
        nr00, ni00, nr01, ni01 = r00, i00, r01, i01
        nr10, ni10 = c * r10 - s * r11, c * i10 - s * i11
        nr11, ni11 = s * r10 + c * r11, s * i10 + c * i11
    elif kind == "crz":  # RZ on qb within qa=1 block
        nr00, ni00, nr01, ni01 = r00, i00, r01, i01
        nr10, ni10 = c * r10 + s * i10, c * i10 - s * r10
        nr11, ni11 = c * r11 - s * i11, c * i11 + s * r11
    else:
        raise ValueError(kind)
    return (_merge2(((nr00, nr01), (nr10, nr11)), qa, qb, n, tb),
            _merge2(((ni00, ni01), (ni10, ni11)), qa, qb, n, tb))


def _h(re, im, q, n):
    tb = re.shape[-1]
    inv = 0.7071067811865476
    r0, r1 = _split1(re, q, n)
    i0, i1 = _split1(im, q, n)
    return (_merge1((r0 + r1) * inv, (r0 - r1) * inv, q, n, tb),
            _merge1((i0 + i1) * inv, (i0 - i1) * inv, q, n, tb))


def _split3(x, qa, qb, qc_, n):
    tb = x.shape[-1]
    t = x.reshape(2 ** qa, 2, 2 ** (qb - qa - 1), 2, 2 ** (qc_ - qb - 1), 2,
                  2 ** (n - qc_ - 1), tb)
    return t


def _cswap(re, im, qa, qb, qc_, n):
    """Fredkin: control qa, swap qb<->qc_ (qa < qb < qc_)."""
    tb = re.shape[-1]
    outs = []
    for x in (re, im):
        t = _split3(x, qa, qb, qc_, n)
        # within control=1 block, swap the (qb, qc_) bit pair (0,1)<->(1,0)
        a01 = t[:, 1, :, 0, :, 1]
        a10 = t[:, 1, :, 1, :, 0]
        t = t.at[:, 1, :, 0, :, 1].set(a10).at[:, 1, :, 1, :, 0].set(a01)
        outs.append(t.reshape(2 ** n, tb))
    return outs[0], outs[1]


def _op_angle(op, theta_blk, data_blk, delta: float = 0.0):
    """Per-lane angle vector for a parameterized op (+ static shift delta)."""
    kind, j = op.param
    if kind == "theta":
        ang = theta_blk[j]
    elif kind == "data":
        ang = data_blk[j]
    elif kind == "const":
        ang = jnp.asarray(j, jnp.float32)
    else:
        raise ValueError(op.param)
    return ang + delta if delta else ang


def _apply_one(op, re, im, n, theta_blk, data_blk, delta: float = 0.0,
               invert: bool = False):
    """Apply one gate (optionally angle-shifted by ``delta`` or inverted)."""
    if op.gate == "h":
        return _h(re, im, op.qubits[0], n)       # self-inverse
    if op.gate == "cswap":
        qa, qb, qc_ = op.qubits
        return _cswap(re, im, qa, qb, qc_, n)    # self-inverse
    ang = _op_angle(op, theta_blk, data_blk, delta)
    if invert:                                   # rotation: g(t)^dagger = g(-t)
        ang = -ang
    c, s = jnp.cos(ang / 2), jnp.sin(ang / 2)
    if op.gate in ("rx", "ry", "rz"):
        return _rot1(re, im, op.qubits[0], n, c, s, op.gate)
    if op.gate in ("ryy", "rzz", "cry", "crz"):
        qa, qb = op.qubits
        if qa > qb:
            if op.gate in ("ryy", "rzz"):        # symmetric under qubit swap
                qa, qb = qb, qa
            else:
                raise NotImplementedError(
                    f"{op.gate} requires ascending (control, target) qubits")
        return _rot2(re, im, qa, qb, n, c, s, op.gate)
    raise NotImplementedError(op.gate)


def _apply_ops(spec: CircuitSpec, re, im, theta_blk, data_blk):
    """Unrolled gate sequence on a (dim, TB) tile. theta_blk: (P, TB)."""
    n = spec.n_qubits
    for op in spec.ops:
        re, im = _apply_one(op, re, im, n, theta_blk, data_blk)
    return re, im


# ------------------------------------------------------------------ kernels
def _fidelity_kernel(spec: CircuitSpec, theta_ref, data_ref, p0_ref):
    tb = theta_ref.shape[-1]
    dim = 2 ** spec.n_qubits
    # |0...0> batch, built in VREGs — never read from HBM.
    row = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0)
    re = jnp.where(row == 0, 1.0, 0.0).astype(jnp.float32)
    im = jnp.zeros((dim, tb), jnp.float32)
    re, im = _apply_ops(spec, re, im, theta_ref[...], data_ref[...])
    prob = re * re + im * im
    half = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0) < (dim // 2)
    p0 = jnp.where(half, prob, 0.0).sum(axis=0, keepdims=True)  # ancilla = MSB
    p0_ref[...] = p0


def _state_kernel(spec: CircuitSpec, theta_ref, data_ref, re_ref, im_ref):
    tb = theta_ref.shape[-1]
    dim = 2 ** spec.n_qubits
    row = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0)
    re = jnp.where(row == 0, 1.0, 0.0).astype(jnp.float32)
    im = jnp.zeros((dim, tb), jnp.float32)
    re, im = _apply_ops(spec, re, im, theta_ref[...], data_ref[...])
    re_ref[...] = re
    im_ref[...] = im


def _grid_call(spec: CircuitSpec, theta_t, data_t, tb: int, interpret: bool,
               want_state: bool):
    """theta_t: (P, C), data_t: (D, C) with C % tb == 0."""
    p, c = theta_t.shape
    d = data_t.shape[0]
    dim = 2 ** spec.n_qubits
    grid = (c // tb,)
    in_specs = [
        pl.BlockSpec((p, tb), lambda i: (0, i)),
        pl.BlockSpec((d, tb), lambda i: (0, i)),
    ]
    if want_state:
        out_shape = [jax.ShapeDtypeStruct((dim, c), jnp.float32)] * 2
        out_specs = [pl.BlockSpec((dim, tb), lambda i: (0, i))] * 2
        kern = functools.partial(_state_kernel, spec)
    else:
        out_shape = jax.ShapeDtypeStruct((1, c), jnp.float32)
        out_specs = pl.BlockSpec((1, tb), lambda i: (0, i))
        kern = functools.partial(_fidelity_kernel, spec)
    return pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(theta_t, data_t)


def vqc_p0(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray,
           tb: int = 4 * LANES, interpret: bool | None = None) -> jnp.ndarray:
    """Batched ancilla-P0 for a circuit bank. theta: (C,P), data: (C,D) -> (C,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c = theta.shape[0]
    tb = min(tb, max(LANES, 1 << (c - 1).bit_length()))
    pad = (-c) % tb
    theta_t = jnp.pad(theta, ((0, pad), (0, 0))).T
    data_t = jnp.pad(data, ((0, pad), (0, 0))).T
    p0 = _grid_call(spec, theta_t, data_t, tb, interpret, want_state=False)
    return p0[0, :c]


def vqc_state(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray,
              tb: int = LANES, interpret: bool | None = None):
    """Batched final statevector (re, im), each (C, 2**n) — for kernel tests."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c = theta.shape[0]
    tb = min(tb, max(LANES, 1 << (c - 1).bit_length()))
    pad = (-c) % tb
    theta_t = jnp.pad(theta, ((0, pad), (0, 0))).T
    data_t = jnp.pad(data, ((0, pad), (0, 0))).T
    re, im = _grid_call(spec, theta_t, data_t, tb, interpret, want_state=True)
    return re[:, :c].T, im[:, :c].T


# ----------------------------------------------- shift-structured execution
#
# The parameter-shift circuit bank is pathologically redundant: its
# (1 + 2P) * B rows differ from the B base rows by exactly ONE angle each.
# ``vqc_p0`` on a materialized bank re-simulates every gate of every row —
# (1+2P) * G gate applications and (P+D) * (1+2P) angle floats per sample.
#
# The QuClassi circuit family has structure the kernel can verify statically
# and exploit to do far better than generic suffix replay:
#
#   * all ops before the SWAP-test tail act on two DISJOINT registers —
#     encoding on the data register (no trainable angles), the variational
#     stack on the trainable register (all trainable angles);
#   * the SWAP-test tail [H(a), CSWAP(a, d_i, t_i)..., H(a)] measures
#     P0 = (1 + |<psi_d|psi_t>|^2) / 2 exactly, so fidelity = 2*P0 - 1
#     = |<psi_d|psi_t>|^2 — an inner product of the two register states.
#
# The shift kernel therefore evolves the two 2**m-dim register states
# (m = register width) instead of the 2**(2m+1)-dim full state:
#
#   1. data register: ONE pass (theta-independent, shared by every variant);
#   2. trainable register FORWARD pass with base angles, checkpointing the
#      prefix state psi_j just before each parameter's (single) dependent
#      gate in VMEM — 2*4*2**m*TB bytes per checkpointed prefix;
#   3. trainable register BACKWARD pass holding the reversed-suffix state
#      chi_j = (U_suffix_j)^dagger psi_d; a rotation gate's shifted variant
#      G_j(theta_j + s) then satisfies
#         F(j, s) = |<psi_d| U_suf G_j(theta_j+s) |psi_j>|^2
#                 = |<chi_j| G_j(theta_j+s) |psi_j>|^2,
#      i.e. each of the 2P (or 4P) variants costs ONE gate application plus
#      one 2**m-dim inner product instead of a full-circuit simulation.
#
# Per sample-tile the kernel reads (P + D) * TB angle floats (vs
# (P+D) * (1+2P) * TB materialized) and applies D_g + 2*T_g + n_variants
# register-local gates (vs (1+2P) * G full-state gates) — the ratios
# ``shift_bank_stats`` reports and benchmarks/kernel_bench.py tracks.
#
# Circuits that don't match the verified structure (interleaved registers,
# multi-use parameters, theta on the data register, non-SWAP-test tail)
# return ``None`` from ``build_shift_plan`` and fall back to the
# materialized-bank path in ``kernels.ops``.

ROT_GATES = ("rx", "ry", "rz", "ryy", "rzz", "cry", "crz")


@dataclasses.dataclass(frozen=True)
class ShiftPlan:
    """Static execution plan for the prefix-reuse shift kernel.

    ``data_ops`` / ``train_ops`` are the body ops remapped to register-local
    qubit indices (register width ``m``); ``theta_pos[j]`` is the index into
    ``train_ops`` of parameter j's unique dependent gate, or -1 when the
    parameter drives no gate (its shifted fidelity is the base fidelity).
    """
    m: int
    data_ops: tuple
    train_ops: tuple
    theta_pos: tuple[int, ...]


def _remap_op(op, mapping):
    return dataclasses.replace(op, qubits=tuple(mapping[q] for q in op.qubits))


@functools.lru_cache(maxsize=None)
def build_shift_plan(spec: CircuitSpec) -> ShiftPlan | None:
    """Verify the SWAP-test product structure; None -> caller must fall back."""
    ops = spec.ops
    # --- tail: H(anc), m CSWAP(anc, d_i, t_i), H(anc)
    if len(ops) < 3 or ops[-1].gate != "h":
        return None
    anc = ops[-1].qubits[0]
    k = len(ops) - 2
    pairs = []
    while k >= 0 and ops[k].gate == "cswap":
        a, d, t = ops[k].qubits
        if a != anc:
            return None
        pairs.append((d, t))
        k -= 1
    if k < 0 or ops[k].gate != "h" or ops[k].qubits != (anc,) or not pairs:
        return None
    pairs.reverse()
    data_q = [d for d, _ in pairs]
    train_q = [t for _, t in pairs]
    m = len(pairs)
    regs = set(data_q) | set(train_q) | {anc}
    if len(regs) != 2 * m + 1 or regs != set(range(spec.n_qubits)):
        return None
    data_map = {q: i for i, q in enumerate(data_q)}
    train_map = {q: i for i, q in enumerate(train_q)}

    # --- body: every op entirely inside one register; theta only on train
    data_ops, train_ops = [], []
    theta_pos: dict[int, int] = {}
    for op in ops[:k]:
        qs = set(op.qubits)
        is_theta = op.param is not None and op.param[0] == "theta"
        if qs <= set(data_q):
            if is_theta or op.gate == "cswap":
                return None
            data_ops.append(_remap_op(op, data_map))
        elif qs <= set(train_q):
            if op.gate == "cswap":
                return None
            if is_theta:
                j = op.param[1]
                if j in theta_pos or op.gate not in ROT_GATES:
                    return None       # multi-use params need full suffix replay
                theta_pos[j] = len(train_ops)
            train_ops.append(_remap_op(op, train_map))
        else:
            return None               # op straddles registers / touches ancilla
    # descending cry/crz would raise inside the kernel; reject here instead
    for op in data_ops + train_ops:
        if op.gate in ("cry", "crz") and op.qubits[0] > op.qubits[1]:
            return None
    pos = tuple(theta_pos.get(j, -1) for j in range(spec.n_theta))
    return ShiftPlan(m=m, data_ops=tuple(data_ops), train_ops=tuple(train_ops),
                     theta_pos=pos)


def _zero_tile(dim: int, tb: int):
    row = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0)
    re = jnp.where(row == 0, 1.0, 0.0).astype(jnp.float32)
    im = jnp.zeros((dim, tb), jnp.float32)
    return re, im


def _inner_fidelity(chi, phi):
    """|<chi|phi>|^2 per lane; chi/phi are (re, im) pairs of (dim, TB)."""
    cre, cim = chi
    pre, pim = phi
    ip_re = (cre * pre + cim * pim).sum(axis=0)
    ip_im = (cre * pim - cim * pre).sum(axis=0)
    return ip_re * ip_re + ip_im * ip_im


def _shiftbank_kernel(plan: ShiftPlan, shifts, groups, n_params: int,
                      theta_ref, data_ref, out_ref):
    """Compute the requested shift groups for one sample tile.

    Output rows follow ``groups``: group 0 is the base fidelity, group
    1 + s*P + j is shift s of param j (bank order).
    """
    tb = theta_ref.shape[-1]
    dim = 2 ** plan.m
    theta_blk = theta_ref[...]
    data_blk = data_ref[...]

    # 1. data register: one theta-independent pass, shared by every variant.
    d_re, d_im = _zero_tile(dim, tb)
    for op in plan.data_ops:
        d_re, d_im = _apply_one(op, d_re, d_im, plan.m, theta_blk, data_blk)

    wanted = set(groups)
    variants = {}                       # op position -> [(group, param, shift)]
    for s_idx, s in enumerate(shifts):
        for j in range(n_params):
            g = 1 + s_idx * n_params + j
            if g not in wanted:
                continue
            if plan.theta_pos[j] < 0:
                variants.setdefault(-1, []).append((g, j, s))  # unused param
            else:
                variants.setdefault(plan.theta_pos[j], []).append((g, j, s))

    # 2. forward pass with base angles, checkpointing each needed prefix.
    checkpoints = {}
    t_re, t_im = _zero_tile(dim, tb)
    for k, op in enumerate(plan.train_ops):
        if k in variants:
            checkpoints[k] = (t_re, t_im)
        t_re, t_im = _apply_one(op, t_re, t_im, plan.m, theta_blk, data_blk)

    rows = {}
    f0 = _inner_fidelity((d_re, d_im), (t_re, t_im))
    if 0 in wanted:
        rows[0] = f0
    for g, _, _ in variants.get(-1, ()):   # shifting an unused param is a no-op
        rows[g] = f0

    # 3. backward pass: chi = (suffix)^dagger psi_d; one gate + one inner
    #    product per variant.
    c_re, c_im = d_re, d_im
    for k in range(len(plan.train_ops) - 1, -1, -1):
        op = plan.train_ops[k]
        for g, j, s in variants.get(k, ()):
            p_re, p_im = checkpoints[k]
            v_re, v_im = _apply_one(op, p_re, p_im, plan.m, theta_blk,
                                    data_blk, delta=s)
            rows[g] = _inner_fidelity((c_re, c_im), (v_re, v_im))
        if k > 0:                      # nothing consumes chi before op 0
            c_re, c_im = _apply_one(op, c_re, c_im, plan.m, theta_blk,
                                    data_blk, invert=True)
    out_ref[...] = jnp.stack([rows[g] for g in groups], axis=0)


def vqc_shift_fidelity(spec: CircuitSpec, theta: jnp.ndarray,
                       data: jnp.ndarray, *, four_term: bool = False,
                       groups: tuple[int, ...] | None = None,
                       tb: int = 4 * LANES,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Prefix-reuse shift-bank fidelities. theta: (B,P), data: (B,D).

    Returns (G, B) where G = len(groups) (default: every group of the bank,
    1 + 2P or 1 + 4P rows) — row g is |<psi_d|psi_t>|^2 with the group's
    (param, shift) applied.  Flattening in group-major order reproduces the
    materialized bank's fidelity vector exactly (same layout).

    Raises ValueError when the spec doesn't match the SWAP-test product
    structure; call ``build_shift_plan`` first (or use ``kernels.ops``,
    which falls back to the materialized path).
    """
    plan = build_shift_plan(spec)
    if plan is None:
        raise ValueError("circuit does not match the SWAP-test product "
                         "structure; use the materialized-bank path")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_shifts = 4 if four_term else 2
    n_groups = 1 + n_shifts * spec.n_theta
    if groups is None:
        groups = tuple(range(n_groups))
    if not groups or not all(0 <= g < n_groups for g in groups):
        raise ValueError(f"groups out of range for {n_groups}-group bank: {groups}")

    from repro.core.shift_rule import shift_values
    shifts = tuple(float(s) for s in shift_values(four_term))

    b = theta.shape[0]
    p, d = theta.shape[1], data.shape[1]
    tb = min(tb, max(LANES, 1 << (b - 1).bit_length()))
    pad = (-b) % tb
    theta_t = jnp.pad(theta.astype(jnp.float32), ((0, pad), (0, 0))).T
    data_t = jnp.pad(data.astype(jnp.float32), ((0, pad), (0, 0))).T
    g = len(groups)
    kern = functools.partial(_shiftbank_kernel, plan, shifts, groups,
                             spec.n_theta)
    out = pl.pallas_call(
        kern,
        grid=((b + pad) // tb,),
        in_specs=[pl.BlockSpec((p, tb), lambda i: (0, i)),
                  pl.BlockSpec((d, tb), lambda i: (0, i))],
        out_specs=pl.BlockSpec((g, tb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((g, b + pad), jnp.float32),
        interpret=interpret,
    )(theta_t, data_t)
    return out[:, :b]


# ------------------------------------------------------- analytic counters
def shift_bank_stats(spec: CircuitSpec, n_samples: int,
                     four_term: bool = False) -> dict:
    """Analytic gate-application and angle-traffic counts, implicit vs
    materialized — the ratios the acceptance benchmark tracks."""
    p, d = spec.n_theta, spec.n_data
    n_groups = 1 + (4 if four_term else 2) * p
    g_full = len(spec.ops)
    mat_gates = n_groups * g_full * n_samples
    mat_angle_floats = n_groups * n_samples * (p + d)
    plan = build_shift_plan(spec)
    if plan is None:                        # fallback executes the same work
        impl_gates = mat_gates
        impl_angle_floats = mat_angle_floats
    else:
        n_variants = sum(1 for j in range(p) if plan.theta_pos[j] >= 0) * \
            (4 if four_term else 2)
        impl_gates = (len(plan.data_ops) + 2 * len(plan.train_ops)
                      + n_variants) * n_samples
        impl_angle_floats = n_samples * (p + d)
    return {
        "n_groups": n_groups,
        "gate_apps_materialized": mat_gates,
        "gate_apps_implicit": impl_gates,
        "gate_apps_ratio": round(mat_gates / impl_gates, 1),
        "angle_bytes_materialized": 4 * mat_angle_floats,
        "angle_bytes_implicit": 4 * impl_angle_floats,
        "angle_bytes_ratio": round(mat_angle_floats / impl_angle_floats, 1),
    }
