"""Fused VQC statevector Pallas kernel — the DQuLearn compute hot-spot.

The paper's data plane executes millions of *small* circuits (5–7 qubits,
10–20 gates): the circuit bank of parameter-shifted subtasks.  On a GPU/RPC
system each circuit is one round-trip; a mechanical port would launch one
XLA op per gate per circuit.  The TPU-native adaptation is to FUSE the whole
circuit — encoding rotations, variational layers, SWAP test, ancilla readout
— into ONE kernel over a VMEM-resident batch of statevectors:

  * layout: statevectors live as (2**n, TB) tiles — basis index on the
    sublane axis, circuit batch on the 128-wide lane axis.  Gate application
    is a 2x2 (or structured 4x4/8x8) linear combination of ROWS, vectorized
    across lanes; per-circuit angles become per-lane cos/sin vectors.
  * complex numbers are (re, im) float32 pairs (TPU has no complex MXU path).
  * the gate sequence is static Python (unrolled at trace time); angles are
    read from VMEM blocks of the banked parameters.
  * HBM traffic: read (P + D) * TB angle floats, write TB results.  The
    statevector NEVER touches HBM — it is created, evolved and measured in
    VMEM/VREGs.  Per-gate dispatch would move 2 * 4 * 2**n * TB bytes per
    gate; fusion removes all of it (see benchmarks/kernel_bench.py).

VMEM budget: state tile is 2 * 4 * 2**n * TB bytes — for the paper's 7-qubit
circuits and TB=512 that is 512 KB, far under the ~16 MB/core VMEM of a
TPU v5e.  Qubit counts up to ~12 fit comfortably (2 * 4 * 4096 * 128 = 4 MB
at TB=128); beyond that, shrink TB.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sim import CircuitSpec

LANES = 128  # TPU lane width; batch tiles are multiples of this.


# ----------------------------------------------------------- gate micro-ops
# Each helper operates on (re, im) arrays of shape (2**n, TB) and per-lane
# angle vectors of shape (TB,).  Qubit q is the q-th MOST significant bit of
# the basis (row) index, matching repro.core.sim.

def _split1(x: jnp.ndarray, q: int, n: int):
    """-> (x0, x1) halves along qubit q's bit; each (2**q, 2**(n-q-1), TB)."""
    tb = x.shape[-1]
    t = x.reshape(2 ** q, 2, 2 ** (n - q - 1), tb)
    return t[:, 0], t[:, 1]


def _merge1(x0, x1, q: int, n: int, tb: int):
    t = jnp.stack([x0, x1], axis=1)
    return t.reshape(2 ** n, tb)


def _rot1(re, im, q, n, c, s, kind):
    """Apply RX/RY/RZ with per-lane cos/sin (c, s) on qubit q."""
    tb = re.shape[-1]
    r0, r1 = _split1(re, q, n)
    i0, i1 = _split1(im, q, n)
    if kind == "ry":                      # [[c,-s],[s,c]] real
        nr0, ni0 = c * r0 - s * r1, c * i0 - s * i1
        nr1, ni1 = s * r0 + c * r1, s * i0 + c * i1
    elif kind == "rx":                    # [[c,-is],[-is,c]]
        nr0, ni0 = c * r0 + s * i1, c * i0 - s * r1
        nr1, ni1 = c * r1 + s * i0, c * i1 - s * r0
    elif kind == "rz":                    # diag(e^{-it/2}, e^{it/2})
        nr0, ni0 = c * r0 + s * i0, c * i0 - s * r0
        nr1, ni1 = c * r1 - s * i1, c * i1 + s * r1
    else:
        raise ValueError(kind)
    return (_merge1(nr0, nr1, q, n, tb), _merge1(ni0, ni1, q, n, tb))


def _split2(x, qa, qb, n):
    """-> 2x2 blocks b[ba][bb] over qubits qa < qb; each block
    (2**qa, 2**(qb-qa-1), 2**(n-qb-1), TB)."""
    tb = x.shape[-1]
    t = x.reshape(2 ** qa, 2, 2 ** (qb - qa - 1), 2, 2 ** (n - qb - 1), tb)
    return ((t[:, 0, :, 0], t[:, 0, :, 1]), (t[:, 1, :, 0], t[:, 1, :, 1]))


def _merge2(b, qa, qb, n, tb):
    t = jnp.stack([jnp.stack([b[0][0], b[0][1]], axis=2),
                   jnp.stack([b[1][0], b[1][1]], axis=2)], axis=1)
    return t.reshape(2 ** n, tb)


def _rot2(re, im, qa, qb, n, c, s, kind):
    """RYY / RZZ / CRY / CRZ with per-lane (c, s); qa < qb required."""
    tb = re.shape[-1]
    R = _split2(re, qa, qb, n)
    I = _split2(im, qa, qb, n)
    r00, r01, r10, r11 = R[0][0], R[0][1], R[1][0], R[1][1]
    i00, i01, i10, i11 = I[0][0], I[0][1], I[1][0], I[1][1]
    if kind == "rzz":    # diag phases: e^{-it/2} on |00>,|11>; e^{+it/2} on |01>,|10>
        nr00, ni00 = c * r00 + s * i00, c * i00 - s * r00
        nr11, ni11 = c * r11 + s * i11, c * i11 - s * r11
        nr01, ni01 = c * r01 - s * i01, c * i01 + s * r01
        nr10, ni10 = c * r10 - s * i10, c * i10 + s * r10
    elif kind == "ryy":  # couples (00,11) with +i s, (01,10) with -i s
        nr00, ni00 = c * r00 - s * i11, c * i00 + s * r11
        nr11, ni11 = c * r11 - s * i00, c * i11 + s * r00
        nr01, ni01 = c * r01 + s * i10, c * i01 - s * r10
        nr10, ni10 = c * r10 + s * i01, c * i10 - s * r01
    elif kind == "cry":  # RY on qb within qa=1 block
        nr00, ni00, nr01, ni01 = r00, i00, r01, i01
        nr10, ni10 = c * r10 - s * r11, c * i10 - s * i11
        nr11, ni11 = s * r10 + c * r11, s * i10 + c * i11
    elif kind == "crz":  # RZ on qb within qa=1 block
        nr00, ni00, nr01, ni01 = r00, i00, r01, i01
        nr10, ni10 = c * r10 + s * i10, c * i10 - s * r10
        nr11, ni11 = c * r11 - s * i11, c * i11 + s * r11
    else:
        raise ValueError(kind)
    return (_merge2(((nr00, nr01), (nr10, nr11)), qa, qb, n, tb),
            _merge2(((ni00, ni01), (ni10, ni11)), qa, qb, n, tb))


def _h(re, im, q, n):
    tb = re.shape[-1]
    inv = 0.7071067811865476
    r0, r1 = _split1(re, q, n)
    i0, i1 = _split1(im, q, n)
    return (_merge1((r0 + r1) * inv, (r0 - r1) * inv, q, n, tb),
            _merge1((i0 + i1) * inv, (i0 - i1) * inv, q, n, tb))


def _split3(x, qa, qb, qc_, n):
    tb = x.shape[-1]
    t = x.reshape(2 ** qa, 2, 2 ** (qb - qa - 1), 2, 2 ** (qc_ - qb - 1), 2,
                  2 ** (n - qc_ - 1), tb)
    return t


def _cswap(re, im, qa, qb, qc_, n):
    """Fredkin: control qa, swap qb<->qc_ (qa < qb < qc_)."""
    tb = re.shape[-1]
    outs = []
    for x in (re, im):
        t = _split3(x, qa, qb, qc_, n)
        # within control=1 block, swap the (qb, qc_) bit pair (0,1)<->(1,0)
        a01 = t[:, 1, :, 0, :, 1]
        a10 = t[:, 1, :, 1, :, 0]
        t = t.at[:, 1, :, 0, :, 1].set(a10).at[:, 1, :, 1, :, 0].set(a01)
        outs.append(t.reshape(2 ** n, tb))
    return outs[0], outs[1]


def _apply_ops(spec: CircuitSpec, re, im, theta_blk, data_blk):
    """Unrolled gate sequence on a (dim, TB) tile. theta_blk: (P, TB)."""
    n = spec.n_qubits
    for op in spec.ops:
        if op.gate == "h":
            re, im = _h(re, im, op.qubits[0], n)
            continue
        if op.gate == "cswap":
            qa, qb, qc_ = op.qubits
            re, im = _cswap(re, im, qa, qb, qc_, n)
            continue
        kind, j = op.param
        ang = theta_blk[j] if kind == "theta" else data_blk[j]  # (TB,)
        c, s = jnp.cos(ang / 2), jnp.sin(ang / 2)
        if op.gate in ("rx", "ry", "rz"):
            re, im = _rot1(re, im, op.qubits[0], n, c, s, op.gate)
        elif op.gate in ("ryy", "rzz", "cry", "crz"):
            qa, qb = op.qubits
            if qa > qb:
                raise NotImplementedError("kernel assumes ascending qubit pairs")
            re, im = _rot2(re, im, qa, qb, n, c, s, op.gate)
        else:
            raise NotImplementedError(op.gate)
    return re, im


# ------------------------------------------------------------------ kernels
def _fidelity_kernel(spec: CircuitSpec, theta_ref, data_ref, p0_ref):
    tb = theta_ref.shape[-1]
    dim = 2 ** spec.n_qubits
    # |0...0> batch, built in VREGs — never read from HBM.
    row = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0)
    re = jnp.where(row == 0, 1.0, 0.0).astype(jnp.float32)
    im = jnp.zeros((dim, tb), jnp.float32)
    re, im = _apply_ops(spec, re, im, theta_ref[...], data_ref[...])
    prob = re * re + im * im
    half = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0) < (dim // 2)
    p0 = jnp.where(half, prob, 0.0).sum(axis=0, keepdims=True)  # ancilla = MSB
    p0_ref[...] = p0


def _state_kernel(spec: CircuitSpec, theta_ref, data_ref, re_ref, im_ref):
    tb = theta_ref.shape[-1]
    dim = 2 ** spec.n_qubits
    row = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0)
    re = jnp.where(row == 0, 1.0, 0.0).astype(jnp.float32)
    im = jnp.zeros((dim, tb), jnp.float32)
    re, im = _apply_ops(spec, re, im, theta_ref[...], data_ref[...])
    re_ref[...] = re
    im_ref[...] = im


def _grid_call(spec: CircuitSpec, theta_t, data_t, tb: int, interpret: bool,
               want_state: bool):
    """theta_t: (P, C), data_t: (D, C) with C % tb == 0."""
    p, c = theta_t.shape
    d = data_t.shape[0]
    dim = 2 ** spec.n_qubits
    grid = (c // tb,)
    in_specs = [
        pl.BlockSpec((p, tb), lambda i: (0, i)),
        pl.BlockSpec((d, tb), lambda i: (0, i)),
    ]
    if want_state:
        out_shape = [jax.ShapeDtypeStruct((dim, c), jnp.float32)] * 2
        out_specs = [pl.BlockSpec((dim, tb), lambda i: (0, i))] * 2
        kern = functools.partial(_state_kernel, spec)
    else:
        out_shape = jax.ShapeDtypeStruct((1, c), jnp.float32)
        out_specs = pl.BlockSpec((1, tb), lambda i: (0, i))
        kern = functools.partial(_fidelity_kernel, spec)
    return pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(theta_t, data_t)


def vqc_p0(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray,
           tb: int = 4 * LANES, interpret: bool | None = None) -> jnp.ndarray:
    """Batched ancilla-P0 for a circuit bank. theta: (C,P), data: (C,D) -> (C,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c = theta.shape[0]
    tb = min(tb, max(LANES, 1 << (c - 1).bit_length()))
    pad = (-c) % tb
    theta_t = jnp.pad(theta, ((0, pad), (0, 0))).T
    data_t = jnp.pad(data, ((0, pad), (0, 0))).T
    p0 = _grid_call(spec, theta_t, data_t, tb, interpret, want_state=False)
    return p0[0, :c]


def vqc_state(spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray,
              tb: int = LANES, interpret: bool | None = None):
    """Batched final statevector (re, im), each (C, 2**n) — for kernel tests."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c = theta.shape[0]
    tb = min(tb, max(LANES, 1 << (c - 1).bit_length()))
    pad = (-c) % tb
    theta_t = jnp.pad(theta, ((0, pad), (0, 0))).T
    data_t = jnp.pad(data, ((0, pad), (0, 0))).T
    re, im = _grid_call(spec, theta_t, data_t, tb, interpret, want_state=True)
    return re[:, :c].T, im[:, :c].T
