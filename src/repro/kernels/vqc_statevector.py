"""Fused VQC statevector Pallas kernel — the DQuLearn compute hot-spot.

The paper's data plane executes millions of *small* circuits (5–7 qubits,
10–20 gates): the circuit bank of parameter-shifted subtasks.  On a GPU/RPC
system each circuit is one round-trip; a mechanical port would launch one
XLA op per gate per circuit.  The TPU-native adaptation is to FUSE the whole
circuit — encoding rotations, variational layers, SWAP test, ancilla readout
— into ONE kernel over a VMEM-resident batch of statevectors:

  * layout: statevectors live as (2**n, TB) tiles — basis index on the
    sublane axis, circuit batch on the 128-wide lane axis.  Gate application
    is a 2x2 (or structured 4x4/8x8) linear combination of ROWS, vectorized
    across lanes; per-circuit angles become per-lane cos/sin vectors.
  * complex numbers are (re, im) float32 pairs (TPU has no complex MXU path).
  * the gate sequence is static Python (unrolled at trace time); angles are
    read from VMEM blocks of the banked parameters.
  * HBM traffic: read (P + D) * TB angle floats, write TB results.  The
    statevector NEVER touches HBM — it is created, evolved and measured in
    VMEM/VREGs.  Per-gate dispatch would move 2 * 4 * 2**n * TB bytes per
    gate; fusion removes all of it (see benchmarks/kernel_bench.py).

VMEM budget: state tile is 2 * 4 * 2**n * TB bytes — for the paper's 7-qubit
circuits and TB=512 that is 512 KB, far under the ~16 MB/core VMEM of a
TPU v5e.  Qubit counts up to ~12 fit comfortably (2 * 4 * 4096 * 128 = 4 MB
at TB=128); beyond that, shrink TB.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sim import CircuitSpec

LANES = 128  # TPU lane width; batch tiles are multiples of this.


def kernel_tb(n_lanes: int, tb: int = 4 * LANES) -> int:
    """Lane-tile width a kernel launch picks for an ``n_lanes`` batch: the
    requested ``tb`` shrunk to the batch's power-of-two envelope, never
    below one LANES tile.  The dispatcher's VMEM model MUST use this same
    policy (a divergent copy would silently mis-predict the real kernel
    footprint)."""
    return min(tb, max(LANES, 1 << (max(n_lanes, 1) - 1).bit_length()))


# ----------------------------------------------------------- gate micro-ops
# Each helper operates on (re, im) arrays of shape (2**n, TB) and per-lane
# angle vectors of shape (TB,).  Qubit q is the q-th MOST significant bit of
# the basis (row) index, matching repro.core.sim.


def _split1(x: jnp.ndarray, q: int, n: int):
    """-> (x0, x1) halves along qubit q's bit; each (2**q, 2**(n-q-1), TB)."""
    tb = x.shape[-1]
    t = x.reshape(2**q, 2, 2 ** (n - q - 1), tb)
    return t[:, 0], t[:, 1]


def _merge1(x0, x1, q: int, n: int, tb: int):
    t = jnp.stack([x0, x1], axis=1)
    return t.reshape(2**n, tb)


def _rot1(re, im, q, n, c, s, kind):
    """Apply RX/RY/RZ with per-lane cos/sin (c, s) on qubit q."""
    tb = re.shape[-1]
    r0, r1 = _split1(re, q, n)
    i0, i1 = _split1(im, q, n)
    if kind == "ry":  # [[c,-s],[s,c]] real
        nr0, ni0 = c * r0 - s * r1, c * i0 - s * i1
        nr1, ni1 = s * r0 + c * r1, s * i0 + c * i1
    elif kind == "rx":  # [[c,-is],[-is,c]]
        nr0, ni0 = c * r0 + s * i1, c * i0 - s * r1
        nr1, ni1 = c * r1 + s * i0, c * i1 - s * r0
    elif kind == "rz":  # diag(e^{-it/2}, e^{it/2})
        nr0, ni0 = c * r0 + s * i0, c * i0 - s * r0
        nr1, ni1 = c * r1 - s * i1, c * i1 + s * r1
    else:
        raise ValueError(kind)
    return (_merge1(nr0, nr1, q, n, tb), _merge1(ni0, ni1, q, n, tb))


def _split2(x, qa, qb, n):
    """-> 2x2 blocks b[ba][bb] over qubits qa < qb; each block
    (2**qa, 2**(qb-qa-1), 2**(n-qb-1), TB)."""
    tb = x.shape[-1]
    t = x.reshape(2**qa, 2, 2 ** (qb - qa - 1), 2, 2 ** (n - qb - 1), tb)
    return ((t[:, 0, :, 0], t[:, 0, :, 1]), (t[:, 1, :, 0], t[:, 1, :, 1]))


def _merge2(b, qa, qb, n, tb):
    t = jnp.stack(
        [
            jnp.stack([b[0][0], b[0][1]], axis=2),
            jnp.stack([b[1][0], b[1][1]], axis=2),
        ],
        axis=1,
    )
    return t.reshape(2**n, tb)


def _rot2(re, im, qa, qb, n, c, s, kind):
    """RYY / RZZ / CRY / CRZ with per-lane (c, s); qa < qb required."""
    tb = re.shape[-1]
    R = _split2(re, qa, qb, n)
    I = _split2(im, qa, qb, n)  # noqa: E741
    r00, r01, r10, r11 = R[0][0], R[0][1], R[1][0], R[1][1]
    i00, i01, i10, i11 = I[0][0], I[0][1], I[1][0], I[1][1]
    if kind == "rzz":  # diag phases: e^{-it/2} on |00>,|11>; e^{+it/2} on |01>,|10>
        nr00, ni00 = c * r00 + s * i00, c * i00 - s * r00
        nr11, ni11 = c * r11 + s * i11, c * i11 - s * r11
        nr01, ni01 = c * r01 - s * i01, c * i01 + s * r01
        nr10, ni10 = c * r10 - s * i10, c * i10 + s * r10
    elif kind == "ryy":  # couples (00,11) with +i s, (01,10) with -i s
        nr00, ni00 = c * r00 - s * i11, c * i00 + s * r11
        nr11, ni11 = c * r11 - s * i00, c * i11 + s * r00
        nr01, ni01 = c * r01 + s * i10, c * i01 - s * r10
        nr10, ni10 = c * r10 + s * i01, c * i10 - s * r01
    elif kind == "cry":  # RY on qb within qa=1 block
        nr00, ni00, nr01, ni01 = r00, i00, r01, i01
        nr10, ni10 = c * r10 - s * r11, c * i10 - s * i11
        nr11, ni11 = s * r10 + c * r11, s * i10 + c * i11
    elif kind == "crz":  # RZ on qb within qa=1 block
        nr00, ni00, nr01, ni01 = r00, i00, r01, i01
        nr10, ni10 = c * r10 + s * i10, c * i10 - s * r10
        nr11, ni11 = c * r11 - s * i11, c * i11 + s * r11
    else:
        raise ValueError(kind)
    return (
        _merge2(((nr00, nr01), (nr10, nr11)), qa, qb, n, tb),
        _merge2(((ni00, ni01), (ni10, ni11)), qa, qb, n, tb),
    )


def _h(re, im, q, n):
    tb = re.shape[-1]
    inv = 0.7071067811865476
    r0, r1 = _split1(re, q, n)
    i0, i1 = _split1(im, q, n)
    return (
        _merge1((r0 + r1) * inv, (r0 - r1) * inv, q, n, tb),
        _merge1((i0 + i1) * inv, (i0 - i1) * inv, q, n, tb),
    )


def _split3(x, qa, qb, qc_, n):
    tb = x.shape[-1]
    t = x.reshape(
        2**qa, 2, 2 ** (qb - qa - 1), 2, 2 ** (qc_ - qb - 1), 2, 2 ** (n - qc_ - 1), tb
    )
    return t


def _cswap(re, im, qa, qb, qc_, n):
    """Fredkin: control qa, swap qb<->qc_ (qa < qb < qc_)."""
    tb = re.shape[-1]
    outs = []
    for x in (re, im):
        t = _split3(x, qa, qb, qc_, n)
        # within control=1 block, swap the (qb, qc_) bit pair (0,1)<->(1,0)
        a01 = t[:, 1, :, 0, :, 1]
        a10 = t[:, 1, :, 1, :, 0]
        t = t.at[:, 1, :, 0, :, 1].set(a10).at[:, 1, :, 1, :, 0].set(a01)
        outs.append(t.reshape(2**n, tb))
    return outs[0], outs[1]


def _op_angle(op, theta_blk, data_blk, delta: float = 0.0):
    """Per-lane angle vector for a parameterized op (+ static shift delta)."""
    kind, j = op.param
    if kind == "theta":
        ang = theta_blk[j]
    elif kind == "data":
        ang = data_blk[j]
    elif kind == "const":
        ang = jnp.asarray(j, jnp.float32)
    else:
        raise ValueError(op.param)
    return ang + delta if delta else ang


def _apply_one(
    op, re, im, n, theta_blk, data_blk, delta: float = 0.0, invert: bool = False
):
    """Apply one gate (optionally angle-shifted by ``delta`` or inverted)."""
    if op.gate == "h":
        return _h(re, im, op.qubits[0], n)  # self-inverse
    if op.gate == "cswap":
        qa, qb, qc_ = op.qubits
        return _cswap(re, im, qa, qb, qc_, n)  # self-inverse
    ang = _op_angle(op, theta_blk, data_blk, delta)
    if invert:  # rotation: g(t)^dagger = g(-t)
        ang = -ang
    c, s = jnp.cos(ang / 2), jnp.sin(ang / 2)
    if op.gate in ("rx", "ry", "rz"):
        return _rot1(re, im, op.qubits[0], n, c, s, op.gate)
    if op.gate in ("ryy", "rzz", "cry", "crz"):
        qa, qb = op.qubits
        if qa > qb:
            if op.gate in ("ryy", "rzz"):  # symmetric under qubit swap
                qa, qb = qb, qa
            else:
                raise NotImplementedError(
                    f"{op.gate} requires ascending (control, target) qubits"
                )
        return _rot2(re, im, qa, qb, n, c, s, op.gate)
    raise NotImplementedError(op.gate)


def _apply_ops(spec: CircuitSpec, re, im, theta_blk, data_blk):
    """Unrolled gate sequence on a (dim, TB) tile. theta_blk: (P, TB)."""
    n = spec.n_qubits
    for op in spec.ops:
        re, im = _apply_one(op, re, im, n, theta_blk, data_blk)
    return re, im


# ------------------------------------------------------------------ kernels
def _fidelity_kernel(spec: CircuitSpec, theta_ref, data_ref, p0_ref):
    tb = theta_ref.shape[-1]
    dim = 2**spec.n_qubits
    # |0...0> batch, built in VREGs — never read from HBM.
    row = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0)
    re = jnp.where(row == 0, 1.0, 0.0).astype(jnp.float32)
    im = jnp.zeros((dim, tb), jnp.float32)
    re, im = _apply_ops(spec, re, im, theta_ref[...], data_ref[...])
    prob = re * re + im * im
    half = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0) < (dim // 2)
    p0 = jnp.where(half, prob, 0.0).sum(axis=0, keepdims=True)  # ancilla = MSB
    p0_ref[...] = p0


def _state_kernel(spec: CircuitSpec, theta_ref, data_ref, re_ref, im_ref):
    tb = theta_ref.shape[-1]
    dim = 2**spec.n_qubits
    row = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0)
    re = jnp.where(row == 0, 1.0, 0.0).astype(jnp.float32)
    im = jnp.zeros((dim, tb), jnp.float32)
    re, im = _apply_ops(spec, re, im, theta_ref[...], data_ref[...])
    re_ref[...] = re
    im_ref[...] = im


def _grid_call(
    spec: CircuitSpec, theta_t, data_t, tb: int, interpret: bool, want_state: bool
):
    """theta_t: (P, C), data_t: (D, C) with C % tb == 0."""
    p, c = theta_t.shape
    d = data_t.shape[0]
    dim = 2**spec.n_qubits
    grid = (c // tb,)
    in_specs = [
        pl.BlockSpec((p, tb), lambda i: (0, i)),
        pl.BlockSpec((d, tb), lambda i: (0, i)),
    ]
    if want_state:
        out_shape = [jax.ShapeDtypeStruct((dim, c), jnp.float32)] * 2
        out_specs = [pl.BlockSpec((dim, tb), lambda i: (0, i))] * 2
        kern = functools.partial(_state_kernel, spec)
    else:
        out_shape = jax.ShapeDtypeStruct((1, c), jnp.float32)
        out_specs = pl.BlockSpec((1, tb), lambda i: (0, i))
        kern = functools.partial(_fidelity_kernel, spec)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(theta_t, data_t)


def vqc_p0(
    spec: CircuitSpec,
    theta: jnp.ndarray,
    data: jnp.ndarray,
    tb: int = 4 * LANES,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Batched ancilla-P0 for a circuit bank. theta: (C,P), data: (C,D) -> (C,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c = theta.shape[0]
    tb = kernel_tb(c, tb)
    pad = (-c) % tb
    theta_t = jnp.pad(theta, ((0, pad), (0, 0))).T
    data_t = jnp.pad(data, ((0, pad), (0, 0))).T
    p0 = _grid_call(spec, theta_t, data_t, tb, interpret, want_state=False)
    return p0[0, :c]


def vqc_state(
    spec: CircuitSpec,
    theta: jnp.ndarray,
    data: jnp.ndarray,
    tb: int = LANES,
    interpret: bool | None = None,
):
    """Batched final statevector (re, im), each (C, 2**n) — for kernel tests."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c = theta.shape[0]
    tb = kernel_tb(c, tb)
    pad = (-c) % tb
    theta_t = jnp.pad(theta, ((0, pad), (0, 0))).T
    data_t = jnp.pad(data, ((0, pad), (0, 0))).T
    re, im = _grid_call(spec, theta_t, data_t, tb, interpret, want_state=True)
    return re[:, :c].T, im[:, :c].T


# ----------------------------------------------- shift-structured execution
#
# The parameter-shift circuit bank is pathologically redundant: its
# (1 + 2P) * B rows differ from the B base rows by exactly ONE angle each.
# ``vqc_p0`` on a materialized bank re-simulates every gate of every row —
# (1+2P) * G gate applications and (P+D) * (1+2P) angle floats per sample.
#
# The QuClassi circuit family has structure the kernel can verify statically
# and exploit to do far better than generic suffix replay:
#
#   * all ops before the SWAP-test tail act on two DISJOINT registers —
#     encoding on the data register (no trainable angles), the variational
#     stack on the trainable register (all trainable angles);
#   * the SWAP-test tail [H(a), CSWAP(a, d_i, t_i)..., H(a)] measures
#     P0 = (1 + |<psi_d|psi_t>|^2) / 2 exactly, so fidelity = 2*P0 - 1
#     = |<psi_d|psi_t>|^2 — an inner product of the two register states.
#
# The shift kernel therefore evolves the two 2**m-dim register states
# (m = register width) instead of the 2**(2m+1)-dim full state:
#
#   1. data register: ONE pass (theta-independent, shared by every variant);
#   2. trainable register FORWARD pass with base angles, checkpointing the
#      prefix state psi_k just before each parameter's FIRST dependent gate
#      in VMEM — 2*4*2**m*TB bytes per checkpointed prefix;
#   3. trainable register BACKWARD pass holding the reversed-suffix state
#      chi_k = (U_suffix_k)^dagger psi_d.  A single-use rotation gate's
#      shifted variant G_j(theta_j + s) then satisfies
#         F(j, s) = |<psi_d| U_suf G_j(theta_j+s) |psi_j>|^2
#                 = |<chi_j| G_j(theta_j+s) |psi_j>|^2,
#      i.e. ONE gate application plus one 2**m-dim inner product instead of
#      a full-circuit simulation.  A MULTI-USE parameter (positions
#      k_1 < ... < k_r) anchors at its LAST dependent gate: chi at k_r + 1
#      covers the unshifted remainder, and the variant REPLAYS only ops
#      k_1..k_r from the k_1 checkpoint with the shift added to each of its
#      own gates — replay depth k_r - k_1 + 1 gates per variant, still far
#      from the full-circuit resimulation the materialized bank pays.
#
# Per sample-tile the kernel reads (P + D) * TB angle floats (vs
# (P+D) * (1+2P) * TB materialized) and applies D_g + 2*T_g + sum(replay_j)
# register-local gates (vs (1+2P) * G full-state gates) — the ratios
# ``shift_bank_stats`` reports and benchmarks/kernel_bench.py tracks.
#
# Circuits that don't match the verified structure (interleaved registers,
# theta on the data register, non-rotation theta gates, non-SWAP-test tail)
# return ``None`` from ``build_shift_plan``.  Circuits WITH a plan whose
# suffix-replay cost exceeds the materialized bank's (a parameter reused
# across most of the circuit) are routed to the materialized path by the
# analytic ``shift_cost_info`` comparison in ``kernels.ops`` — the binary
# plan-exists decision became a cost crossover.

ROT_GATES = ("rx", "ry", "rz", "ryy", "rzz", "cry", "crz")


@dataclasses.dataclass(frozen=True)
class ShiftPlan:
    """Static execution plan for the prefix-reuse shift kernel.

    ``data_ops`` / ``train_ops`` are the body ops remapped to register-local
    qubit indices (register width ``m``); ``theta_positions[j]`` is the
    ascending tuple of indices into ``train_ops`` of parameter j's dependent
    gates — empty when the parameter drives no gate (its shifted fidelity is
    the base fidelity), length > 1 for multi-use parameters (executed by
    suffix replay over the [first, last] span).
    """

    m: int
    data_ops: tuple
    train_ops: tuple
    theta_positions: tuple[tuple[int, ...], ...]

    @property
    def theta_pos(self) -> tuple[int, ...]:
        """Legacy single-position view: parameter j's FIRST dependent gate
        (its checkpoint position), or -1 when it drives no gate."""
        return tuple(ps[0] if ps else -1 for ps in self.theta_positions)

    def replay_depth(self, j: int) -> int:
        """Gates a shift variant of parameter j replays from its checkpoint
        (1 for single-use parameters, 0 for unused ones)."""
        ps = self.theta_positions[j]
        return (ps[-1] - ps[0] + 1) if ps else 0


def _remap_op(op, mapping):
    return dataclasses.replace(op, qubits=tuple(mapping[q] for q in op.qubits))


@functools.lru_cache(maxsize=None)
def build_shift_plan(spec: CircuitSpec) -> ShiftPlan | None:
    """Verify the SWAP-test product structure; None -> caller must fall back."""
    ops = spec.ops
    # --- tail: H(anc), m CSWAP(anc, d_i, t_i), H(anc)
    if len(ops) < 3 or ops[-1].gate != "h":
        return None
    anc = ops[-1].qubits[0]
    k = len(ops) - 2
    pairs = []
    while k >= 0 and ops[k].gate == "cswap":
        a, d, t = ops[k].qubits
        if a != anc:
            return None
        pairs.append((d, t))
        k -= 1
    if k < 0 or ops[k].gate != "h" or ops[k].qubits != (anc,) or not pairs:
        return None
    pairs.reverse()
    data_q = [d for d, _ in pairs]
    train_q = [t for _, t in pairs]
    m = len(pairs)
    regs = set(data_q) | set(train_q) | {anc}
    if len(regs) != 2 * m + 1 or regs != set(range(spec.n_qubits)):
        return None
    data_map = {q: i for i, q in enumerate(data_q)}
    train_map = {q: i for i, q in enumerate(train_q)}

    # --- body: every op entirely inside one register; theta only on train
    data_ops, train_ops = [], []
    theta_pos: dict[int, list[int]] = {}
    for op in ops[:k]:
        qs = set(op.qubits)
        is_theta = op.param is not None and op.param[0] == "theta"
        if qs <= set(data_q):
            if is_theta or op.gate == "cswap":
                return None
            data_ops.append(_remap_op(op, data_map))
        elif qs <= set(train_q):
            if op.gate == "cswap":
                return None
            if is_theta:
                j = op.param[1]
                if op.gate not in ROT_GATES:
                    return None  # no shift rule for non-rotation theta gates
                # multi-use params accumulate their positions; the kernel
                # replays the [first, last] span per shift variant.
                theta_pos.setdefault(j, []).append(len(train_ops))
            train_ops.append(_remap_op(op, train_map))
        else:
            return None  # op straddles registers / touches ancilla
    # descending cry/crz would raise inside the kernel; reject here instead
    for op in data_ops + train_ops:
        if op.gate in ("cry", "crz") and op.qubits[0] > op.qubits[1]:
            return None
    pos = tuple(tuple(theta_pos.get(j, ())) for j in range(spec.n_theta))
    return ShiftPlan(
        m=m,
        data_ops=tuple(data_ops),
        train_ops=tuple(train_ops),
        theta_positions=pos,
    )


def _zero_tile(dim: int, tb: int):
    row = jax.lax.broadcasted_iota(jnp.int32, (dim, tb), 0)
    re = jnp.where(row == 0, 1.0, 0.0).astype(jnp.float32)
    im = jnp.zeros((dim, tb), jnp.float32)
    return re, im


def _inner_fidelity(chi, phi):
    """|<chi|phi>|^2 per lane; chi/phi are (re, im) pairs of (dim, TB)."""
    cre, cim = chi
    pre, pim = phi
    ip_re = (cre * pre + cim * pim).sum(axis=0)
    ip_im = (cre * pim - cim * pre).sum(axis=0)
    return ip_re * ip_re + ip_im * ip_im


def _collect_variants(plan: ShiftPlan, shifts, groups, n_params: int):
    """Static (trace-time) map: ANCHOR train-op position -> [(group, param,
    shift)].

    A variant anchors at its parameter's LAST dependent gate — the backward
    pass's chi there covers the unshifted circuit remainder, and the shifted
    part replays forward from the checkpoint at the parameter's FIRST
    dependent gate (one gate for single-use parameters).  Position -1
    collects groups whose parameter drives no gate (their shifted fidelity
    is the base fidelity)."""
    wanted = set(groups)
    variants = {}
    for s_idx, s in enumerate(shifts):
        for j in range(n_params):
            g = 1 + s_idx * n_params + j
            if g not in wanted:
                continue
            ps = plan.theta_positions[j]
            variants.setdefault(ps[-1] if ps else -1, []).append((g, j, s))
    return variants


def _replay_variant(plan: ShiftPlan, j: int, s: float, state, theta_blk, data_blk):
    """Suffix replay for one shift variant: apply parameter j's dependent
    span of train ops to its checkpoint ``state``, the shift ``s`` added to
    every gate the parameter drives.  Single-use parameters degenerate to
    the one shifted gate application of the original kernel."""
    first, last = plan.theta_positions[j][0], plan.theta_positions[j][-1]
    re, im = state
    for k in range(first, last + 1):
        op = plan.train_ops[k]
        delta = s if op.param == ("theta", j) else 0.0
        re, im = _apply_one(op, re, im, plan.m, theta_blk, data_blk, delta=delta)
    return re, im


def _shiftbank_kernel(
    plan: ShiftPlan, shifts, groups, n_params: int, theta_ref, data_ref, out_ref
):
    """Compute the requested shift groups for one sample tile.

    Output rows follow ``groups``: group 0 is the base fidelity, group
    1 + s*P + j is shift s of param j (bank order).
    """
    tb = theta_ref.shape[-1]
    dim = 2**plan.m
    theta_blk = theta_ref[...]
    data_blk = data_ref[...]

    # 1. data register: one theta-independent pass, shared by every variant.
    d_re, d_im = _zero_tile(dim, tb)
    for op in plan.data_ops:
        d_re, d_im = _apply_one(op, d_re, d_im, plan.m, theta_blk, data_blk)

    wanted = set(groups)
    variants = _collect_variants(plan, shifts, groups, n_params)
    anchors = sorted(k for k in variants if k >= 0)
    firsts = {
        plan.theta_positions[j][0] for a in anchors for (_, j, _) in variants[a]
    }

    # 2. forward pass with base angles, checkpointing the prefix before each
    #    anchored parameter's FIRST dependent gate.
    checkpoints = {}
    t_re, t_im = _zero_tile(dim, tb)
    for k, op in enumerate(plan.train_ops):
        if k in firsts:
            checkpoints[k] = (t_re, t_im)
        t_re, t_im = _apply_one(op, t_re, t_im, plan.m, theta_blk, data_blk)

    rows = {}
    f0 = _inner_fidelity((d_re, d_im), (t_re, t_im))
    if 0 in wanted:
        rows[0] = f0
    for g, _, _ in variants.get(-1, ()):  # shifting an unused param is a no-op
        rows[g] = f0

    # 3. backward pass: chi = (suffix)^dagger psi_d; one suffix replay + one
    #    inner product per variant (a single gate for single-use params).
    #    chi below the shallowest anchor is never consumed — stop there.
    lowest = anchors[0] if anchors else len(plan.train_ops)
    c_re, c_im = d_re, d_im
    for k in range(len(plan.train_ops) - 1, lowest - 1, -1):
        op = plan.train_ops[k]
        for g, j, s in variants.get(k, ()):
            first = plan.theta_positions[j][0]
            v_re, v_im = _replay_variant(
                plan, j, s, checkpoints[first], theta_blk, data_blk
            )
            rows[g] = _inner_fidelity((c_re, c_im), (v_re, v_im))
        if k > lowest:
            c_re, c_im = _apply_one(
                op, c_re, c_im, plan.m, theta_blk, data_blk, invert=True
            )
    out_ref[...] = jnp.stack([rows[g] for g in groups], axis=0)


# --------------------------------------- VMEM-aware checkpoint spilling
#
# The single-sweep kernel above holds EVERY needed prefix checkpoint live in
# VMEM between the forward and backward passes: P states of 2*4*2**m*TB
# bytes each.  For the paper's registers (m <= 3) that is kilobytes; for
# wide registers (m > 6 at the production TB = 512) the checkpoint set
# alone exceeds a TPU core's ~16 MB VMEM and the launch cannot lower.
# Rather than ejecting those circuits to the (1+2P)x-slower materialized
# path, the shift executor SPILLS: the train-op sequence is cut into depth
# tiles of at most ``cap`` checkpointed positions (a multi-use parameter's
# [first, last] replay span is atomic — tile boundaries never split it),
# the forward launch writes each tile's boundary prefix state to HBM (a
# pallas output), and ONE double-buffered backward launch sweeps every
# tile: each tile re-derives its <= cap checkpoints from the spilled
# boundary, consumes the reversed-suffix state chi carried over from the
# previous (deeper) tile in VMEM, and emits its variants' fidelity rows.
# The boundary fetches ping-pong between two VMEM buffers — tile t+1's
# async HBM copy is started before tile t's compute, so the fetch latency
# the old per-tile launches serialized now hides under gate application.
# Same op-application order per lane as the single sweep -> identical
# results; cost is one extra in-register forward pass (the recompute) plus
# 2 * (n_tiles + 1) register states of HBM spill traffic.

#: default per-launch checkpoint VMEM budget: ~16 MB/core minus headroom
#: for the angle blocks, the running states, and double buffering.
VMEM_BUDGET_BYTES = 14 * 1024 * 1024

#: live non-checkpoint states a tile launch holds (running state, chi,
#: boundary, one shifted variant) — reserved out of the budget.
_RESERVED_STATES = 4


def _state_bytes(m: int, tb: int) -> int:
    """Bytes of one (re, im) register state tile."""
    return 2 * 4 * (2**m) * tb


def checkpoint_vmem_bytes(plan: ShiftPlan, n_positions: int, tb: int) -> int:
    """VMEM the single-sweep kernel needs for its live checkpoint set."""
    return (n_positions + _RESERVED_STATES) * _state_bytes(plan.m, tb)


def _merge_spans(plan: ShiftPlan, positions):
    """Merge variant anchor positions into atomic (first, n_checkpoints)
    segments.

    Each anchor drags its parameter's whole [first, last] replay span along
    (single-use/point positions span themselves).  Overlapping spans fuse
    into one segment — a tile boundary inside a span would strand a replay's
    checkpoint in the previous tile.  Returns ascending (lo, n_ckpt) pairs
    where ``lo`` is the segment's first checkpoint position and ``n_ckpt``
    its distinct checkpoint count."""
    first_of = {ps[-1]: ps[0] for ps in plan.theta_positions if ps}
    segments: list[list] = []  # [lo, hi_anchor, {checkpoint positions}]
    for f, k in sorted((first_of.get(k, k), k) for k in positions):
        if segments and f <= segments[-1][1]:
            segments[-1][1] = max(segments[-1][1], k)
            segments[-1][2].add(f)
        else:
            segments.append([f, k, {f}])
    return [(seg[0], len(seg[2])) for seg in segments]


def plan_depth_tiles(
    plan: ShiftPlan, positions, tb: int, vmem_budget: int = VMEM_BUDGET_BYTES
):
    """Cut variant anchor positions into depth tiles that fit the budget.

    ``positions``: ascending train-op indices of variant anchors (a
    parameter's last dependent gate; equal to its checkpoint position for
    single-use parameters).  Returns None when every checkpoint fits in one
    sweep (no spilling), else a tuple of (lo, hi) train-op ranges — tile t
    re-derives its checkpoints from the spilled boundary state at op ``lo``
    and walks chi from op ``hi`` down to ``lo``.  Multi-use replay spans are
    atomic: a segment never straddles a tile boundary (an oversized segment
    becomes its own tile).  Single-use plans tile exactly as before.
    """
    positions = sorted(positions)
    if not positions:
        return None
    cap = max(1, vmem_budget // _state_bytes(plan.m, tb) - _RESERVED_STATES)
    segments = _merge_spans(plan, positions)
    if sum(n for _, n in segments) <= cap:
        return None
    chunks: list[list] = []
    cur, cur_n = [], 0
    for lo, n in segments:
        if cur and cur_n + n > cap:
            chunks.append(cur)
            cur, cur_n = [], 0
        cur.append((lo, n))
        cur_n += n
    if cur:
        chunks.append(cur)
    bounds = [c[0][0] for c in chunks] + [len(plan.train_ops)]
    return tuple(zip(bounds[:-1], bounds[1:]))


def plan_gate_apps(plan: ShiftPlan, shifts, groups, n_params: int) -> int:
    """Analytic per-lane gate applications of the prefix-reuse execution for
    the requested groups: the data-register pass + the forward pass + the
    backward inverse walk down to the shallowest anchor + every variant's
    suffix replay (one gate for single-use parameters, the [first, last]
    span for multi-use ones)."""
    variants = _collect_variants(plan, shifts, groups, n_params)
    anchors = [k for k in variants if k >= 0]
    total = len(plan.data_ops) + len(plan.train_ops)
    if not anchors:
        return total
    total += len(plan.train_ops) - min(anchors)
    for k in anchors:
        for _, j, _ in variants[k]:
            total += plan.replay_depth(j)
    return total


@functools.lru_cache(maxsize=None)
def shift_cost_info(
    spec: CircuitSpec,
    four_term: bool = False,
    groups: tuple[int, ...] | None = None,
) -> dict:
    """Analytic per-lane cost of executing a shift bank implicitly (prefix
    reuse + suffix replay) vs materialized ((1+2P)x full-circuit rows), and
    the mode the ops layer selects.  This replaces the old binary
    plan-exists -> fused decision: a plan whose replay cost exceeds the
    materialized bank's (a parameter reused across most of the circuit)
    routes to materialization.  The coalescer's ``batch_cost_units`` and
    ``api.backend.CostModel`` charge from the same numbers, so placement
    and admission see the true suffix-replay cost."""
    from repro.core.shift_rule import shift_values

    n_shifts = 4 if four_term else 2
    n_groups = 1 + n_shifts * spec.n_theta
    if groups is None:
        groups = tuple(range(n_groups))
    materialized = len(spec.ops) * len(groups)
    plan = build_shift_plan(spec)
    if plan is None:
        return {
            "gate_apps_implicit": None,
            "gate_apps_materialized": materialized,
            "replay_depth_max": 0,
            "use_implicit": False,
        }
    shifts = tuple(float(s) for s in shift_values(four_term))
    implicit = plan_gate_apps(plan, shifts, groups, spec.n_theta)
    depth = max((plan.replay_depth(j) for j in range(spec.n_theta)), default=0)
    return {
        "gate_apps_implicit": implicit,
        "gate_apps_materialized": materialized,
        "replay_depth_max": depth,
        "use_implicit": implicit < materialized,
    }


def use_shift_plan(
    spec: CircuitSpec,
    four_term: bool = False,
    groups: tuple[int, ...] | None = None,
) -> bool:
    """True when the implicit prefix-reuse path analytically beats
    materializing the requested groups (requires a plan to exist)."""
    return shift_cost_info(spec, four_term, groups)["use_implicit"]


def shift_execution_info(
    spec: CircuitSpec,
    n_samples: int,
    *,
    four_term: bool = False,
    groups: tuple[int, ...] | None = None,
    tb: int = 4 * LANES,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> dict:
    """Static execution-mode report: which path a shift bank takes and what
    it costs.  ``mode`` is "materialize" (no product structure, or replay
    analytically dearer than materializing), "fused" (single-sweep
    prefix-reuse launch) or "spill" (VMEM-tiled prefix reuse; ``launches``
    counts the forward launch plus one per depth-tile segment of the
    double-buffered backward launch); the dispatcher's worker-VMEM model
    and the benchmarks both read this."""
    plan = build_shift_plan(spec)
    n_shifts = 4 if four_term else 2
    n_groups = 1 + n_shifts * spec.n_theta
    if groups is None:
        groups = tuple(range(n_groups))
    tb_eff = kernel_tb(n_samples, tb)
    cost = shift_cost_info(spec, four_term, tuple(groups))
    base = {
        "gate_apps_implicit": cost["gate_apps_implicit"],
        "gate_apps_materialized": cost["gate_apps_materialized"],
        "replay_depth_max": cost["replay_depth_max"],
        "vmem_budget": vmem_budget,
    }
    if plan is None or not cost["use_implicit"]:
        return {
            "mode": "materialize",
            "launches": 1,
            "n_tiles": 0,
            "vmem_bytes": _state_bytes(spec.n_qubits, tb_eff),
            **base,
        }
    from repro.core.shift_rule import shift_values

    variants = _collect_variants(plan, shift_values(four_term), groups, spec.n_theta)
    positions = sorted(k for k in variants if k >= 0)
    tiles = plan_depth_tiles(plan, positions, tb_eff, vmem_budget)
    if tiles is None:
        n_ckpt = len({plan.theta_positions[j][0] for k in positions
                      for (_, j, _) in variants[k]})
        return {
            "mode": "fused",
            "launches": 1,
            "n_tiles": 0,
            "vmem_bytes": checkpoint_vmem_bytes(plan, n_ckpt, tb_eff),
            **base,
        }
    # live checkpoints of the fullest tile; +1 state for the second
    # ping-pong boundary buffer of the double-buffered backward launch.
    # Tiling itself still budgets without the extra buffer (bit-identical
    # plan selection) — the 14 MB nominal budget already reserves the
    # double-buffering headroom below the ~16 MB physical VMEM.
    n_ckpt_max = max(
        len({plan.theta_positions[j][0] for k in positions if lo <= k < hi
             for (_, j, _) in variants[k]})
        for lo, hi in tiles
    )
    return {
        "mode": "spill",
        "launches": 1 + len(tiles),
        "n_tiles": len(tiles),
        "vmem_bytes": checkpoint_vmem_bytes(plan, n_ckpt_max, tb_eff)
        + _state_bytes(plan.m, tb_eff),
        "spill_buffer_bytes": _state_bytes(plan.m, tb_eff),
        "spilled_bytes": 2 * (len(tiles) + 1) * _state_bytes(plan.m, tb_eff),
        "overlap_ratio": round((len(tiles) - 1) / len(tiles), 4),
        **base,
    }


def _shift_forward_kernel(
    plan: ShiftPlan, tile_los, theta_ref, data_ref, f0_ref, d_ref, bnd_ref
):
    """Spill-mode forward launch: data-register pass, base fidelity, and the
    tile-boundary prefix states written to HBM (``bnd_ref`` rows are
    [re; im] stacks, one 2*dim block per tile)."""
    tb = theta_ref.shape[-1]
    dim = 2**plan.m
    theta_blk = theta_ref[...]
    data_blk = data_ref[...]
    d_re, d_im = _zero_tile(dim, tb)
    for op in plan.data_ops:
        d_re, d_im = _apply_one(op, d_re, d_im, plan.m, theta_blk, data_blk)
    d_ref[...] = jnp.concatenate([d_re, d_im], axis=0)

    los = {lo: t for t, lo in enumerate(tile_los)}
    t_re, t_im = _zero_tile(dim, tb)
    for k, op in enumerate(plan.train_ops):
        if k in los:
            t = los[k]
            bnd_ref[2 * t * dim : (2 * t + 1) * dim, :] = t_re
            bnd_ref[(2 * t + 1) * dim : (2 * t + 2) * dim, :] = t_im
        t_re, t_im = _apply_one(op, t_re, t_im, plan.m, theta_blk, data_blk)
    f0_ref[...] = _inner_fidelity((d_re, d_im), (t_re, t_im))[None, :]


def _shift_tile_kernel(
    plan: ShiftPlan,
    tile_plan,
    theta_ref,
    data_ref,
    chi_ref,
    bnd_hbm_ref,
    rows_ref,
    buf_a,
    buf_b,
    sems,
):
    """Double-buffered spill backward launch: EVERY depth tile in one call.

    ``tile_plan``: ((tile_index, lo, hi, rows_t), ...) deepest tile first,
    each rows_t a tuple of (group, param, shift, anchor) in descending
    anchor order; ``bnd_hbm_ref`` holds the forward launch's tile-boundary
    prefix states in HBM (memory space ANY, full array — sliced here by
    tile index and lane-grid position).  Two VMEM boundary buffers
    ping-pong: the async copy for the NEXT (shallower) tile's boundary is
    started before the current tile's compute, so the HBM fetch latency
    the old one-launch-per-tile path serialized now overlaps gate
    application.  chi is carried across tiles in registers (no HBM chi
    round-trip).  Per-lane op-application order is identical to the serial
    per-tile kernels — results are bit-identical."""
    tb = theta_ref.shape[-1]
    dim = 2**plan.m
    i = pl.program_id(0)
    theta_blk = theta_ref[...]
    data_blk = data_ref[...]
    bufs = (buf_a, buf_b)

    def fetch(slot, pos):
        t = tile_plan[pos][0]
        return pltpu.make_async_copy(
            bnd_hbm_ref.at[pl.ds(2 * t * dim, 2 * dim), pl.ds(i * tb, tb)],
            bufs[slot],
            sems.at[slot],
        )

    fetch(0, 0).start()  # warm-up: the deepest tile's boundary
    c_re, c_im = chi_ref[:dim, :], chi_ref[dim:, :]
    out_rows = []
    for pos, (t, lo, hi, rows_t) in enumerate(tile_plan):
        slot = pos % 2
        if pos + 1 < len(tile_plan):
            fetch(1 - slot, pos + 1).start()  # next boundary in flight
        fetch(slot, pos).wait()
        # re-derive this tile's checkpoints from its boundary prefix state
        firsts = {plan.theta_positions[j][0] for (_, j, _, _) in rows_t}
        last = max(firsts)
        re, im = bufs[slot][:dim, :], bufs[slot][dim:, :]
        checkpoints = {}
        for k in range(lo, last + 1):
            if k in firsts:
                checkpoints[k] = (re, im)
            if k < last:
                re, im = _apply_one(
                    plan.train_ops[k], re, im, plan.m, theta_blk, data_blk
                )
        # chi walk + per-variant suffix replay, same order as the single
        # sweep; chi at lo seeds the next (shallower) tile directly.
        rows = {}
        for k in range(hi - 1, lo - 1, -1):
            op = plan.train_ops[k]
            for g, j, s, anchor in rows_t:
                if anchor != k:
                    continue
                first = plan.theta_positions[j][0]
                v = _replay_variant(
                    plan, j, s, checkpoints[first], theta_blk, data_blk
                )
                rows[g] = _inner_fidelity((c_re, c_im), v)
            if k > lo or pos + 1 < len(tile_plan):
                c_re, c_im = _apply_one(
                    op, c_re, c_im, plan.m, theta_blk, data_blk, invert=True
                )
        out_rows.extend(rows[g] for g, _, _, _ in rows_t)
    rows_ref[...] = jnp.stack(out_rows, axis=0)


def _shift_fidelity_spilled(
    spec: CircuitSpec,
    plan: ShiftPlan,
    shifts,
    groups,
    tiles,
    theta_t,
    data_t,
    tb: int,
    interpret: bool,
) -> jnp.ndarray:
    """Orchestrate the spilled execution: one forward launch writes the
    tile-boundary prefix states to HBM, then ONE double-buffered backward
    launch sweeps every depth tile (``_shift_tile_kernel``), overlapping
    each tile's boundary fetch with the previous tile's compute.
    ``shift_execution_info``'s "launches" (1 + n_tiles) counts the forward
    launch plus the backward launch's per-tile segments — the unit the
    launch observer reports and the trend gate pins."""
    p, lanes = theta_t.shape
    d = data_t.shape[0]
    dim = 2**plan.m
    n_tiles = len(tiles)
    grid = (lanes // tb,)
    lane_spec = lambda rows: pl.BlockSpec((rows, tb), lambda i: (0, i))  # noqa: E731
    in_specs = [lane_spec(p), lane_spec(d)]

    variants = _collect_variants(plan, shifts, groups, spec.n_theta)
    fwd = pl.pallas_call(
        functools.partial(_shift_forward_kernel, plan, tuple(lo for lo, _ in tiles)),
        grid=grid,
        in_specs=in_specs,
        out_specs=[lane_spec(1), lane_spec(2 * dim), lane_spec(2 * n_tiles * dim)],
        out_shape=[
            jax.ShapeDtypeStruct((1, lanes), jnp.float32),
            jax.ShapeDtypeStruct((2 * dim, lanes), jnp.float32),
            jax.ShapeDtypeStruct((2 * n_tiles * dim, lanes), jnp.float32),
        ],
        interpret=interpret,
    )(theta_t, data_t)
    f0, d_state, boundaries = fwd

    rows_by_group = {}
    if 0 in groups:
        rows_by_group[0] = f0[0]
    for g, _, _ in variants.get(-1, ()):
        rows_by_group[g] = f0[0]

    tile_plan = []
    for t in range(n_tiles - 1, -1, -1):  # deepest tile first
        lo, hi = tiles[t]
        rows_t = tuple(
            (g, j, s, k)
            for k in range(hi - 1, lo - 1, -1)
            for (g, j, s) in variants.get(k, ())
        )
        tile_plan.append((t, lo, hi, rows_t))
    tile_plan = tuple(tile_plan)
    all_rows = tuple(r for (_, _, _, rows_t) in tile_plan for r in rows_t)

    rows_out = pl.pallas_call(
        functools.partial(_shift_tile_kernel, plan, tile_plan),
        grid=grid,
        in_specs=in_specs
        + [lane_spec(2 * dim), pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=lane_spec(len(all_rows)),
        out_shape=jax.ShapeDtypeStruct((len(all_rows), lanes), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2 * dim, tb), jnp.float32),
            pltpu.VMEM((2 * dim, tb), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(theta_t, data_t, d_state, boundaries)
    for idx, (g, _, _, _) in enumerate(all_rows):
        rows_by_group[g] = rows_out[idx]
    return jnp.stack([rows_by_group[g] for g in groups], axis=0)


def vqc_shift_fidelity(
    spec: CircuitSpec,
    theta: jnp.ndarray,
    data: jnp.ndarray,
    *,
    four_term: bool = False,
    groups: tuple[int, ...] | None = None,
    tb: int = 4 * LANES,
    interpret: bool | None = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> jnp.ndarray:
    """Prefix-reuse shift-bank fidelities. theta: (B,P), data: (B,D).

    Returns (G, B) where G = len(groups) (default: every group of the bank,
    1 + 2P or 1 + 4P rows) — row g is |<psi_d|psi_t>|^2 with the group's
    (param, shift) applied.  Flattening in group-major order reproduces the
    materialized bank's fidelity vector exactly (same layout).

    When the live checkpoint set exceeds ``vmem_budget`` (wide registers,
    m > 6 at production tile sizes) execution is automatically split into
    VMEM-sized depth tiles with boundary states spilled to HBM — same
    results, 1 + n_tiles launches instead of 1 (``shift_execution_info``
    reports the chosen mode).

    Raises ValueError when the spec doesn't match the SWAP-test product
    structure; call ``build_shift_plan`` first (or use ``kernels.ops``,
    which falls back to the materialized path).
    """
    plan = build_shift_plan(spec)
    if plan is None:
        raise ValueError(
            "circuit does not match the SWAP-test product "
            "structure; use the materialized-bank path"
        )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_shifts = 4 if four_term else 2
    n_groups = 1 + n_shifts * spec.n_theta
    if groups is None:
        groups = tuple(range(n_groups))
    if not groups or not all(0 <= g < n_groups for g in groups):
        raise ValueError(f"groups out of range for {n_groups}-group bank: {groups}")

    from repro.core.shift_rule import shift_values

    shifts = tuple(float(s) for s in shift_values(four_term))

    b = theta.shape[0]
    p, d = theta.shape[1], data.shape[1]
    tb = kernel_tb(b, tb)
    pad = (-b) % tb
    theta_t = jnp.pad(theta.astype(jnp.float32), ((0, pad), (0, 0))).T
    data_t = jnp.pad(data.astype(jnp.float32), ((0, pad), (0, 0))).T

    variants = _collect_variants(plan, shifts, groups, spec.n_theta)
    positions = sorted(k for k in variants if k >= 0)
    tiles = plan_depth_tiles(plan, positions, tb, vmem_budget)
    if tiles is not None:
        out = _shift_fidelity_spilled(
            spec, plan, shifts, groups, tiles, theta_t, data_t, tb, interpret
        )
        return out[:, :b]

    g = len(groups)
    kern = functools.partial(_shiftbank_kernel, plan, shifts, groups, spec.n_theta)
    out = pl.pallas_call(
        kern,
        grid=((b + pad) // tb,),
        in_specs=[
            pl.BlockSpec((p, tb), lambda i: (0, i)),
            pl.BlockSpec((d, tb), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((g, tb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((g, b + pad), jnp.float32),
        interpret=interpret,
    )(theta_t, data_t)
    return out[:, :b]


# ------------------------------------------------------- analytic counters
def shift_bank_stats(
    spec: CircuitSpec, n_samples: int, four_term: bool = False
) -> dict:
    """Analytic gate-application and angle-traffic counts, implicit vs
    materialized — the ratios the acceptance benchmark tracks."""
    p, d = spec.n_theta, spec.n_data
    n_groups = 1 + (4 if four_term else 2) * p
    g_full = len(spec.ops)
    mat_gates = n_groups * g_full * n_samples
    mat_angle_floats = n_groups * n_samples * (p + d)
    cost = shift_cost_info(spec, four_term)
    if not cost["use_implicit"]:  # fallback executes the same work
        impl_gates = mat_gates
        impl_angle_floats = mat_angle_floats
    else:
        impl_gates = cost["gate_apps_implicit"] * n_samples
        impl_angle_floats = n_samples * (p + d)
    return {
        "n_groups": n_groups,
        "gate_apps_materialized": mat_gates,
        "gate_apps_implicit": impl_gates,
        "gate_apps_ratio": round(mat_gates / impl_gates, 1),
        "angle_bytes_materialized": 4 * mat_angle_floats,
        "angle_bytes_implicit": 4 * impl_angle_floats,
        "angle_bytes_ratio": round(mat_angle_floats / impl_angle_floats, 1),
    }


def multibank_stats(
    spec: CircuitSpec,
    bank_sizes,
    four_term: bool = False,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> dict:
    """Analytic launch-count and lane accounting for a fused multi-bank
    shift execution of K same-spec banks vs K per-bank launches.

    ``bank_sizes``: per-bank sample counts B_k.  Per-bank execution costs
    one prefix-reuse launch per bank (times any spill tiling); the fused
    path packs every bank's LANES-padded lane segment into ONE launch and
    computes the union of the requested groups for all lanes.  Lane fill is
    identical by construction (per-bank segments pad independently in both
    paths); the fused win is the launch count — the metric the regression
    gate pins."""
    k = len(bank_sizes)
    occupied = sum(bank_sizes)
    padded = sum(-(-b // LANES) * LANES for b in bank_sizes)
    info = shift_execution_info(
        spec, max(bank_sizes), four_term=four_term, vmem_budget=vmem_budget
    )
    per_bank_launches = k * info["launches"]
    fused_info = shift_execution_info(
        spec, padded, four_term=four_term, vmem_budget=vmem_budget
    )
    fused_launches = fused_info["launches"]
    return {
        "n_banks": k,
        "bank_sizes": list(bank_sizes),
        "mode": fused_info["mode"],
        "launches_per_bank_path": per_bank_launches,
        "launches_fused": fused_launches,
        "launch_ratio": round(per_bank_launches / fused_launches, 2),
        "occupied_lanes": occupied,
        "padded_lanes": padded,
        "lane_fill": round(occupied / padded, 4),
    }
