"""MusicGen-large [arXiv:2306.05284] — decoder-only over EnCodec RVQ tokens.

4 codebooks @ 2048 entries; embeddings summed per frame, one output head per
codebook (we model the parallel/flattened codebook pattern; the EnCodec
codec itself is a stubbed frontend per the brief)."""
from repro.configs.base import ModelConfig, register

MUSICGEN_LARGE = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    kv_heads=32,           # full MHA
    d_ff=8192,
    vocab=2048,            # per-codebook
    n_codebooks=4,
    activation="gelu",
    optimizer="adamw",
    microbatch=16,
    source="arXiv:2306.05284 (MusicGen)",
))
