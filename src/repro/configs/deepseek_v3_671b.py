"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA attention, 1 shared + 256
routed experts (top-8), multi-token prediction head.

The assigned pool spec gives d_ff=2048 (the routed-expert width) and MoE on
all layers; DeepSeek-V3's first-3-dense-layer detail is not part of the
assigned config and is omitted (noted in DESIGN.md)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register

DEEPSEEK_V3_671B = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    kv_heads=128,          # MLA: latent cache is shared; heads decompress
    d_ff=0,
    vocab=129_280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, every=1),
    mtp_depth=1,
    activation="silu_gated",
    optimizer="momentum",
    microbatch=8,
    source="arXiv:2412.19437 (DeepSeek-V3)",
))
