"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-135M family] — small llama-arch."""
from repro.configs.base import ModelConfig, register

SMOLLM_360M = register(ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    kv_heads=5,            # GQA kv=5
    head_dim=64,
    d_ff=2560,
    vocab=49_152,
    activation="silu_gated",
    optimizer="adamw",
    microbatch=32,
    source="hf:HuggingFaceTB/SmolLM-360M",
))
