"""Model / run configuration system for the architecture zoo.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``; the registry maps ``--arch <id>`` to it.  Configs are
plain frozen dataclasses — no framework magic — and each one provides a
``reduced()`` variant (<=2 layers, d_model <= 512, <= 4 experts) for CPU
smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0       # DeepSeek-style always-on shared expert(s)
    every: int = 1                  # MoE FFN every Nth layer (Jamba: 2)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    dropless: bool = False          # capacity = T*K (exact; tests/decode-math)
    pad_to: int = 0                 # pad expert bank to this count (0 = off):
                                    # dead experts are never routed to; lets
                                    # E shard over the mesh when n_experts
                                    # doesn't divide the model axis (§Perf)
    dispatch: str = "flat"          # "flat": one (T*K, D) scatter stream;
                                    # "per_k": K scatters of (T, D) — avoids
                                    # materializing the K-fold token payload
                                    # (its f32 backward gather dominated the
                                    # deepseek collective term, §Perf)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba / xLSTM cell dims."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                 # d_inner = expand * d_model
    chunk: int = 256                # chunked-scan block length
    n_heads: int = 4                # xLSTM heads


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # layer pattern: list of block kinds, tiled over n_layers.
    # kinds: "attn" | "mamba" | "mlstm" | "slstm"
    pattern: tuple[str, ...] = ("attn",)
    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 = full attention
    mla: Optional[MLAConfig] = None
    # "naive" materializes (B,H,S,S) scores; "chunked" is the flash-style
    # online-softmax over KV blocks (beyond-paper §Perf optimization)
    attention_impl: str = "naive"
    attention_chunk: int = 1024
    # ffn
    activation: str = "silu_gated"  # silu_gated | gelu | relu2 (squared ReLU)
    moe: Optional[MoEConfig] = None
    # ssm
    ssm: Optional[SSMConfig] = None
    # multimodal stub frontends
    n_prefix_embeds: int = 0        # VLM: patch embeddings prepended
    prefix_embed_dim: int = 0       # raw frontend dim (projector maps to d_model)
    n_codebooks: int = 0            # audio: EnCodec codebook count
    # multi-token prediction (DeepSeek-V3)
    mtp_depth: int = 0
    # norms / misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # training
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    microbatch: int = 8             # grad-accum microbatch (global batch rows)
    remat: bool = True
    dtype: str = "bfloat16"
    # citation for the assigned-architecture pool
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern period {len(self.pattern)}")
        return self.n_layers // len(self.pattern)

    def is_moe_layer(self, idx_in_period: int, period_idx: int = 0) -> bool:
        if self.moe is None:
            return False
        global_idx = period_idx * len(self.pattern) + idx_in_period
        return (global_idx % self.moe.every) == (self.moe.every - 1)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 periods, d_model<=512, <=4 experts."""
        pat = self.pattern
        n_layers = len(pat) * min(2, self.n_periods)
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        kv = max(1, min(self.kv_heads, n_heads, 2))
        hd = max(16, d_model // n_heads)
        moe = None
        if self.moe:
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(2, self.moe.top_k),
                d_ff_expert=min(self.moe.d_ff_expert, 128))
        mla = None
        if self.mla:
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                            qk_nope_head_dim=hd, qk_rope_head_dim=hd // 2,
                            v_head_dim=hd)
        ssm = None
        if self.ssm:
            ssm = dataclasses.replace(self.ssm, d_state=8, chunk=32,
                                      n_heads=min(2, self.ssm.n_heads))
        return self.with_(
            n_layers=n_layers, d_model=d_model, n_heads=n_heads, kv_heads=kv,
            head_dim=hd, d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512), moe=moe, mla=mla, ssm=ssm,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_prefix_embeds=min(self.n_prefix_embeds, 8) or self.n_prefix_embeds,
            prefix_embed_dim=min(self.prefix_embed_dim, 64) if self.prefix_embed_dim else 0,
            microbatch=2, dtype="float32")


# --------------------------------------------------------------- registry
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import all config modules lazily
        from repro import configs as _c  # noqa
        _c.load_all()
    return _REGISTRY[name]


def all_names() -> list[str]:
    from repro import configs as _c
    _c.load_all()
    return sorted(_REGISTRY)


# ----------------------------------------------------------- input shapes
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
