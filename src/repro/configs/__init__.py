"""Config registry — one module per assigned architecture.

``repro.configs.base.get(name)`` lazily imports everything here.
"""
from __future__ import annotations

import importlib

_MODULES = (
    "nemotron_4_340b",
    "phi_3_vision_4_2b",
    "granite_34b",
    "smollm_360m",
    "qwen3_4b",
    "granite_moe_3b_a800m",
    "musicgen_large",
    "xlstm_125m",
    "jamba_v0_1_52b",
    "deepseek_v3_671b",
    "quclassi_paper",
)

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
