"""xLSTM-125M [arXiv:2405.04517] — alternating mLSTM (matrix-memory,
chunk-parallel) and sLSTM (scalar-memory, sequential) blocks; no FFN
(d_ff=0): the cells carry their own projections."""
from repro.configs.base import ModelConfig, SSMConfig, register

XLSTM_125M = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    kv_heads=4,
    d_ff=0,
    vocab=50_304,
    pattern=("mlstm", "slstm"),
    ssm=SSMConfig(n_heads=4, chunk=256),
    activation="gelu",
    optimizer="adamw",
    microbatch=32,
    source="arXiv:2405.04517 (xLSTM)",
))
