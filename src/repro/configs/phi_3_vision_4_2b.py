"""Phi-3-vision-128k-instruct [hf:microsoft/Phi-3-vision-128k-instruct] —
phi3-mini language backbone + CLIP ViT-L/14 frontend (stubbed: precomputed
patch embeddings, 576 patches @ 1024-dim, projected to d_model)."""
from repro.configs.base import ModelConfig, register

PHI_3_VISION = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    kv_heads=32,           # spec: GQA kv=32 (full MHA)
    d_ff=8192,
    vocab=32_064,
    activation="silu_gated",
    n_prefix_embeds=576,   # CLIP ViT-L/14 @ 336px -> 24x24 patches
    prefix_embed_dim=1024,
    optimizer="adamw",
    microbatch=8,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
))
