"""Jamba-v0.1 52B [arXiv:2403.19887] — hybrid Mamba+attention (1:7 ratio,
attention at period offset 4), MoE 16e top-2 on every second layer."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register

JAMBA_V01_52B = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=65_536,
    # 8-block period: attn at index 4, Mamba elsewhere (1:7 interleave)
    pattern=("mamba", "mamba", "mamba", "mamba",
             "attn", "mamba", "mamba", "mamba"),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, every=2),
    activation="silu_gated",
    optimizer="momentum",
    microbatch=8,
    source="arXiv:2403.19887 (Jamba)",
))
