"""Nemotron-4-340B [arXiv:2402.16819] — dense GQA with squared-ReLU MLP."""
from repro.configs.base import ModelConfig, register

NEMOTRON_4_340B = register(ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    kv_heads=8,            # GQA kv=8
    head_dim=192,
    d_ff=73728,
    vocab=256_000,
    activation="relu2",    # squared ReLU, non-gated (4x d_model FFN)
    rope_theta=10_000.0,
    optimizer="momentum",  # adam states would not fit 16 GB/chip at 340B/256
    microbatch=16,
    source="arXiv:2402.16819 (Nemotron-4 340B)",
))
