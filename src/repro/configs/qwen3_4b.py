"""Qwen3-4B [hf:Qwen/Qwen3-8B family] — GQA kv=8 with per-head QK RMSNorm."""
from repro.configs.base import ModelConfig, register

QWEN3_4B = register(ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    kv_heads=8,
    head_dim=128,          # Qwen3 decouples head_dim from d_model/n_heads
    d_ff=9728,
    vocab=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    activation="silu_gated",
    optimizer="adamw",
    microbatch=16,
    source="hf:Qwen/Qwen3-4B",
))
