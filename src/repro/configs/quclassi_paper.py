"""The paper's own workload configs: QuClassi quantum-classical CNN at the
evaluated qubit/layer settings (§IV-A) — registered alongside the classical
zoo so the launcher can `--arch quclassi-5q-1l` etc."""
from repro.core.quclassi import QuClassiConfig
from repro.core.segmentation import SegmentationConfig

QUCLASSI_CONFIGS: dict[str, QuClassiConfig] = {}

for qc in (5, 7):
    for nl in (1, 2, 3):
        QUCLASSI_CONFIGS[f"quclassi-{qc}q-{nl}l"] = QuClassiConfig(
            qc=qc, n_layers=nl,
            seg=SegmentationConfig(filter_width=4, stride=2, n_filters=4),
            image_size=(8, 8),
        )


def get_quclassi(name: str) -> QuClassiConfig:
    return QUCLASSI_CONFIGS[name]
