"""Granite-34B-Code [arXiv:2405.04324] — llama-arch, MQA (kv=1), 88 layers."""
from repro.configs.base import ModelConfig, register

GRANITE_34B = register(ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    kv_heads=1,            # MQA
    d_ff=24576,
    vocab=49_152,
    activation="silu_gated",
    optimizer="momentum",
    microbatch=16,
    source="arXiv:2405.04324 (Granite Code Models)",
))
