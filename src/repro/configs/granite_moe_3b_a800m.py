"""Granite-3.0-3B-A800M MoE [hf:ibm-granite/granite-3.0-1b-a400m-base family].

Assigned spec: 32L d_model=1536 24H (kv=8) expert d_ff=512 vocab=49155,
"MoE 40e top-8".  NOTE: the pool entry's gloss says "32 experts top-8" but
the explicit config field says 40e — we follow the explicit field (40).
"""
from repro.configs.base import ModelConfig, MoEConfig, register

GRANITE_MOE_3B = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    kv_heads=8,
    d_ff=0,                          # every FFN is MoE
    vocab=49_155,
    activation="silu_gated",
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512, every=1),
    optimizer="adamw",
    microbatch=16,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
))
