"""Pytree checkpointing to .npz (offline container — no orbax).

Flattens a pytree of arrays with '/'-joined key paths; restores into the
same structure.  Suitable for both the quantum model params and the
reduced-config transformer smoke runs.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save(path: str, tree, metadata: dict | None = None) -> None:
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, __meta__=json.dumps(metadata or {}), **flat)


def load(path: str, like=None):
    """Load a checkpoint.  With ``like`` (a template pytree), restores the
    exact structure; otherwise returns the flat {path: array} dict."""
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files if k != "__meta__"}
        meta = json.loads(str(z["__meta__"])) if "__meta__" in z.files else {}
    if like is None:
        return flat, meta

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    restored = []
    for path_keys, leaf in leaves_with_paths:
        key = "/".join(_key_str(k) for k in path_keys)
        arr = flat[key]
        restored.append(jnp.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, restored), meta


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
