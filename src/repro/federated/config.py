"""Typed configuration for the federated DQL subsystem.

``FederatedConfig`` is the one knob surface for the round loop: how many
rounds, when a round closes (quorum fraction + deadline, or a full sync
barrier), what happens to stragglers (FedAsync-style staleness fold-in vs
drop), and the privacy options (pairwise-mask secure aggregation, Gaussian
DP noise).  Validation happens at construction, mirroring the other
``repro.api`` config dataclasses.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    """Round-loop knobs for ``FederatedCoordinator`` / the virtual-clock
    driver.

    ``n_rounds``: aggregation rounds to run.
    ``quorum``: fraction of a round's launched participants whose updates
    must arrive before the round may close early (the round still closes at
    its deadline with whoever arrived).  ``quorum=1.0`` waits for everyone
    until the deadline.
    ``barrier``: sync-barrier mode — the round closes only when EVERY
    launched participant has reported, ignoring quorum and deadline (the
    baseline the quorum path is benchmarked against).
    ``round_deadline_s``: explicit per-round deadline; ``None`` derives one
    as ``deadline_factor x`` the slowest participant's EWMA service-time
    estimate (``ServiceModel``, bootstrapped from the analytic per-circuit
    calibration over the currently-healthy worker count).
    ``late_policy``: ``"fold"`` folds a late update into the next round's
    aggregate with weight ``staleness_alpha ** rounds_late`` (FedAsync-style
    discount), dropping it once ``rounds_late > max_staleness``; ``"drop"``
    discards every late update.
    ``weighted``: weighted FedAvg — each tenant's update is weighted by its
    configured tenant weight (shard size by default in the session layer)
    instead of uniformly.
    ``secure_aggregation``: pairwise seeded masks that cancel in the sum, so
    the aggregator only ever observes the masked total (``repro.federated
    .secure``).  ``dp_noise_multiplier``: Gaussian noise scale (in units of
    ``dp_clip``) added to the aggregate; > 0 requires ``dp_clip``, the
    per-update L2 clipping bound.  ``dp_delta``: the delta the
    epsilon-accounting stub reports epsilon at.
    ``seed``: master seed for masks/noise (local-update seeds belong to the
    session layer).
    ``max_sim_seconds``: virtual-clock budget for the whole experiment —
    the driver stops a run whose rounds cannot make progress (e.g. every
    tenant wedged on crashed workers) instead of spinning heartbeats
    forever.
    """

    n_rounds: int = 5
    quorum: float = 0.75
    barrier: bool = False
    round_deadline_s: float | None = None
    deadline_factor: float = 3.0
    late_policy: str = "fold"
    staleness_alpha: float = 0.5
    max_staleness: int = 2
    weighted: bool = False
    secure_aggregation: bool = False
    dp_noise_multiplier: float = 0.0
    dp_clip: float | None = None
    dp_delta: float = 1e-5
    seed: int = 0
    max_sim_seconds: float = 1e6

    def __post_init__(self):
        if self.n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {self.n_rounds}")
        if not 0.0 < self.quorum <= 1.0:
            raise ValueError(f"quorum must be in (0, 1], got {self.quorum}")
        if self.round_deadline_s is not None and self.round_deadline_s <= 0:
            raise ValueError(
                f"round_deadline_s must be > 0, got {self.round_deadline_s}"
            )
        if self.deadline_factor <= 0:
            raise ValueError(
                f"deadline_factor must be > 0, got {self.deadline_factor}"
            )
        if self.late_policy not in ("fold", "drop"):
            raise ValueError(
                f"late_policy must be 'fold' or 'drop', got {self.late_policy!r}"
            )
        if not 0.0 < self.staleness_alpha <= 1.0:
            raise ValueError(
                f"staleness_alpha must be in (0, 1], got {self.staleness_alpha}"
            )
        if self.max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {self.max_staleness}"
            )
        if self.dp_noise_multiplier < 0:
            raise ValueError(
                f"dp_noise_multiplier must be >= 0, got {self.dp_noise_multiplier}"
            )
        if self.dp_noise_multiplier > 0 and self.dp_clip is None:
            raise ValueError("dp_noise_multiplier > 0 requires dp_clip")
        if self.dp_clip is not None and self.dp_clip <= 0:
            raise ValueError(f"dp_clip must be > 0, got {self.dp_clip}")
        if not 0.0 < self.dp_delta < 1.0:
            raise ValueError(f"dp_delta must be in (0, 1), got {self.dp_delta}")
        if self.max_sim_seconds <= 0:
            raise ValueError(
                f"max_sim_seconds must be > 0, got {self.max_sim_seconds}"
            )


__all__ = ["FederatedConfig"]
