"""repro.federated — round-based federated DQL over the multi-tenant stack.

The paper's loop (distributed quantum workers execute subtasks, results
loop back to the classical side for the next iteration) generalized to
federated learning: per-tenant local training on private shards, gateway-
side FedAvg aggregation, rounds closing on quorum + deadline instead of a
sync barrier, FedAsync-style staleness fold-in for stragglers, optional
pairwise-mask secure aggregation and Gaussian DP noise.

Layering:
  * ``config``  — ``FederatedConfig``: the typed knob surface.
  * ``secure``  — canceling pairwise masks, DP noise, epsilon stub.
  * ``rounds``  — ``FederatedCoordinator``: the clock-agnostic round state
    machine, ``RoundRecord`` / ``FederatedReport``.
  * ``driver``  — the virtual-clock driver over ``SystemSimulation``
    (composes with fault schedules and arrival storms).
  * ``session`` — ``FederatedSession`` + QuClassi local-training helpers;
    the ``QuantumCluster.federated_session`` surface.
"""
from repro.federated.config import FederatedConfig
from repro.federated.driver import FederatedDriver, TenantSpec, run_federated
from repro.federated.rounds import (
    FederatedCoordinator,
    FederatedReport,
    RoundRecord,
    fedavg,
)
from repro.federated.secure import (
    PrivacyAccountant,
    gaussian_noise,
    pairwise_masks,
)
from repro.federated.session import (
    FederatedSession,
    make_quclassi_eval_fn,
    make_quclassi_update_fn,
    shard_dataset,
)

__all__ = [
    "FederatedConfig",
    "FederatedCoordinator",
    "FederatedDriver",
    "FederatedReport",
    "FederatedSession",
    "PrivacyAccountant",
    "RoundRecord",
    "TenantSpec",
    "fedavg",
    "gaussian_noise",
    "make_quclassi_eval_fn",
    "make_quclassi_update_fn",
    "pairwise_masks",
    "run_federated",
    "shard_dataset",
]
