"""Federated aggregation rounds: the timing-agnostic coordinator core.

``FederatedCoordinator`` owns the global parameter tree and the round state
machine; it never looks at a clock of its own — every transition takes a
caller-supplied ``now`` (virtual seconds under the simulation driver,
``perf_counter`` seconds in a live deployment), which is what makes the
whole loop bit-deterministic on the virtual clock.

Round protocol (driver's calls in order):

    begin_round(r, now, participants)   # opens the round, traces round_start
    status = offer(tenant, update, now) # per arriving update:
                                        #   participated / late_folded /
                                        #   late_dropped / nan_rejected
    quorum_reached() / all_arrived()    # close-condition queries
    record = close_round(now)           # FedAvg (+ folds, masks, DP noise),
                                        #   applies the aggregate, traces
                                        #   round_aggregated

Aggregation is weighted FedAvg over parameter-DELTA trees (local params
minus the round's starting global params): on-time updates carry their
tenant weight, folded late updates from earlier rounds carry
``weight * staleness_alpha ** rounds_late``.  All arithmetic is numpy
float64, so secure-aggregation mask cancellation stays ~1e-12.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

from repro.federated.config import FederatedConfig
from repro.federated.secure import (
    PrivacyAccountant,
    clip_update,
    gaussian_noise,
    pairwise_masks,
)

#: update trees are dicts of arrays (the ``quclassi.init_params`` layout);
#: flat float64 vectors are the aggregation/masking currency.
ParamTree = dict


def tree_flatten(tree: ParamTree) -> np.ndarray:
    """Concatenate a param tree's leaves (sorted by key) into float64."""
    return np.concatenate(
        [np.asarray(tree[k], dtype=np.float64).ravel() for k in sorted(tree)]
    )


def tree_unflatten(vec: np.ndarray, like: ParamTree) -> ParamTree:
    """Inverse of ``tree_flatten`` against a template tree's shapes."""
    out, i = {}, 0
    for k in sorted(like):
        a = np.asarray(like[k])
        n = a.size
        out[k] = vec[i:i + n].reshape(a.shape)
        i += n
    assert i == vec.size, (i, vec.size)
    return out


@dataclasses.dataclass
class _Fold:
    """A late update carried into a future round's aggregate."""

    tenant: str
    round_idx: int  # the round it trained against
    vec: np.ndarray
    weight: float


@dataclasses.dataclass
class RoundRecord:
    """One closed aggregation round, for ``FederatedReport``."""

    round_idx: int
    started_at: float
    closed_at: float
    deadline: Optional[float]
    participants: list[str]
    on_time: list[str]
    folded: list[str]  # late updates from EARLIER rounds folded in here
    nan_rejected: list[str]
    quorum_wait_s: float
    update_norm: float
    mean_update_norm: float
    weight_total: float

    @property
    def duration_s(self) -> float:
        return self.closed_at - self.started_at

    def to_dict(self) -> dict:
        return {
            "round": self.round_idx,
            "started_at": round(self.started_at, 9),
            "closed_at": round(self.closed_at, 9),
            "deadline": None if self.deadline is None else round(self.deadline, 9),
            "participants": list(self.participants),
            "on_time": list(self.on_time),
            "folded": list(self.folded),
            "nan_rejected": list(self.nan_rejected),
            "quorum_wait_s": round(self.quorum_wait_s, 9),
            "update_norm": round(self.update_norm, 9),
            "mean_update_norm": round(self.mean_update_norm, 9),
        }


@dataclasses.dataclass
class FederatedReport:
    """What a federated run hands back: the final global parameters, the
    per-round records, convergence telemetry, and the privacy ledger."""

    config: FederatedConfig
    params: ParamTree
    rounds: list[RoundRecord]
    #: resolution counts per tenant: participated / late / dropped
    participation: dict[str, dict[str, int]]
    #: accuracy after each round on a held-out set (session layer fills it
    #: in when an eval_fn is configured; empty otherwise).
    accuracy_by_round: list[float] = dataclasses.field(default_factory=list)
    privacy: Optional[dict] = None
    #: the underlying SimulationReport when the run was driven on the
    #: virtual clock (None for pure in-process runs).
    simulation: object | None = None

    @property
    def total_seconds(self) -> float:
        if not self.rounds:
            return 0.0
        return self.rounds[-1].closed_at - self.rounds[0].started_at

    @property
    def rounds_per_second(self) -> float:
        return len(self.rounds) / max(self.total_seconds, 1e-9)

    @property
    def quorum_wait_share(self) -> float:
        """Share of total round time spent holding the round open after the
        first on-time update had already arrived — the straggler tax the
        quorum + deadline policy exists to bound."""
        total = sum(r.duration_s for r in self.rounds)
        wait = sum(r.quorum_wait_s for r in self.rounds)
        return wait / max(total, 1e-9)

    def summary(self) -> dict:
        out = {
            "rounds": len(self.rounds),
            "total_seconds": round(self.total_seconds, 6),
            "rounds_per_second": round(self.rounds_per_second, 6),
            "quorum_wait_share": round(self.quorum_wait_share, 6),
            "participation": {
                t: dict(c) for t, c in sorted(self.participation.items())
            },
            "round_records": [r.to_dict() for r in self.rounds],
        }
        if self.accuracy_by_round:
            out["accuracy_by_round"] = [
                round(a, 6) for a in self.accuracy_by_round
            ]
        if self.privacy is not None:
            out["privacy"] = self.privacy
        return out


class FederatedCoordinator:
    """Gateway-side aggregation-round state machine (see module docstring).

    ``weights``: per-tenant FedAvg weight (only used when
    ``config.weighted``; defaults to 1.0).  ``telemetry`` /
    ``trace``: optional ``repro.serve.metrics.Telemetry`` and
    ``repro.obs.TraceRecorder`` hooks — participation counters and
    ``FEDERATED_STAGES`` round events flow through them when given.
    """

    def __init__(
        self,
        config: FederatedConfig,
        params: ParamTree,
        *,
        weights: Optional[dict[str, float]] = None,
        telemetry=None,
        trace=None,
    ):
        self.config = config
        self.params = {
            k: np.asarray(v, dtype=np.float64) for k, v in params.items()
        }
        self.weights = dict(weights or {})
        self.telemetry = telemetry
        self.trace = trace if trace is not None else getattr(
            telemetry, "trace", None
        )
        self.accountant = PrivacyAccountant()
        self.records: list[RoundRecord] = []
        self.participation: dict[str, dict[str, int]] = {}
        # ---- open-round state
        self.round_idx: int = -1
        self.open = False
        self._started_at = 0.0
        self._deadline: Optional[float] = None
        self._participants: list[str] = []
        self._arrived: dict[str, np.ndarray] = {}  # on-time, in arrival order
        self._first_arrival: Optional[float] = None
        self._nan_rejected: list[str] = []
        self._folds: list[_Fold] = []  # late updates awaiting the next close

    # -------------------------------------------------------------- helpers
    def _weight(self, tenant: str) -> float:
        return self.weights.get(tenant, 1.0) if self.config.weighted else 1.0

    def _count(self, tenant: str, status: str) -> None:
        c = self.participation.setdefault(
            tenant, {"participated": 0, "late": 0, "dropped": 0}
        )
        c[status] += 1
        if self.telemetry is not None:
            self.telemetry.on_federated_update(tenant, status)

    def _trace(self, stage: str, now: float, tenant=None, args=None) -> None:
        if self.trace is not None:
            self.trace.round_event(
                self.round_idx, stage, now, tenant=tenant, args=args
            )

    @property
    def quorum_needed(self) -> int:
        if self.config.barrier:
            return len(self._participants)
        return max(
            1, math.ceil(self.config.quorum * len(self._participants))
        )

    def quorum_reached(self) -> bool:
        return len(self._arrived) >= self.quorum_needed

    def all_arrived(self) -> bool:
        return len(self._arrived) >= len(self._participants)

    # ------------------------------------------------------------ round API
    def begin_round(
        self,
        round_idx: int,
        now: float,
        participants: list[str],
        *,
        deadline: Optional[float] = None,
    ) -> None:
        if self.open:
            raise RuntimeError(f"round {self.round_idx} still open")
        if not participants:
            raise ValueError("a round needs at least one participant")
        self.round_idx = round_idx
        self.open = True
        self._started_at = now
        self._deadline = deadline
        self._participants = list(participants)
        self._arrived = {}
        self._first_arrival = None
        self._nan_rejected = []
        self._trace(
            "round_start",
            now,
            args={
                "participants": list(participants),
                "deadline": deadline,
                "quorum_needed": self.quorum_needed,
            },
        )

    def offer(self, tenant: str, update: ParamTree, now: float) -> str:
        """One tenant's update arrives (on time or late); returns its
        resolution: ``participated`` / ``late_folded`` / ``late_dropped`` /
        ``nan_rejected``.  ``update`` is a parameter-DELTA tree against the
        round's starting global params."""
        vec = tree_flatten(update)
        on_time = (
            self.open
            and tenant in self._participants
            and tenant not in self._arrived
        )
        if on_time and not np.isfinite(vec).all():
            # NaN/Inf guard: a diverged local update never reaches the
            # aggregate (and never poisons a fold).
            self._nan_rejected.append(tenant)
            self._count(tenant, "dropped")
            self._trace("update_received", now, tenant=tenant,
                        args={"nan": True})
            return "nan_rejected"
        if on_time:
            self._arrived[tenant] = vec
            if self._first_arrival is None:
                self._first_arrival = now
            self._count(tenant, "participated")
            self._trace(
                "update_received",
                now,
                tenant=tenant,
                args={"norm": round(float(np.linalg.norm(vec)), 9)},
            )
            return "participated"
        # not on time: the tenant's round already closed (or it was never a
        # participant of the open one) — same resolution as any straggler.
        return self.offer_late(tenant, update, now, self.round_idx)

    def offer_late(self, tenant: str, update: ParamTree, now: float,
                   trained_round: int) -> str:
        """A straggler's update from ``trained_round`` arriving after that
        round closed (possibly several closes ago).  Folds it into the next
        aggregate with the staleness discount, or drops it."""
        vec = tree_flatten(update)
        if not np.isfinite(vec).all():
            self._count(tenant, "dropped")
            self._trace("update_late", now, tenant=tenant, args={"nan": True})
            return "nan_rejected"
        # next close is round self.round_idx when open, else round_idx + 1
        next_close = self.round_idx if self.open else self.round_idx + 1
        rounds_late = max(1, next_close - trained_round)
        if (
            self.config.late_policy == "drop"
            or rounds_late > self.config.max_staleness
        ):
            self._count(tenant, "dropped")
            self._trace("update_late", now, tenant=tenant,
                        args={"resolution": "dropped",
                              "rounds_late": rounds_late})
            return "late_dropped"
        w = self._weight(tenant) * (
            self.config.staleness_alpha ** rounds_late
        )
        self._folds.append(_Fold(tenant, trained_round, vec, w))
        self._count(tenant, "late")
        self._trace(
            "update_late",
            now,
            tenant=tenant,
            args={
                "resolution": "folded",
                "rounds_late": rounds_late,
                "weight": round(w, 9),
            },
        )
        return "late_folded"

    def resolve_missing(self, tenant: str) -> None:
        """A straggler whose update never arrived at all (crashed tenant,
        end of experiment): counts as dropped in the participation ledger."""
        self._count(tenant, "dropped")

    def close_round(self, now: float) -> RoundRecord:
        """Aggregate and apply: weighted FedAvg over the on-time updates
        plus any pending staleness-discounted folds, optionally through the
        pairwise-mask secure path and with Gaussian DP noise."""
        if not self.open:
            raise RuntimeError("no open round to close")
        cfg = self.config
        dim = tree_flatten(self.params).size
        entries: list[tuple[str, np.ndarray, float]] = []
        for tenant, vec in self._arrived.items():
            entries.append(
                (tenant, clip_update(vec, cfg.dp_clip), self._weight(tenant))
            )
        folds, self._folds = self._folds, []
        for f in folds:
            entries.append((f.tenant, clip_update(f.vec, cfg.dp_clip), f.weight))

        weight_total = sum(w for _, _, w in entries)
        if entries:
            if cfg.secure_aggregation:
                # the aggregator only ever sums MASKED weighted updates; the
                # pairwise masks cancel in the total (secure.pairwise_masks).
                names = [f"{t}#{i}" for i, (t, _, _) in enumerate(entries)]
                masks = pairwise_masks(cfg.seed, self.round_idx, names, dim)
                total = np.zeros(dim, dtype=np.float64)
                for name, (_, vec, w) in zip(names, entries):
                    total += vec * w + masks[name]
            else:
                total = np.zeros(dim, dtype=np.float64)
                for _, vec, w in entries:
                    total += vec * w
            agg = total / weight_total
            if cfg.dp_noise_multiplier > 0:
                scale = cfg.dp_noise_multiplier * cfg.dp_clip / len(entries)
                agg = agg + gaussian_noise(cfg.seed, self.round_idx, dim, scale)
                self.accountant.spend(cfg.dp_noise_multiplier)
            flat = tree_flatten(self.params) + agg
            self.params = tree_unflatten(flat, self.params)
            update_norm = float(np.linalg.norm(agg))
            mean_norm = float(
                np.mean([np.linalg.norm(v) for _, v, _ in entries])
            )
        else:
            # nobody made it: the round closes empty and params stand still
            update_norm = 0.0
            mean_norm = 0.0

        wait = (
            now - self._first_arrival
            if self._first_arrival is not None
            else now - self._started_at
        )
        rec = RoundRecord(
            round_idx=self.round_idx,
            started_at=self._started_at,
            closed_at=now,
            deadline=self._deadline,
            participants=list(self._participants),
            on_time=list(self._arrived),
            folded=[f.tenant for f in folds],
            nan_rejected=list(self._nan_rejected),
            quorum_wait_s=max(wait, 0.0),
            update_norm=update_norm,
            mean_update_norm=mean_norm,
            weight_total=weight_total,
        )
        self.records.append(rec)
        self.open = False
        self._trace(
            "round_aggregated",
            now,
            args={
                "on_time": len(rec.on_time),
                "folded": len(rec.folded),
                "update_norm": round(update_norm, 9),
            },
        )
        if self.telemetry is not None:
            self.telemetry.on_round_aggregated()
        return rec

    # -------------------------------------------------------------- report
    def report(
        self,
        *,
        accuracy_by_round: Optional[list[float]] = None,
        simulation=None,
    ) -> FederatedReport:
        privacy = None
        if self.accountant.rounds:
            privacy = self.accountant.summary(self.config.dp_delta)
        return FederatedReport(
            config=self.config,
            params=dict(self.params),
            rounds=list(self.records),
            participation={
                t: dict(c) for t, c in self.participation.items()
            },
            accuracy_by_round=list(accuracy_by_round or []),
            privacy=privacy,
            simulation=simulation,
        )


def fedavg(
    updates: dict[str, ParamTree],
    weights: Optional[dict[str, float]] = None,
) -> ParamTree:
    """One-shot (weighted) FedAvg over delta trees — the stateless core the
    coordinator applies per round, exposed for direct use and tests."""
    if not updates:
        raise ValueError("fedavg needs at least one update")
    names = sorted(updates)
    w = np.array(
        [1.0 if weights is None else weights.get(n, 1.0) for n in names],
        dtype=np.float64,
    )
    vecs = np.stack([tree_flatten(updates[n]) for n in names])
    agg = (vecs * w[:, None]).sum(axis=0) / w.sum()
    return tree_unflatten(agg, updates[names[0]])


UpdateFn = Callable[[str, int, ParamTree], ParamTree]

__all__ = [
    "FederatedCoordinator",
    "FederatedReport",
    "RoundRecord",
    "UpdateFn",
    "fedavg",
    "tree_flatten",
    "tree_unflatten",
]
