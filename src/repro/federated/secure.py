"""Secure aggregation + differential-privacy noise for federated rounds.

Two independent mechanisms, both optional via ``FederatedConfig``:

*Pairwise-mask secure aggregation* (Bonawitz et al. style, simulation-grade):
every ordered pair (i, j) of a round's participants derives a shared mask
vector from a seed both can compute; participant i ADDS the mask for every
j > i and SUBTRACTS it for every j < i, so the masks cancel exactly in the
sum and the aggregator recovers ``sum(updates)`` without ever observing an
individual update.  All arithmetic is float64, so cancellation error is at
the 1e-12 level — far inside the 1e-6 equivalence bound the tests pin.

Caveat (documented, intentionally out of scope): real secure aggregation
must survive participants dropping out AFTER masking (secret-shared seed
recovery).  Here masks are generated over the round's *realized* on-time
participant set at aggregation time, so dropout recovery never arises; the
protocol hole is the gap between this simulation and a deployment.

*Gaussian DP noise*: updates are L2-clipped to ``dp_clip`` and the average
gets ``N(0, (noise_multiplier * clip / n)^2)`` noise per coordinate.
``PrivacyAccountant`` is an epsilon-accounting STUB — basic composition of
the Gaussian mechanism, not a tight moments/RDP accountant — good for
surfacing "how much noise did this run spend" in reports, not for
production privacy claims.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


def _pair_rng(seed: int, round_idx: int, a: str, b: str) -> np.random.Generator:
    """Shared generator for the (a, b) pair: both sides derive the same
    stream from (seed, round, sorted pair names), hashed through numpy's
    SeedSequence so it is stable across platforms and runs."""
    lo, hi = sorted((a, b))
    entropy = [seed, round_idx] + [ord(c) for c in lo] + [7] + [ord(c) for c in hi]
    return np.random.default_rng(np.random.SeedSequence(entropy))


def pairwise_masks(
    seed: int, round_idx: int, participants: list[str], dim: int
) -> dict[str, np.ndarray]:
    """Per-participant mask vectors that cancel exactly in the sum.

    ``mask[i] = sum_{j: i < j} m_ij - sum_{j: j < i} m_ji`` where ``m_ij``
    is the pair (i, j)'s shared stream — each pair's term appears once with
    each sign, so ``sum(mask.values())`` is identically zero (float64
    rounding aside).  Deterministic in (seed, round, participant set)."""
    order = sorted(participants)
    masks = {p: np.zeros(dim, dtype=np.float64) for p in order}
    for i, a in enumerate(order):
        for b in order[i + 1:]:
            m = _pair_rng(seed, round_idx, a, b).standard_normal(dim)
            masks[a] += m
            masks[b] -= m
    return masks


def clip_update(vec: np.ndarray, clip: float | None) -> np.ndarray:
    """L2-clip an update to norm <= ``clip`` (no-op when clip is None)."""
    if clip is None:
        return vec
    norm = float(np.linalg.norm(vec))
    if norm <= clip or norm == 0.0:
        return vec
    return vec * (clip / norm)


def gaussian_noise(
    seed: int, round_idx: int, dim: int, scale: float
) -> np.ndarray:
    """Deterministic per-round DP noise vector, N(0, scale^2) iid."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x0D9, round_idx]))
    return rng.standard_normal(dim) * scale


@dataclasses.dataclass
class PrivacyAccountant:
    """Epsilon-accounting STUB for the Gaussian mechanism.

    Tracks how many noised rounds ran at which ``noise_multiplier`` (noise
    stddev in units of the clipping bound).  ``epsilon`` applies the basic
    advanced-composition bound for the Gaussian mechanism,
    ``eps ~= sqrt(2 k ln(1/delta)) / sigma``, which is loose but monotone
    and dependency-free — a placeholder to be swapped for an RDP accountant.
    """

    noise_multiplier: float = 0.0
    rounds: int = 0

    def spend(self, noise_multiplier: float) -> None:
        if self.rounds and abs(noise_multiplier - self.noise_multiplier) > 1e-12:
            raise ValueError(
                "accountant stub assumes a constant noise multiplier; got "
                f"{noise_multiplier} after {self.noise_multiplier}"
            )
        self.noise_multiplier = noise_multiplier
        self.rounds += 1

    def epsilon(self, delta: float = 1e-5) -> float | None:
        """Loose composed epsilon at ``delta``; None when no noise ran."""
        if self.rounds == 0 or self.noise_multiplier == 0.0:
            return None
        return math.sqrt(2.0 * self.rounds * math.log(1.0 / delta)) / (
            self.noise_multiplier
        )

    def summary(self, delta: float = 1e-5) -> dict:
        out = {
            "rounds": self.rounds,
            "noise_multiplier": self.noise_multiplier,
        }
        eps = self.epsilon(delta)
        if eps is not None:
            out["epsilon"] = round(eps, 4)
            out["delta"] = delta
        return out


__all__ = [
    "PrivacyAccountant",
    "clip_update",
    "gaussian_noise",
    "pairwise_masks",
]
