"""Virtual-clock federated round driver over ``SystemSimulation``.

This is where the round loop meets the multi-tenant runtime: each round,
every *free* tenant gets a local-training job (its round's circuit bank)
submitted into ONE shared simulation — through the serving gateway when the
simulation runs in gateway mode — and the coordinator observes per-tenant
update arrival times via ``SystemSimulation.job_callbacks``.  Rounds close
on quorum + deadline (or the sync barrier), late completions fold in with
the staleness discount, and the whole schedule composes with
``worker_failures`` fault schedules and arrival storms because it IS the
same event loop.

Determinism: local updates are computed eagerly (seeded numerics) at round
launch against the round's starting global parameters; the virtual clock
only decides WHEN each update is observed and whether it made quorum.
Timing and numerics are therefore independently deterministic, and the
whole run is bit-reproducible for a fixed seed.

Deadlines ride the ``ServiceModel`` EWMA: each tenant's observed
launch-to-arrival time updates an estimator keyed by its circuit family,
and a round's deadline is ``deadline_factor x`` the slowest participant's
estimate.  Round 0 bootstraps from the analytic per-circuit calibration
divided across the currently *healthy* workers (a fault schedule that has
already crashed a worker shrinks the denominator — the fleet-health
tie-in).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.comanager.simulation import SystemSimulation
from repro.comanager.tenancy import JobSpec
from repro.federated.config import FederatedConfig
from repro.federated.rounds import FederatedCoordinator, FederatedReport, UpdateFn


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One federated tenant: its per-round local-training job shape and its
    scheduling contract in the shared gateway."""

    name: str
    qc: int = 5
    n_layers: int = 1
    n_circuits: int = 32  # circuits per round (the local-training bank)
    weight: float = 1.0
    priority: int = 1
    slo_ms: Optional[float] = None
    service_override: Optional[float] = None

    def __post_init__(self):
        if "@r" in self.name:
            raise ValueError(
                f"tenant name {self.name!r} may not contain '@r' (reserved "
                "for per-round job ids)"
            )
        if self.n_circuits < 1:
            raise ValueError(f"n_circuits must be >= 1, got {self.n_circuits}")

    def job(self, round_idx: int, submit_time: float) -> JobSpec:
        return JobSpec(
            client_id=f"{self.name}@r{round_idx}",
            qc=self.qc,
            n_layers=self.n_layers,
            n_circuits=self.n_circuits,
            submit_time=submit_time,
            service_override=self.service_override,
        )


def _split_job_id(cid: str) -> tuple[str, int]:
    name, r = cid.rsplit("@r", 1)
    return name, int(r)


class FederatedDriver:
    """Owns one ``SystemSimulation`` and one ``FederatedCoordinator`` and
    runs the round loop to completion on the virtual clock.  Use
    ``run_federated`` unless you need to poke at the pieces."""

    def __init__(
        self,
        config: FederatedConfig,
        tenants: list[TenantSpec],
        update_fn: UpdateFn,
        params0: dict,
        sim: SystemSimulation,
        *,
        eval_fn: Optional[Callable[[dict], float]] = None,
        telemetry=None,
    ):
        if not tenants:
            raise ValueError("need at least one tenant")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")
        self.config = config
        self.tenants = {t.name: t for t in tenants}
        self.update_fn = update_fn
        self.eval_fn = eval_fn
        self.sim = sim
        if telemetry is None and sim.gateway is not None:
            telemetry = sim.gateway.telemetry
        self.telemetry = telemetry
        self.coordinator = FederatedCoordinator(
            config,
            params0,
            weights={t.name: t.weight for t in tenants},
            telemetry=telemetry,
        )
        from repro.serve.metrics import ServiceModel

        # driver-owned EWMA (same estimator class the gateway placement
        # rides), keyed by circuit family — kept separate from the
        # gateway's instance so round-level observations never perturb
        # batch-placement estimates.
        self.service = ServiceModel()
        self._seed_service_priors()
        # ---- round bookkeeping
        self._outstanding: dict[str, int] = {}  # tenant -> round in flight
        self._launched_at: dict[tuple[str, int], float] = {}
        self._updates: dict[tuple[str, int], dict] = {}  # eager local updates
        self._deferred_round: Optional[int] = None
        self._deadline_entry = None
        self.accuracy_by_round: list[float] = []
        self.finished = False
        sim.job_callbacks.append(self._on_job_done)
        sim.loop.on("fed_deadline", self._on_deadline)

    # ----------------------------------------------------------- estimates
    def _seed_service_priors(self) -> None:
        """Bootstrap the EWMA with the analytic calibration so round 0 has a
        deadline: bank seconds = n_circuits x per-circuit service time,
        spread across the workers healthy at t=0 (fault schedules that
        crash a worker before the start shrink the effective fleet)."""
        healthy = 0
        for wid in self.sim.workers:
            f = self.sim.failures.get(wid)
            if f is None or not f.crashed(0.0):
                healthy += 1
        healthy = max(healthy, 1)
        for t in self.tenants.values():
            key = ("fed", t.qc, t.n_layers)
            per_circuit = t.job(0, 0.0).service_time(self.sim.env)
            self.service.update(
                key, t.n_circuits, t.n_circuits * per_circuit / healthy
            )

    def _round_deadline(self, now: float, participants: list[str]) -> float | None:
        if self.config.barrier:
            return None
        if self.config.round_deadline_s is not None:
            return now + self.config.round_deadline_s
        slowest = max(
            self.service.estimate(
                ("fed", self.tenants[n].qc, self.tenants[n].n_layers),
                self.tenants[n].n_circuits,
            )
            for n in participants
        )
        # in gateway mode a bank can sit a full coalescer flush deadline
        # before anything executes — a pure service-time estimate would close
        # round 0 before the first batch even dispatched.
        floor = 0.0
        if self.sim.gateway is not None:
            floor = self.sim.gateway.coalescer.deadline
        return now + self.config.deadline_factor * (slowest + floor)

    # -------------------------------------------------------------- rounds
    def _launch_round(self, round_idx: int, now: float) -> bool:
        """Open round ``round_idx`` over the currently free tenants; False
        when every tenant is still busy straggling (the round is deferred
        until the next completion frees one)."""
        free = [n for n in self.tenants if n not in self._outstanding]
        if not free:
            return False
        # eager local updates against the round's starting global params:
        # numerics are fixed here; the clock only decides observation order.
        params = {k: np.array(v) for k, v in self.coordinator.params.items()}
        for name in free:
            self._updates[(name, round_idx)] = self.update_fn(
                name, round_idx, params
            )
        deadline = self._round_deadline(now, free)
        self.coordinator.begin_round(round_idx, now, free, deadline=deadline)
        for name in free:
            t = self.tenants[name]
            self._outstanding[name] = round_idx
            self._launched_at[(name, round_idx)] = now
            self.sim.submit_job(
                t.job(round_idx, now),
                weight=t.weight,
                priority=t.priority,
                slo_ms=t.slo_ms,
            )
        if deadline is not None:
            self._deadline_entry = self.sim.loop.schedule(
                deadline, "fed_deadline", round_idx
            )
        return True

    def _on_job_done(self, cid: str, t: float) -> None:
        if "@r" not in cid:
            return  # not a federated round job (shared simulation)
        name, r = _split_job_id(cid)
        if name not in self.tenants or self._outstanding.get(name) != r:
            return
        del self._outstanding[name]
        launched = self._launched_at.pop((name, r))
        spec = self.tenants[name]
        self.service.update(
            ("fed", spec.qc, spec.n_layers), spec.n_circuits, t - launched
        )
        update = self._updates.pop((name, r))
        co = self.coordinator
        if co.open and co.round_idx == r:
            co.offer(name, update, t)
            close = co.all_arrived() if self.config.barrier else co.quorum_reached()
            if close:
                self._close_round(t)
        else:
            # straggler: its round already closed — fold with the staleness
            # discount or drop, per config.
            co.offer_late(name, update, t, r)
        if self._deferred_round is not None and not co.open:
            if self._launch_round(self._deferred_round, t):
                self._deferred_round = None

    def _on_deadline(self, t: float, round_idx: int) -> None:
        co = self.coordinator
        if co.open and co.round_idx == round_idx:
            self._close_round(t)

    def _close_round(self, t: float) -> None:
        if self._deadline_entry is not None:
            self.sim.loop.cancel(self._deadline_entry)
            self._deadline_entry = None
        rec = self.coordinator.close_round(t)
        if self.eval_fn is not None:
            self.accuracy_by_round.append(
                float(self.eval_fn(self.coordinator.params))
            )
        nxt = rec.round_idx + 1
        if nxt >= self.config.n_rounds:
            self.finished = True
            # the experiment is over: stop the loop even though straggler
            # jobs (e.g. a tenant wedged on a crashed worker) would keep
            # heartbeat chains alive forever.
            self.sim.loop.stop()
            return
        if not self._launch_round(nxt, t):
            self._deferred_round = nxt

    # ----------------------------------------------------------------- run
    def run(self) -> FederatedReport:
        self.sim.start()
        self._launch_round(0, 0.0)
        self.sim.loop.run(until=self.config.max_sim_seconds)
        # stragglers that never reported by the end of the run
        for name in sorted(self._outstanding):
            self.coordinator.resolve_missing(name)
        sim_report = self.sim.finish()
        return self.coordinator.report(
            accuracy_by_round=self.accuracy_by_round,
            simulation=sim_report,
        )


def run_federated(
    config: FederatedConfig,
    tenants: list[TenantSpec],
    update_fn: UpdateFn,
    params0: dict,
    worker_cfgs,
    *,
    eval_fn: Optional[Callable[[dict], float]] = None,
    **sim_kwargs,
) -> FederatedReport:
    """One-call federated experiment on the virtual clock.

    ``update_fn(tenant, round_idx, global_params) -> delta tree`` computes a
    tenant's local update (must be deterministic — seed it on its inputs);
    ``sim_kwargs`` forward to ``SystemSimulation`` (gateway mode, fault
    schedules, observability, ...).  Per-tenant scheduling policy comes from
    each ``TenantSpec``, not the simulation's tenant maps (round job ids are
    created as the clock advances, so the closed-world maps cannot name
    them)."""
    for banned in ("jobs", "tenant_weights", "tenant_priorities", "tenant_slos_ms"):
        if banned in sim_kwargs:
            raise ValueError(
                f"{banned} is managed by the federated driver; configure "
                "tenants via TenantSpec"
            )
    sim_kwargs.setdefault("run_until", config.max_sim_seconds)
    sim = SystemSimulation(worker_cfgs, [], **sim_kwargs)
    driver = FederatedDriver(
        config, tenants, update_fn, params0, sim, eval_fn=eval_fn
    )
    return driver.run()


__all__ = ["FederatedDriver", "TenantSpec", "run_federated"]
