"""User-facing federated DQL session: QuClassi local training + the round
loop, wired to a ``QuantumCluster``.

``FederatedSession`` is what ``QuantumCluster.federated_session(...)``
returns: it carries the cluster's fleet + ``SimulationConfig`` into the
virtual-clock driver and keeps the resulting ``FederatedReport`` for
telemetry queries.  The QuClassi helpers build the deterministic local
``update_fn`` (a few steps of exact-gradient SGD on the tenant's shard) and
the per-round eval hook the accuracy-vs-rounds benchmark plots.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.federated.config import FederatedConfig
from repro.federated.driver import TenantSpec, run_federated
from repro.federated.rounds import FederatedReport, UpdateFn


def shard_dataset(
    images, labels, tenants: list[str], seed: int = 0
) -> dict[str, tuple]:
    """Deterministic near-even split of a dataset across tenants (each
    tenant's shard is its private local-training data)."""
    if not tenants:
        raise ValueError("need at least one tenant")
    rng = np.random.default_rng(np.random.SeedSequence([seed, len(tenants)]))
    perm = rng.permutation(len(images))
    parts = np.array_split(perm, len(tenants))
    return {
        t: (np.asarray(images)[idx], np.asarray(labels)[idx])
        for t, idx in zip(sorted(tenants), parts)
    }


def make_quclassi_update_fn(
    qcfg,
    shards: dict[str, tuple],
    *,
    lr: float = 0.1,
    local_steps: int = 1,
) -> UpdateFn:
    """Local-training closure for the round loop: ``local_steps`` of exact
    autodiff-gradient SGD on the tenant's shard, starting from the round's
    global parameters; returns the parameter DELTA tree in float64.
    Deterministic in (tenant shard, round params) — exactly what the
    bit-determinism gate needs."""
    import jax
    import jax.numpy as jnp

    from repro.core import quclassi

    def update_fn(tenant: str, round_idx: int, params: dict) -> dict:
        x, y = shards[tenant]
        p = {k: jnp.asarray(v) for k, v in params.items()}
        for _ in range(local_steps):
            _, g, _ = quclassi.grad_autodiff(qcfg, p, x, y)
            p = jax.tree.map(lambda a, b: a - lr * b, p, g)
        return {
            k: np.asarray(p[k], dtype=np.float64)
            - np.asarray(params[k], dtype=np.float64)
            for k in params
        }

    return update_fn


def make_quclassi_eval_fn(qcfg, eval_set) -> Callable[[dict], float]:
    """Held-out accuracy of the global parameters after each round."""
    import jax.numpy as jnp

    from repro.core import quclassi

    x, y = eval_set

    def eval_fn(params: dict) -> float:
        p = {k: jnp.asarray(v) for k, v in params.items()}
        return float(quclassi.accuracy(qcfg, p, x, y))

    return eval_fn


class FederatedSession:
    """One federated experiment bound to a cluster's fleet and simulation
    config.  ``run()`` executes the whole round loop on the virtual clock
    and returns (and retains) the ``FederatedReport``."""

    def __init__(
        self,
        cluster,
        config: FederatedConfig,
        tenants: list[TenantSpec],
        update_fn: UpdateFn,
        params0: dict,
        *,
        eval_fn: Optional[Callable[[dict], float]] = None,
        worker_failures: Optional[dict] = None,
        simulation=None,
    ):
        self.cluster = cluster
        self.config = config
        self.tenants = list(tenants)
        self.update_fn = update_fn
        self.params0 = params0
        self.eval_fn = eval_fn
        self.worker_failures = worker_failures
        self.simulation = simulation or cluster.config.simulation
        self.report: Optional[FederatedReport] = None

    def run(self) -> FederatedReport:
        kw = self.simulation.simulation_kwargs()
        self.report = run_federated(
            self.config,
            self.tenants,
            self.update_fn,
            self.params0,
            list(self.cluster.config.workers),
            eval_fn=self.eval_fn,
            worker_failures=self.worker_failures,
            **kw,
        )
        return self.report

    def telemetry(self) -> Optional[dict]:
        """The gateway telemetry summary of the finished run (federated
        participation counters under each tenant row, ``federated_rounds``
        at the top level), or None before ``run()`` / without a gateway."""
        if self.report is None or self.report.simulation is None:
            return None
        return self.report.simulation.gateway_summary

    def summary(self) -> Optional[dict]:
        """The finished run's ``FederatedReport.summary()``."""
        return None if self.report is None else self.report.summary()


__all__ = [
    "FederatedSession",
    "make_quclassi_eval_fn",
    "make_quclassi_update_fn",
    "shard_dataset",
]
