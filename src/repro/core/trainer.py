"""DQuLearn training driver — Algorithm 1's epoch loop, end to end.

Per epoch (lines 4-26): start timer -> segment data / encode -> build the
parameter-shift circuit bank -> execute every circuit in the bank through the
chosen executor (local fused kernel, per-worker batches, or a sharded mesh)
-> assemble gradients -> update parameters -> stop timer, record accuracy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quclassi
from repro.core.quclassi import QuClassiConfig
from repro.data import pipeline
from repro.optim import optimizers


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    loss: float
    train_accuracy: float
    test_accuracy: float
    wall_seconds: float
    circuits_executed: int


@dataclasses.dataclass
class TrainReport:
    epochs: list[EpochRecord]
    params: dict

    @property
    def final_test_accuracy(self) -> float:
        return self.epochs[-1].test_accuracy if self.epochs else 0.0


def train(
    cfg: QuClassiConfig,
    train_set,
    test_set,
    *,
    epochs: int = 10,
    batch_size: int = 8,
    lr: float = 1e-3,
    grad_mode: str = "shift",
    executor=None,
    optimizer: str = "sgd",
    gateway=None,
    client_id: str = "trainer",
    bank_mode: str = "auto",
    priority: int = 1,
    slo_ms: Optional[float] = None,
    policy=None,
    seed: int = 0,
    log: Optional[Callable[[str], None]] = None,
) -> TrainReport:
    """Train QuClassi per Algorithm 1.

    ``grad_mode``: 'shift' (paper-faithful circuit-bank path, optionally
    distributed via ``executor``) or 'autodiff' (exact local path — same
    math for 1-2 layer configs, used for fast accuracy runs).

    ``gateway``: a ``repro.serve.GatewayRuntime``; the shift-rule circuit
    banks are then streamed through the online serving gateway as client
    ``client_id`` — coalesced (possibly with other tenants sharing the
    runtime) into lane-aligned mega-batches, placed by the co-Manager, and
    executed by the fused Pallas kernel.  Fidelities come back in submission
    order, so gradient assembly is unchanged.  A runtime constructed with
    ``mode="async"`` rides the async path transparently: submissions stream
    into the pump loop while earlier batches execute on the worker pool, and
    the per-bank gather blocks on out-of-order futures.

    ``priority`` / ``slo_ms`` (gateway mode): this client's strict
    scheduling tier (lower = served first — a tier-0 interactive tenant
    preempts tier-1 training traffic) and end-to-end latency SLO, forwarded
    to ``Gateway.register_client``.

    ``bank_mode``: 'materialized' (explicit (C, P) circuit banks),
    'implicit' (``ShiftBank``s — shift-aware executors run them through the
    prefix-reuse kernel; a gateway then carries per-(param, shift) group
    subtasks instead of per-row circuits), or 'auto' (implicit exactly when
    the executor declares the ``shiftbank`` capability — see
    ``repro.api.capabilities``).

    ``policy``: a ``repro.api.TenantPolicy``; when given it supersedes the
    loose ``priority`` / ``slo_ms`` kwargs (the preferred way to carry a
    tenant's scheduling contract — ``repro.api.Session.train`` wires it).
    """
    if bank_mode not in ("auto", "implicit", "materialized"):
        raise ValueError(f"unknown bank_mode {bank_mode!r}")
    if policy is not None:
        priority, slo_ms = policy.priority, policy.slo_ms
    implicit = {"auto": None, "implicit": True, "materialized": False}[bank_mode]
    if gateway is not None:
        if executor is not None:
            raise ValueError("pass either executor or gateway, not both")
        gw_opts = dict(priority=priority, slo_ms=slo_ms)
        if policy is not None:
            gw_opts["weight"] = policy.weight
        executor = (
            gateway.shift_executor(cfg.spec, client_id, **gw_opts)
            if bank_mode == "implicit"
            else gateway.executor(cfg.spec, client_id, **gw_opts)
        )
    (xtr, ytr), (xte, yte) = train_set, test_set
    xtr, xte = pipeline.clean(xtr), pipeline.clean(xte)
    params = quclassi.init_params(cfg, jax.random.PRNGKey(seed))
    opt = optimizers.make(optimizer, lr)
    opt_state = opt.init(params)
    records: list[EpochRecord] = []

    for epoch in range(epochs):                       # line 4
        t0 = time.perf_counter()                      # line 5: epoch timer
        losses, n_circ = [], 0
        for bi, (xb, yb) in enumerate(
            pipeline.batches(xtr, ytr, batch_size, seed=seed * 997 + epoch)
        ):
            xb, yb = jnp.asarray(xb), jnp.asarray(yb)
            if grad_mode == "shift":
                loss, grads, _ = quclassi.grad_shift(
                    cfg, params, xb, yb, executor=executor, implicit=implicit
                )
                n_circ += quclassi.total_bank_circuits(cfg, xb.shape[0])
            else:
                loss, grads, _ = quclassi.grad_autodiff(cfg, params, xb, yb)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = optimizers.apply_updates(params, updates)
            losses.append(float(loss))
        wall = time.perf_counter() - t0               # lines 24-25
        tr_acc = float(
            quclassi.accuracy(cfg, params, jnp.asarray(xtr), jnp.asarray(ytr))
        )
        te_acc = float(
            quclassi.accuracy(cfg, params, jnp.asarray(xte), jnp.asarray(yte))
        )
        rec = EpochRecord(epoch, float(np.mean(losses)), tr_acc, te_acc, wall, n_circ)
        records.append(rec)                           # line 26: accuracy/epoch
        if log:
            log(
                f"epoch {epoch}: loss={rec.loss:.4f} train_acc={tr_acc:.3f} "
                f"test_acc={te_acc:.3f} wall={wall:.2f}s circuits={n_circ}"
            )
    return TrainReport(records, params)
