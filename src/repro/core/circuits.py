"""QuClassi-style variational circuit construction (paper §IV-A).

A DQuLearn circuit over ``qc`` qubits has three registers:

  qubit 0                    : ancilla (SWAP-test readout)
  qubits 1 .. m              : DATA register   (m = (qc-1)//2 qubits)
  qubits m+1 .. 2m           : TRAINABLE register

The trainable register is prepared by a stack of variational layers:

  "single"   : RY + RZ on every trainable qubit          (2m params)
  "dual"     : RYY + RZZ on adjacent qubit pairs          (2(m-1) params)
  "entangle" : CRY + CRZ on adjacent qubit pairs          (2(m-1) params)

matching the paper's three configurations — 1 layer = [single],
2 layers = [single, dual], 3 layers = [single, dual, entangle].

The DATA register is prepared by rotation encoding (RX+RY per qubit, angles
supplied at run time — "we utilize X and Y rotations to encode our data",
paper §III-A).  Fidelity between the registers is read out with the standard
SWAP test: H(anc) -> CSWAP(anc, d_i, t_i) -> H(anc); then
P(anc=0) = (1 + |<psi|phi>|^2) / 2.
"""
from __future__ import annotations

from repro.core.sim import CircuitSpec, Op

LAYER_SEQUENCE = ("single", "dual", "entangle")


def layers_for_count(n_layers: int) -> tuple[str, ...]:
    """Paper's layer configurations: 1 -> [single], 2 -> +dual, 3 -> +entangle."""
    if not 1 <= n_layers <= 3:
        raise ValueError(f"paper evaluates 1..3 layers, got {n_layers}")
    return LAYER_SEQUENCE[:n_layers]


def registers(qc: int) -> tuple[int, list[int], list[int]]:
    """-> (ancilla, data qubits, trainable qubits) for a qc-qubit circuit."""
    if qc % 2 == 0 or qc < 3:
        raise ValueError(
            f"need odd qubit count >=3 (ancilla + 2 equal registers), got {qc}"
        )
    m = (qc - 1) // 2
    anc = 0
    data_q = list(range(1, 1 + m))
    train_q = list(range(1 + m, 1 + 2 * m))
    return anc, data_q, train_q


def n_theta_for(qc: int, n_layers: int) -> int:
    m = (qc - 1) // 2
    total = 0
    for name in layers_for_count(n_layers):
        total += 2 * m if name == "single" else 2 * (m - 1)
    return total


def n_data_angles_for(qc: int) -> int:
    m = (qc - 1) // 2
    return 2 * m  # RX + RY per data qubit


def variational_ops(
    train_q: list[int], layer_names: tuple[str, ...], theta_offset: int = 0
):
    """Ops for the trainable register; returns (ops, n_theta)."""
    ops: list[Op] = []
    j = theta_offset
    m = len(train_q)
    for name in layer_names:
        if name == "single":
            for q in train_q:
                ops.append(Op("ry", (q,), ("theta", j))); j += 1
                ops.append(Op("rz", (q,), ("theta", j))); j += 1
        elif name == "dual":
            for a, b in zip(train_q[:-1], train_q[1:]):
                ops.append(Op("ryy", (a, b), ("theta", j))); j += 1
                ops.append(Op("rzz", (a, b), ("theta", j))); j += 1
        elif name == "entangle":
            for a, b in zip(train_q[:-1], train_q[1:]):
                ops.append(Op("cry", (a, b), ("theta", j))); j += 1
                ops.append(Op("crz", (a, b), ("theta", j))); j += 1
        else:
            raise ValueError(name)
    return ops, j - theta_offset


def encoding_ops(data_q: list[int], data_offset: int = 0):
    """RX+RY rotation encoding on the data register; returns (ops, n_data)."""
    ops: list[Op] = []
    j = data_offset
    for q in data_q:
        ops.append(Op("rx", (q,), ("data", j))); j += 1
        ops.append(Op("ry", (q,), ("data", j))); j += 1
    return ops, j - data_offset


def swap_test_ops(anc: int, data_q: list[int], train_q: list[int]) -> list[Op]:
    ops = [Op("h", (anc,))]
    for d, t in zip(data_q, train_q):
        ops.append(Op("cswap", (anc, d, t)))
    ops.append(Op("h", (anc,)))
    return ops


def build_quclassi_circuit(qc: int, n_layers: int) -> CircuitSpec:
    """The full DQuLearn subtask circuit: encode -> variational -> SWAP test.

    ``qc`` is the paper's qubit-count knob (5 or 7 in the evaluation).
    """
    anc, data_q, train_q = registers(qc)
    enc_ops, n_data = encoding_ops(data_q)
    var_ops, n_theta = variational_ops(train_q, layers_for_count(n_layers))
    ops = tuple(enc_ops + var_ops + swap_test_ops(anc, data_q, train_q))
    return CircuitSpec(n_qubits=qc, ops=ops, n_theta=n_theta, n_data=n_data)


def _mirror_twin(op: Op, train_q: list[int]) -> Op:
    """The register-mirrored twin of a variational op: each qubit at local
    index i maps to local index m-1-i.  Two-qubit pairs stay ascending
    (pair (i, i+1) mirrors to (m-2-i, m-1-i)), so cry/crz twins keep the
    (control, target) order the kernel requires."""
    m = len(train_q)
    base = train_q[0]
    mirrored = tuple(sorted(train_q[m - 1 - (q - base)] for q in op.qubits))
    return Op(op.gate, mirrored, op.param)


def build_tied_quclassi_circuit(qc: int, n_layers: int) -> CircuitSpec:
    """A weight-tied (2-reuse) hardware-efficient variant of the QuClassi
    circuit: every variational parameter drives TWO gates — the original
    gate and its register-mirrored twin at the same angle (the parameter
    sharing common in the hardware-efficient architectures surveyed in
    Sünkel et al.).  Same parameter count as ``build_quclassi_circuit``,
    twice the variational depth.  Exercises the multi-use suffix-replay
    shift plans: the twin sits adjacent to its original, so each variant
    replays a two-gate span from one checkpoint instead of falling back to
    the (1+2P)x materialized bank."""
    anc, data_q, train_q = registers(qc)
    enc_ops, n_data = encoding_ops(data_q)
    var_ops, n_theta = variational_ops(train_q, layers_for_count(n_layers))
    tied: list[Op] = []
    for op in var_ops:
        tied.append(op)
        tied.append(_mirror_twin(op, train_q))
    ops = tuple(enc_ops + tied + swap_test_ops(anc, data_q, train_q))
    return CircuitSpec(n_qubits=qc, ops=ops, n_theta=n_theta, n_data=n_data)


def circuit_depth(spec: CircuitSpec) -> int:
    return len(spec.ops)


def qubit_demand(spec: CircuitSpec) -> int:
    """Resource demand D_c of a circuit (Algorithm 2) = its qubit width."""
    return spec.n_qubits
