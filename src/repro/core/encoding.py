"""Classical-data -> qubit encodings (paper §III-A, Logical Circuit Generator).

Two encodings:

* ``rotation_angles`` — the paper's default ("we utilize X and Y rotations to
  encode our data"): a flattened patch is mapped to 2 angles per data qubit
  (RX, RY), either directly (pixel -> angle in [0, pi]) or through the
  model's classical dense layer (Algorithm 1 line 10).

* ``amplitude_encoding`` — the log_n encoding referenced in Algorithm 1
  line 8: 2**m values are L2-normalized onto the amplitudes of m qubits.
  Returned as an (re, im) register state for state-preparation-based loading.
"""
from __future__ import annotations

import jax.numpy as jnp


def rotation_angles(patch: jnp.ndarray, n_angles: int) -> jnp.ndarray:
    """Map a flattened patch (..., P) to (..., n_angles) rotation angles.

    Pixels are assumed in [0, 1]; angle = pixel * pi.  If P != n_angles the
    patch is average-pooled (P > n) or tiled (P < n) — this is the direct
    (dense-layer-free) path used by unit tests and the runtime benchmarks.
    """
    p = patch.shape[-1]
    if p == n_angles:
        v = patch
    elif p > n_angles:
        # average-pool groups of ceil(P/n) pixels
        pad = (-p) % n_angles
        v = jnp.pad(patch, [(0, 0)] * (patch.ndim - 1) + [(0, pad)])
        v = v.reshape(*patch.shape[:-1], n_angles, -1).mean(-1)
    else:
        reps = -(-n_angles // p)
        v = jnp.tile(patch, [1] * (patch.ndim - 1) + [reps])[..., :n_angles]
    return (v * jnp.pi).astype(jnp.float32)


def amplitude_encoding(values: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """log_n encoding: (..., 2**m) values -> normalized m-qubit state (re, im)."""
    dim = values.shape[-1]
    if dim & (dim - 1):
        raise ValueError(f"amplitude encoding needs a power-of-two length, got {dim}")
    norm = jnp.linalg.norm(values, axis=-1, keepdims=True)
    # Guard the all-zero patch: fall back to |0...0>.
    safe = jnp.where(
        norm > 1e-8,
        values / jnp.maximum(norm, 1e-8),
        jnp.zeros_like(values).at[..., 0].set(1.0),
    )
    return safe.astype(jnp.float32), jnp.zeros_like(safe, dtype=jnp.float32)


def angles_to_unit_interval(angles: jnp.ndarray) -> jnp.ndarray:
    """Inverse of the pixel->angle map (for round-trip tests)."""
    return angles / jnp.pi
