"""Parameter-shift training: circuit-bank generation + gradient assembly
(Algorithm 1, lines 12–22).

For every trainable parameter theta_j the paper appends one forward-shifted
(+pi/2) and one backward-shifted (-pi/2) circuit to the *circuit bank* cB;
the bank is what gets distributed to quantum workers, and the returned
fidelities are assembled into gradients on the classical side.

Exactness note (recorded in DESIGN.md): the two-term rule
    dF/dtheta_j = (F(theta + pi/2 e_j) - F(theta - pi/2 e_j)) / 2
is exact for RX/RY/RZ/RYY/RZZ (generator eigenvalues +-1/2) but NOT for the
controlled rotations CRY/CRZ of the Entanglement Unitary layer (generator
eigenvalues {0, +-1/2} -> two frequencies).  The paper's Algorithm 1 uses the
two-term rule for all parameters; we implement that faithfully as the default
and additionally provide the exact four-term rule
    dF/dtheta = c+ [F(+pi/2) - F(-pi/2)] - c- [F(+3pi/2) - F(-3pi/2)],
    c+- = (sqrt(2) +- 1) / (4 sqrt(2))
as ``exact_controlled=True`` so tests can quantify the approximation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.api.capabilities import capabilities_of
from repro.core import fidelity as fid
from repro.core.sim import CircuitSpec

SHIFT = jnp.pi / 2
_SQ2 = 2.0**0.5
C_PLUS = (_SQ2 + 1.0) / (4.0 * _SQ2)
C_MINUS = (_SQ2 - 1.0) / (4.0 * _SQ2)

#: executor signature: (theta_bank (C,P), data_bank (C,D)) -> fidelities (C,)
Executor = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def controlled_param_indices(spec: CircuitSpec) -> tuple[int, ...]:
    """Theta indices driven by controlled-rotation gates (4-term params)."""
    idx = []
    for op in spec.ops:
        if op.gate in ("cry", "crz") and op.param and op.param[0] == "theta":
            idx.append(op.param[1])
    return tuple(sorted(set(idx)))


def shift_values(four_term: bool) -> tuple[float, ...]:
    """Shift magnitudes in bank-group order: +-pi/2 [, +-3pi/2]."""
    base = (SHIFT, -SHIFT)
    return base + (3 * SHIFT, -3 * SHIFT) if four_term else base


def group_descriptors(n_params: int, four_term: bool):
    """Per-(param, shift) group descriptors in bank order.

    Group g covers bank rows [g*B, (g+1)*B): g=0 is the unshifted base
    (descriptor ``(-1, 0.0)``), g = 1 + s*P + j is shift s of param j.
    """
    out = [(-1, 0.0)]
    for s in shift_values(four_term):
        for j in range(n_params):
            out.append((j, float(s)))
    return tuple(out)


def _split_results(f: jnp.ndarray, b: int, p: int, four_term: bool):
    """fidelities (C,) -> (f0 (B,), f_plus (P,B), f_minus (P,B)[, f3p, f3m])."""
    f0 = f[:b]
    body = f[b : b + 2 * p * b].reshape(2, p, b)
    out = [f0, body[0], body[1]]
    if four_term:
        tail = f[b + 2 * p * b :].reshape(2, p, b)
        out += [tail[0], tail[1]]
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class CircuitBank:
    """A flat batch of (theta, data) circuit instances + index bookkeeping.

    Layout (C = n_base + 2 * P * B [+ 2 * P * B more when four_term]):
      [0, B)                 : unshifted circuits (forward pass, loss value)
      [B + (s*P + j)*B + b]  : s=0 plus-shift, s=1 minus-shift of param j, sample b
      four-term tail         : same layout with +-3pi/2 shifts
    """
    theta: jnp.ndarray  # (C, P)
    data: jnp.ndarray   # (C, D)
    n_samples: int
    n_params: int
    four_term: bool

    @property
    def n_circuits(self) -> int:
        return self.theta.shape[0]

    def split_results(self, f: jnp.ndarray):
        return _split_results(f, self.n_samples, self.n_params, self.four_term)


@dataclasses.dataclass(frozen=True)
class ShiftBank:
    """An IMPLICIT circuit bank: base angles + shift descriptors only.

    Semantically identical to the ``CircuitBank`` that ``materialize()``
    returns, but it never stores the (C, P) theta matrix — just the per-sample
    base ``theta (B, P)``, ``data (B, D)`` and the static group structure.
    Shift-aware executors (the prefix-reuse Pallas kernel, the group-scheduled
    data-plane executors, the serving gateway) consume it directly; everything
    else goes through ``materialize()`` and keeps working unchanged.
    """
    theta: jnp.ndarray  # (B, P) base thetas, one row per sample
    data: jnp.ndarray   # (B, D)
    n_samples: int
    n_params: int
    four_term: bool

    @property
    def n_shifts(self) -> int:
        return 4 if self.four_term else 2

    @property
    def n_groups(self) -> int:
        return 1 + self.n_shifts * self.n_params

    @property
    def n_circuits(self) -> int:
        return self.n_groups * self.n_samples

    def group_descriptors(self):
        return group_descriptors(self.n_params, self.four_term)

    def split_results(self, f: jnp.ndarray):
        return _split_results(f, self.n_samples, self.n_params, self.four_term)

    def materialize(self) -> CircuitBank:
        """The escape hatch: expand to the explicit (C, P) bank.

        Bit-identical to ``build_bank`` on the same base angles (same
        broadcast + concatenation arithmetic), pinned by tests.
        """
        b, p = self.n_samples, self.n_params
        eye = jnp.eye(p, dtype=self.theta.dtype)

        def shifted(s):
            t = self.theta[None, :, :] + s * eye[:, None, :]   # (P, B, P)
            return t.reshape(p * b, p)

        blocks = [self.theta]
        blocks += [shifted(s) for s in shift_values(self.four_term)]
        theta_bank = jnp.concatenate(blocks, 0)
        data_bank = jnp.tile(self.data, (self.n_groups, 1))
        return CircuitBank(
            theta_bank, data_bank, n_samples=b, n_params=p, four_term=self.four_term
        )


def build_bank(
    theta: jnp.ndarray, data: jnp.ndarray, four_term: bool = False
) -> CircuitBank:
    """Build the circuit bank for a sample batch. theta: (P,), data: (B, D)."""
    (p,) = theta.shape
    b = data.shape[0]
    eye = jnp.eye(p, dtype=theta.dtype)

    def shifted(s):
        # (P, P) thetas, tiled over B -> (P, B, P)
        t = theta[None, :] + s * eye
        return jnp.broadcast_to(t[:, None, :], (p, b, p))

    blocks = [
        jnp.broadcast_to(theta[None, :], (b, p)),
        shifted(SHIFT).reshape(p * b, p),
        shifted(-SHIFT).reshape(p * b, p),
    ]
    if four_term:
        blocks += [
            shifted(3 * SHIFT).reshape(p * b, p),
            shifted(-3 * SHIFT).reshape(p * b, p),
        ]
    theta_bank = jnp.concatenate(blocks, 0)

    reps = theta_bank.shape[0] // b
    data_bank = jnp.tile(data, (reps, 1))
    return CircuitBank(
        theta_bank, data_bank, n_samples=b, n_params=p, four_term=four_term
    )


def build_shift_bank(
    theta: jnp.ndarray, data: jnp.ndarray, four_term: bool = False
) -> ShiftBank:
    """Build the implicit bank. theta: (P,) or per-sample (B, P); data: (B, D)."""
    b = data.shape[0]
    if theta.ndim == 1:
        theta = jnp.broadcast_to(theta[None, :], (b, theta.shape[0]))
    return ShiftBank(
        theta, data, n_samples=b, n_params=theta.shape[1], four_term=four_term
    )


def group_bank_sets(items):
    """Group (spec, ShiftBank) pairs into FUSABLE bank-sets.

    Banks can share one multi-bank kernel launch exactly when they agree on
    circuit structure and shift rule: same ``CircuitSpec`` (hash ==
    structural identity) and same ``four_term``.  Base angles and sample
    counts may differ — they become per-lane data of the fused launch.
    Returns ``{(spec, four_term): [bank, ...]}`` preserving submission
    order within each set (the serving coalescer keys batches the same
    way via ``ShiftGroupKey``)."""
    sets: dict = {}
    for spec, bank in items:
        sets.setdefault((spec, bank.four_term), []).append(bank)
    return sets


def run_bank_set(executor, banks) -> list:
    """Execute several same-spec implicit banks through ``executor``.

    Executors that fuse whole bank-sets declare the ``multibank``
    capability (``repro.api.capabilities``) and receive the list itself
    (one multi-bank launch); everything else falls back to per-bank
    ``run_bank`` calls — same results, K launches."""
    banks = list(banks)
    if capabilities_of(executor).multibank:
        return list(executor(banks))
    return [run_bank(executor, bank) for bank in banks]


def default_executor(spec: CircuitSpec) -> Executor:
    return jax.jit(lambda t, d: fid.fidelity_batch(spec, t, d))


def run_bank(executor: Executor, bank) -> jnp.ndarray:
    """Execute a bank (implicit or materialized) through ``executor``.

    Executors that understand implicit banks declare the ``shiftbank``
    capability (``repro.api.capabilities.declare``; legacy duck-typed
    ``accepts_shiftbank`` callables still resolve through the deprecation
    shim in ``capabilities_of``) and are called with the ``ShiftBank``
    itself; every other executor keeps its ``(theta, data)`` signature and
    receives the materialized bank — the escape hatch that keeps the whole
    existing executor zoo working.
    """
    if isinstance(bank, ShiftBank):
        if capabilities_of(executor).shiftbank:
            return executor(bank)
        mat = bank.materialize()
        return executor(mat.theta, mat.data)
    return executor(bank.theta, bank.data)


def assemble_gradient(
    spec: CircuitSpec, bank: CircuitBank, fids: jnp.ndarray, labels: jnp.ndarray
):
    """-> (loss (scalar), grad_theta (P,), per-sample fidelities (B,)).

    The classical Quantum State Analyst step: chain dL/dF through the
    shift-rule estimate of dF/dtheta.
    """
    parts = bank.split_results(fids)
    f0, f_plus, f_minus = parts[0], parts[1], parts[2]
    dfdt = (f_plus - f_minus) / 2.0  # (P, B) two-term estimate
    if bank.four_term:
        f3p, f3m = parts[3], parts[4]
        four = C_PLUS * (f_plus - f_minus) - C_MINUS * (f3p - f3m)
        ctrl = controlled_param_indices(spec)
        if ctrl:
            mask = jnp.zeros((bank.n_params, 1)).at[jnp.array(ctrl), 0].set(1.0)
            dfdt = mask * four + (1.0 - mask) * dfdt
    chain = fid.bce_grad_wrt_fidelity(f0, labels)  # (B,)
    grad = (dfdt * chain[None, :]).mean(-1)  # (P,)
    loss = fid.bce_loss(f0, labels).mean()
    return loss, grad, f0


def parameter_shift_grad(
    spec: CircuitSpec,
    theta: jnp.ndarray,
    data: jnp.ndarray,
    labels: jnp.ndarray,
    executor: Executor | None = None,
    exact_controlled: bool = False,
    implicit: bool | None = None,
):
    """One full Algorithm-1 gradient step's worth of circuit-bank work.

    Builds the bank, executes it (by default locally; in the distributed
    system the executor routes through the co-Manager), assembles gradients.

    ``implicit``: build a ``ShiftBank`` (never materializing the (C, P) theta
    matrix) instead of the explicit bank.  ``None`` = auto: implicit exactly
    when the executor declares the ``shiftbank`` capability.  Shift-unaware
    executors still work under ``implicit=True`` via ``materialize()``.
    """
    four = exact_controlled and bool(controlled_param_indices(spec))
    run = executor or default_executor(spec)
    if implicit is None:
        implicit = capabilities_of(run).shiftbank
    build = build_shift_bank if implicit else build_bank
    bank = build(theta, data, four_term=four)
    fids = run_bank(run, bank)
    return assemble_gradient(spec, bank, fids, labels)


def autodiff_grad(
    spec: CircuitSpec, theta: jnp.ndarray, data: jnp.ndarray, labels: jnp.ndarray
):
    """Exact gradient through the simulator (validation oracle for the rule)."""

    def loss_fn(t):
        f = fid.fidelity_batch(
            spec, jnp.broadcast_to(t, (data.shape[0],) + t.shape), data
        )
        return fid.bce_loss(f, labels).mean(), f

    (loss, f), g = jax.value_and_grad(loss_fn, has_aux=True)(theta)
    return loss, g, f
