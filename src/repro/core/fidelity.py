"""SWAP-test fidelity readout + fidelity-based loss (Quantum Measurement +
Quantum State Analyst modules of the paper's architecture, Fig 1).

After the SWAP test, P(ancilla = 0) = (1 + F) / 2 where
F = |<data|trainable>|^2, so F = 2 P0 - 1.  The paper's Quantum Measurement
module "calculates the fidelity from one ancilla qubit which is used to
calculate model loss".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sim
from repro.core.sim import CircuitSpec

_EPS = 1e-7


def ancilla_p0(spec: CircuitSpec, theta, data) -> jnp.ndarray:
    state = sim.run_circuit(spec, theta, data)
    return sim.marginal_p0(state, qubit=0, n_qubits=spec.n_qubits)


def fidelity(spec: CircuitSpec, theta, data) -> jnp.ndarray:
    """F = |<phi(data)|psi(theta)>|^2 in [0, 1] via the SWAP test."""
    return jnp.clip(2.0 * ancilla_p0(spec, theta, data) - 1.0, 0.0, 1.0)


def fidelity_batch(spec: CircuitSpec, theta, data) -> jnp.ndarray:
    """vmap over leading batch axes of both theta and data: (B,P),(B,D)->(B,)."""
    return jax.vmap(lambda t, d: fidelity(spec, t, d))(theta, data)


def bce_loss(fid: jnp.ndarray, label: jnp.ndarray) -> jnp.ndarray:
    """Binary cross-entropy with fidelity as p(class=1) (QuClassi's loss)."""
    f = jnp.clip(fid, _EPS, 1.0 - _EPS)
    return -(label * jnp.log(f) + (1.0 - label) * jnp.log(1.0 - f))


def bce_grad_wrt_fidelity(fid: jnp.ndarray, label: jnp.ndarray) -> jnp.ndarray:
    """dL/dF, evaluated classically by the Quantum State Analyst."""
    f = jnp.clip(fid, _EPS, 1.0 - _EPS)
    return (f - label) / (f * (1.0 - f))
