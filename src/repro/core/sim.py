"""Pure-JAX statevector simulator over (re, im) float32 pairs.

This is the reference data plane for DQuLearn: exact statevector simulation
of the few-qubit circuits the paper distributes (5 and 7 qubits in the
evaluation; anything up to ~20 qubits is fine on one device).

Layout convention: a state over ``n`` qubits is a pair of float32 arrays of
shape ``(..., 2**n)`` (leading axes = batch).  Qubit 0 is the MOST significant
bit of the basis index, matching how circuit diagrams are usually read
top-down: basis index = q0 q1 ... q_{n-1} in binary.

All functions are jit/vmap/grad-compatible; the circuit structure is static
Python, the angles are traced.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

from repro.core import gates as G

State = tuple[jnp.ndarray, jnp.ndarray]


def zero_state(n_qubits: int, batch: tuple[int, ...] = ()) -> State:
    dim = 2**n_qubits
    re = jnp.zeros(batch + (dim,), jnp.float32).at[..., 0].set(1.0)
    im = jnp.zeros(batch + (dim,), jnp.float32)
    return re, im


def apply_gate(state: State, u: G.Mat, qubits: Sequence[int], n_qubits: int) -> State:
    """Apply a k-qubit gate ``u`` to ``qubits`` of an n-qubit state.

    Works by viewing the state as a rank-n tensor of shape (2,)*n, moving the
    target axes to the front, contracting with the (2**k, 2**k) matrix, and
    moving axes back.  Batch axes are preserved.
    """
    re, im = state
    k = len(qubits)
    batch = re.shape[:-1]
    nb = len(batch)
    t = re.reshape(batch + (2,) * n_qubits), im.reshape(batch + (2,) * n_qubits)

    axes = [nb + q for q in qubits]
    rest = [nb + i for i in range(n_qubits) if i not in set(qubits)]
    perm = list(range(nb)) + axes + rest
    t_re = jnp.transpose(t[0], perm).reshape(batch + (2**k, -1))
    t_im = jnp.transpose(t[1], perm).reshape(batch + (2**k, -1))

    u_re, u_im = u
    # complex matmul: (U_re + i U_im) @ (t_re + i t_im)
    o_re = jnp.einsum("ij,...jk->...ik", u_re, t_re) - jnp.einsum(
        "ij,...jk->...ik", u_im, t_im
    )
    o_im = jnp.einsum("ij,...jk->...ik", u_re, t_im) + jnp.einsum(
        "ij,...jk->...ik", u_im, t_re
    )

    o_re = o_re.reshape(batch + (2,) * n_qubits)
    o_im = o_im.reshape(batch + (2,) * n_qubits)
    inv = [0] * (nb + n_qubits)
    for i, p in enumerate(perm):
        inv[p] = i
    o_re = jnp.transpose(o_re, inv).reshape(batch + (2**n_qubits,))
    o_im = jnp.transpose(o_im, inv).reshape(batch + (2**n_qubits,))
    return o_re, o_im


# ------------------------------------------------------------- circuit spec
@dataclasses.dataclass(frozen=True)
class Op:
    """One gate in a circuit.

    ``param`` selects the angle source:
      ("theta", j)  -> trainable parameter j
      ("data", j)   -> data-encoding angle j
      ("const", v)  -> fixed float angle v
      None          -> non-parameterized gate
    """
    gate: str
    qubits: tuple[int, ...]
    param: tuple | None = None

    def __post_init__(self):
        ctor, k, takes_angle = G.GATES[self.gate]
        assert len(self.qubits) == k, (self.gate, self.qubits)
        assert takes_angle == (self.param is not None), (self.gate, self.param)


@dataclasses.dataclass(frozen=True)
class CircuitSpec:
    """Static circuit structure: gates are Python data, angles are traced."""
    n_qubits: int
    ops: tuple[Op, ...]
    n_theta: int
    n_data: int

    def angle_of(self, op: Op, theta, data):
        kind, j = op.param
        if kind == "theta":
            return theta[..., j]
        if kind == "data":
            return data[..., j]
        if kind == "const":
            return jnp.asarray(j, jnp.float32)
        raise ValueError(op.param)


def run_circuit(spec: CircuitSpec, theta, data, state: State | None = None) -> State:
    """Execute ``spec`` from |0...0> (or ``state``). theta: (n_theta,), data: (n_data,)."""
    if state is None:
        state = zero_state(spec.n_qubits)
    for op in spec.ops:
        ctor, _, takes_angle = G.GATES[op.gate]
        u = ctor(spec.angle_of(op, theta, data)) if takes_angle else ctor()
        state = apply_gate(state, u, op.qubits, spec.n_qubits)
    return state


def probabilities(state: State) -> jnp.ndarray:
    re, im = state
    return re * re + im * im


def marginal_p0(state: State, qubit: int, n_qubits: int) -> jnp.ndarray:
    """P(measuring |0> on ``qubit``)."""
    p = probabilities(state)
    batch = p.shape[:-1]
    t = p.reshape(batch + (2,) * n_qubits)
    t = jnp.moveaxis(t, len(batch) + qubit, len(batch))
    return t.reshape(batch + (2, -1))[..., 0, :].sum(-1)


def state_norm(state: State) -> jnp.ndarray:
    return jnp.sqrt(probabilities(state).sum(-1))
