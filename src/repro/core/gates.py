"""Quantum gate matrices in (real, imag) float32 pairs.

TPU has no native complex arithmetic in the MXU/VPU datapath, so the whole
statevector stack represents complex tensors as a pair of float arrays
``(re, im)``.  Every gate constructor returns ``(U_re, U_im)`` with shape
``(2**k, 2**k)`` for a k-qubit gate.  Parameterized constructors accept a
scalar (or batched) angle and are fully traceable/differentiable.

Gate set = what DQuLearn's QuClassi workload needs (paper §IV-A):
  Single Qubit Unitary layer : RY, RZ          (+ RX for data encoding)
  Dual Qubit Unitary layer   : RYY, RZZ
  Entanglement Unitary layer : CRY, CRZ
  SWAP-test measurement      : H, CSWAP
"""
from __future__ import annotations

import jax.numpy as jnp

Mat = tuple[jnp.ndarray, jnp.ndarray]  # (re, im)

_SQRT2_INV = 0.7071067811865476


def _c(re, im) -> Mat:
    return jnp.asarray(re, jnp.float32), jnp.asarray(im, jnp.float32)


# ---------------------------------------------------------------- constants
def h() -> Mat:
    m = jnp.array([[1.0, 1.0], [1.0, -1.0]], jnp.float32) * _SQRT2_INV
    return m, jnp.zeros_like(m)


def x() -> Mat:
    m = jnp.array([[0.0, 1.0], [1.0, 0.0]], jnp.float32)
    return m, jnp.zeros_like(m)


def swap() -> Mat:
    m = (
        jnp.zeros((4, 4), jnp.float32)
        .at[0, 0]
        .set(1)
        .at[1, 2]
        .set(1)
        .at[2, 1]
        .set(1)
        .at[3, 3]
        .set(1)
    )
    return m, jnp.zeros_like(m)


def cswap() -> Mat:
    """Controlled-SWAP (Fredkin), control = first qubit of the 3."""
    m = jnp.eye(8, dtype=jnp.float32)
    # |1ab> -> |1ba>: swap basis indices 0b101 (5) and 0b110 (6).
    m = m.at[5, 5].set(0).at[6, 6].set(0).at[5, 6].set(1).at[6, 5].set(1)
    return m, jnp.zeros_like(m)


# ------------------------------------------------------------ rotations (1q)
def rx(theta) -> Mat:
    c = jnp.cos(theta / 2).astype(jnp.float32)
    s = jnp.sin(theta / 2).astype(jnp.float32)
    z = jnp.zeros_like(c)
    re = jnp.stack([jnp.stack([c, z]), jnp.stack([z, c])])
    im = jnp.stack([jnp.stack([z, -s]), jnp.stack([-s, z])])
    return re, im


def ry(theta) -> Mat:
    c = jnp.cos(theta / 2).astype(jnp.float32)
    s = jnp.sin(theta / 2).astype(jnp.float32)
    re = jnp.stack([jnp.stack([c, -s]), jnp.stack([s, c])])
    return re, jnp.zeros_like(re)


def rz(theta) -> Mat:
    c = jnp.cos(theta / 2).astype(jnp.float32)
    s = jnp.sin(theta / 2).astype(jnp.float32)
    z = jnp.zeros_like(c)
    re = jnp.stack([jnp.stack([c, z]), jnp.stack([z, c])])
    im = jnp.stack([jnp.stack([-s, z]), jnp.stack([z, s])])
    return re, im


# ------------------------------------------------------------ rotations (2q)
def ryy(theta) -> Mat:
    """exp(-i theta/2 Y⊗Y)."""
    c = jnp.cos(theta / 2).astype(jnp.float32)
    s = jnp.sin(theta / 2).astype(jnp.float32)
    z = jnp.zeros_like(c)
    re = jnp.stack(
        [
            jnp.stack([c, z, z, z]),
            jnp.stack([z, c, z, z]),
            jnp.stack([z, z, c, z]),
            jnp.stack([z, z, z, c]),
        ]
    )
    # Y⊗Y |00>=-|11>, |01>=|10> basis phases: exp(-i t/2 YY) has +i s on
    # (00,11),(11,00) and -i s on (01,10),(10,01).
    im = jnp.stack(
        [
            jnp.stack([z, z, z, s]),
            jnp.stack([z, z, -s, z]),
            jnp.stack([z, -s, z, z]),
            jnp.stack([s, z, z, z]),
        ]
    )
    return re, im


def rzz(theta) -> Mat:
    """exp(-i theta/2 Z⊗Z) = diag(e^-it/2, e^it/2, e^it/2, e^-it/2)."""
    c = jnp.cos(theta / 2).astype(jnp.float32)
    s = jnp.sin(theta / 2).astype(jnp.float32)
    z = jnp.zeros_like(c)
    re = jnp.stack(
        [
            jnp.stack([c, z, z, z]),
            jnp.stack([z, c, z, z]),
            jnp.stack([z, z, c, z]),
            jnp.stack([z, z, z, c]),
        ]
    )
    im = jnp.stack(
        [
            jnp.stack([-s, z, z, z]),
            jnp.stack([z, s, z, z]),
            jnp.stack([z, z, s, z]),
            jnp.stack([z, z, z, -s]),
        ]
    )
    return re, im


def _controlled(u: Mat) -> Mat:
    """diag(I2, U) for a 1q gate U -> 4x4, control = first qubit."""
    u_re, u_im = u
    re = jnp.eye(4, dtype=jnp.float32)
    re = re.at[2:, 2:].set(u_re)
    im = jnp.zeros((4, 4), jnp.float32).at[2:, 2:].set(u_im)
    return re, im


def cry(theta) -> Mat:
    return _controlled(ry(theta))


def crz(theta) -> Mat:
    return _controlled(rz(theta))


#: name -> (constructor, n_qubits, takes_angle)
GATES = {
    "h": (h, 1, False),
    "x": (x, 1, False),
    "swap": (swap, 2, False),
    "cswap": (cswap, 3, False),
    "rx": (rx, 1, True),
    "ry": (ry, 1, True),
    "rz": (rz, 1, True),
    "ryy": (ryy, 2, True),
    "rzz": (rzz, 2, True),
    "cry": (cry, 2, True),
    "crz": (crz, 2, True),
}
