"""Task Segmentation module (paper §III-A, Fig 2).

Decomposes a large classical input (an image) into filter-sized sections that
are small enough to encode on low-qubit quantum workers.  The paper's
evaluation settings: stride s=2, filter width w=4, nF=4 filters — "These
settings allowed for images small enough that they could be processed by the
lower qubit count computers."
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SegmentationConfig:
    filter_width: int = 4   # w in Algorithm 1
    stride: int = 2         # s in Algorithm 1
    n_filters: int = 4      # nF in Algorithm 1


def n_patches(height: int, width: int, cfg: SegmentationConfig) -> tuple[int, int]:
    """Patch grid dims after implicit zero-padding to cover the full image."""
    def count(sz):
        return max(1, -(-(sz - cfg.filter_width) // cfg.stride) + 1)
    return count(height), count(width)


def segment(images: jnp.ndarray, cfg: SegmentationConfig) -> jnp.ndarray:
    """(B, H, W) images -> (B, n_patches, w*w) flattened sections.

    Sections are extracted in row-major order with stride ``cfg.stride`` and
    zero padding on the bottom/right edges ("there might be padding between
    the sections", paper Fig 2).  Static shapes only — jit-safe.
    """
    b, h, w = images.shape
    ph, pw = n_patches(h, w, cfg)
    need_h = (ph - 1) * cfg.stride + cfg.filter_width
    need_w = (pw - 1) * cfg.stride + cfg.filter_width
    x = jnp.pad(images, ((0, 0), (0, need_h - h), (0, need_w - w)))

    rows = []
    for i in range(ph):
        for j in range(pw):
            r, c = i * cfg.stride, j * cfg.stride
            rows.append(
                x[:, r : r + cfg.filter_width, c : c + cfg.filter_width].reshape(b, -1)
            )
    return jnp.stack(rows, axis=1)  # (B, ph*pw, w*w)


def reassemble_coverage(height: int, width: int, cfg: SegmentationConfig) -> np.ndarray:
    """How many patches cover each source pixel (property-test helper)."""
    ph, pw = n_patches(height, width, cfg)
    need_h = (ph - 1) * cfg.stride + cfg.filter_width
    need_w = (pw - 1) * cfg.stride + cfg.filter_width
    cov = np.zeros((need_h, need_w), np.int32)
    for i in range(ph):
        for j in range(pw):
            r, c = i * cfg.stride, j * cfg.stride
            cov[r:r + cfg.filter_width, c:c + cfg.filter_width] += 1
    return cov[:height, :width]


def subtasks_per_image(height: int, width: int, cfg: SegmentationConfig) -> int:
    ph, pw = n_patches(height, width, cfg)
    return ph * pw * cfg.n_filters
