"""The DQuLearn training workload: a quantum-classical CNN classifier
(QuClassi [29] as used by the paper, Algorithm 1).

Pipeline per image:
  Task Segmentation -> patches (B, Np, w*w)
  classical dense layer -> data-encoding angles per patch (Algorithm 1 l.10)
  per class c: SWAP-test fidelity F_c(patch) against trainable register theta_c
  class score = mean over patches of F_c; one-vs-all BCE loss.

Two gradient paths:
  * ``grad_shift``    — the paper's distributed path: parameter-shift circuit
    bank per class, executable by any ``Executor`` (locally, or routed
    through the co-Manager to quantum workers).
  * ``grad_autodiff`` — exact gradients through the simulator; used as the
    fast local path and the correctness oracle (identical for single/dual
    layers where the 2-term rule is exact).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.api.capabilities import capabilities_of
from repro.core import circuits, fidelity as fid, segmentation, shift_rule
from repro.core.sim import CircuitSpec


@dataclasses.dataclass(frozen=True)
class QuClassiConfig:
    qc: int = 5                   # qubit count (paper: 5 or 7)
    n_layers: int = 1             # 1..3 (single / +dual / +entangle)
    n_classes: int = 2
    seg: segmentation.SegmentationConfig = segmentation.SegmentationConfig()
    image_size: tuple[int, int] = (8, 8)   # paper downsamples MNIST patches
    use_dense: bool = True

    @property
    def spec(self) -> CircuitSpec:
        return circuits.build_quclassi_circuit(self.qc, self.n_layers)

    @property
    def n_theta(self) -> int:
        return circuits.n_theta_for(self.qc, self.n_layers)

    @property
    def n_angles(self) -> int:
        return circuits.n_data_angles_for(self.qc)

    @property
    def patch_dim(self) -> int:
        return self.seg.filter_width**2

    @property
    def n_patches(self) -> int:
        ph, pw = segmentation.n_patches(*self.image_size, self.seg)
        return ph * pw


def init_params(cfg: QuClassiConfig, key: jax.Array) -> dict:
    """Network weights: theta ~ U[0, pi] per class (Algorithm 1 l.2)."""
    k1, k2 = jax.random.split(key)
    params = {
        "theta": jax.random.uniform(
            k1, (cfg.n_classes, cfg.n_theta), minval=0.0, maxval=jnp.pi
        ),
    }
    if cfg.use_dense:
        scale = 1.0 / jnp.sqrt(cfg.patch_dim)
        params["w"] = jax.random.normal(k2, (cfg.patch_dim, cfg.n_angles)) * scale
        params["b"] = jnp.zeros((cfg.n_angles,))
    return params


def encode_patches(
    cfg: QuClassiConfig, params: dict, patches: jnp.ndarray
) -> jnp.ndarray:
    """(B, Np, w*w) patches -> (B, Np, n_angles) rotation angles."""
    if cfg.use_dense:
        z = patches @ params["w"] + params["b"]            # dense layer (l.10-11)
        return jnp.pi * jax.nn.sigmoid(z)
    from repro.core import encoding
    return encoding.rotation_angles(patches, cfg.n_angles)


def class_fidelities(
    cfg: QuClassiConfig, params: dict, images: jnp.ndarray
) -> jnp.ndarray:
    """(B, H, W) images -> (B, n_classes) mean patch fidelity per class."""
    spec = cfg.spec
    patches = segmentation.segment(images, cfg.seg)        # (B, Np, P)
    angles = encode_patches(cfg, params, patches)          # (B, Np, A)
    flat = angles.reshape(-1, angles.shape[-1])            # (B*Np, A)

    def per_class(theta):
        t = jnp.broadcast_to(theta, (flat.shape[0],) + theta.shape)
        f = fid.fidelity_batch(spec, t, flat)              # (B*Np,)
        return f.reshape(angles.shape[0], -1).mean(-1)     # (B,)

    return jax.vmap(per_class)(params["theta"]).T          # (B, C)


def one_vs_all_loss(fids: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """fids (B, C), integer labels (B,) -> scalar mean BCE over classes."""
    onehot = jax.nn.one_hot(labels, fids.shape[-1])
    return fid.bce_loss(fids, onehot).mean()


def predict(cfg: QuClassiConfig, params: dict, images: jnp.ndarray) -> jnp.ndarray:
    return class_fidelities(cfg, params, images).argmax(-1)


def accuracy(cfg: QuClassiConfig, params: dict, images, labels) -> jnp.ndarray:
    return (predict(cfg, params, images) == labels).mean()


# ------------------------------------------------------------ gradient paths
def grad_autodiff(cfg: QuClassiConfig, params: dict, images, labels):
    """Exact gradients for all parameters (dense + quantum) via the simulator."""
    def loss_fn(p):
        f = class_fidelities(cfg, p, images)
        return one_vs_all_loss(f, labels), f
    (loss, f), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
    return loss, g, f


def build_class_banks(
    cfg: QuClassiConfig, params: dict, images: jnp.ndarray, implicit: bool = False
):
    """The distributable work unit: one circuit bank per class (Algorithm 1).

    Returns (banks, angles) where banks[c] covers every (patch, shifted-theta)
    circuit for class c.  Total circuits = C * (B*Np) * (2*P + 1).

    ``implicit=True`` builds ``ShiftBank``s — base angles + shift descriptors
    only, never the (C, P) theta matrix; ``shiftbank``-capable executors run
    them with the prefix-reuse kernel, everything else via ``materialize()``.
    """
    patches = segmentation.segment(images, cfg.seg)
    angles = encode_patches(cfg, params, patches).reshape(-1, cfg.n_angles)
    build = shift_rule.build_shift_bank if implicit else shift_rule.build_bank
    banks = [build(params["theta"][c], angles) for c in range(cfg.n_classes)]
    return banks, angles


def grad_shift(
    cfg: QuClassiConfig,
    params: dict,
    images,
    labels,
    executor: shift_rule.Executor | None = None,
    implicit: bool | None = None,
):
    """Paper-faithful distributed gradient: execute per-class circuit banks
    (optionally through the co-Manager) and assemble theta gradients.

    ``implicit``: route through implicit ``ShiftBank``s (None = auto: exactly
    when the executor declares the ``shiftbank`` capability).

    Dense-layer params, when present, are trained with exact chain-rule
    gradients holding theta fixed (autodiff through the data-encoding path) —
    see DESIGN.md §2 for why this mirrors the paper's classical update.
    """
    spec = cfg.spec
    run = executor or shift_rule.default_executor(spec)
    if implicit is None:
        implicit = capabilities_of(run).shiftbank
    banks, _ = build_class_banks(cfg, params, images, implicit=implicit)
    onehot = jax.nn.one_hot(labels, cfg.n_classes)
    b, np_ = images.shape[0], cfg.n_patches

    theta_grads, losses, fids_per_class = [], [], []
    for c, bank in enumerate(banks):
        fids = shift_rule.run_bank(run, bank)
        f0, f_plus, f_minus = bank.split_results(fids)[:3]
        # class score per image = mean patch fidelity (matches
        # class_fidelities); chain BCE through the per-image MEAN, then
        # distribute to the per-patch shift-rule estimates.
        f_img = f0.reshape(b, np_).mean(-1)                       # (B,)
        dfdt = (f_plus - f_minus) / 2.0                           # (P, B*Np)
        df_img = dfdt.reshape(-1, b, np_).mean(-1)                # (P, B)
        chain = fid.bce_grad_wrt_fidelity(f_img, onehot[:, c])    # (B,)
        # 1/(B*C) normalization to match one_vs_all_loss's mean over (B, C)
        theta_grads.append((df_img * chain[None, :]).mean(-1) / cfg.n_classes)
        losses.append(fid.bce_loss(f_img, onehot[:, c]).mean())
        fids_per_class.append(f_img)

    grads = {"theta": jnp.stack(theta_grads)}
    if cfg.use_dense:
        def dense_loss(wb):
            p2 = dict(params, **wb)
            f = class_fidelities(cfg, p2, images)
            return one_vs_all_loss(f, labels)
        gw = jax.grad(dense_loss)({"w": params["w"], "b": params["b"]})
        grads.update(gw)
    loss = jnp.stack(losses).mean()
    return loss, grads, jnp.stack(fids_per_class, -1)


def total_bank_circuits(cfg: QuClassiConfig, batch: int) -> int:
    """Circuits per gradient step — the workload the co-Manager schedules."""
    per_class = batch * cfg.n_patches * (2 * cfg.n_theta + 1)
    return cfg.n_classes * per_class
