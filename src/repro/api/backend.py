"""The unified ``ExecutionBackend`` protocol + adapters for every executor
family.

DQuLearn grew five duck-typed executor factories (``worker_batched_executor``,
``worker_pool_executor``, ``worker_multibank_executor``, ``sharded_executor``,
``MeshSpillExecutor``), each advertising what it can consume through ad-hoc
attributes.  The protocol here replaces that with one contract:

    capabilities() -> Capabilities     what the backend consumes natively
    run_rows(theta, data) -> fids      materialized (C, P)/(C, D) row batches
    run_bank(bank) -> fids             one bank (implicit or materialized)
    run_bank_set(banks) -> [fids, ...] same-spec bank sets (fused when able)
    cost_model() -> CostModel          analytic work / VMEM estimates

Every adapter is ALSO a legacy ``shift_rule.Executor`` callable (``__call__``
dispatches on the argument shape), so the protocol objects drop into every
existing dispatch site — ``shift_rule.run_bank``, ``grad_shift(executor=)``,
``train(executor=)`` — unchanged, and ``capabilities_of`` reads their
declaration without the deprecated attribute probes.
"""

from __future__ import annotations

import math
from typing import Protocol, Sequence, runtime_checkable

from repro.api.capabilities import Capabilities, capabilities_of
from repro.core import shift_rule
from repro.core.sim import CircuitSpec
from repro.kernels.vqc_statevector import (
    LANES,
    shift_cost_info,
    shift_execution_info,
)


@runtime_checkable
class ExecutionBackend(Protocol):
    """The one contract every executor family implements (via the adapters
    below) and every dispatch layer consumes."""

    def capabilities(self) -> Capabilities: ...

    def run_rows(self, theta_bank, data_bank): ...

    def run_bank(self, bank): ...

    def run_bank_set(self, banks) -> list: ...

    def cost_model(self) -> "CostModel": ...


# ------------------------------------------------------- analytic cost model
class CostModel:
    """Analytic per-bank cost estimates, comparable across backends.

    ``bank_cost_units``: gate applications x padded kernel lanes — the same
    unit ``serve.dispatcher.batch_cost_units`` charges to worker CRU, so a
    backend's estimate slots straight into the serving EWMA.  Shift-capable
    backends pay the analytic prefix-reuse cost from
    ``kernels.shift_cost_info`` (data pass + forward pass + deepest suffix
    + each variant's replay span — one gate for single-use parameters, the
    [first, last] dependent span for multi-use ones); everything else pays
    the full gate sequence per materialized row.  Multi-use-param banks are
    therefore no longer mis-charged the full materialized cost.

    ``bank_vmem_bytes``: modeled per-device VMEM working set (post
    depth-tile spilling for shift banks), divided over ``n_shards`` for
    mesh-sharded backends.
    """

    def __init__(self, *, shiftbank: bool, n_shards: int = 1):
        self.shiftbank = shiftbank
        self.n_shards = max(1, n_shards)

    @staticmethod
    def _lanes(n: int) -> int:
        return math.ceil(n / LANES) * LANES

    def _materialized_units(self, spec: CircuitSpec, n_circuits: int) -> float:
        return float(len(spec.ops) * self._lanes(n_circuits))

    def bank_cost_units(self, spec: CircuitSpec, bank) -> float:
        if not isinstance(bank, shift_rule.ShiftBank) or not self.shiftbank:
            n = bank.n_circuits
            return self._materialized_units(spec, n) / self.n_shards
        cost = shift_cost_info(spec, bank.four_term)
        if not cost["use_implicit"]:  # no structure / replay dearer: materialize
            return self._materialized_units(spec, bank.n_circuits) / self.n_shards
        gate_apps = cost["gate_apps_implicit"]
        return float(gate_apps * self._lanes(bank.n_samples)) / self.n_shards

    def bank_vmem_bytes(self, spec: CircuitSpec, bank) -> int:
        if isinstance(bank, shift_rule.ShiftBank) and self.shiftbank:
            lanes = self._lanes(math.ceil(bank.n_samples / self.n_shards))
            info = shift_execution_info(spec, lanes, four_term=bank.four_term)
            return info["vmem_bytes"]
        lanes = self._lanes(math.ceil(bank.n_circuits / self.n_shards))
        from repro.kernels.vqc_statevector import _state_bytes, kernel_tb

        return _state_bytes(spec.n_qubits, kernel_tb(lanes))


# ------------------------------------------------------------- adapter base
class _BackendBase:
    """Shared ``ExecutionBackend`` plumbing.

    Subclasses provide ``_rows_executor(n_rows)`` and (when shift-capable)
    ``_bank_executor(bank)`` returning legacy callables; the base supplies
    the protocol surface, the bank-set fallback loop, and the legacy
    ``__call__`` compatibility so adapters remain drop-in
    ``shift_rule.Executor``s.
    """

    _caps = Capabilities()
    _n_shards = 1

    def __init__(self, spec: CircuitSpec):
        self.spec = spec

    # -- protocol surface
    def capabilities(self) -> Capabilities:
        return self._caps

    def cost_model(self) -> CostModel:
        return CostModel(shiftbank=self._caps.shiftbank, n_shards=self._n_shards)

    def run_rows(self, theta_bank, data_bank):
        return self._rows_executor(theta_bank.shape[0])(theta_bank, data_bank)

    def run_bank(self, bank):
        if isinstance(bank, shift_rule.ShiftBank) and self._caps.shiftbank:
            return self._bank_executor(bank)(bank)
        if isinstance(bank, shift_rule.ShiftBank):
            bank = bank.materialize()
        return self.run_rows(bank.theta, bank.data)

    def run_bank_set(self, banks) -> list:
        return [self.run_bank(b) for b in banks]

    def close(self) -> None:
        pass

    # -- legacy Executor compatibility: adapters drop into every existing
    #    dispatch site (run_bank / run_bank_set / grad_shift / train).
    def __call__(self, x, data_bank=None):
        if data_bank is not None:
            return self.run_rows(x, data_bank)
        if isinstance(x, shift_rule.ShiftBank):
            return self.run_bank(x)
        if isinstance(x, shift_rule.CircuitBank):
            return self.run_rows(x.theta, x.data)
        if isinstance(x, (list, tuple)):
            return self.run_bank_set(x)
        raise TypeError(
            f"cannot execute {type(x).__name__}: expected a bank, a bank "
            f"sequence, or (theta_bank, data_bank)"
        )

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class _WorkerBackendBase(_BackendBase):
    """Per-worker scheduling backends (batched / pooled).

    ``assignment`` pins a fixed unit->worker map (rows of a materialized
    bank, (param, shift) groups of an implicit one); when omitted, each
    call derives a round-robin assignment for its own unit count, so one
    backend serves banks of any size.  Underlying executors are cached per
    (unit count, assignment) — they bind the grouping permutation at
    construction.
    """

    def __init__(
        self,
        spec: CircuitSpec,
        n_workers: int = 4,
        assignment: Sequence[int] | None = None,
    ):
        super().__init__(spec)
        self.n_workers = n_workers
        self.assignment = None if assignment is None else tuple(assignment)
        self._executors: dict[tuple, object] = {}

    def _make(self, assignment):
        raise NotImplementedError

    def _executor_for(self, n_units: int):
        from repro.comanager.dataplane import round_robin_assignment

        a = self.assignment or tuple(round_robin_assignment(n_units, self.n_workers))
        key = (n_units, a)
        if key not in self._executors:
            self._executors[key] = self._make(a)
        return self._executors[key]

    def _rows_executor(self, n_rows: int):
        if self.assignment is not None and len(self.assignment) != n_rows:
            # the underlying executor validates bank-shaped inputs itself,
            # but the row path would silently run only the assigned rows.
            raise ValueError(
                f"pinned assignment covers {len(self.assignment)} rows, "
                f"got a {n_rows}-row bank"
            )
        return self._executor_for(n_rows)

    def _bank_executor(self, bank):
        return self._executor_for(bank.n_groups)


class BatchedWorkerBackend(_WorkerBackendBase):
    """Adapter over ``dataplane.worker_batched_executor``: sequential
    per-worker fused-kernel groups, shift-aware via per-group scheduling."""

    _caps = Capabilities(shiftbank=True, vmem_model=True)

    def _make(self, assignment):
        from repro.comanager.dataplane import worker_batched_executor

        return worker_batched_executor(self.spec, assignment, self.n_workers)


class PooledWorkerBackend(_WorkerBackendBase):
    """Adapter over ``dataplane.worker_pool_executor``: per-worker groups
    overlap on a thread pool; results stay bit-identical to the sequential
    path.  ``close()`` shuts every cached executor's pool down."""

    _caps = Capabilities(shiftbank=True, vmem_model=True)

    def __init__(
        self,
        spec: CircuitSpec,
        n_workers: int = 4,
        assignment: Sequence[int] | None = None,
        max_threads: int | None = None,
    ):
        super().__init__(spec, n_workers, assignment)
        self.max_threads = max_threads

    def _make(self, assignment):
        from repro.comanager.dataplane import worker_pool_executor

        return worker_pool_executor(
            self.spec, assignment, self.n_workers, max_threads=self.max_threads
        )

    def close(self) -> None:
        for run in self._executors.values():
            run.close()
        self._executors.clear()


class MultibankWorkerBackend(_WorkerBackendBase):
    """Adapter over ``dataplane.worker_multibank_executor``: the schedulable
    unit is the (bank, group) subtask of a same-spec bank SET, and each
    worker executes all its subtasks as one fused multi-bank launch."""

    _caps = Capabilities(shiftbank=True, multibank=True, vmem_model=True)

    def _make(self, assignment):
        from repro.comanager.dataplane import worker_multibank_executor

        return worker_multibank_executor(self.spec, assignment, self.n_workers)

    def run_bank_set(self, banks) -> list:
        banks = list(banks)
        if not all(isinstance(b, shift_rule.ShiftBank) for b in banks):
            # materialized banks have no (bank, group) structure to fuse
            return [self.run_bank(b) for b in banks]
        n_subtasks = sum(b.n_groups for b in banks)
        return list(self._executor_for(n_subtasks)(banks))

    def run_bank(self, bank):
        if isinstance(bank, shift_rule.ShiftBank):
            return self.run_bank_set([bank])[0]
        return super().run_bank(bank)

    def _rows_executor(self, n_rows: int):
        # row batches have no (bank, group) structure: route them through
        # the per-worker batched path with the same worker count.
        from repro.comanager.dataplane import (
            round_robin_assignment,
            worker_batched_executor,
        )

        if self.assignment is not None and len(self.assignment) != n_rows:
            raise ValueError(
                f"pinned assignment covers {len(self.assignment)} rows, "
                f"got a {n_rows}-row bank"
            )
        key = ("rows", n_rows)
        if key not in self._executors:
            self._executors[key] = worker_batched_executor(
                self.spec,
                self.assignment
                or round_robin_assignment(n_rows, self.n_workers),
                self.n_workers,
            )
        return self._executors[key]


class ShardedBackend(_BackendBase):
    """Adapter over ``dataplane.sharded_executor``: whole banks shard over
    one mesh axis with ``shard_map``; bank sets fuse through ``run_banks``
    with lane segments sharded the same way."""

    _caps = Capabilities(shiftbank=True, multibank=True, sharded=True, vmem_model=True)

    def __init__(self, spec: CircuitSpec, mesh=None, axis: str = "data"):
        super().__init__(spec)
        if mesh is None:
            from repro.launch.mesh import make_data_mesh

            mesh = make_data_mesh()
        self.mesh = mesh
        self.axis = axis
        self._n_shards = mesh.shape[axis]
        from repro.comanager.dataplane import sharded_executor

        self._run = sharded_executor(spec, mesh, axis)

    def _rows_executor(self, n_rows: int):
        return self._run

    def _bank_executor(self, bank):
        return self._run

    def run_bank_set(self, banks) -> list:
        banks = list(banks)
        if not all(isinstance(b, shift_rule.ShiftBank) for b in banks):
            return [self.run_bank(b) for b in banks]
        if len({b.four_term for b in banks}) > 1:
            raise ValueError("banks in one fused set must share four_term")
        group_sets = tuple(tuple(range(b.n_groups)) for b in banks)
        outs = self._run.run_banks(
            tuple(b.theta for b in banks),
            tuple(b.data for b in banks),
            banks[0].four_term,
            group_sets,
        )
        return [o.reshape(-1) for o in outs]


class MeshSpillBackend(_BackendBase):
    """Adapter over ``dataplane.MeshSpillExecutor``: the whole-mesh escape
    hatch for mega-batches that fit no single worker.  Per-spec sharded
    executors build lazily inside the spill executor, so one backend (and
    one shard_map trace per structure) serves every circuit spec."""

    _caps = Capabilities(
        shiftbank=True,
        multibank=True,
        sharded=True,
        vmem_model=True,
        mesh_spill=True,
    )

    def __init__(self, spec: CircuitSpec, mesh=None, axis: str = "data"):
        super().__init__(spec)
        from repro.comanager.dataplane import MeshSpillExecutor

        if mesh is None:
            # match ShardedBackend: spill onto ALL local devices by default
            # (MeshSpillExecutor's own fallback is the degenerate 1x1 mesh).
            from repro.launch.mesh import make_data_mesh

            mesh = make_data_mesh()
        self.executor = MeshSpillExecutor(mesh, axis)
        self._n_shards = self.executor.mesh.shape[axis]

    def run_rows(self, theta_bank, data_bank):
        return self.executor.rows(self.spec, theta_bank, data_bank)

    def run_bank(self, bank):
        if not isinstance(bank, shift_rule.ShiftBank):
            return self.run_rows(bank.theta, bank.data)
        groups = tuple(range(bank.n_groups))
        out = self.executor.banks(
            self.spec, (bank.theta,), (bank.data,), bank.four_term, (groups,)
        )
        return out[0].reshape(-1)

    def run_bank_set(self, banks) -> list:
        banks = list(banks)
        if not all(isinstance(b, shift_rule.ShiftBank) for b in banks):
            return [self.run_bank(b) for b in banks]
        if len({b.four_term for b in banks}) > 1:
            raise ValueError("banks in one fused set must share four_term")
        outs = self.executor.banks(
            self.spec,
            tuple(b.theta for b in banks),
            tuple(b.data for b in banks),
            banks[0].four_term,
            tuple(tuple(range(b.n_groups)) for b in banks),
        )
        return [o.reshape(-1) for o in outs]


# ----------------------------------------------------------- legacy bridge
class CallableBackend(_BackendBase):
    """Wrap a legacy ``shift_rule.Executor`` callable as an
    ``ExecutionBackend``.  Capabilities come from ``capabilities_of`` — i.e.
    a declaration when the callable has one, else the deprecation shim's
    reading of the old duck-typed attributes."""

    def __init__(self, spec: CircuitSpec, run):
        super().__init__(spec)
        self._run = run
        self._caps = capabilities_of(run)

    def run_rows(self, theta_bank, data_bank):
        return self._run(theta_bank, data_bank)

    def run_bank(self, bank):
        return shift_rule.run_bank(self._run, bank)

    def run_bank_set(self, banks) -> list:
        return shift_rule.run_bank_set(self._run, banks)

    def close(self) -> None:
        close = getattr(self._run, "close", None)
        if close is not None:
            close()


def as_backend(executor, spec: CircuitSpec | None = None) -> ExecutionBackend:
    """Coerce anything executor-shaped to an ``ExecutionBackend``.

    Protocol objects pass through; legacy callables (declared or
    duck-typed) wrap in ``CallableBackend`` — ``spec`` is required for
    those, since the cost model and row padding are per-structure."""
    if isinstance(executor, ExecutionBackend):
        return executor
    if spec is None:
        raise TypeError(
            "wrapping a legacy executor callable requires the CircuitSpec "
            "it executes (as_backend(run, spec))"
        )
    return CallableBackend(spec, executor)


#: the five executor families, by name — the facade's backend factory.
BACKEND_KINDS = {
    "batched": BatchedWorkerBackend,
    "pooled": PooledWorkerBackend,
    "multibank": MultibankWorkerBackend,
    "sharded": ShardedBackend,
    "mesh_spill": MeshSpillBackend,
}


def make_backend(kind: str, spec: CircuitSpec, **kw) -> ExecutionBackend:
    """Build one of the five adapter families by name."""
    try:
        cls = BACKEND_KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown backend kind {kind!r}; choose from "
            f"{sorted(BACKEND_KINDS)}"
        ) from None
    return cls(spec, **kw)


__all__ = [
    "BACKEND_KINDS",
    "BatchedWorkerBackend",
    "CallableBackend",
    "CostModel",
    "ExecutionBackend",
    "MeshSpillBackend",
    "MultibankWorkerBackend",
    "PooledWorkerBackend",
    "ShardedBackend",
    "as_backend",
    "make_backend",
]
