"""Typed configuration for the tenant-session facade.

These dataclasses replace the kwarg piles that accreted on the old entry
points: ``TenantPolicy`` carries what ``Gateway.register_client`` /
``train(priority=, slo_ms=)`` took loose, ``ServingConfig`` what
``GatewayRuntime.__init__`` took loose, and ``SimulationConfig`` the
virtual-clock knobs of ``SystemSimulation``'s 19-kwarg ``__init__``.
``ClusterConfig`` bundles them with the worker fleet — one object that the
``QuantumCluster`` facade consumes for serving, training, and simulation
alike.  Validation happens at construction, so a typo fails where it is
written instead of deep inside a runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.comanager.faults import FaultToleranceConfig
from repro.comanager.worker import WorkerConfig
from repro.obs.config import ObservabilityConfig

#: default heterogeneous fleet (matches the paper's 5/10/15/20-qubit
#: workers and GatewayRuntime's historical default).
DEFAULT_WORKER_QUBITS = (5, 10, 15, 20)


def default_workers() -> tuple[WorkerConfig, ...]:
    return tuple(
        WorkerConfig(f"w{i + 1}", q) for i, q in enumerate(DEFAULT_WORKER_QUBITS)
    )


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant scheduling contract.

    ``priority``: strict tier (lower = served strictly first).
    ``slo_ms``: end-to-end latency SLO; shortens coalescer flush deadlines
    and arms deadline-miss accounting.  ``weight``: weighted-fair share
    within the tier.  ``max_pending`` / ``max_in_flight``: backpressure
    bounds (None = gateway defaults).
    """

    priority: int = 1
    slo_ms: Optional[float] = None
    weight: float = 1.0
    max_pending: Optional[int] = None
    max_in_flight: Optional[int] = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"weight must be positive, got {self.weight}")
        if self.slo_ms is not None and self.slo_ms <= 0:
            raise ValueError(f"slo_ms must be positive, got {self.slo_ms}")
        # the gateway treats 0 as "use the default", so bounds must be >= 1
        # (None = gateway default) — reject both 0 and negatives here.
        if self.max_pending is not None and self.max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {self.max_pending}")
        if self.max_in_flight is not None and self.max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {self.max_in_flight}"
            )

    def register_kwargs(self) -> dict:
        """The ``Gateway.register_client`` keyword view of this policy."""
        kw = dict(weight=self.weight, priority=self.priority, slo_ms=self.slo_ms)
        if self.max_pending is not None:
            kw["max_pending"] = self.max_pending
        if self.max_in_flight is not None:
            kw["max_in_flight"] = self.max_in_flight
        return kw


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Real-execution serving runtime shape (was ``GatewayRuntime`` kwargs).

    ``mode``: "sync" (inline execution) or "async" (pump thread +
    per-worker execution slots).  ``target`` / ``deadline``: coalescer
    size trigger and flush deadline.  ``mesh_spill`` routes oversized
    batches to the whole-mesh executor; ``evict_over_slo`` (async only)
    sheds fully-expired batches with ``DeadlineExceeded``.
    """

    target: Optional[int] = None
    deadline: float = 1.0
    mode: str = "sync"
    slots_per_worker: int = 1
    mesh_spill: bool = True
    worker_vmem_bytes: Optional[int] = None
    evict_over_slo: bool = False
    #: global weighted-fair admission cap: total outstanding circuits
    #: (queued + in flight) across all tenants; above it, a tenant at or
    #: over its weighted share gets ``Backpressure`` at submit.  Calibrate
    #: at the throughput knee with ``repro.scale.knee`` (None = never shed).
    max_system_pending: Optional[int] = None
    #: per-priority-tier outstanding caps (tier -> cap): bounds each tier's
    #: queued + in-flight circuits independently of the global cap, so a
    #: low-tier burst cannot consume a high tier's admission headroom;
    #: shedding is weighted-fair within the tier.  None = no tier caps.
    max_pending_per_tier: Optional[dict[int, int]] = None
    #: tracing + metrics knobs (None = trace everything at the defaults;
    #: ``ObservabilityConfig.disabled()`` turns the recorder off).
    observability: Optional[ObservabilityConfig] = None
    #: retry / migration / hedging / circuit-breaker knobs (None = the
    #: ``FaultToleranceConfig`` defaults: 1 in-place retry, no hedging,
    #: breaker trips after 3 consecutive failures).
    fault_tolerance: Optional[FaultToleranceConfig] = None

    def __post_init__(self):
        if self.mode not in ("sync", "async"):
            raise ValueError(
                f"mode must be 'sync' or 'async', got {self.mode!r}"
            )
        if self.evict_over_slo and self.mode != "async":
            raise ValueError(
                "evict_over_slo requires mode='async' (the sync dispatcher "
                "has no ready queue)"
            )
        if self.slots_per_worker < 1:
            raise ValueError(
                f"slots_per_worker must be >= 1, got {self.slots_per_worker}"
            )
        if self.max_system_pending is not None and self.max_system_pending < 1:
            raise ValueError(
                f"max_system_pending must be >= 1, got {self.max_system_pending}"
            )
        if self.max_pending_per_tier is not None:
            for tier, cap in self.max_pending_per_tier.items():
                if cap < 1:
                    raise ValueError(
                        f"max_pending_per_tier[{tier}] must be >= 1, got {cap}"
                    )
        if self.target is not None:
            # fail where the typo is written, not at first (lazy) runtime
            # construction deep inside the coalescer.
            from repro.kernels.vqc_statevector import LANES

            if self.target <= 0 or self.target % LANES:
                raise ValueError(
                    f"target {self.target} must be a positive multiple of "
                    f"the kernel lane width {LANES}"
                )

    def runtime_kwargs(self) -> dict:
        """The ``GatewayRuntime`` keyword view of this config."""
        kw = dict(
            target=self.target,
            deadline=self.deadline,
            mode=self.mode,
            slots_per_worker=self.slots_per_worker,
            mesh_spill=self.mesh_spill,
            evict_over_slo=self.evict_over_slo,
            observability=self.observability,
            fault_tolerance=self.fault_tolerance,
        )
        if self.worker_vmem_bytes is not None:
            kw["worker_vmem_bytes"] = self.worker_vmem_bytes
        if self.max_system_pending is not None:
            kw["max_system_pending"] = self.max_system_pending
        if self.max_pending_per_tier is not None:
            kw["max_pending_per_tier"] = dict(self.max_pending_per_tier)
        return kw


@dataclasses.dataclass(frozen=True)
class SimulationConfig:
    """Virtual-clock runtime knobs (was ``SystemSimulation``'s kwarg pile).

    Field semantics are unchanged from ``SystemSimulation.__init__`` — see
    its docstring for the calibration notes; this object just makes the
    pile typed, defaulted, and reusable across runs.
    """

    env: str = "ibmq"
    tenancy: Optional[str] = None
    multi_tenant: bool = True
    policy: str = "cru"
    fidelity_floor: float = 0.0
    eager_completion: bool = True
    heartbeat_period: float = 5.0
    assign_latency: float = 0.01
    classical_overhead: float = 0.0
    lockstep: bool = False
    fair_queue: bool = False
    run_until: float = 1e7
    gateway: bool = False
    gateway_target: Optional[int] = None
    gateway_deadline: float = 1.0
    gateway_async: bool = False
    #: per-tenant admission queue bound (None = gateway default).
    gateway_max_pending: Optional[int] = None
    #: global weighted-fair outstanding cap — the knee-calibrated admission
    #: control (``repro.scale.knee``); None = admit everything.
    gateway_max_system_pending: Optional[int] = None
    #: per-priority-tier outstanding caps (tier -> cap); None = no tier caps.
    gateway_max_pending_per_tier: Optional[dict[int, int]] = None
    #: gateway-mode tracing + metrics knobs (None = trace everything).
    observability: Optional[ObservabilityConfig] = None

    def __post_init__(self):
        if self.tenancy is not None and self.tenancy not in (
            "multi",
            "single_circuit",
            "user_exclusive",
        ):
            raise ValueError(f"unknown tenancy {self.tenancy!r}")
        if self.policy not in ("cru", "noise_aware"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.gateway_target is not None:
            from repro.kernels.vqc_statevector import LANES

            if self.gateway_target <= 0 or self.gateway_target % LANES:
                raise ValueError(
                    f"gateway_target {self.gateway_target} must be a "
                    f"positive multiple of the kernel lane width {LANES}"
                )
        for name in ("gateway_max_pending", "gateway_max_system_pending"):
            value = getattr(self, name)
            if value is not None and value < 1:
                raise ValueError(f"{name} must be >= 1, got {value}")
        if self.gateway_max_pending_per_tier is not None:
            for tier, cap in self.gateway_max_pending_per_tier.items():
                if cap < 1:
                    raise ValueError(
                        f"gateway_max_pending_per_tier[{tier}] must be >= 1, "
                        f"got {cap}"
                    )

    def simulation_kwargs(self) -> dict:
        """The ``SystemSimulation`` keyword view of this config.

        Shallow on purpose: ``dataclasses.asdict`` would deep-convert the
        nested ``ObservabilityConfig`` into a plain dict, and the simulation
        passes it through to the trace recorder as the typed object."""
        return {
            f.name: getattr(self, f.name) for f in dataclasses.fields(self)
        }


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One typed object describing the whole co-managed system: the worker
    fleet plus the serving and simulation runtime shapes."""

    workers: tuple[WorkerConfig, ...] = dataclasses.field(
        default_factory=default_workers
    )
    serving: ServingConfig = ServingConfig()
    simulation: SimulationConfig = SimulationConfig()

    def __post_init__(self):
        if not self.workers:
            raise ValueError("a cluster needs at least one worker")
        ids = [w.worker_id for w in self.workers]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate worker ids in {ids}")
        # tolerate lists at the call site; store the canonical tuple
        if not isinstance(self.workers, tuple):
            object.__setattr__(self, "workers", tuple(self.workers))

    @classmethod
    def homogeneous(
        cls, n_workers: int, max_qubits: int, *, serving=None, simulation=None, **kw
    ) -> "ClusterConfig":
        workers = tuple(
            WorkerConfig(f"w{i + 1}", max_qubits, **kw) for i in range(n_workers)
        )
        return cls(
            workers=workers,
            serving=serving or ServingConfig(),
            simulation=simulation or SimulationConfig(),
        )


__all__ = [
    "ClusterConfig",
    "DEFAULT_WORKER_QUBITS",
    "ServingConfig",
    "SimulationConfig",
    "TenantPolicy",
    "default_workers",
]
