"""``QuantumCluster`` / ``Session``: the one tenant-facing entry point.

The paper's co-management story is ONE control plane placing any client's
circuits on any worker; this facade is its API counterpart — one object
(``QuantumCluster``) that owns the worker fleet, the serving gateway, the
execution backends, and the virtual-clock simulation, and one per-tenant
handle (``Session``) through which a client submits circuits, trains, and
reads its telemetry.  The same session object rides the synchronous
dispatcher, the async worker-pool runtime, and the virtual-clock
simulation, because all three consume the ``ExecutionBackend`` protocol
and the gateway's tenant registry underneath.

    cluster = QuantumCluster(ClusterConfig(serving=ServingConfig(mode="async")))
    sess = cluster.session("alice", TenantPolicy(priority=0, slo_ms=500.0))
    fut = sess.submit(spec, theta, data)          # one circuit
    report = sess.train(qcfg, train_set, test_set)  # Algorithm 1, served
    print(sess.telemetry())
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.api.backend import ExecutionBackend, make_backend
from repro.api.config import ClusterConfig, TenantPolicy
from repro.comanager.worker import WorkerConfig
from repro.core.sim import CircuitSpec


class QuantumCluster:
    """Facade over the co-managed multi-tenant system.

    Lazily materializes a ``serve.GatewayRuntime`` (per its
    ``ServingConfig``) the first time real execution is needed, so
    simulation-only and backend-only uses never spin up serving threads.
    Context-manage it (or call ``close()``) to stop async runtimes.
    """

    def __init__(self, config: ClusterConfig | None = None, **overrides):
        if config is None:
            config = ClusterConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self._runtime = None
        self._sessions: dict[str, "Session"] = {}

    # ------------------------------------------------------------ runtime
    @property
    def runtime(self):
        """The real-execution serving runtime (built on first use)."""
        if self._runtime is None:
            from repro.serve.dispatcher import GatewayRuntime

            self._runtime = GatewayRuntime(
                list(self.config.workers),
                **self.config.serving.runtime_kwargs(),
            )
        return self._runtime

    @property
    def telemetry(self):
        return self.runtime.telemetry

    @property
    def fleet(self):
        """Per-worker health vitals (``serve.fleet.FleetHealth``) of the
        active dispatcher — state machine, failure rates, breaker trips."""
        return self.runtime.dispatcher.fleet

    def register_worker(self, worker: WorkerConfig) -> None:
        """Add a worker to the live runtime (new capacity is placeable on
        the next dispatch; the fleet's max width is re-derived)."""
        self.runtime.dispatcher.register_worker(worker)
        self.config = dataclasses.replace(
            self.config, workers=(*self.config.workers, worker)
        )

    def drain_worker(self, worker_id: str, timeout: float = 30.0) -> None:
        """Gracefully remove a worker: stop placing on it, wait for its
        in-flight batches, then forget it.  In-flight work elsewhere is
        untouched."""
        self.runtime.dispatcher.drain_worker(worker_id, timeout=timeout)
        self.config = dataclasses.replace(
            self.config,
            workers=tuple(
                w for w in self.config.workers if w.worker_id != worker_id
            ),
        )

    def close(self) -> None:
        if self._runtime is not None:
            self._runtime.close()
            self._runtime = None
        # a rebuilt runtime starts with an empty tenant registry: existing
        # handles must re-register (with their full policy) on next use, and
        # the session table resets so tenants can be reconfigured.
        for sess in self._sessions.values():
            sess._registered = False
        self._sessions.clear()

    def __enter__(self) -> "QuantumCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- sessions
    def session(
        self,
        tenant: str,
        policy: TenantPolicy | None = None,
        *,
        bank_mode: str | None = None,
    ) -> "Session":
        """Open (or retrieve) the tenant's session handle.

        Omitted arguments mean "whatever the session already has" (new
        sessions default to ``TenantPolicy()`` and ``bank_mode='auto'``);
        re-opening an existing session with a DIFFERENT explicit policy or
        bank mode is an error — the gateway's scheduler state is
        per-tenant, not per-handle.  ``close()`` resets the table so
        tenants can be reconfigured."""
        if bank_mode not in (None, "auto", "implicit", "materialized"):
            raise ValueError(f"unknown bank_mode {bank_mode!r}")
        existing = self._sessions.get(tenant)
        if existing is not None:
            if (policy is not None and existing.policy != policy) or (
                bank_mode is not None and existing.bank_mode != bank_mode
            ):
                raise ValueError(
                    f"session {tenant!r} already open with a different "
                    f"policy/bank_mode; close the cluster to reconfigure"
                )
            return existing
        sess = Session(self, tenant, policy or TenantPolicy(), bank_mode or "auto")
        self._sessions[tenant] = sess
        return sess

    @property
    def policies(self) -> dict[str, TenantPolicy]:
        return {t: s.policy for t, s in self._sessions.items()}

    # ----------------------------------------------------------- backends
    def backend(self, kind: str, spec: CircuitSpec, **kw) -> ExecutionBackend:
        """Build one of the five executor-family adapters against this
        cluster's fleet (worker count defaults to the configured fleet)."""
        if kind in ("batched", "pooled", "multibank"):
            kw.setdefault("n_workers", len(self.config.workers))
        return make_backend(kind, spec, **kw)

    # --------------------------------------------------------- simulation
    def simulate(
        self,
        jobs,
        *,
        worker_failures: dict | None = None,
        arrivals: dict | None = None,
        simulation=None,
    ):
        """Run the virtual-clock system simulation for ``jobs`` under this
        cluster's fleet and ``SimulationConfig``, with every open session's
        ``TenantPolicy`` forwarded to the gateway (weights, priorities,
        SLOs).  Returns the ``SimulationReport``."""
        from repro.comanager.simulation import SystemSimulation

        sim_cfg = simulation or self.config.simulation
        policies = self.policies
        kw = sim_cfg.simulation_kwargs()
        if policies and kw.get("gateway"):
            # forward UNFILTERED: a session tenant absent from the submitted
            # jobs hits SystemSimulation's unknown-id validation instead of
            # silently losing its policy (the typo class this PR eliminates).
            kw["tenant_weights"] = {t: p.weight for t, p in policies.items()}
            kw["tenant_priorities"] = {t: p.priority for t, p in policies.items()}
            kw["tenant_slos_ms"] = {
                t: p.slo_ms for t, p in policies.items() if p.slo_ms is not None
            }
        sim = SystemSimulation(
            list(self.config.workers),
            list(jobs),
            worker_failures=worker_failures,
            arrivals=arrivals,
            **kw,
        )
        return sim.run()

    # ----------------------------------------------------------- federated
    def federated_session(
        self,
        tenants,
        config=None,
        *,
        update_fn=None,
        params0=None,
        qcfg=None,
        dataset=None,
        eval_set=None,
        lr: float = 0.1,
        local_steps: int = 1,
        worker_failures: dict | None = None,
        simulation=None,
    ):
        """Open a federated DQL session over this cluster's fleet
        (``repro.federated``): per-tenant local training on private shards,
        gateway-side FedAvg rounds closing on quorum + deadline, on the
        virtual clock.

        ``tenants``: ``TenantSpec`` list, or plain names (default spec).
        Either pass ``update_fn`` + ``params0`` directly, or ``qcfg`` + a
        ``dataset`` ``(images, labels)`` — the dataset is then sharded
        deterministically across tenants and the local update is
        ``local_steps`` of exact-gradient SGD at ``lr`` (``eval_set`` adds
        per-round held-out accuracy).  Returns a ``FederatedSession``;
        call ``.run()`` for the ``FederatedReport``."""
        from repro.federated import (
            FederatedConfig,
            FederatedSession,
            TenantSpec,
            make_quclassi_eval_fn,
            make_quclassi_update_fn,
            shard_dataset,
        )

        config = config or FederatedConfig()
        specs = [
            t if isinstance(t, TenantSpec) else TenantSpec(name=t)
            for t in tenants
        ]
        eval_fn = None
        if update_fn is None:
            if qcfg is None or dataset is None:
                raise ValueError(
                    "pass update_fn + params0, or qcfg + dataset to build "
                    "the QuClassi local-training update"
                )
            import jax

            from repro.core import quclassi

            shards = shard_dataset(
                dataset[0], dataset[1], [t.name for t in specs], seed=config.seed
            )
            update_fn = make_quclassi_update_fn(
                qcfg, shards, lr=lr, local_steps=local_steps
            )
            if params0 is None:
                params0 = quclassi.init_params(
                    qcfg, jax.random.PRNGKey(config.seed)
                )
            if eval_set is not None:
                eval_fn = make_quclassi_eval_fn(qcfg, eval_set)
        elif params0 is None:
            raise ValueError("params0 is required with an explicit update_fn")
        return FederatedSession(
            self,
            config,
            specs,
            update_fn,
            params0,
            eval_fn=eval_fn,
            worker_failures=worker_failures,
            simulation=simulation,
        )


class Session:
    """One tenant's handle on the cluster: submit, train, observe.

    Created via ``QuantumCluster.session`` — constructing it registers the
    tenant (with its full ``TenantPolicy``) in the serving gateway the
    first time real execution is touched."""

    def __init__(
        self,
        cluster: QuantumCluster,
        tenant: str,
        policy: TenantPolicy,
        bank_mode: str,
    ):
        self.cluster = cluster
        self.tenant = tenant
        self.policy = policy
        self.bank_mode = bank_mode
        self._registered = False

    # ---------------------------------------------------------- plumbing
    def _gateway(self):
        gw = self.cluster.runtime.gateway
        if not self._registered:
            if self.tenant not in gw.tenants:
                gw.register_client(self.tenant, **self.policy.register_kwargs())
            self._registered = True
        return gw

    def executor(self, spec: CircuitSpec):
        """The gateway-backed ``shift_rule.Executor`` for this tenant.

        ``bank_mode='implicit'`` (or 'auto') returns the shift-aware
        executor: implicit banks enter as (param, shift) group subtasks
        and coalesce across tenants into fused multi-bank launches;
        'materialized' returns the per-row executor."""
        self._gateway()
        rt = self.cluster.runtime
        kw = dict(
            weight=self.policy.weight,
            priority=self.policy.priority,
            slo_ms=self.policy.slo_ms,
        )
        if self.bank_mode == "materialized":
            return rt.executor(spec, self.tenant, **kw)
        return rt.shift_executor(spec, self.tenant, **kw)

    # ----------------------------------------------------------- serving
    def submit(self, spec: CircuitSpec, theta, data):
        """Admit one circuit; returns its ``CircuitFuture``.  Call
        ``drain()`` (or keep submitting) to force partial batches out."""
        gw = self._gateway()
        rt = self.cluster.runtime
        fut = gw.submit(self.tenant, spec, (theta, data), now=rt.dispatcher.clock())
        rt.dispatcher.kick()
        return fut

    def drain(self) -> int:
        """Flush partial coalescer buffers and run everything pending."""
        return self.cluster.runtime.dispatcher.drain()

    # ---------------------------------------------------------- training
    def train(self, qcfg, train_set, test_set, **train_kwargs):
        """Run Algorithm-1 training for this tenant through the cluster's
        serving runtime (``core.trainer.train`` with this session's policy
        and bank mode pre-wired)."""
        from repro.core import trainer

        self._gateway()
        return trainer.train(
            qcfg,
            train_set,
            test_set,
            gateway=self.cluster.runtime,
            client_id=self.tenant,
            bank_mode=self.bank_mode,
            policy=self.policy,
            **train_kwargs,
        )

    # --------------------------------------------------------- telemetry
    def telemetry(self) -> Optional[dict]:
        """This tenant's slice of the gateway telemetry summary (latency
        percentiles, throughput, SLO attainment), or None before any
        completed work."""
        summary = self.cluster.runtime.telemetry.summary()
        for row in summary.get("tenants", []):
            if row.get("client") == self.tenant:
                return row
        return None

    def trace(self) -> list[dict]:
        """This tenant's finished circuit lifecycle records (oldest first):
        timestamped stage transitions submit -> ... -> complete/evict, the
        executing worker, and the outcome.  Empty when tracing is disabled
        (``ServingConfig.observability``) or nothing has finished yet; use
        ``cluster.telemetry.trace.export_chrome_trace(path)`` for the
        Perfetto view across all tenants."""
        return self.cluster.runtime.telemetry.trace.tenant_records(self.tenant)


__all__ = ["QuantumCluster", "Session"]
