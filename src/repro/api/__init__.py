"""``repro.api`` — the public surface of the co-managed quantum system.

Two layers:

* ``ExecutionBackend`` protocol (``backend``): one capability-declaring
  contract over all five executor families (per-worker batched, pooled,
  multi-bank, mesh-sharded, whole-mesh spill), with adapters that remain
  drop-in legacy ``shift_rule.Executor`` callables.
* ``QuantumCluster`` / ``Session`` facade (``cluster``): typed configs
  (``ClusterConfig``, ``TenantPolicy``, ``ServingConfig``,
  ``SimulationConfig``) and per-tenant session handles that front the
  trainer, the sync/async serving gateways, and the virtual-clock
  simulation through one object.

Heavy submodules load lazily (PEP 562): ``repro.core.shift_rule`` imports
``repro.api.capabilities`` at module scope, while ``repro.api.backend``
imports ``repro.core.shift_rule`` — eager package imports here would turn
that into a partially-initialized-module crash for anyone importing
``repro.core.shift_rule`` first.
"""

from repro.api.capabilities import (
    MATERIALIZED_ONLY,
    Capabilities,
    capabilities_of,
    declare,
)

_LAZY = {
    "BACKEND_KINDS": "repro.api.backend",
    "BatchedWorkerBackend": "repro.api.backend",
    "CallableBackend": "repro.api.backend",
    "CostModel": "repro.api.backend",
    "ExecutionBackend": "repro.api.backend",
    "MeshSpillBackend": "repro.api.backend",
    "MultibankWorkerBackend": "repro.api.backend",
    "PooledWorkerBackend": "repro.api.backend",
    "ShardedBackend": "repro.api.backend",
    "as_backend": "repro.api.backend",
    "make_backend": "repro.api.backend",
    "ClusterConfig": "repro.api.config",
    "FaultSpec": "repro.comanager.faults",
    "FaultToleranceConfig": "repro.comanager.faults",
    "FederatedConfig": "repro.federated",
    "FederatedReport": "repro.federated",
    "FederatedSession": "repro.federated",
    "TenantSpec": "repro.federated",
    "ObservabilityConfig": "repro.obs.config",
    "ServingConfig": "repro.api.config",
    "SimulationConfig": "repro.api.config",
    "TenantPolicy": "repro.api.config",
    "default_workers": "repro.api.config",
    "QuantumCluster": "repro.api.cluster",
    "Session": "repro.api.cluster",
}

__all__ = sorted(
    [
        "Capabilities",
        "MATERIALIZED_ONLY",
        "capabilities_of",
        "declare",
        *_LAZY,
    ]
)


def __getattr__(name: str):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(target), name)


def __dir__():
    return __all__
