"""Capability declaration for circuit-bank executors.

This is the vocabulary of the ``ExecutionBackend`` protocol
(``repro.api.backend``): instead of duck-typed ``accepts_shiftbank`` /
``accepts_bankset`` attribute probes scattered through ``core``,
``comanager`` and ``serve``, an executor DECLARES what it can consume and
every dispatch site asks ``capabilities_of``.  Legacy callables that still
carry only the old attributes keep working through the single deprecation
shim at the bottom of ``capabilities_of`` — the one place in the codebase
where the old attribute probes survive.

This module is intentionally dependency-free (no jax, no other ``repro``
imports): ``repro.core.shift_rule`` imports it at module scope, while
``repro.api.backend`` imports ``repro.core.shift_rule`` — keeping this file
a leaf is what makes that cycle-free.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What an executor/backend can consume natively.

    ``shiftbank``: executes implicit ``shift_rule.ShiftBank``s directly
    (the prefix-reuse kernel path) — called as ``run(bank)``.

    ``multibank``: fuses whole same-spec BANK SETS into shared launches —
    called as ``run([bank, ...]) -> [fids, ...]`` (``run_bank_set``).

    ``sharded``: execution shards over a device mesh (``shard_map``), so
    lane working sets divide across devices.

    ``vmem_model``: the backend's cost model reports a post-spill
    per-device VMEM footprint (the kernel's depth-tiled checkpoint
    spilling keeps it bounded), so dispatchers may budget against it.

    ``mesh_spill``: oversized work (register width or VMEM working set
    above any single worker) reroutes to the whole mesh instead of
    failing fast.
    """

    shiftbank: bool = False
    multibank: bool = False
    sharded: bool = False
    vmem_model: bool = False
    mesh_spill: bool = False


#: the empty declaration: only materialized ``(theta, data)`` row batches.
MATERIALIZED_ONLY = Capabilities()


def declare(executor, **caps):
    """Attach declared ``Capabilities`` to a callable executor.

    The legacy ``accepts_shiftbank`` / ``accepts_bankset`` duck-typing
    attributes are mirrored for not-yet-migrated callers (they are
    attributes, not probes — reading capabilities via ``getattr`` belongs
    exclusively to the ``capabilities_of`` shim).  Returns the executor so
    factories can ``return declare(run, shiftbank=True)``.
    """
    c = Capabilities(**caps)
    executor.capabilities = c
    executor.accepts_shiftbank = c.shiftbank
    executor.accepts_bankset = c.multibank
    return executor


def capabilities_of(executor) -> Capabilities:
    """Resolve an executor's declared capabilities.

    Declared capabilities win: a ``capabilities`` attribute holding either
    a ``Capabilities`` instance (``declare``-d callables) or a zero-arg
    method returning one (``ExecutionBackend`` objects).  Anything else
    falls through to the DEPRECATION SHIM — the single surviving
    duck-typed probe of the old ``accepts_shiftbank`` / ``accepts_bankset``
    attributes, which keeps pre-protocol executors working unchanged.
    """
    cap = getattr(executor, "capabilities", None)
    if callable(cap):
        cap = cap()
    if isinstance(cap, Capabilities):
        return cap
    # deprecation shim: the ONE place the legacy attribute probes remain.
    return Capabilities(
        shiftbank=bool(getattr(executor, "accepts_shiftbank", False)),
        multibank=bool(getattr(executor, "accepts_bankset", False)),
    )
