"""Event loop + full system simulation behaviour tests (paper's runtime
claims, in miniature): worker scaling, multi-tenancy, eviction recovery."""
import pytest

from repro.comanager import tenancy
from repro.comanager.events import EventLoop
from repro.comanager.simulation import SystemSimulation, homogeneous_workers
from repro.comanager.tenancy import JobSpec
from repro.comanager.worker import WorkerConfig


def fresh_jobs(*specs):
    return [JobSpec(**s) for s in specs]


# -------------------------------------------------------------- event loop
def test_event_loop_ordering():
    lp = EventLoop()
    seen = []
    lp.on("e", lambda t, p: seen.append((t, p)))
    lp.schedule(2.0, "e", "b")
    lp.schedule(1.0, "e", "a")
    lp.schedule(2.0, "e", "c")   # same time: FIFO by sequence
    lp.run()
    assert seen == [(1.0, "a"), (2.0, "b"), (2.0, "c")]


def test_event_loop_rejects_past():
    lp = EventLoop()
    lp.on("e", lambda t, p: lp.schedule(t - 1.0, "e") if t < 2 else None)
    lp.schedule(1.0, "e")
    with pytest.raises(ValueError):
        lp.run()


def test_event_loop_cancel():
    lp = EventLoop()
    seen = []
    lp.on("e", lambda t, p: seen.append(p))
    keep = lp.schedule(1.0, "e", "keep")
    drop = lp.schedule(2.0, "e", "drop")
    lp.cancel(drop)
    lp.run()
    assert seen == ["keep"]


# ------------------------------------------------------------- simulation
def run_sim(n_workers, jobs, **kw):
    workers = homogeneous_workers(n_workers, kw.pop("max_qubits", 29))
    return SystemSimulation(workers, jobs, **kw).run()


def test_all_circuits_complete_exactly_once():
    jobs = fresh_jobs(dict(client_id="c1", qc=5, n_layers=1, n_circuits=40))
    rep = run_sim(2, jobs)
    assert rep.total_circuits == 40
    assert rep.jobs["c1"].n_circuits == 40
    assert len(rep.assignments) >= 40


def test_more_workers_reduce_makespan():
    """The paper's central runtime claim (Figs 3-5), in miniature."""
    times = []
    for nw in (1, 2, 4):
        jobs = fresh_jobs(dict(client_id="c1", qc=5, n_layers=1,
                               n_circuits=64, service_override=1.0))
        rep = run_sim(nw, jobs, max_qubits=5, classical_overhead=0.01)
        times.append(rep.makespan)
    assert times[0] > times[1] > times[2]
    # 1 worker with 5 qubits is fully serial: ~64s
    assert times[0] == pytest.approx(64.0, rel=0.1)


def test_not_linear_speedup_with_classical_overhead():
    """Fig 5a discussion: 2 workers does NOT halve runtime — the serial
    classical side (circuit generation / state analysis) caps the gain."""
    res = {}
    for nw in (1, 2):
        jobs = fresh_jobs(dict(client_id="c1", qc=5, n_layers=1,
                               n_circuits=64, service_override=0.2))
        rep = run_sim(nw, jobs, max_qubits=5, classical_overhead=0.15)
        res[nw] = rep.makespan
    assert res[2] < res[1]
    assert res[2] > res[1] / 2  # diminishing returns


def test_multi_tenant_beats_single_tenant():
    """Fig 6: concurrent clients sharing big workers finish sooner than
    under single-tenant (one-user-per-machine) semantics."""
    def jobs4():
        return fresh_jobs(
            dict(client_id="5q1l", qc=5, n_layers=1, n_circuits=30,
                 service_override=0.5),
            dict(client_id="5q2l", qc=5, n_layers=2, n_circuits=30,
                 service_override=0.8),
            dict(client_id="7q1l", qc=7, n_layers=1, n_circuits=30,
                 service_override=0.6),
            dict(client_id="7q2l", qc=7, n_layers=2, n_circuits=30,
                 service_override=0.9))

    workers = [WorkerConfig("w1", 5), WorkerConfig("w2", 10),
               WorkerConfig("w3", 15), WorkerConfig("w4", 20)]
    multi = SystemSimulation(workers, jobs4(), multi_tenant=True).run()
    single = SystemSimulation(workers, jobs4(), multi_tenant=False).run()
    assert multi.makespan < single.makespan
    assert multi.circuits_per_second > single.circuits_per_second


def test_small_worker_useless_for_wide_circuits():
    """'worker-1, which only has 5 qubits, is useless to a 7-qubit circuit'"""
    jobs = fresh_jobs(dict(client_id="c7", qc=7, n_layers=1, n_circuits=10,
                           service_override=1.0))
    workers = [WorkerConfig("w_small", 5), WorkerConfig("w_big", 10)]
    rep = SystemSimulation(workers, jobs).run()
    assigned_to = {wid for (_, _, wid) in rep.assignments}
    assert assigned_to == {"w_big"}


def test_worker_failure_eviction_and_recovery():
    jobs = fresh_jobs(dict(client_id="c1", qc=5, n_layers=1, n_circuits=30,
                           service_override=2.0))
    workers = homogeneous_workers(2, 5)
    rep = SystemSimulation(workers, jobs, worker_failures={"w1": 10.0},
                           run_until=1e5).run()
    # w1 dies at t=10 -> evicted after 3 missed heartbeats; all circuits
    # still complete (requeued onto w2)
    assert rep.jobs["c1"].n_circuits == 30
    assert any(wid == "w1" for (_, wid) in rep.evictions)
    # later assignments all go to the survivor
    late = [wid for (t, _, wid) in rep.assignments if t > 30.0]
    assert late and set(late) == {"w2"}


def test_heterogeneous_workers_capacity_packing():
    """A 20-qubit worker runs four 5q circuits concurrently."""
    jobs = fresh_jobs(dict(client_id="c1", qc=5, n_layers=1, n_circuits=4,
                           service_override=5.0))
    workers = [WorkerConfig("w20", 20, contention=0.0)]
    rep = SystemSimulation(workers, jobs).run()
    # all four run concurrently -> makespan ~ one service time, not 4x
    assert rep.makespan < 10.0


def test_late_joining_worker_gets_work():
    """Dynamic registration: a worker joining mid-run is used."""
    jobs = fresh_jobs(dict(client_id="c1", qc=5, n_layers=1, n_circuits=40,
                           service_override=1.0))
    workers = homogeneous_workers(2, 5)
    sim = SystemSimulation(workers, jobs)
    # registration events are scheduled in run(); move w2's to t=15
    sim.loop.schedule(0.0, "register", "w1")
    sim.loop.schedule(15.0, "register", "w2")
    for job in sim.jobs.values():
        sim.loop.schedule(job.submit_time, "submit", job)
    sim.loop.schedule(sim.heartbeat_period, "liveness", None)
    sim.loop.run()
    used = {wid for (t, _, wid) in sim.manager.assignments if t >= 15.0}
    assert "w2" in used


def test_deterministic_replay():
    def go():
        jobs = fresh_jobs(dict(client_id="a", qc=5, n_layers=1, n_circuits=25,
                               service_override=0.7),
                          dict(client_id="b", qc=7, n_layers=2, n_circuits=25,
                               service_override=1.1, submit_time=3.0))
        workers = [WorkerConfig("w1", 10), WorkerConfig("w2", 15)]
        rep = SystemSimulation(workers, jobs).run()
        return rep.makespan, tuple(rep.assignments)

    assert go() == go()


def test_paper_job_counts():
    job = tenancy.paper_job("c", 5, 3)
    assert job.n_circuits == 4320
    job = tenancy.paper_job("c", 7, 1)
    assert job.n_circuits == 2016
