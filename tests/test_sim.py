"""Statevector simulator tests: apply_gate vs a dense complex-matrix oracle,
norm preservation (property), qubit-ordering conventions, marginals."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core import gates as G, sim


def dense_oracle(n, u, qubits):
    """Build the full 2^n x 2^n complex matrix for gate u on `qubits`
    (qubit 0 = most significant bit, matching sim.py's convention)."""
    m = np.asarray(u[0]) + 1j * np.asarray(u[1])
    k = len(qubits)
    full = np.zeros((2 ** n, 2 ** n), complex)
    rest = [q for q in range(n) if q not in qubits]
    for col in range(2 ** n):
        bits = [(col >> (n - 1 - q)) & 1 for q in range(n)]
        sub_in = 0
        for i, q in enumerate(qubits):
            sub_in = (sub_in << 1) | bits[q]
        for sub_out in range(2 ** k):
            amp = m[sub_out, sub_in]
            if amp == 0:
                continue
            out_bits = list(bits)
            for i, q in enumerate(qubits):
                out_bits[q] = (sub_out >> (k - 1 - i)) & 1
            row = 0
            for b in out_bits:
                row = (row << 1) | b
            full[row, col] += amp
    return full


def random_state(n, rng, batch=()):
    v = rng.normal(size=batch + (2 ** n,)) + 1j * rng.normal(size=batch + (2 ** n,))
    v /= np.linalg.norm(v, axis=-1, keepdims=True)
    return (jnp.asarray(v.real, jnp.float32), jnp.asarray(v.imag, jnp.float32))


CASES = [
    ("h", (0,), 1), ("h", (1,), 3), ("x", (2,), 3),
    ("rx", (0,), 2), ("ry", (1,), 2), ("rz", (2,), 4),
    ("ryy", (0, 1), 3), ("rzz", (1, 2), 3), ("ryy", (0, 2), 3),
    ("cry", (0, 1), 2), ("crz", (2, 0), 3),
    ("swap", (0, 2), 3), ("cswap", (0, 1, 2), 3), ("cswap", (2, 0, 4), 5),
]


@pytest.mark.parametrize("name,qubits,n", CASES)
def test_apply_gate_matches_dense_oracle(name, qubits, n):
    rng = np.random.default_rng(hash((name, qubits, n)) % 2 ** 31)
    ctor, k, takes_angle = G.GATES[name]
    u = ctor(0.6137) if takes_angle else ctor()
    st_in = random_state(n, rng)
    out = sim.apply_gate(st_in, u, qubits, n)
    got = np.asarray(out[0]) + 1j * np.asarray(out[1])

    full = dense_oracle(n, u, qubits)
    vin = np.asarray(st_in[0]) + 1j * np.asarray(st_in[1])
    np.testing.assert_allclose(got, full @ vin, atol=1e-5)


def test_apply_gate_batched_matches_loop():
    rng = np.random.default_rng(3)
    n, u = 3, G.ry(1.01)
    st_in = random_state(n, rng, batch=(4,))
    out = sim.apply_gate(st_in, u, (1,), n)
    for b in range(4):
        single = sim.apply_gate((st_in[0][b], st_in[1][b]), u, (1,), n)
        np.testing.assert_allclose(out[0][b], single[0], atol=1e-6)
        np.testing.assert_allclose(out[1][b], single[1], atol=1e-6)


@given(theta=st.floats(-np.pi, np.pi), qubit=st.integers(0, 3),
       gate=st.sampled_from(["rx", "ry", "rz", "h"]))
def test_norm_preserved(theta, qubit, gate):
    n = 4
    ctor, _, takes_angle = G.GATES[gate]
    u = ctor(jnp.float32(theta)) if takes_angle else ctor()
    state = sim.zero_state(n)
    state = sim.apply_gate(state, G.h(), (0,), n)  # spread amplitude
    state = sim.apply_gate(state, u, (qubit,), n)
    assert abs(float(sim.state_norm(state)) - 1.0) < 1e-5


def test_zero_state():
    re, im = sim.zero_state(3, batch=(2,))
    assert re.shape == (2, 8) and im.shape == (2, 8)
    np.testing.assert_allclose(re[:, 0], 1.0)
    assert float(jnp.abs(re[:, 1:]).max()) == 0.0
    assert float(jnp.abs(im).max()) == 0.0


def test_qubit0_is_most_significant():
    n = 2
    state = sim.zero_state(n)
    state = sim.apply_gate(state, G.x(), (0,), n)  # |10>
    p = np.asarray(sim.probabilities(state))
    assert p.argmax() == 2  # basis index 0b10


def test_marginal_p0():
    n = 2
    state = sim.zero_state(n)
    state = sim.apply_gate(state, G.h(), (0,), n)     # (|00>+|10>)/sqrt2
    np.testing.assert_allclose(float(sim.marginal_p0(state, 0, n)), 0.5, atol=1e-6)
    np.testing.assert_allclose(float(sim.marginal_p0(state, 1, n)), 1.0, atol=1e-6)


def test_run_circuit_angle_sources():
    spec = sim.CircuitSpec(
        n_qubits=1,
        ops=(sim.Op("ry", (0,), ("theta", 0)),
             sim.Op("ry", (0,), ("data", 0)),
             sim.Op("ry", (0,), ("const", 0.25))),
        n_theta=1, n_data=1)
    theta = jnp.array([0.3])
    data = jnp.array([0.45])
    out = sim.run_circuit(spec, theta, data)
    expect = sim.run_circuit(
        sim.CircuitSpec(1, (sim.Op("ry", (0,), ("const", 1.0)),), 0, 0),
        jnp.zeros(0), jnp.zeros(0))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(expect[0]), atol=1e-6)


def test_op_validates_arity():
    with pytest.raises(AssertionError):
        sim.Op("ry", (0, 1), ("theta", 0))       # 1q gate, 2 qubits
    with pytest.raises(AssertionError):
        sim.Op("h", (0,), ("theta", 0))          # h takes no angle
    with pytest.raises(AssertionError):
        sim.Op("ry", (0,))                       # ry needs an angle
