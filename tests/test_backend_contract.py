"""Shared contract suite for every ``ExecutionBackend`` adapter.

One parametrized module runs all five executor families through identical
checks: bank identity against the ``materialize()`` reference, capability
honesty (a declared ``shiftbank`` backend must never materialize; a
declared ``multibank`` backend must fuse bank sets through the multi-bank
kernel), cost-model sanity, and legacy ``shift_rule.Executor``
interoperability.  Adding a sixth executor family means adding one factory
line here — the contract is the test."""

import jax

jax.config.update("jax_platforms", "cpu")

import numpy as np
import pytest
import jax.numpy as jnp

from repro import api
from repro.api.backend import (
    BACKEND_KINDS,
    CallableBackend,
    ExecutionBackend,
    as_backend,
    make_backend,
)
from repro.core import circuits, shift_rule
from repro.kernels import ops as kops

KINDS = sorted(BACKEND_KINDS)


@pytest.fixture(scope="module")
def setup():
    spec = circuits.build_quclassi_circuit(5, 1)
    rng = np.random.default_rng(7)
    theta = jnp.asarray(rng.uniform(0, np.pi, spec.n_theta), jnp.float32)
    # odd sample count exercises lane / shard padding in every adapter
    data = jnp.asarray(rng.uniform(0, np.pi, (3, spec.n_data)), jnp.float32)
    bank = shift_rule.build_shift_bank(theta, data)
    mat = bank.materialize()
    ref = np.asarray(kops.vqc_fidelity(spec, mat.theta, mat.data))
    return spec, bank, mat, ref


def _backend(kind, spec):
    kw = {"n_workers": 3} if kind in ("batched", "pooled", "multibank") else {}
    return make_backend(kind, spec, **kw)


@pytest.fixture(params=KINDS)
def backend(request, setup):
    spec = setup[0]
    be = _backend(request.param, spec)
    yield be
    be.close()


# ------------------------------------------------------------ bank identity
def test_run_bank_matches_materialized_reference(backend, setup):
    _, bank, _, ref = setup
    got = np.asarray(backend.run_bank(bank))
    assert got.shape == (bank.n_circuits,)
    np.testing.assert_allclose(got, ref, atol=1e-5)


def test_run_rows_matches_reference(backend, setup):
    _, _, mat, ref = setup
    got = np.asarray(backend.run_rows(mat.theta, mat.data))
    np.testing.assert_allclose(got, ref, atol=1e-5)


def test_run_bank_set_accepts_materialized_banks(backend, setup):
    """Contract: every adapter's run_bank_set handles materialized
    ``CircuitBank``s (per-bank fallback — no (bank, group) structure to
    fuse), not just implicit ``ShiftBank``s."""
    _, bank, mat, ref = setup
    outs = backend.run_bank_set([mat, bank])
    assert len(outs) == 2
    np.testing.assert_allclose(np.asarray(outs[0]), ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[1]), ref, atol=1e-5)


def test_run_bank_set_matches_per_bank(backend, setup):
    spec, bank, _, ref = setup
    rng = np.random.default_rng(11)
    other = shift_rule.build_shift_bank(
        jnp.asarray(rng.uniform(0, np.pi, spec.n_theta), jnp.float32),
        jnp.asarray(rng.uniform(0, np.pi, (2, spec.n_data)), jnp.float32),
    )
    mat2 = other.materialize()
    ref2 = np.asarray(kops.vqc_fidelity(spec, mat2.theta, mat2.data))
    outs = backend.run_bank_set([bank, other])
    assert len(outs) == 2
    np.testing.assert_allclose(np.asarray(outs[0]), ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[1]), ref2, atol=1e-5)


# -------------------------------------------------------- capability honesty
def test_protocol_and_declaration(backend):
    assert isinstance(backend, ExecutionBackend)
    caps = backend.capabilities()
    # capabilities_of resolves the declaration, not the legacy shim
    assert api.capabilities_of(backend) == caps


def test_shiftbank_backends_never_materialize(backend, setup, monkeypatch):
    """Honesty: a declared shiftbank backend must consume the implicit bank
    directly; everything else must fall back through materialize()."""
    _, bank, _, ref = setup
    calls = {"n": 0}
    real = shift_rule.ShiftBank.materialize

    def spy(self):
        calls["n"] += 1
        return real(self)

    monkeypatch.setattr(shift_rule.ShiftBank, "materialize", spy)
    got = np.asarray(backend.run_bank(bank))
    np.testing.assert_allclose(got, ref, atol=1e-5)
    if backend.capabilities().shiftbank:
        assert calls["n"] == 0, "declared shiftbank but materialized"
    else:
        assert calls["n"] > 0, "declared materialize-only but skipped it"


def test_multibank_honesty_mixed_shift_rules(backend, setup):
    """Honesty: a declared ``multibank`` backend genuinely fuses, so a set
    mixing shift rules (two-term + four-term banks cannot share a launch)
    must be rejected; per-bank fallback backends run it fine."""
    spec, bank, _, _ = setup
    other = shift_rule.build_shift_bank(bank.theta[0], bank.data, four_term=True)
    if backend.capabilities().multibank:
        with pytest.raises(ValueError, match="four_term"):
            backend.run_bank_set([bank, other])
    else:
        outs = backend.run_bank_set([bank, other])
        assert len(outs) == 2 and outs[1].shape == (other.n_circuits,)


def test_multibank_worker_single_fused_launch(setup, monkeypatch):
    """The multibank worker adapter runs a whole same-spec set through ONE
    fused multi-bank kernel entry per worker, not one launch per bank."""
    spec, bank, _, ref = setup
    calls = {"n": 0}
    real = kops.vqc_fidelity_shiftgroups_multibank

    def spy(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(kops, "vqc_fidelity_shiftgroups_multibank", spy)
    be = make_backend("multibank", spec, n_workers=2)
    outs = be.run_bank_set([bank, bank, bank])
    assert len(outs) == 3
    assert calls["n"] <= 2, "expected at most one fused launch per worker"
    for o in outs:
        np.testing.assert_allclose(np.asarray(o), ref, atol=1e-5)


def test_legacy_executor_interop(backend, setup):
    """Adapters remain drop-in ``shift_rule.Executor`` callables: run_bank
    and run_bank_set dispatch through the protocol object unchanged."""
    _, bank, _, ref = setup
    np.testing.assert_allclose(
        np.asarray(shift_rule.run_bank(backend, bank)), ref, atol=1e-5
    )
    outs = shift_rule.run_bank_set(backend, [bank, bank])
    for o in outs:
        np.testing.assert_allclose(np.asarray(o), ref, atol=1e-5)


# ----------------------------------------------------------------- cost model
def test_cost_model_sanity(backend, setup):
    spec, bank, mat, _ = setup
    cm = backend.cost_model()
    cost = cm.bank_cost_units(spec, bank)
    assert cost > 0 and np.isfinite(cost)
    assert cm.bank_vmem_bytes(spec, bank) > 0
    assert cm.bank_cost_units(spec, mat) > 0
    # at full lane tiles the prefix-reuse estimate must undercut the
    # materialized bank (at B=3 both round up to one 128-lane tile, so the
    # ratio only becomes meaningful at realistic widths)
    from repro.kernels.vqc_statevector import LANES

    wide = shift_rule.build_shift_bank(
        bank.theta[0],
        jnp.tile(bank.data, (LANES // bank.n_samples + 1, 1))[:LANES],
        four_term=bank.four_term,
    )
    wide_cost = cm.bank_cost_units(spec, wide)
    wide_mat_cost = cm.bank_cost_units(spec, wide.materialize())
    if backend.capabilities().shiftbank:
        assert wide_cost < wide_mat_cost, (wide_cost, wide_mat_cost)
    # monotone in sample count (at lane-tile granularity: 2 tiles > 1 tile)
    wider = shift_rule.build_shift_bank(
        wide.theta[0],
        jnp.tile(wide.data, (2, 1)),
        four_term=wide.four_term,
    )
    assert cm.bank_cost_units(spec, wider) > wide_cost >= cost


# -------------------------------------------------------------- legacy bridge
def test_as_backend_wraps_legacy_callables(setup):
    spec, bank, _, ref = setup

    def legacy(theta_bank, data_bank):
        return kops.vqc_fidelity(spec, theta_bank, data_bank)

    be = as_backend(legacy, spec)
    assert isinstance(be, CallableBackend)
    assert not be.capabilities().shiftbank  # shim: undeclared => materialized
    np.testing.assert_allclose(np.asarray(be.run_bank(bank)), ref, atol=1e-5)

    declared = kops.shiftbank_executor(spec)
    be2 = as_backend(declared, spec)
    assert be2.capabilities().shiftbank
    np.testing.assert_allclose(np.asarray(be2.run_bank(bank)), ref, atol=1e-5)

    # protocol objects pass through untouched
    assert as_backend(be2) is be2
    with pytest.raises(TypeError, match="CircuitSpec"):
        as_backend(legacy)


def test_make_backend_rejects_unknown_kind(setup):
    with pytest.raises(ValueError, match="unknown backend kind"):
        make_backend("warp_drive", setup[0])


@pytest.mark.parametrize("kind", ["batched", "pooled", "multibank"])
def test_pinned_assignment_length_mismatch_rejected(kind, setup):
    """A backend pinned to a fixed row assignment must reject banks of any
    other size instead of silently executing only the assigned rows."""
    spec, _, mat, _ = setup
    be = make_backend(kind, spec, n_workers=2, assignment=(0, 1, 0, 1))
    with pytest.raises(ValueError, match="assignment"):
        be.run_rows(mat.theta, mat.data)  # 63 rows != 4 pinned
    be.close()
