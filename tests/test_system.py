"""End-to-end system behaviour: the full DQuLearn loop with REAL circuit
execution routed through the co-Manager's schedule (control plane decides,
data plane executes, gradients assemble identically), plus the sharded
executor on the host mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comanager import dataplane, tenancy
from repro.comanager.simulation import SystemSimulation, homogeneous_workers
from repro.core import quclassi
from repro.core.quclassi import QuClassiConfig
from repro.data import mnist


@pytest.fixture(scope="module")
def setup():
    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(3, 9, n_per_class=8, seed=0)
    params = quclassi.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, jnp.asarray(x[:4]), jnp.asarray(y[:4])


def test_schedule_from_simulation_drives_real_execution(setup):
    """Control plane -> data plane wiring: use the co-Manager's actual
    assignment log as the executor's worker assignment."""
    cfg, params, x, y = setup
    banks, _ = quclassi.build_class_banks(cfg, params, x)
    n_circ = banks[0].n_circuits

    jobs = [tenancy.JobSpec("c1", cfg.qc, cfg.n_layers, n_circ,
                            service_override=0.1)]
    workers = homogeneous_workers(4, 10)
    sim = SystemSimulation(workers, jobs)
    rep = sim.run()
    assert len({tid for (_, tid, _) in rep.assignments}) == n_circ

    # payload i -> worker index chosen by the co-Manager
    order = {wid: i for i, wid in enumerate(sorted(w.worker_id for w in workers))}
    assignment = np.zeros(n_circ, int)
    task_payload = {t.task_id: t.payload for t in sim.manager.task_registry.values()}
    for (_, tid, wid) in rep.assignments:
        assignment[task_payload[tid]] = order[wid]

    ex = dataplane.worker_batched_executor(cfg.spec, assignment, 4)
    l1, g1, f1 = quclassi.grad_shift(cfg, params, x, y, executor=ex)
    l2, g2, f2 = quclassi.grad_shift(cfg, params, x, y)
    np.testing.assert_allclose(np.asarray(g1["theta"]), np.asarray(g2["theta"]),
                               atol=1e-5)


def test_sharded_executor_on_host_mesh(setup):
    """shard_map whole-bank execution on the (trivial) host mesh == local."""
    cfg, params, x, y = setup
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    ex = dataplane.sharded_executor(cfg.spec, mesh)
    banks, _ = quclassi.build_class_banks(cfg, params, x)
    bank = banks[0]
    f_sharded = ex(bank.theta, bank.data)
    from repro.core import shift_rule
    f_local = shift_rule.default_executor(cfg.spec)(bank.theta, bank.data)
    np.testing.assert_allclose(np.asarray(f_sharded), np.asarray(f_local),
                               atol=1e-5)


def test_sharded_executor_pads_ragged_banks(setup):
    cfg, params, x, _ = setup
    from repro.launch.mesh import make_host_mesh
    ex = dataplane.sharded_executor(cfg.spec, make_host_mesh())
    theta = jnp.zeros((7, cfg.n_theta))      # not a multiple of anything
    data = jnp.zeros((7, cfg.n_angles))
    out = ex(theta, data)
    assert out.shape == (7,)


def test_multitenant_schedule_still_exact(setup):
    """Four concurrent clients, heterogeneous workers — every client's
    gradient math is unaffected by where its circuits ran (paper §IV-B)."""
    cfg, params, x, y = setup
    banks, _ = quclassi.build_class_banks(cfg, params, x)
    n_circ = banks[0].n_circuits

    jobs = [tenancy.JobSpec(f"c{k}", 5, 1, n_circ, service_override=0.05,
                            submit_time=0.2 * k) for k in range(4)]
    from repro.comanager.worker import WorkerConfig
    workers = [WorkerConfig("w1", 5), WorkerConfig("w2", 10),
               WorkerConfig("w3", 15), WorkerConfig("w4", 20)]
    sim = SystemSimulation(workers, jobs, multi_tenant=True)
    rep = sim.run()
    assert len(rep.jobs) == 4

    order = {w.worker_id: i for i, w in enumerate(workers)}
    task_payload = {t.task_id: (t.client_id, t.payload)
                    for t in sim.manager.task_registry.values()}
    # client c2's circuits, wherever they ran, reproduce the local result
    assignment = np.zeros(n_circ, int)
    for (_, tid, wid) in rep.assignments:
        cid, payload = task_payload[tid]
        if cid == "c2":
            assignment[payload] = order[wid]
    ex = dataplane.worker_batched_executor(cfg.spec, assignment, 4)
    f_dist = ex(banks[0].theta, banks[0].data)
    from repro.core import shift_rule
    f_local = shift_rule.default_executor(cfg.spec)(banks[0].theta, banks[0].data)
    np.testing.assert_allclose(np.asarray(f_dist), np.asarray(f_local), atol=1e-5)
