"""Tests for the ``repro.api`` tenant-session facade: typed configs,
``QuantumCluster`` / ``Session`` wiring into the serving gateway, the
virtual-clock simulation bridge, and the ``SystemSimulation`` kwarg
validation it rides on."""

import jax

jax.config.update("jax_platforms", "cpu")

import numpy as np
import pytest
import jax.numpy as jnp

from repro.api import (
    ClusterConfig,
    QuantumCluster,
    ServingConfig,
    SimulationConfig,
    TenantPolicy,
)
from repro.comanager import tenancy
from repro.comanager.simulation import SystemSimulation
from repro.comanager.worker import WorkerConfig
from repro.core import quclassi
from repro.core.quclassi import QuClassiConfig


@pytest.fixture(scope="module")
def qcfg():
    return QuClassiConfig(qc=5, n_layers=1)


# ------------------------------------------------------------- typed configs
def test_serving_config_validation():
    with pytest.raises(ValueError, match="sync"):
        ServingConfig(mode="bogus")
    with pytest.raises(ValueError, match="async"):
        ServingConfig(evict_over_slo=True)  # sync default has no ready queue
    with pytest.raises(ValueError, match="slots_per_worker"):
        ServingConfig(slots_per_worker=0)
    # lane-width typos fail at construction, not at lazy runtime build
    with pytest.raises(ValueError, match="lane width"):
        ServingConfig(target=8)
    ServingConfig(target=256)  # multiples are fine


def test_tenant_policy_validation():
    with pytest.raises(ValueError, match="weight"):
        TenantPolicy(weight=0.0)
    with pytest.raises(ValueError, match="slo_ms"):
        TenantPolicy(slo_ms=-5.0)
    # 0 would silently become the gateway default; negatives wedge admission
    with pytest.raises(ValueError, match="max_pending"):
        TenantPolicy(max_pending=0)
    with pytest.raises(ValueError, match="max_in_flight"):
        TenantPolicy(max_in_flight=-1)
    kw = TenantPolicy(priority=0, slo_ms=250.0, weight=2.0).register_kwargs()
    assert kw == {"weight": 2.0, "priority": 0, "slo_ms": 250.0}


def test_cluster_config_validation():
    with pytest.raises(ValueError, match="at least one worker"):
        ClusterConfig(workers=())
    with pytest.raises(ValueError, match="duplicate"):
        ClusterConfig(workers=(WorkerConfig("w1", 5), WorkerConfig("w1", 7)))
    cfg = ClusterConfig.homogeneous(3, 10)
    assert [w.worker_id for w in cfg.workers] == ["w1", "w2", "w3"]
    with pytest.raises(ValueError, match="tenancy"):
        SimulationConfig(tenancy="shared_nothing")
    with pytest.raises(ValueError, match="lane width"):
        SimulationConfig(gateway=True, gateway_target=8)


# --------------------------------------------------------- session -> gateway
def test_session_registers_policy_in_gateway(qcfg):
    with QuantumCluster() as cluster:
        sess = cluster.session(
            "alice", TenantPolicy(priority=0, slo_ms=500.0, weight=2.0)
        )
        sess.executor(qcfg.spec)  # touches the runtime -> registers
        st = cluster.runtime.gateway.tenants["alice"]
        assert st.priority == 0
        assert st.weight == 2.0
        assert st.slo_s == pytest.approx(0.5)
        # same handle back (explicit same policy OR omitted args);
        # conflicting explicit reopen rejected
        same = TenantPolicy(priority=0, slo_ms=500.0, weight=2.0)
        assert cluster.session("alice", same) is sess
        assert cluster.session("alice") is sess
        with pytest.raises(ValueError, match="already open"):
            cluster.session("alice", TenantPolicy(priority=1))


def test_close_resets_sessions_and_reregisters_policy(qcfg):
    """After close(), a retained session handle re-registers with its FULL
    policy on the rebuilt runtime (not gateway defaults), and the tenant
    can be reconfigured via a fresh session()."""
    cluster = QuantumCluster()
    sess = cluster.session("alice", TenantPolicy(priority=0, slo_ms=100.0, weight=3.0))
    sess.executor(qcfg.spec)
    cluster.close()
    sess.executor(qcfg.spec)  # old handle, new runtime
    st = cluster.runtime.gateway.tenants["alice"]
    assert (st.priority, st.weight) == (0, 3.0)
    assert st.slo_s == pytest.approx(0.1)
    cluster.close()
    redone = cluster.session("alice", TenantPolicy(priority=5))  # reconfigure
    assert redone.policy.priority == 5
    cluster.close()


def test_session_submit_and_drain(qcfg):
    rng = np.random.default_rng(3)
    with QuantumCluster(
        ClusterConfig(serving=ServingConfig(target=128, deadline=0.25))
    ) as cluster:
        sess = cluster.session("streamer")
        futs = [
            sess.submit(
                qcfg.spec,
                jnp.asarray(rng.uniform(0, np.pi, qcfg.n_theta), jnp.float32),
                jnp.asarray(rng.uniform(0, np.pi, qcfg.n_angles), jnp.float32),
            )
            for _ in range(9)
        ]
        sess.drain()
        assert all(f.done for f in futs)
        tel = sess.telemetry()
        assert tel is not None and tel["completed"] == 9


def test_session_executor_bit_identical_to_pre_redesign_path(qcfg):
    """The facade is a front, not a fork: a materialized-mode session's
    executor IS the old ``GatewayRuntime.executor`` path, so gradients are
    bit-identical; implicit mode matches to kernel tolerance."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.uniform(0, 1, (2, 8, 8)), jnp.float32)
    y = jnp.asarray([0, 1])
    params = quclassi.init_params(qcfg, jax.random.PRNGKey(0))
    with QuantumCluster() as cluster:
        sess = cluster.session("trainer", bank_mode="materialized")
        l_new, g_new, _ = quclassi.grad_shift(
            qcfg, params, x, y, executor=sess.executor(qcfg.spec)
        )
        old = cluster.runtime.executor(qcfg.spec, "legacy-tenant")
        l_old, g_old, _ = quclassi.grad_shift(qcfg, params, x, y, executor=old)
        assert float(l_new) == float(l_old)
        np.testing.assert_array_equal(
            np.asarray(g_new["theta"]), np.asarray(g_old["theta"])
        )
        imp = cluster.session("trainer-imp", bank_mode="implicit")
        _, g_imp, _ = quclassi.grad_shift(
            qcfg, params, x, y, executor=imp.executor(qcfg.spec)
        )
        np.testing.assert_allclose(
            np.asarray(g_imp["theta"]), np.asarray(g_old["theta"]), atol=1e-5
        )


def test_cluster_backend_factory_uses_fleet_size(qcfg):
    cluster = QuantumCluster(ClusterConfig.homogeneous(3, 10))
    be = cluster.backend("batched", qcfg.spec)
    assert be.n_workers == 3
    assert cluster.backend("sharded", qcfg.spec).capabilities().sharded


# ------------------------------------------------------- virtual-clock bridge
def _jobs():
    return [
        tenancy.JobSpec("a", 5, 1, 48, service_override=0.3),
        tenancy.JobSpec("b", 7, 1, 48, service_override=0.3),
    ]


def test_simulate_forwards_session_policies():
    cfg = ClusterConfig(
        simulation=SimulationConfig(gateway=True, classical_overhead=0.01)
    )
    cluster = QuantumCluster(cfg)
    cluster.session("a", TenantPolicy(priority=0, slo_ms=2000.0, weight=2.0))
    cluster.session("b", TenantPolicy(weight=0.5))
    rep = cluster.simulate(_jobs())
    assert rep.total_circuits == 96
    assert rep.gateway_summary is not None
    slos = {t["client"]: t.get("slo_s") for t in rep.gateway_summary["tenants"]}
    assert slos.get("a") == pytest.approx(2.0)
    # identical to driving SystemSimulation by hand with the same kwargs
    legacy = SystemSimulation(
        list(cfg.workers),
        _jobs(),
        gateway=True,
        classical_overhead=0.01,
        tenant_weights={"a": 2.0, "b": 0.5},
        tenant_priorities={"a": 0, "b": 1},
        tenant_slos_ms={"a": 2000.0},
    ).run()
    assert rep.makespan == pytest.approx(legacy.makespan)
    assert rep.circuits_per_second == pytest.approx(legacy.circuits_per_second)


def test_simulate_rejects_sessions_not_in_jobs():
    """A misspelled session tenant must hit SystemSimulation's unknown-id
    validation, not silently lose its policy."""
    cfg = ClusterConfig(simulation=SimulationConfig(gateway=True))
    cluster = QuantumCluster(cfg)
    cluster.session("alicee", TenantPolicy(priority=0))  # typo'd tenant
    with pytest.raises(ValueError, match="alicee"):
        cluster.simulate([tenancy.JobSpec("alice", 5, 1, 8)])


def test_simulate_without_gateway_matches_legacy():
    cfg = ClusterConfig(
        workers=(WorkerConfig("w1", 5), WorkerConfig("w2", 10)),
        simulation=SimulationConfig(classical_overhead=0.02, fair_queue=True),
    )
    rep = QuantumCluster(cfg).simulate(_jobs()[:1])
    legacy = SystemSimulation(
        [WorkerConfig("w1", 5), WorkerConfig("w2", 10)],
        _jobs()[:1],
        classical_overhead=0.02,
        fair_queue=True,
    ).run()
    assert rep.makespan == pytest.approx(legacy.makespan)


# --------------------------------------------- SystemSimulation kwarg checks
@pytest.mark.parametrize(
    "kwarg",
    ["tenant_weights", "tenant_priorities", "tenant_slos_ms", "arrivals"],
)
def test_simulation_rejects_unknown_tenant_ids(kwarg):
    value = {"nobody": [0.0]} if kwarg == "arrivals" else {"nobody": 1}
    with pytest.raises(ValueError, match=rf"{kwarg}.*nobody"):
        SystemSimulation(
            [WorkerConfig("w1", 5)],
            [tenancy.JobSpec("a", 5, 1, 4)],
            gateway=True,
            **{kwarg: value},
        )


def test_simulation_rejects_unknown_worker_failures():
    with pytest.raises(ValueError, match=r"worker_failures.*w9"):
        SystemSimulation(
            [WorkerConfig("w1", 5)],
            [tenancy.JobSpec("a", 5, 1, 4)],
            worker_failures={"w9": 10.0},
        )


def test_simulation_accepts_known_overrides():
    sim = SystemSimulation(
        [WorkerConfig("w1", 5)],
        [tenancy.JobSpec("a", 5, 1, 4)],
        gateway=True,
        tenant_weights={"a": 2.0},
        tenant_priorities={"a": 0},
        tenant_slos_ms={"a": 1000.0},
    )
    assert sim.gateway.tenants["a"].weight == 2.0
