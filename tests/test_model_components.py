"""Transformer component tests: GQA vs naive reference, decode==prefill,
MLA absorbed-decode equivalence, MoE routing invariants, SSM scan parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models import attention, common, ffn as ffn_mod, moe as moe_mod, ssm


def mini_cfg(**kw):
    base = dict(name="mini", family="dense", n_layers=2, d_model=64, n_heads=4,
                kv_heads=2, d_ff=128, vocab=97, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def naive_mha(params, x, cfg):
    """Reference attention: expand KV heads to full MHA, O(S^2) loops-free."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.kv_heads
    pos = jnp.arange(s)[None, :]
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = common.apply_rope(q, pos, cfg.rope_theta)
    k = common.apply_rope(k, pos, cfg.rope_theta)
    k = jnp.repeat(k, h // kv, axis=2)
    v = jnp.repeat(v, h // kv, axis=2)
    out = np.zeros((b, s, h, hd), np.float32)
    qn, kn, vn = map(np.asarray, (q, k, v))
    for bi in range(b):
        for hi in range(h):
            sc = (qn[bi, :, hi] @ kn[bi, :, hi].T) * hd ** -0.5
            if cfg.sliding_window:
                mask = np.tril(np.ones((s, s))) * \
                    (np.arange(s)[None, :] > np.arange(s)[:, None] - cfg.sliding_window)
            else:
                mask = np.tril(np.ones((s, s)))
            sc = np.where(mask > 0, sc, -1e30)
            p = np.exp(sc - sc.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            out[bi, :, hi] = p @ vn[bi, :, hi]
    return out.reshape(b, s, h * hd) @ np.asarray(params["wo"])


@pytest.mark.parametrize("qk_norm", [False, True])
@pytest.mark.parametrize("window", [0, 3])
def test_gqa_matches_naive_reference(qk_norm, window):
    cfg = mini_cfg(qk_norm=qk_norm, sliding_window=window)
    key = jax.random.PRNGKey(0)
    params = attention.init_gqa_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    got = attention.gqa_attention(params, x, cfg)
    want = naive_mha(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4)


@pytest.mark.parametrize("kv_heads", [1, 2, 4])
def test_gqa_decode_matches_prefill(kv_heads):
    """Feeding tokens one-by-one through the cache == full forward."""
    cfg = mini_cfg(kv_heads=kv_heads)
    key = jax.random.PRNGKey(2)
    params = attention.init_gqa_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, cfg.d_model))
    full = attention.gqa_attention(params, x, cfg)

    cache = attention.init_gqa_cache(cfg, 2, 6, jnp.float32)
    outs = []
    for t in range(6):
        o, cache = attention.gqa_decode(params, x[:, t:t + 1], cache,
                                        jnp.int32(t), cfg)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=2e-4)


def test_sliding_window_ring_buffer_decode():
    """Windowed decode with a ring cache matches windowed full attention on
    the final token (the only one the serve step emits)."""
    cfg = mini_cfg(sliding_window=4)
    key = jax.random.PRNGKey(3)
    params = attention.init_gqa_params(key, cfg, jnp.float32)
    s = 10
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, s, cfg.d_model))
    full = attention.gqa_attention(params, x, cfg)

    cache = attention.init_gqa_cache(cfg, 1, s, jnp.float32)
    assert cache["k"].shape[1] == 4          # ring of window size
    for t in range(s):
        o, cache = attention.gqa_decode(params, x[:, t:t + 1], cache,
                                        jnp.int32(t), cfg)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-4)


def test_mla_decode_matches_attention():
    mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                    qk_rope_head_dim=8, v_head_dim=16)
    cfg = mini_cfg(mla=mla)
    key = jax.random.PRNGKey(4)
    params = attention.init_mla_params(key, cfg, jnp.float32)
    s = 5
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, s, cfg.d_model)) * 0.5
    full = attention.mla_attention(params, x, cfg)
    cache = attention.init_mla_cache(cfg, 2, s, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = attention.mla_decode(params, x[:, t:t + 1], cache,
                                        jnp.int32(t), cfg)
        outs.append(o)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=3e-4)


def test_mla_cache_is_per_token_compact():
    """MLA caches kv_lora+rope floats per token, independent of head count."""
    mla = MLAConfig(kv_lora_rank=16, qk_rope_head_dim=8)
    cfg = mini_cfg(mla=mla, n_heads=4)
    c = attention.init_mla_cache(cfg, 1, 10, jnp.float32)
    per_tok = sum(a.size for a in jax.tree.leaves(c)) / 10
    assert per_tok == 16 + 8


# ------------------------------------------------------------------- FFN
@pytest.mark.parametrize("act", ["silu_gated", "gelu", "relu2"])
def test_ffn_activations(act):
    key = jax.random.PRNGKey(0)
    p = ffn_mod.init_ffn_params(key, 16, 32, act, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16))
    y = ffn_mod.ffn(p, x, act)
    assert y.shape == (4, 16)
    assert np.isfinite(np.asarray(y)).all()


def test_relu2_is_squared_relu():
    fn = common.activation_fn("relu2")
    x = jnp.array([-2.0, 0.5, 3.0])
    np.testing.assert_allclose(np.asarray(fn(x)), [0.0, 0.25, 9.0], atol=1e-6)


# ------------------------------------------------------------------- MoE
def moe_cfg(**kw):
    moe = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, **kw)
    return mini_cfg(moe=moe, family="moe")


def test_moe_output_shape_and_aux():
    cfg = moe_cfg()
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y, aux = moe_mod.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) > 0.0
    assert np.isfinite(np.asarray(y)).all()


def test_moe_dropless_matches_manual_topk():
    """With capacity = T*K (no drops), MoE == explicit per-token expert mix."""
    cfg = moe_cfg(dropless=True)
    key = jax.random.PRNGKey(5)
    p = moe_mod.init_moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 6, cfg.d_model))
    y, _ = moe_mod.moe_ffn(p, x, cfg)

    m = cfg.moe
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gv, ei = jax.lax.top_k(probs, m.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for kk in range(m.top_k):
            e = int(ei[t, kk])
            ex = {k: v[e:e + 1] for k, v in p["experts"].items()}
            o = moe_mod._expert_ffn(ex, xt[t][None, None, :], cfg.activation)
            want[t] += float(gv[t, kk]) * np.asarray(o[0, 0])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)), want,
                               atol=2e-4)


def test_moe_shared_expert_always_on():
    cfg_s = moe_cfg(n_shared_experts=1)
    key = jax.random.PRNGKey(6)
    p = moe_mod.init_moe_params(key, cfg_s, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, cfg_s.d_model))
    y_with, _ = moe_mod.moe_ffn(p, x, cfg_s)
    # zero the shared expert -> output changes (it contributes for all tokens)
    p2 = dict(p, shared=jax.tree.map(jnp.zeros_like, p["shared"]))
    y_without, _ = moe_mod.moe_ffn(p2, x, cfg_s)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-6


def test_moe_capacity_drops_overflow():
    """With tiny capacity_factor, some tokens get dropped (output smaller)."""
    cfg = moe_cfg(capacity_factor=0.25)
    key = jax.random.PRNGKey(7)
    p = moe_mod.init_moe_params(key, cfg, jnp.float32)
    # enough tokens to exceed the t*K<=64 dropless escape hatch
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))
    y_cap, _ = moe_mod.moe_ffn(p, x, cfg)
    cfg_free = moe_cfg(dropless=True)
    y_free, _ = moe_mod.moe_ffn(p, x, cfg_free)
    assert float(jnp.abs(y_cap - y_free).max()) > 1e-6


# ------------------------------------------------------------------- SSM
def ssm_cfg(pattern, **kw):
    return mini_cfg(pattern=pattern, d_ff=0, family="ssm",
                    ssm=SSMConfig(d_state=8, chunk=4, n_heads=2), **kw)


@pytest.mark.parametrize("kind,init,apply,init_state", [
    ("mamba", ssm.init_mamba_params, ssm.mamba_mixer, ssm.init_mamba_state),
    ("mlstm", ssm.init_mlstm_params, ssm.mlstm_mixer, ssm.init_mlstm_state),
    ("slstm", ssm.init_slstm_params, ssm.slstm_mixer, ssm.init_slstm_state),
])
def test_ssm_decode_matches_full_scan(kind, init, apply, init_state):
    """Step-by-step recurrent decode == full-sequence scan (causality)."""
    cfg = ssm_cfg((kind,))
    key = jax.random.PRNGKey(0)
    p = init(key, cfg, jnp.float32)
    s = 6
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, s, cfg.d_model)) * 0.3
    full, _ = apply(p, x, cfg, state=None)

    st = init_state(cfg, 2, jnp.float32)
    outs = []
    for t in range(s):
        o, st = apply(p, x[:, t:t + 1], cfg, state=st)
        outs.append(o)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("kind,init,apply", [
    ("mamba", ssm.init_mamba_params, ssm.mamba_mixer),
    ("mlstm", ssm.init_mlstm_params, ssm.mlstm_mixer),
    ("slstm", ssm.init_slstm_params, ssm.slstm_mixer),
])
def test_ssm_causality(kind, init, apply):
    """Output at position t must not depend on inputs after t."""
    cfg = ssm_cfg((kind,))
    key = jax.random.PRNGKey(1)
    p = init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, cfg.d_model))
    y1, _ = apply(p, x, cfg, state=None)
    x2 = x.at[:, 5:].set(99.0)
    y2, _ = apply(p, x2, cfg, state=None)
    np.testing.assert_allclose(np.asarray(y1[:, :5]), np.asarray(y2[:, :5]),
                               atol=1e-4)


# ---------------------------------------------------------------- common
def test_rms_norm():
    x = jnp.array([[3.0, 4.0]])
    w = jnp.ones(2)
    y = common.rms_norm(x, w, 0.0)
    rms = np.sqrt((9 + 16) / 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) / rms, atol=1e-5)


def test_rope_rotation_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 8))
    pos = jnp.arange(4)[None, :]
    y = common.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), atol=1e-4)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 1, 1, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 8))

    def dot_at(i, j):
        qi = common.apply_rope(q, jnp.array([[i]]), 10_000.0)
        kj = common.apply_rope(k, jnp.array([[j]]), 10_000.0)
        return float((qi * kj).sum())

    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), abs=1e-4)


def test_causal_mask():
    m = np.asarray(common.causal_mask(4, 4, 0))
    assert (m[np.triu_indices(4, 1)] < -1e29).all()
    assert (np.tril(m) == 0).all()


def test_cross_entropy_uniform():
    v = 7
    logits = jnp.zeros((3, v))
    labels = jnp.array([0, 3, 6])
    ce = common.cross_entropy(logits, labels)
    assert float(ce) == pytest.approx(np.log(v), rel=1e-5)


def test_moe_per_k_dispatch_equals_flat():
    """The per_k dispatch mode (§Perf deepseek iteration) is bit-identical
    to flat dispatch when dropless (no capacity races to re-order)."""
    import dataclasses
    cfg_flat = moe_cfg(dropless=True)
    cfg_perk = mini_cfg(family="moe", moe=dataclasses.replace(
        cfg_flat.moe, dispatch="per_k"))
    key = jax.random.PRNGKey(8)
    p = moe_mod.init_moe_params(key, cfg_flat, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg_flat.d_model))
    y1, a1 = moe_mod.moe_ffn(p, x, cfg_flat)
    y2, a2 = moe_mod.moe_ffn(p, x, cfg_perk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
    assert float(a1) == pytest.approx(float(a2))


def test_moe_expert_padding_identical_outputs():
    """pad_to adds dead experts only — outputs unchanged."""
    import dataclasses
    cfg = moe_cfg(dropless=True)
    cfg_pad = mini_cfg(family="moe", moe=dataclasses.replace(cfg.moe, pad_to=8))
    key = jax.random.PRNGKey(9)
    p = moe_mod.init_moe_params(key, cfg, jnp.float32)
    p_pad = moe_mod.init_moe_params(key, cfg_pad, jnp.float32)
    # copy the real experts into the padded bank
    for k in p["experts"]:
        p_pad["experts"][k] = p_pad["experts"][k].at[:4].set(p["experts"][k])
    p_pad["router"] = p["router"]
    p_pad["shared"] = p.get("shared", p_pad.get("shared"))
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, cfg.d_model))
    y1, _ = moe_mod.moe_ffn(p, x, cfg)
    y2, _ = moe_mod.moe_ffn(p_pad, x, cfg_pad)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
