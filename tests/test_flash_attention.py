"""Pallas flash-attention kernel vs oracle: shape/dtype/block sweeps
(interpret mode on CPU; BlockSpec tiling targets TPU VMEM)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import (flash_attention, flash_hbm_bytes,
                                           gqa_flash_attention)
from repro.models import attention


def softmax_ref(q, k, v, causal=True, window=0):
    s = q.shape[1]
    sc = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                    k.astype(jnp.float32))
    qp, kp = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
    ok = kp <= qp if causal else jnp.ones((s, s), bool)
    if window:
        ok = ok & (kp > qp - window)
    sc = jnp.where(ok[None], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)


def rand_qkv(bh, s, hd, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i),
                                     (bh, s, hd), dtype) * 0.5
    return mk(0), mk(1), mk(2)


@pytest.mark.parametrize("s,hd", [(32, 16), (64, 32), (128, 64), (256, 128)])
def test_shape_sweep(s, hd):
    q, k, v = rand_qkv(4, s, hd, jnp.float32, seed=s)
    o = flash_attention(q, k, v, block_q=min(64, s), block_k=min(64, s),
                        interpret=True)
    r = softmax_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(16, 16), (16, 32), (64, 16), (128, 128)])
def test_block_sweep(bq, bk):
    q, k, v = rand_qkv(2, 128, 32, jnp.float32)
    o = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    r = softmax_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_dtype_sweep(dtype, atol):
    q, k, v = rand_qkv(2, 64, 32, dtype)
    o = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    r = softmax_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=atol)
    assert o.dtype == dtype


@pytest.mark.parametrize("window", [4, 16, 64])
def test_sliding_window(window):
    q, k, v = rand_qkv(2, 64, 32, jnp.float32, seed=window)
    o = flash_attention(q, k, v, window=window, block_q=16, block_k=16,
                        interpret=True)
    r = softmax_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_non_causal():
    q, k, v = rand_qkv(2, 32, 16, jnp.float32)
    o = flash_attention(q, k, v, causal=False, block_q=16, block_k=16,
                        interpret=True)
    r = softmax_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@pytest.mark.parametrize("kv_heads", [1, 2, 4])
def test_gqa_layer_matches_naive(kv_heads):
    cfg = ModelConfig(name="m", family="dense", n_layers=2, d_model=64,
                      n_heads=4, kv_heads=kv_heads, d_ff=128, vocab=97,
                      dtype="float32", attention_impl="flash")
    key = jax.random.PRNGKey(1)
    p = attention.init_gqa_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 32, 64))
    naive = attention.gqa_attention(p, x, cfg)
    flash = gqa_flash_attention(p, x, cfg)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(naive), atol=2e-5)


def test_model_forward_with_flash():
    from repro.configs import base as cfg_base
    from repro.models import transformer
    cfg = cfg_base.get("smollm-360m").reduced().with_(
        attention_impl="flash", attention_chunk=8)
    model = transformer.Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    logits, _ = model.prefill(params, batch)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_analytic_hbm_model():
    """Kernel HBM bytes ~ S*sqrt(S) (KV re-read per q-block) vs naive S^2:
    >=10x at 32k with 512-blocks, and the gap widens with S."""
    b, h, hd = 2, 15, 64
    naive_32k = 2 * b * h * 32768 ** 2 * 4 * 2      # scores write+read, f32
    flash_32k = flash_hbm_bytes(b, 32768, h, 5, hd)
    assert flash_32k < naive_32k / 10
    ratio_32k = naive_32k / flash_32k
    naive_128k = 2 * b * h * 131072 ** 2 * 4 * 2
    ratio_128k = naive_128k / flash_hbm_bytes(b, 131072, h, 5, hd)
    assert ratio_128k > ratio_32k
