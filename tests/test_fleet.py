"""Elastic fleet (repro.serve.fleet + fault-tolerant dispatch): worker
health state machine and circuit breaker, typed fault schedules, retry /
migration with bit-identical replay on both dispatchers, hedged dispatch
with first-result-wins, live membership (register/drain at runtime), and
the virtual-clock simulation's mirrored fault kinds."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.comanager.faults import (
    FaultSpec,
    FaultToleranceConfig,
    normalize_failures,
)
from repro.comanager.simulation import SystemSimulation, homogeneous_workers
from repro.comanager.tenancy import JobSpec
from repro.comanager.worker import WorkerConfig
from repro.core.quclassi import QuClassiConfig
from repro.kernels import ops as kops
from repro.serve import GatewayRuntime
from repro.serve.fleet import FaultInjector, FleetHealth, InjectedWorkerFault


def wait_until(pred, timeout=10.0):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.005)
    return False


@pytest.fixture(scope="module")
def specs():
    return QuClassiConfig(qc=5, n_layers=1), QuClassiConfig(qc=7, n_layers=1)


def rows_for(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.uniform(0, np.pi, (n, cfg.n_theta)), jnp.float32)
    data = jnp.asarray(rng.uniform(0, np.pi, (n, cfg.n_angles)), jnp.float32)
    return theta, data


def two_jobs():
    return [
        JobSpec("alice", n_circuits=30, qc=5, n_layers=1, submit_time=0.0),
        JobSpec("bob", n_circuits=30, qc=5, n_layers=1, submit_time=0.0),
    ]


# ---------------------------------------------------- health state machine
class TestFleetHealth:
    def mk(self, **kw):
        fleet = FleetHealth(FaultToleranceConfig(**kw))
        fleet.add("w1")
        return fleet

    def test_breaker_trips_after_consecutive_failures(self):
        fleet = self.mk(breaker_threshold=3, breaker_cooldown_s=5.0)
        assert not fleet.on_failure("w1", 0.0)
        assert not fleet.on_failure("w1", 0.1)
        assert fleet.on_failure("w1", 0.2)  # third strike trips
        assert fleet.state("w1") == "offline"
        assert not fleet.placeable("w1", 1.0)
        assert "w1" in fleet.unplaceable(1.0)

    def test_success_resets_consecutive_count(self):
        fleet = self.mk(breaker_threshold=2)
        fleet.on_failure("w1", 0.0)
        fleet.on_success("w1")
        assert not fleet.on_failure("w1", 0.1)  # count restarted
        assert fleet.state("w1") != "offline"

    def test_cooldown_half_opens_to_probation(self):
        fleet = self.mk(breaker_threshold=1, breaker_cooldown_s=2.0)
        fleet.on_failure("w1", 0.0)
        assert not fleet.placeable("w1", 1.0)
        assert fleet.placeable("w1", 2.5)  # half-open trial
        assert fleet.state("w1") == "probation"

    def test_probation_failure_retrips_immediately(self):
        fleet = self.mk(breaker_threshold=3, breaker_cooldown_s=2.0)
        for i in range(3):
            fleet.on_failure("w1", i * 0.1)
        assert fleet.placeable("w1", 3.0)
        assert fleet.on_failure("w1", 3.1)  # one probation strike re-trips
        assert fleet.state("w1") == "offline"
        assert fleet.snapshot()["w1"]["offline_trips"] == 2

    def test_probation_success_closes_breaker(self):
        fleet = self.mk(breaker_threshold=1, breaker_cooldown_s=1.0)
        fleet.on_failure("w1", 0.0)
        assert fleet.placeable("w1", 2.0)
        fleet.on_success("w1")
        assert fleet.state("w1") in ("idle", "busy")
        assert fleet.snapshot()["w1"]["consecutive_errors"] == 0

    def test_failure_rate_is_ewma(self):
        fleet = self.mk(failure_alpha=0.5, breaker_threshold=100)
        fleet.on_failure("w1", 0.0)
        assert fleet.snapshot()["w1"]["failure_rate"] == pytest.approx(0.5)
        fleet.on_success("w1")
        assert fleet.snapshot()["w1"]["failure_rate"] == pytest.approx(0.25)

    def test_draining_not_placeable_and_never_trips(self):
        fleet = self.mk(breaker_threshold=1)
        fleet.mark_draining("w1")
        assert not fleet.placeable("w1", 0.0)
        assert not fleet.on_failure("w1", 0.0)  # drain beats breaker
        assert fleet.state("w1") == "draining"

    def test_maintenance_and_reactivate(self):
        fleet = self.mk()
        fleet.mark_maintenance("w1")
        assert not fleet.placeable("w1", 0.0)
        fleet.reactivate("w1")
        assert fleet.placeable("w1", 0.0)

    def test_busy_slot_accounting(self):
        fleet = self.mk()
        fleet.on_dispatch("w1")
        assert fleet.state("w1") == "busy"
        fleet.on_release("w1")
        assert fleet.state("w1") == "idle"

    def test_snapshot_counters(self):
        fleet = self.mk(breaker_threshold=2)
        fleet.on_dispatch("w1")
        fleet.on_failure("w1", 0.0)
        fleet.record_retry("w1")
        fleet.record_migration("w1")
        fleet.record_hedge("w1")
        snap = fleet.snapshot()["w1"]
        assert snap["failures"] == 1
        assert snap["retries"] == 1
        assert snap["migrations"] == 1
        assert snap["hedges"] == 1
        assert snap["state"] == "busy"


# ------------------------------------------------- fault-schedule validation
class TestFaultSchedules:
    @pytest.mark.parametrize(
        "bad",
        [
            {"kind": "nope"},
            {"at": -1.0},
            {"at": float("nan")},
            {"kind": "crash_recover", "at": 5.0, "recover_at": 2.0},
            {"kind": "crash_recover", "at": 5.0, "recover_at": float("inf")},
            {"kind": "slowdown", "factor": 0.0},
            {"kind": "flaky", "p": 1.5},
            "never",
        ],
    )
    def test_invalid_specs_name_the_worker(self, bad):
        with pytest.raises(ValueError, match="w1"):
            normalize_failures({"w1": bad})

    def test_legacy_float_still_means_crash(self):
        spec = normalize_failures({"w1": 3.5})["w1"]
        assert spec.kind == "crash" and spec.at == 3.5
        assert not spec.crashed(3.0) and spec.crashed(4.0)

    def test_crash_recover_window(self):
        spec = FaultSpec(kind="crash_recover", at=2.0, recover_at=5.0)
        assert not spec.crashed(1.0)
        assert spec.crashed(2.0) and spec.crashed(4.9)
        assert not spec.crashed(5.0)
        assert spec.crashed_between(1.0, 3.0)
        assert not spec.crashed_between(5.0, 9.0)

    def test_flaky_drops_deterministic_and_retries_progress(self):
        spec = FaultSpec(kind="flaky", p=0.5, seed=7)
        draws = [spec.drops(11, k, 0.0) for k in range(64)]
        assert draws == [spec.drops(11, k, 0.0) for k in range(64)]
        assert any(draws) and not all(draws)  # retries eventually pass

    def test_simulation_rejects_bad_schedule_at_construction(self):
        with pytest.raises(ValueError, match="w1"):
            SystemSimulation(
                homogeneous_workers(2, 10),
                two_jobs(),
                worker_failures={"w1": {"kind": "flaky", "p": -0.1}},
            )


# ------------------------------------------- real dispatchers: crash replay
def crash_runtime(specs, mode, **ft_kw):
    """Two-worker runtime with w1 hard-crashed from t=0: every batch placed
    on (or retried against) w1 fails, trips its breaker, and must migrate
    to w2 through the coalescer requeue path."""
    cfg5, cfg7 = specs
    ft = FaultToleranceConfig(
        retry_limit=0, breaker_threshold=1, breaker_cooldown_s=3600.0, **ft_kw
    )
    inj = FaultInjector({"w1": FaultSpec(kind="crash", at=0.0)})
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 10), WorkerConfig("w2", 10)],
        target=8,
        lanes=8,
        deadline=0.05,
        mode=mode,
        fault_tolerance=ft,
        fault_injector=inj,
    )
    return rt


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_crash_migration_is_bit_identical(specs, mode):
    """The headline replay guarantee: a mid-batch worker crash migrates the
    batch to a survivor and every CircuitFuture resolves to exactly the
    value a fault-free run produces — no lost futures, no duplicates."""
    cfg5, cfg7 = specs
    rt = crash_runtime(specs, mode)
    try:
        t5, d5 = rows_for(cfg5, 8, seed=1)
        t7, d7 = rows_for(cfg7, 8, seed=2)
        now = rt.dispatcher.clock
        futs5 = [
            rt.gateway.submit("alice", cfg5.spec, (t5[i], d5[i]), now())
            for i in range(8)
        ]
        futs7 = [
            rt.gateway.submit("bob", cfg7.spec, (t7[i], d7[i]), now())
            for i in range(8)
        ]
        if mode == "sync":
            rt.dispatcher.drain()
        else:
            rt.dispatcher.kick()
        vals5 = [f.result(timeout=60.0) for f in futs5]
        vals7 = [f.result(timeout=60.0) for f in futs7]
        assert all(f.done for f in futs5 + futs7)
        # bit-identical to the fault-free reference, in submission order
        ref5 = np.asarray(kops.vqc_fidelity(cfg5.spec, t5, d5))
        ref7 = np.asarray(kops.vqc_fidelity(cfg7.spec, t7, d7))
        assert np.array_equal(np.asarray(jnp.stack(vals5)), ref5)
        assert np.array_equal(np.asarray(jnp.stack(vals7)), ref7)
        # the crashed worker is tripped offline; work migrated to w2
        assert rt.dispatcher.fleet.state("w1") == "offline"
        summary = rt.telemetry.summary()
        assert summary["migrated_batches"] >= 1
        assert summary["fleet"]["w1"]["failures"] >= 1
        assert summary["fleet"]["w1"]["migrations"] >= 1
        assert summary["fleet"]["w1"]["offline_trips"] >= 1
    finally:
        rt.close()


def test_sync_terminal_failure_fails_futures(specs):
    """Both workers crashed: no survivor to migrate to — the batch's
    futures must resolve with the error (not hang) and the error must
    propagate from run_batch."""
    cfg5, _ = specs
    ft = FaultToleranceConfig(retry_limit=0, breaker_threshold=1)
    inj = FaultInjector(
        {
            "w1": FaultSpec(kind="crash", at=0.0),
            "w2": FaultSpec(kind="crash", at=0.0),
        }
    )
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 10), WorkerConfig("w2", 10)],
        target=8,
        lanes=8,
        deadline=0.05,
        mode="sync",
        fault_tolerance=ft,
        fault_injector=inj,
    )
    try:
        theta, data = rows_for(cfg5, 8)
        now = rt.dispatcher.clock
        futs = [
            rt.gateway.submit("alice", cfg5.spec, (theta[i], data[i]), now())
            for i in range(8)
        ]
        with pytest.raises(InjectedWorkerFault):
            rt.dispatcher.drain()
        assert all(f.done for f in futs)
        for f in futs:
            with pytest.raises(InjectedWorkerFault):
                f.result(timeout=1.0)
    finally:
        rt.close()


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_transient_failure_retries_in_place(specs, mode):
    """A kernel that fails exactly once recovers via the in-place retry —
    no migration, and the retry is visible in fleet telemetry."""
    cfg5, _ = specs
    boom = {"n": 0}

    def flaky_kernel(spec, theta, data):
        boom["n"] += 1
        if boom["n"] == 1:
            raise RuntimeError("transient kernel fault")
        return kops.vqc_fidelity(spec, theta, data)

    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 10)],
        target=8,
        lanes=8,
        deadline=0.05,
        mode=mode,
        kernel=flaky_kernel,
        fault_tolerance=FaultToleranceConfig(retry_limit=2, breaker_threshold=5),
    )
    try:
        theta, data = rows_for(cfg5, 8)
        now = rt.dispatcher.clock
        futs = [
            rt.gateway.submit("alice", cfg5.spec, (theta[i], data[i]), now())
            for i in range(8)
        ]
        if mode == "sync":
            rt.dispatcher.drain()
        else:
            rt.dispatcher.kick()
        vals = [f.result(timeout=60.0) for f in futs]
        ref = np.asarray(kops.vqc_fidelity(cfg5.spec, theta, data))
        assert np.array_equal(np.asarray(jnp.stack(vals)), ref)
        summary = rt.telemetry.summary()
        assert summary["fleet"]["w1"]["retries"] == 1
        assert "migrated_batches" not in summary
        assert rt.dispatcher.fleet.state("w1") in ("idle", "busy")
    finally:
        rt.close()


# ----------------------------------------------------------------- hedging
def test_async_hedge_first_result_wins(specs):
    """A stalled primary slot past hedge_k x the EWMA estimate gets a
    duplicate dispatch on another worker; the duplicate's result resolves
    the futures while the straggler is still stuck, and the straggler's
    late result is discarded without double-resolution."""
    cfg5, _ = specs
    gate = threading.Event()
    calls = {"n": 0}

    def stall_first_kernel(spec, theta, data):
        calls["n"] += 1
        if calls["n"] == 1:
            assert gate.wait(timeout=30.0), "test gate never released"
        return kops.vqc_fidelity(spec, theta, data)

    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 10), WorkerConfig("w2", 10)],
        target=8,
        lanes=8,
        deadline=0.05,
        mode="async",
        kernel=stall_first_kernel,
        fault_tolerance=FaultToleranceConfig(hedge_k=0.05, breaker_threshold=10),
    )
    try:
        theta, data = rows_for(cfg5, 8)
        now = rt.dispatcher.clock
        futs = [
            rt.gateway.submit("alice", cfg5.spec, (theta[i], data[i]), now())
            for i in range(8)
        ]
        rt.dispatcher.kick()
        vals = [f.result(timeout=60.0) for f in futs]  # hedge resolved these
        assert not gate.is_set()
        ref = np.asarray(kops.vqc_fidelity(cfg5.spec, theta, data))
        assert np.array_equal(np.asarray(jnp.stack(vals)), ref)
        summary = rt.telemetry.summary()
        hedges = sum(ev["hedges"] for ev in summary["fleet"].values())
        assert hedges >= 1
    finally:
        gate.set()
        rt.close()
        # the straggler settled without touching the already-set futures
        assert all(f.done for f in futs)


# --------------------------------------------------------- live membership
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_register_worker_adds_capacity_at_runtime(specs, mode):
    """A fleet of one 5q worker cannot host 7q circuits; registering a 10q
    worker at runtime makes them servable without a restart."""
    cfg5, cfg7 = specs
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5)],
        target=8,
        lanes=8,
        deadline=0.05,
        mode=mode,
    )
    try:
        rt.dispatcher.register_worker(WorkerConfig("w2", 10))
        assert set(rt.dispatcher.fleet.workers()) == {"w1", "w2"}
        t7, d7 = rows_for(cfg7, 8)
        now = rt.dispatcher.clock
        futs = [
            rt.gateway.submit("alice", cfg7.spec, (t7[i], d7[i]), now())
            for i in range(8)
        ]
        if mode == "sync":
            rt.dispatcher.drain()
        else:
            rt.dispatcher.kick()
        vals = [f.result(timeout=60.0) for f in futs]
        ref = np.asarray(kops.vqc_fidelity(cfg7.spec, t7, d7))
        assert np.array_equal(np.asarray(jnp.stack(vals)), ref)
        with pytest.raises(ValueError):
            rt.dispatcher.register_worker(WorkerConfig("w2", 10))  # duplicate
    finally:
        rt.close()


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_drain_worker_removes_it_gracefully(specs, mode):
    """Draining waits for in-flight work, then forgets the worker: it stops
    being placeable and later submissions run entirely on the survivors."""
    cfg5, _ = specs
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 10), WorkerConfig("w2", 10)],
        target=8,
        lanes=8,
        deadline=0.05,
        mode=mode,
    )
    try:
        theta, data = rows_for(cfg5, 8)
        now = rt.dispatcher.clock
        futs = [
            rt.gateway.submit("alice", cfg5.spec, (theta[i], data[i]), now())
            for i in range(8)
        ]
        if mode == "sync":
            rt.dispatcher.drain()
        else:
            rt.dispatcher.kick()
        for f in futs:
            f.result(timeout=60.0)
        rt.dispatcher.drain_worker("w1")
        assert "w1" not in rt.dispatcher.fleet.workers()
        assert "w1" not in rt.dispatcher.manager.workers
        futs2 = [
            rt.gateway.submit("alice", cfg5.spec, (theta[i], data[i]), now())
            for i in range(8)
        ]
        if mode == "sync":
            rt.dispatcher.drain()
        else:
            rt.dispatcher.kick()
        for f in futs2:
            f.result(timeout=60.0)
        with pytest.raises(KeyError):
            rt.dispatcher.drain_worker("nope")
    finally:
        rt.close()


# ----------------------------------------------- bounded error ring buffer
def test_async_error_ring_is_bounded(specs):
    cfg5, _ = specs
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5)],
        target=8,
        lanes=8,
        deadline=0.05,
        mode="async",
    )
    try:
        d = rt.dispatcher
        cap = d.ERRORS_CAPACITY
        with d._cv:
            for i in range(cap + 10):
                d._push_error_locked(RuntimeError(f"e{i}"))
        assert len(d.errors) == cap
        assert d.errors_dropped == 10
        # oldest entries were evicted, newest retained
        assert str(d.errors[-1]) == f"e{cap + 9}"
    finally:
        rt.close()


# ------------------------------------------------- simulation fault parity
def test_sim_crash_recover_completes_all_jobs():
    sim = SystemSimulation(
        homogeneous_workers(3, 10),
        two_jobs(),
        heartbeat_period=1.0,
        worker_failures={
            "w1": FaultSpec(kind="crash_recover", at=0.2, recover_at=5.0)
        },
    )
    r = sim.run()
    assert r.total_circuits == 60
    assert set(r.jobs) == {"alice", "bob"}
    # the recovered worker re-registered and did real work afterwards
    assert "w1" in sim.manager.workers


def test_sim_slowdown_stretches_makespan():
    base = SystemSimulation(homogeneous_workers(2, 10), two_jobs()).run()
    slow = SystemSimulation(
        homogeneous_workers(2, 10),
        two_jobs(),
        worker_failures={"w1": {"kind": "slowdown", "at": 0.0, "factor": 4.0}},
    ).run()
    assert slow.total_circuits == base.total_circuits == 60
    assert slow.makespan > base.makespan


def test_sim_flaky_worker_completes_via_requeue():
    r = SystemSimulation(
        homogeneous_workers(2, 10),
        two_jobs(),
        worker_failures={"w1": {"kind": "flaky", "p": 0.4}},
    ).run()
    assert r.total_circuits == 60 and set(r.jobs) == {"alice", "bob"}


def test_sim_gateway_crash_recover_migrates_batches():
    r = SystemSimulation(
        homogeneous_workers(3, 10),
        two_jobs(),
        gateway=True,
        gateway_deadline=0.2,
        heartbeat_period=1.0,
        worker_failures={
            "w1": FaultSpec(kind="crash_recover", at=0.1, recover_at=6.0)
        },
    ).run()
    assert set(r.jobs) == {"alice", "bob"}
    assert r.gateway_summary["migrated_batches"] >= 1
    assert r.gateway_summary["migrated_circuits"] >= 1


def test_sim_gateway_flaky_requeues_through_coalescer():
    r = SystemSimulation(
        homogeneous_workers(2, 10),
        two_jobs(),
        gateway=True,
        gateway_deadline=0.2,
        worker_failures={"w1": {"kind": "flaky", "p": 0.5}},
    ).run()
    assert set(r.jobs) == {"alice", "bob"}
    assert r.gateway_summary["migrated_batches"] >= 1
