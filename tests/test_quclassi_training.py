"""QuClassi model + distributed-executor equivalence + training integration.

The paper's key accuracy claim is that DISTRIBUTION DOES NOT CHANGE THE MATH:
the distributed system reaches the same accuracy as the non-distributed one
(<2% difference, §IV-B).  In our system this is exact: any executor returns
fidelities in bank order, so gradients are bit-identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comanager import dataplane
from repro.core import quclassi, shift_rule
from repro.core.quclassi import QuClassiConfig
from repro.core.trainer import train
from repro.data import mnist


@pytest.fixture(scope="module")
def small_data():
    x, y = mnist.make_pair_dataset(1, 5, n_per_class=16, seed=0)
    return jnp.asarray(x[:8]), jnp.asarray(y[:8])


def test_init_params_shapes():
    cfg = QuClassiConfig(qc=5, n_layers=2)
    p = quclassi.init_params(cfg, jax.random.PRNGKey(0))
    assert p["theta"].shape == (2, cfg.n_theta)
    assert p["w"].shape == (16, cfg.n_angles)
    assert float(p["theta"].min()) >= 0.0
    assert float(p["theta"].max()) <= np.pi


def test_class_fidelities_shape_and_range(small_data):
    x, _ = small_data
    cfg = QuClassiConfig(qc=5, n_layers=1)
    p = quclassi.init_params(cfg, jax.random.PRNGKey(0))
    f = quclassi.class_fidelities(cfg, p, x)
    assert f.shape == (8, 2)
    assert float(f.min()) >= -1e-6 and float(f.max()) <= 1 + 1e-6


@pytest.mark.parametrize("nl", [1, 2])
def test_shift_equals_autodiff_exact_layers(small_data, nl):
    x, y = small_data
    cfg = QuClassiConfig(qc=5, n_layers=nl)
    p = quclassi.init_params(cfg, jax.random.PRNGKey(1))
    l1, g1, f1 = quclassi.grad_shift(cfg, p, x, y)
    l2, g2, f2 = quclassi.grad_autodiff(cfg, p, x, y)
    assert abs(float(l1 - l2)) < 1e-5
    np.testing.assert_allclose(np.asarray(g1["theta"]), np.asarray(g2["theta"]),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-5)


def test_distribution_does_not_change_gradients(small_data):
    """Round-robin over 4 'workers' == single-shot local execution."""
    x, y = small_data
    cfg = QuClassiConfig(qc=5, n_layers=2)
    p = quclassi.init_params(cfg, jax.random.PRNGKey(2))
    spec = cfg.spec
    n_bank = (2 * cfg.n_theta + 1) * x.shape[0] * cfg.n_patches
    assignment = dataplane.round_robin_assignment(n_bank, 4)
    dist = dataplane.worker_batched_executor(spec, assignment, 4)

    l1, g1, f1 = quclassi.grad_shift(cfg, p, x, y, executor=dist)
    l2, g2, f2 = quclassi.grad_shift(cfg, p, x, y)
    np.testing.assert_allclose(np.asarray(g1["theta"]), np.asarray(g2["theta"]),
                               atol=1e-5)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-5)


def test_arbitrary_assignment_same_result(small_data):
    """Any scheduler decision yields the same fidelities (order restored)."""
    x, y = small_data
    cfg = QuClassiConfig(qc=5, n_layers=1)
    p = quclassi.init_params(cfg, jax.random.PRNGKey(3))
    banks, _ = quclassi.build_class_banks(cfg, p, x)
    bank = banks[0]
    rng = np.random.default_rng(0)
    assignment = rng.integers(0, 3, bank.n_circuits)
    ex = dataplane.worker_batched_executor(cfg.spec, assignment, 3)
    f_dist = ex(bank.theta, bank.data)
    f_local = shift_rule.default_executor(cfg.spec)(bank.theta, bank.data)
    np.testing.assert_allclose(np.asarray(f_dist), np.asarray(f_local), atol=1e-5)


def test_total_bank_circuits():
    cfg = QuClassiConfig(qc=5, n_layers=1)   # n_theta=4, 8x8 img -> 9 patches
    assert quclassi.total_bank_circuits(cfg, batch=2) == 2 * 2 * 9 * 9


@pytest.mark.slow
def test_training_learns():
    """End-to-end Algorithm 1: accuracy improves well above chance."""
    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(1, 5, n_per_class=40, seed=0)
    (xtr, ytr), (xte, yte) = mnist.train_test_split(x, y)
    # lr=0.05/seed=0 plateaus at 0.70 under current jax PRNG streams (theta
    # init lands near a shallow basin); lr=0.1 escapes it across seeds
    # (seed 0 -> 0.80, seed 1 -> 1.00) — the claim tested is still
    # "learning well above chance", not one lucky seed.
    rep = train(cfg, (xtr, ytr), (xte, yte), epochs=10, batch_size=16,
                lr=0.1, optimizer="adam", grad_mode="autodiff", seed=1)
    assert rep.final_test_accuracy >= 0.8
    assert rep.epochs[-1].loss < rep.epochs[0].loss


@pytest.mark.slow
def test_training_shift_mode_one_epoch():
    """The distributed-gradient path trains (loss decreases)."""
    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(3, 6, n_per_class=12, seed=1)
    (xtr, ytr), (xte, yte) = mnist.train_test_split(x, y)
    rep = train(cfg, (xtr, ytr), (xte, yte), epochs=2, batch_size=6,
                lr=0.05, optimizer="adam", grad_mode="shift")
    assert rep.epochs[0].circuits_executed > 0
    assert np.isfinite(rep.epochs[-1].loss)
