"""Unit tests for the (re, im) gate matrices: unitarity, special values,
generator structure — including hypothesis sweeps over angles."""
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core import gates as G

ANGLES = st.floats(min_value=-4 * np.pi, max_value=4 * np.pi,
                   allow_nan=False, allow_infinity=False)


def to_complex(u):
    re, im = u
    return np.asarray(re) + 1j * np.asarray(im)


def assert_unitary(u, atol=1e-6):
    m = to_complex(u)
    eye = np.eye(m.shape[0])
    np.testing.assert_allclose(m.conj().T @ m, eye, atol=atol)


@pytest.mark.parametrize("name", list(G.GATES))
def test_all_gates_unitary_at_fixed_angle(name):
    ctor, k, takes_angle = G.GATES[name]
    u = ctor(0.731) if takes_angle else ctor()
    assert to_complex(u).shape == (2 ** k, 2 ** k)
    assert_unitary(u)


@pytest.mark.parametrize("name", [n for n, (_, _, a) in G.GATES.items() if a])
@given(theta=ANGLES)
def test_parameterized_gates_unitary(name, theta):
    ctor, _, _ = G.GATES[name]
    assert_unitary(ctor(jnp.float32(theta)))


@pytest.mark.parametrize("name", [n for n, (_, _, a) in G.GATES.items() if a])
def test_rotations_identity_at_zero(name):
    ctor, k, _ = G.GATES[name]
    m = to_complex(ctor(0.0))
    np.testing.assert_allclose(m, np.eye(2 ** k), atol=1e-7)


@pytest.mark.parametrize("name", ["rx", "ry", "rz", "ryy", "rzz"])
def test_rotations_4pi_periodic(name):
    ctor = G.GATES[name][0]
    a, b = to_complex(ctor(1.234)), to_complex(ctor(1.234 + 4 * np.pi))
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_rx_matches_exponential():
    theta = 0.917
    X = np.array([[0, 1], [1, 0]], complex)
    expect = np.cos(theta / 2) * np.eye(2) - 1j * np.sin(theta / 2) * X
    np.testing.assert_allclose(to_complex(G.rx(theta)), expect, atol=1e-6)


def test_ry_matches_exponential():
    theta = -2.3
    Y = np.array([[0, -1j], [1j, 0]])
    expect = np.cos(theta / 2) * np.eye(2) - 1j * np.sin(theta / 2) * Y
    np.testing.assert_allclose(to_complex(G.ry(theta)), expect, atol=1e-6)


def test_rz_matches_exponential():
    theta = 0.4
    expect = np.diag([np.exp(-1j * theta / 2), np.exp(1j * theta / 2)])
    np.testing.assert_allclose(to_complex(G.rz(theta)), expect, atol=1e-6)


@pytest.mark.parametrize("name,pauli", [("ryy", "Y"), ("rzz", "Z")])
def test_two_qubit_rotations_match_exponential(name, pauli):
    from scipy_free_expm import expm2  # local helper below

    theta = 1.371
    P = {"Y": np.array([[0, -1j], [1j, 0]]), "Z": np.diag([1, -1])}[pauli]
    gen = np.kron(P, P)
    expect = expm2(-1j * theta / 2 * gen)
    got = to_complex(G.GATES[name][0](theta))
    np.testing.assert_allclose(got, expect, atol=1e-6)


# tiny expm for 4x4 via eigendecomposition (no scipy in container)
import sys
import types

_mod = types.ModuleType("scipy_free_expm")


def _expm2(m):
    w, v = np.linalg.eig(m)
    return (v * np.exp(w)) @ np.linalg.inv(v)


_mod.expm2 = _expm2
sys.modules["scipy_free_expm"] = _mod


def test_cry_controlled_structure():
    theta = 0.83
    m = to_complex(G.cry(theta))
    np.testing.assert_allclose(m[:2, :2], np.eye(2), atol=1e-7)
    np.testing.assert_allclose(m[:2, 2:], 0, atol=1e-7)
    np.testing.assert_allclose(m[2:, :2], 0, atol=1e-7)
    np.testing.assert_allclose(m[2:, 2:], to_complex(G.ry(theta)), atol=1e-7)


def test_cswap_permutation():
    m = to_complex(G.cswap())
    # control=0 -> identity on first 4 basis states
    np.testing.assert_allclose(m[:4, :4], np.eye(4), atol=1e-7)
    # control=1 -> swap the two target bits: |101> <-> |110>
    expect = np.eye(4)[[0, 2, 1, 3]]
    np.testing.assert_allclose(m[4:, 4:], expect, atol=1e-7)


def test_hadamard_self_inverse():
    m = to_complex(G.h())
    np.testing.assert_allclose(m @ m, np.eye(2), atol=1e-6)
