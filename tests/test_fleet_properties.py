"""Property tests for failure-tolerant dispatch (hypothesis; optional —
minimal environments skip this module).

The replay invariant under arbitrary single-worker crash schedules: every
submitted circuit's future resolves exactly once, to the bit-identical
value a fault-free run produces, and the coalescer requeue path neither
loses nor duplicates members.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.comanager.faults import FaultSpec, FaultToleranceConfig  # noqa: E402
from repro.comanager.simulation import (  # noqa: E402
    SystemSimulation,
    homogeneous_workers,
)
from repro.comanager.tenancy import JobSpec  # noqa: E402
from repro.comanager.worker import WorkerConfig  # noqa: E402
from repro.core.quclassi import QuClassiConfig  # noqa: E402
from repro.serve import Gateway, GatewayRuntime  # noqa: E402
from repro.serve.fleet import FaultInjector  # noqa: E402

CFG = QuClassiConfig(qc=5, n_layers=1)


def fake_kernel(spec, theta, data):
    """Cheap, deterministic, per-lane-independent stand-in for the Pallas
    kernel — lane i's value depends only on row i, so batch composition
    (and therefore migration/re-coalescing) cannot change it."""
    return theta.sum(axis=-1) * 1000.0 + data.sum(axis=-1)


def rows(n, seed):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.uniform(0, np.pi, (n, CFG.n_theta)), jnp.float32)
    data = jnp.asarray(rng.uniform(0, np.pi, (n, CFG.n_angles)), jnp.float32)
    return theta, data


# ---------------------------------------------- crash schedule -> replay
@settings(max_examples=15, deadline=None)
@given(
    crash_worker=st.sampled_from(["w1", "w2"]),
    crash_at=st.floats(0.0, 0.05, allow_nan=False),
    recover_after=st.one_of(st.none(), st.floats(0.01, 0.1, allow_nan=False)),
    seed=st.integers(0, 2**16),
)
def test_single_worker_crash_is_bit_identical(
    crash_worker, crash_at, recover_after, seed
):
    """ANY single-worker crash schedule on the real AsyncDispatcher: all
    futures resolve exactly once, bit-identical to the fault-free values,
    with no lost or duplicated CircuitFuture across requeue/re-placement."""
    spec = FaultSpec(
        kind="crash" if recover_after is None else "crash_recover",
        at=crash_at,
        recover_at=None if recover_after is None else crash_at + recover_after,
    )
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 10), WorkerConfig("w2", 10)],
        target=4,
        lanes=4,
        deadline=0.02,
        mode="async",
        kernel=fake_kernel,
        fault_tolerance=FaultToleranceConfig(
            retry_limit=1, breaker_threshold=1, breaker_cooldown_s=0.05
        ),
        fault_injector=FaultInjector({crash_worker: spec}),
    )
    try:
        theta, data = rows(8, seed)
        now = rt.dispatcher.clock
        futs = [
            rt.gateway.submit("t", CFG.spec, (theta[i], data[i]), now())
            for i in range(8)
        ]
        rt.dispatcher.kick()
        vals = np.asarray([float(f.result(timeout=30.0)) for f in futs])
        ref = np.asarray(fake_kernel(CFG.spec, theta, data))
        assert np.array_equal(vals, ref)
        # exactly-once: CircuitFuture.set asserts on double resolution, so
        # done-ness here proves one-and-only-one settlement per circuit
        assert all(f.done for f in futs)
    finally:
        rt.close()


# -------------------------------------- coalescer requeue conservation
@settings(max_examples=30, deadline=None)
@given(
    counts=st.lists(st.integers(1, 9), min_size=1, max_size=4),
    requeue_idx=st.integers(0, 7),
)
def test_requeue_conserves_members_and_order(counts, requeue_idx):
    """gateway.requeue of an emitted batch re-coalesces every member exactly
    once, front of the queue, preserving the batch's internal lane order."""
    gw = Gateway(target=4, deadline=10.0, lanes=4)
    seq = 0
    for ci, n in enumerate(counts):
        gw.register_client(f"c{ci}")
        for _ in range(n):
            gw.submit(f"c{ci}", ("k", 5), payload=seq, now=0.0)
            seq += 1
    batches = list(gw.pump(0.0)) + list(gw.flush(1e9))
    all_members = [m.seq for b in batches for m in b.members]
    assert sorted(all_members) == list(range(seq))  # nothing lost at emit
    victim = batches[requeue_idx % len(batches)]
    victim_seqs = [m.seq for m in victim.members]
    gw.requeue(victim, now=2.0)
    replayed = list(gw.pump(2.0)) + list(gw.flush(1e9))
    replayed_seqs = [m.seq for b in replayed for m in b.members]
    # exactly the victim's members come back, in the same relative order
    assert replayed_seqs == victim_seqs
    assert gw.idle


# ------------------------------------- virtual-clock crash conservation
@settings(max_examples=10, deadline=None)
@given(
    widx=st.integers(1, 3),
    at=st.floats(0.05, 3.0, allow_nan=False),
    recover_after=st.one_of(st.none(), st.floats(0.5, 4.0, allow_nan=False)),
)
def test_sim_crash_schedule_conserves_circuits(widx, at, recover_after):
    """Under any single-worker crash(+recover) schedule the gateway-mode
    simulation still completes every tenant's every circuit."""
    spec = FaultSpec(
        kind="crash" if recover_after is None else "crash_recover",
        at=at,
        recover_at=None if recover_after is None else at + recover_after,
    )
    r = SystemSimulation(
        homogeneous_workers(3, 10),
        [
            JobSpec("alice", n_circuits=20, qc=5, n_layers=1, submit_time=0.0),
            JobSpec("bob", n_circuits=20, qc=5, n_layers=2, submit_time=0.2),
        ],
        gateway=True,
        gateway_deadline=0.2,
        heartbeat_period=1.0,
        worker_failures={f"w{widx}": spec},
    ).run()
    assert r.total_circuits == 40
    assert set(r.jobs) == {"alice", "bob"}
