"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single-CPU device count (only launch/dryrun.py forces 512 placeholders)."""
import os

import numpy as np
import pytest

# Keep hypothesis deterministic and CI-friendly.  hypothesis is optional:
# minimal environments run the non-property tests; the property modules
# importorskip it themselves.
try:
    from hypothesis import settings
except ImportError:
    settings = None
else:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# the `slow` marker is registered in pyproject.toml ([tool.pytest.ini_options]),
# which also excludes it from default runs via addopts = -m "not slow"
