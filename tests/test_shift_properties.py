"""Property tests for the multi-use suffix-replay shift plans.

Random SWAP-test-structured circuits with REPEATED parameters — arbitrary
reuse counts, interleavings, and gate mixes — must produce shift-bank
fidelities that match the ``materialize()`` + dense-oracle reference to
<= 1e-5 on every execution path: the single-sweep fused kernel, the fused
multi-bank launch, and the depth-tiled spilled path (including a genuinely
wide m = 8 register).  The plan's cost accounting must agree with a direct
count over the generated circuit.

The generator is a plain seeded ``random.Random`` walk so a fixed seed set
always runs; when hypothesis is installed it additionally drives the seed
space (and shrinks failures to a minimal seed).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import shift_rule
from repro.core.sim import CircuitSpec, Op
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels import vqc_statevector as K

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal environments: deterministic seeds only
    HAVE_HYPOTHESIS = False

SINGLE_GATES = ("rx", "ry", "rz")
PAIR_GATES = ("ryy", "rzz", "cry", "crz")
TB = 128


def random_multiuse_spec(seed, m_max=2, n_params_max=3, n_ops_max=8):
    """A random product-structure circuit whose trainable stack reuses
    parameters: encoding on the data register, a random gate list on the
    trainable register where each gate draws its parameter from a small
    pool (so repeats are the norm), then the SWAP-test tail."""
    rng = random.Random(seed)
    m = rng.randint(1, m_max)
    n_params = rng.randint(1, n_params_max)
    n_ops = rng.randint(n_params, n_ops_max)
    anc = 0
    data_q = list(range(1, 1 + m))
    train_q = list(range(1 + m, 1 + 2 * m))
    ops = [Op("rx", (q,), ("data", i)) for i, q in enumerate(data_q)]
    for _ in range(n_ops):
        j = rng.randrange(n_params)
        if m > 1 and rng.random() < 0.5:
            gate = rng.choice(PAIR_GATES)
            a = rng.randrange(m - 1)
            ops.append(Op(gate, (train_q[a], train_q[a + 1]), ("theta", j)))
        else:
            gate = rng.choice(SINGLE_GATES)
            ops.append(Op(gate, (rng.choice(train_q),), ("theta", j)))
    ops.append(Op("h", (anc,)))
    ops += [Op("cswap", (anc, d, t)) for d, t in zip(data_q, train_q)]
    ops.append(Op("h", (anc,)))
    return CircuitSpec(
        n_qubits=1 + 2 * m, ops=tuple(ops), n_theta=n_params, n_data=m
    )


def _reference(spec, bank):
    mat = bank.materialize()
    return np.asarray(
        ref.vqc_fidelity_ref(spec, mat.theta, mat.data)
    ).reshape(bank.n_groups, bank.n_samples)


def _bank(spec, seed, b=2, four_term=False):
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(
        key, (spec.n_theta,), jnp.float32, minval=0.0, maxval=np.pi
    )
    data = jax.random.uniform(
        jax.random.fold_in(key, 1), (b, spec.n_data), jnp.float32,
        minval=0.0, maxval=np.pi,
    )
    return shift_rule.build_shift_bank(theta, data, four_term=four_term)


def check_fused(seed, four_term):
    spec = random_multiuse_spec(seed)
    plan = K.build_shift_plan(spec)
    assert plan is not None
    bank = _bank(spec, seed, four_term=four_term)
    got = np.asarray(
        K.vqc_shift_fidelity(spec, bank.theta, bank.data, four_term=four_term)
    )
    np.testing.assert_allclose(got, _reference(spec, bank), atol=1e-5)
    # plan bookkeeping agrees with a direct scan of the generated circuit
    for j in range(spec.n_theta):
        uses = [
            i for i, op in enumerate(plan.train_ops)
            if op.param == ("theta", j)
        ]
        assert plan.theta_positions[j] == tuple(uses)
        assert plan.replay_depth(j) == ((uses[-1] - uses[0] + 1) if uses else 0)


def check_spilled(seed):
    """Force a one-checkpoint budget so every replay span becomes its own
    depth tile (or merges with its overlap neighbours)."""
    spec = random_multiuse_spec(seed)
    plan = K.build_shift_plan(spec)
    assert plan is not None
    bank = _bank(spec, seed)
    budget = K.checkpoint_vmem_bytes(plan, 1, TB)
    got = np.asarray(
        K.vqc_shift_fidelity(
            spec, bank.theta, bank.data, tb=TB, vmem_budget=budget
        )
    )
    np.testing.assert_allclose(got, _reference(spec, bank), atol=1e-5)


def check_multibank(seed):
    spec = random_multiuse_spec(seed)
    b1 = _bank(spec, seed)
    b2 = _bank(spec, seed + 1, b=3)
    gs = (tuple(range(b1.n_groups)), tuple(range(0, b2.n_groups, 2)))
    got = kops.vqc_fidelity_shiftgroups_multibank(
        spec, (b1.theta, b2.theta), (b1.data, b2.data), False, gs
    )
    for bank, groups, out in zip((b1, b2), gs, got):
        want = _reference(spec, bank)[list(groups)]
        np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)


def check_ops_layer(seed):
    """Whatever side of the cost crossover a random circuit lands on, the
    public ops wrapper returns the reference fidelities."""
    spec = random_multiuse_spec(seed)
    bank = _bank(spec, seed)
    got = np.asarray(kops.vqc_fidelity_shiftgroups(spec, bank.theta, bank.data))
    np.testing.assert_allclose(got, _reference(spec, bank), atol=1e-5)
    cost = K.shift_cost_info(spec)
    assert cost["gate_apps_implicit"] is not None
    assert cost["use_implicit"] == (
        cost["gate_apps_implicit"] < cost["gate_apps_materialized"]
    )


# ------------------------------------------- deterministic seed coverage
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("four_term", [False, True])
def test_fused_replay_matches_materialized(seed, four_term):
    check_fused(seed, four_term)


@pytest.mark.parametrize("seed", range(100, 103))
def test_spilled_replay_matches_materialized(seed):
    check_spilled(seed)


@pytest.mark.parametrize("seed", range(200, 203))
def test_multibank_replay_matches_per_bank(seed):
    check_multibank(seed)


@pytest.mark.parametrize("seed", range(300, 304))
def test_ops_layer_selection_always_correct(seed):
    check_ops_layer(seed)


def test_wide_register_multiuse_spill_m8():
    """Deterministic anchor at m = 8 (the acceptance width): a tied stack
    on a wide register runs the spilled path with overlapped boundary
    fetches and matches the full-sweep result."""
    m = 8
    anc = 0
    data_q = list(range(1, 1 + m))
    train_q = list(range(1 + m, 1 + 2 * m))
    ops = [Op("rx", (q,), ("data", i)) for i, q in enumerate(data_q)]
    for j in range(6):  # 6 params x 2 adjacent uses
        q = train_q[j % m]
        ops.append(Op("ry", (q,), ("theta", j)))
        ops.append(Op("rz", (q,), ("theta", j)))
    ops.append(Op("h", (anc,)))
    ops += [Op("cswap", (anc, d, t)) for d, t in zip(data_q, train_q)]
    ops.append(Op("h", (anc,)))
    spec = CircuitSpec(n_qubits=1 + 2 * m, ops=tuple(ops), n_theta=6, n_data=m)
    plan = K.build_shift_plan(spec)
    assert plan is not None
    bank = _bank(spec, 11)
    budget = K.checkpoint_vmem_bytes(plan, 2, TB)
    tiles = K.plan_depth_tiles(
        plan, sorted(ps[-1] for ps in plan.theta_positions), TB, budget
    )
    assert tiles is not None and len(tiles) > 1
    spilled = np.asarray(
        K.vqc_shift_fidelity(
            spec, bank.theta, bank.data, tb=TB, vmem_budget=budget
        )
    )
    full = np.asarray(K.vqc_shift_fidelity(spec, bank.theta, bank.data, tb=TB))
    np.testing.assert_allclose(spilled, full, atol=1e-5)


# --------------------------------------------- hypothesis-driven seeds
if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), four_term=st.booleans())
    def test_fused_replay_property(seed, four_term):
        check_fused(seed, four_term)

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_spilled_replay_property(seed):
        check_spilled(seed)

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_multibank_replay_property(seed):
        check_multibank(seed)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_ops_layer_selection_property(seed):
        check_ops_layer(seed)
