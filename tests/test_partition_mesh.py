"""Partitioner / mesh unit tests (host mesh — no 512-device forcing here)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st
from jax.sharding import PartitionSpec as P

from repro.launch import partition
from repro.launch.mesh import batch_axes, data_axis_size, make_host_mesh


class FakeMesh:
    """Shape-only stand-in so Partitioner logic is testable without
    actually materializing 256 devices."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)
        import numpy as _np
        self.devices = _np.empty(tuple(shape.values()), dtype=object)


def pod_partitioner():
    return partition.Partitioner.__new__(partition.Partitioner), None


def make_partitioner(shape):
    p = partition.Partitioner.__new__(partition.Partitioner)
    mesh = FakeMesh(shape)
    p.mesh = mesh
    p.model_n = shape.get("model", 1)
    p.data_n = shape.get("data", 1)
    p.batch_ax = tuple(a for a in ("pod", "data") if a in shape)
    p.batch_n = int(np.prod([shape[a] for a in p.batch_ax]))
    return p


SINGLE = {"data": 16, "model": 16}
MULTI = {"pod": 2, "data": 16, "model": 16}


def test_param_spec_2d_matrix():
    p = make_partitioner(SINGLE)
    assert p.param_spec("lm_head", (1024, 4096)) == P("data", "model")
    # non-divisible dims stay unsharded
    assert p.param_spec("lm_head", (1000, 4096)) == P(None, "model")
    assert p.param_spec("lm_head", (1024, 100)) == P("data", None)


def test_param_spec_embed_vocab_parallel():
    p = make_partitioner(SINGLE)
    assert p.param_spec("embed", (49152, 960)) == P("model", "data")


def test_param_spec_block_leading_period_axis():
    p = make_partitioner(SINGLE)
    spec = p.param_spec("blocks/0/mixer/wq", (12, 960, 960))
    assert spec == P(None, "data", "model")


def test_param_spec_experts():
    p = make_partitioner(SINGLE)
    # E=48 divides 16 -> expert parallel; E=40 does not -> replicated E
    assert p.param_spec("blocks/0/ffn/experts/w_in", (12, 48, 1536, 512)) \
        == P(None, "model", "data", None)
    assert p.param_spec("blocks/0/ffn/experts/w_in", (12, 40, 1536, 512)) \
        == P(None, None, "data", None)


def test_param_spec_vectors_replicated():
    p = make_partitioner(SINGLE)
    assert p.param_spec("final_norm", (960,)) == P(None)
    assert p.param_spec("opt/step", ()) == P()


def test_batch_spec_single_and_multi_pod():
    ps = make_partitioner(SINGLE)
    assert ps.batch_spec((256, 4096)) == P("data", None)
    pm = make_partitioner(MULTI)
    assert pm.batch_spec((256, 4096)) == P(("pod", "data"), None)
    # batch=1 (long_500k): unshardable -> replicated batch dim
    assert pm.batch_spec((1, 4096)) == P(None, None)


def test_cache_spec_batch_shardable():
    p = make_partitioner(SINGLE)
    # (period, B, T, kv, hd): batch over data, T over model
    spec = p.cache_spec("blocks/0/k", (12, 128, 32768, 8, 64))
    assert spec[1] == "data" and spec[2] == "model"


def test_cache_spec_context_parallel_fallback():
    p = make_partitioner(SINGLE)
    # batch=1: length axis takes every available device
    spec = p.cache_spec("blocks/0/k", (12, 1, 524288, 8, 64))
    assert spec[1] is None
    assert spec[2] == ("data", "model")


@given(rows=st.sampled_from([1, 2, 8, 64, 100, 256, 4096]),
       cols=st.sampled_from([1, 60, 128, 960, 2560, 49152]))
def test_param_spec_always_valid(rows, cols):
    """Whatever the shape, the spec's sharded dims must divide."""
    p = make_partitioner(SINGLE)
    spec = p.param_spec("w", (rows, cols))
    for dim, ax in zip((rows, cols), spec):
        if ax == "data":
            assert dim % 16 == 0
        if ax == "model":
            assert dim % 16 == 0


def test_host_mesh_and_axes():
    mesh = make_host_mesh()
    assert batch_axes(mesh) == ("data",)
    assert data_axis_size(mesh) == 1


def test_opt_shardings_mirror_params():
    p = make_partitioner(SINGLE)
    params = {"w": jax.ShapeDtypeStruct((1024, 4096), jnp.float32)}
    opt = {"m": {"w": jax.ShapeDtypeStruct((1024, 4096), jnp.float32)},
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    # use the host mesh for real NamedShardings
    real = partition.Partitioner(make_host_mesh())
    shard = real.opt_shardings(opt, params)
    assert shard["m"]["w"].spec == real.param_spec("w", (1024, 4096))
    assert shard["step"].spec == P()
