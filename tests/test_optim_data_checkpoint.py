"""Optimizer / data pipeline / checkpoint substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.data import mnist, pipeline
from repro.optim import optimizers


# -------------------------------------------------------------- optimizers
@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw"])
def test_optimizer_reduces_quadratic(name):
    opt = optimizers.make(name, 0.1)
    params = {"x": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}           # d/dx ||x||^2
        updates, state = opt.update(grads, state, params)
        params = optimizers.apply_updates(params, updates)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_sgd_exact_step():
    opt = optimizers.make("sgd", 0.5)
    p = {"x": jnp.array([1.0])}
    s = opt.init(p)
    u, s = opt.update({"x": jnp.array([2.0])}, s, p)
    np.testing.assert_allclose(np.asarray(u["x"]), [-1.0])


def test_adam_first_step_is_lr_sized():
    opt = optimizers.make("adam", 0.1)
    p = {"x": jnp.array([0.0])}
    s = opt.init(p)
    u, _ = opt.update({"x": jnp.array([7.0])}, s, p)
    np.testing.assert_allclose(np.asarray(u["x"]), [-0.1], atol=1e-6)


def test_adamw_decay():
    opt = optimizers.make("adamw", 0.1, weight_decay=0.1)
    p = {"x": jnp.array([10.0])}
    s = opt.init(p)
    u, _ = opt.update({"x": jnp.array([0.0])}, s, p)
    # pure decay term: -lr * wd * p = -0.1*0.1*10
    np.testing.assert_allclose(np.asarray(u["x"]), [-0.1], atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}   # norm 5
    clipped, gn = optimizers.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(5.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)
    # under the cap: untouched
    same, _ = optimizers.clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0])


def test_schedule_callable_lr():
    opt = optimizers.make("sgd", lambda step: 1.0 / step)
    p = {"x": jnp.array([0.0])}
    s = opt.init(p)
    u1, s = opt.update({"x": jnp.array([1.0])}, s, p)
    u2, s = opt.update({"x": jnp.array([1.0])}, s, p)
    assert float(u1["x"][0]) == pytest.approx(-1.0)
    assert float(u2["x"][0]) == pytest.approx(-0.5)


# --------------------------------------------------------------------- data
def test_mnist_determinism_and_shapes():
    x1, y1 = mnist.make_pair_dataset(3, 9, n_per_class=10, seed=4)
    x2, y2 = mnist.make_pair_dataset(3, 9, n_per_class=10, seed=4)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (20, 8, 8)
    assert set(np.unique(y1)) == {0, 1}
    assert x1.min() >= 0.0 and x1.max() <= 1.0


def test_mnist_classes_distinguishable():
    """Mean images of the two classes differ substantially."""
    x, y = mnist.make_pair_dataset(1, 8, n_per_class=30, seed=0)
    m1, m0 = x[y == 1].mean(0), x[y == 0].mean(0)
    assert np.abs(m1 - m0).mean() > 0.05


def test_pipeline_clean():
    x = np.array([[0.5, 100.0], [0.1, 0.2]], np.float32)
    out = pipeline.clean(x)
    assert out.max() <= 1.0 and out.min() >= 0.0


def test_pipeline_batches_cover_all_and_shuffle():
    x = np.arange(20).reshape(10, 2).astype(np.float32)
    y = np.arange(10)
    got = list(pipeline.batches(x, y, 5, seed=1))
    assert len(got) == 2
    all_labels = sorted(np.concatenate([b[1] for b in got]).tolist())
    assert all_labels == list(range(10))
    got2 = list(pipeline.batches(x, y, 5, seed=1))
    np.testing.assert_array_equal(got[0][1], got2[0][1])  # deterministic


def test_pipeline_drop_remainder():
    x = np.zeros((7, 1), np.float32)
    y = np.zeros(7)
    assert len(list(pipeline.batches(x, y, 3))) == 2
    assert len(list(pipeline.batches(x, y, 3, drop_remainder=False))) == 3


def test_synthetic_tokens():
    t = pipeline.synthetic_tokens(0, 2, 8, 100)
    assert t.shape == (2, 8) and t.dtype == jnp.int32
    assert int(t.max()) < 100 and int(t.min()) >= 0


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)},
            "lst": [jnp.zeros(2), jnp.ones(3)]}
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, tree, metadata={"step": 7})
    restored, meta = checkpoint.load(path, like=tree)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_flat_load(tmp_path):
    path = os.path.join(tmp_path, "c.npz")
    checkpoint.save(path, {"x": jnp.array([1.0, 2.0])})
    flat, meta = checkpoint.load(path)
    np.testing.assert_allclose(flat["x"], [1.0, 2.0])
