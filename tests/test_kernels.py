"""Pallas VQC kernel vs pure-jnp oracle: shape/dtype sweeps + allclose.

The kernel targets TPU (BlockSpec/VMEM); on CPU it runs with interpret=True,
which executes the same kernel body.  ref.py is the independent oracle built
on repro.core.sim.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import circuits, fidelity as fid
from repro.kernels import ops, ref


def _rand(qc, nl, batch, seed=0):
    spec = circuits.build_quclassi_circuit(qc, nl)
    k = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(k, (batch, spec.n_theta), jnp.float32,
                               minval=-np.pi, maxval=np.pi)
    data = jax.random.uniform(jax.random.fold_in(k, 1), (batch, spec.n_data),
                              jnp.float32, minval=0.0, maxval=np.pi)
    return spec, theta, data


@pytest.mark.parametrize("qc", [3, 5, 7, 9])
@pytest.mark.parametrize("nl", [1, 2, 3])
def test_fidelity_kernel_vs_ref_qubit_sweep(qc, nl):
    spec, theta, data = _rand(qc, nl, batch=8, seed=qc * 10 + nl)
    got = ops.vqc_fidelity(spec, theta, data)
    want = ref.vqc_fidelity_ref(spec, theta, data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("batch", [1, 2, 7, 16, 33, 128])
def test_fidelity_kernel_batch_sweep(batch):
    spec, theta, data = _rand(5, 2, batch=batch, seed=batch)
    got = ops.vqc_fidelity(spec, theta, data)
    want = ref.vqc_fidelity_ref(spec, theta, data)
    assert got.shape == (batch,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fidelity_kernel_float64_inputs_downcast():
    spec, theta, data = _rand(5, 1, batch=4)
    got = ops.vqc_fidelity(spec, theta.astype(jnp.float32),
                           data.astype(jnp.float32))
    assert got.dtype == jnp.float32


@pytest.mark.parametrize("qc,nl", [(5, 1), (5, 3), (7, 2)])
def test_state_kernel_vs_ref(qc, nl):
    spec, theta, data = _rand(qc, nl, batch=4, seed=1)
    re_k, im_k = ops.vqc_state(spec, theta, data)
    re_r, im_r = ref.vqc_state_ref(spec, theta, data)
    np.testing.assert_allclose(np.asarray(re_k), np.asarray(re_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(im_k), np.asarray(im_r), atol=1e-5)


@pytest.mark.parametrize("qc,nl", [(5, 2), (7, 1)])
def test_p0_kernel_vs_ref(qc, nl):
    spec, theta, data = _rand(qc, nl, batch=6, seed=2)
    got = ops.vqc_p0(spec, theta, data)
    want = ref.vqc_p0_ref(spec, theta, data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_ref_matches_core_sim():
    """The oracle itself is validated against the core simulator."""
    spec, theta, data = _rand(5, 3, batch=5, seed=3)
    want = fid.fidelity_batch(spec, theta, data)
    got = ref.vqc_fidelity_ref(spec, theta, data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_kernel_norm_preserved():
    spec, theta, data = _rand(7, 3, batch=3)
    re, im = ops.vqc_state(spec, theta, data)
    norms = np.sqrt(np.sum(np.asarray(re) ** 2 + np.asarray(im) ** 2, -1))
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)


def test_kernel_executor_signature():
    spec, theta, data = _rand(5, 1, batch=4)
    run = ops.kernel_executor(spec)
    out = run(theta, data)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.vqc_fidelity_ref(spec, theta, data)),
                               atol=1e-5)


def test_kernel_under_jit_and_grad_path():
    """The jitted wrapper composes with surrounding jit (dry-run requirement)."""
    spec, theta, data = _rand(5, 1, batch=4)

    @jax.jit
    def f(t, d):
        return ops.vqc_fidelity(spec, t, d).sum()

    v = f(theta, data)
    assert np.isfinite(float(v))
