"""Public-API snapshot: ``repro.api.__all__`` and ``repro.serve.__all__``
are asserted against the committed snapshot so accidental surface changes
(a renamed symbol, a leaked helper) fail CI instead of shipping silently.

Intentional surface changes update the snapshot in the same PR:

    PYTHONPATH=src python tests/test_public_api.py --update
"""

import json
import pathlib

SNAPSHOT = pathlib.Path(__file__).parent / "snapshots" / "public_api.json"


def _current() -> dict:
    import repro.api
    import repro.serve

    return {
        "repro.api": sorted(repro.api.__all__),
        "repro.serve": sorted(repro.serve.__all__),
    }


def test_public_api_matches_snapshot():
    snap = json.loads(SNAPSHOT.read_text())
    current = _current()
    assert current == snap, (
        "public API surface drifted from tests/snapshots/public_api.json; "
        "if intentional, regenerate with "
        "`PYTHONPATH=src python tests/test_public_api.py --update` "
        "and commit the diff"
    )


def test_public_api_symbols_resolve():
    """Everything advertised in __all__ must actually import (lazy loaders
    included) and nothing private leaks in."""
    import repro.api
    import repro.serve

    for mod in (repro.api, repro.serve):
        for name in mod.__all__:
            assert not name.startswith("_"), name
            assert getattr(mod, name) is not None, f"{mod.__name__}.{name}"


if __name__ == "__main__":
    import sys

    if "--update" in sys.argv:
        SNAPSHOT.write_text(json.dumps(_current(), indent=2) + "\n")
        print(f"updated {SNAPSHOT}")
    else:
        print(json.dumps(_current(), indent=2))
