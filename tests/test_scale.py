"""repro.scale: storm generation, replay, knee finding, admission control."""
import dataclasses

import numpy as np
import pytest

from repro.scale import (
    ArrivalProcess,
    CumulativeTimer,
    IntervalTicker,
    SweepPoint,
    TenantPopulation,
    WorkloadSpec,
    calibrate_admission,
    config_diff,
    default_fleet,
    find_knee,
    replay_sim,
    standard_populations,
    sweep,
)
from repro.serve.gateway import Backpressure, Gateway


def small_spec(n=60, rate=1.0, **kw):
    return WorkloadSpec(
        populations=standard_populations(n, rate_per_tenant=rate, slo_scale=2.0),
        duration_s=8.0,
        seed=11,
        **kw,
    )


# ------------------------------------------------------ arrival processes
@pytest.mark.parametrize("kind", ["poisson", "bursty", "heavy_tail", "diurnal"])
def test_arrival_process_mean_rate(kind):
    """Every process realizes its configured mean rate (long window)."""
    proc = ArrivalProcess(kind=kind, rate=2.0)
    rng = np.random.default_rng(3)
    duration = 2000.0
    n = sum(len(proc.sample(rng, duration)) for _ in range(3)) / 3
    assert n == pytest.approx(2.0 * duration, rel=0.15)


@pytest.mark.parametrize("kind", ["poisson", "bursty", "heavy_tail", "diurnal"])
def test_arrival_offsets_in_window_and_sorted(kind):
    proc = ArrivalProcess(kind=kind, rate=5.0)
    offs = proc.sample(np.random.default_rng(0), 30.0)
    assert offs == sorted(offs)
    assert all(0.0 <= t < 30.0 for t in offs)


def test_heavy_tail_is_burstier_than_poisson():
    """Lomax inter-arrivals have a heavier gap tail than exponential."""
    rng1, rng2 = np.random.default_rng(5), np.random.default_rng(5)
    ht = ArrivalProcess(kind="heavy_tail", rate=1.0, alpha=1.3)
    po = ArrivalProcess(kind="poisson", rate=1.0)
    g_ht = np.diff(ht.sample(rng1, 5000.0))
    g_po = np.diff(po.sample(rng2, 5000.0))
    assert np.max(g_ht) > np.max(g_po) * 2


def test_arrival_validation():
    with pytest.raises(ValueError, match="unknown arrival kind"):
        ArrivalProcess(kind="weibull")
    with pytest.raises(ValueError, match="rate"):
        ArrivalProcess(rate=0.0)
    with pytest.raises(ValueError, match="alpha"):
        ArrivalProcess(kind="heavy_tail", alpha=1.0)
    with pytest.raises(ValueError, match="depth"):
        ArrivalProcess(kind="diurnal", depth=1.0)


# ------------------------------------------------------- trace generation
def test_generate_is_deterministic():
    t1, t2 = small_spec().generate(), small_spec().generate()
    assert t1.tenants == t2.tenants
    assert t1.arrivals == t2.arrivals
    assert t1.n_circuits == t2.n_circuits


def test_generate_different_seed_differs():
    t1 = small_spec().generate()
    t2 = dataclasses.replace(small_spec(), seed=12).generate()
    assert t1.arrivals != t2.arrivals


def test_load_scales_offered_rate():
    spec = small_spec(n=200)
    n1 = spec.at_load(1.0).generate().n_circuits
    n3 = spec.at_load(3.0).generate().n_circuits
    assert n3 == pytest.approx(3 * n1, rel=0.25)


def test_population_policies_carried():
    trace = small_spec(n=100).generate()
    by_pop = {}
    for t in trace.tenants:
        by_pop.setdefault(t.population, t)
        assert (t.qc, t.n_layers) in {(5, 1), (5, 2), (7, 1), (7, 2)}
    assert by_pop["interactive"].priority == 0
    assert by_pop["interactive"].weight == 4.0
    assert by_pop["interactive"].slo_ms == 4000.0  # 2000 x slo_scale 2
    assert by_pop["batch"].priority == 1
    assert by_pop["bursty"].priority == 2
    summary = trace.summary()
    assert summary["n_tenants"] == trace.n_tenants
    assert set(summary["tenants_by_population"]) == {
        "interactive", "batch", "bursty",
    }


def test_spec_validation():
    pops = standard_populations(30)
    with pytest.raises(ValueError, match="duplicate"):
        WorkloadSpec(populations=(pops[0], pops[0]))
    with pytest.raises(ValueError, match="load"):
        WorkloadSpec(populations=pops, load=0.0)
    with pytest.raises(ValueError, match="unknown circuit spec"):
        TenantPopulation(
            name="x", n_tenants=1, arrival=ArrivalProcess(),
            circuit_mix=((9, 9, 1.0),),
        )


# --------------------------------------------------------------- replay
def test_replay_sim_completes_everything():
    res = replay_sim(small_spec().generate(), workers=default_fleet(1))
    assert res.completed == res.submitted
    assert res.rejected == 0
    assert res.slo_attainment is not None
    assert res.p99_latency_s > 0
    assert res.achieved_cps > 0


def test_replay_sim_deterministic():
    spec = small_spec()
    r1 = replay_sim(spec.generate(), workers=default_fleet(1))
    r2 = replay_sim(spec.generate(), workers=default_fleet(1))
    assert r1.row() == r2.row()


def test_replay_admission_cap_sheds_load():
    """A tight global cap on an overloaded storm rejects without losing
    accounting: completed + rejected == submitted, and the simulation's
    reject counter agrees with the replay aggregate."""
    trace = small_spec(n=120, rate=4.0).generate()
    res = replay_sim(
        trace, workers=default_fleet(1), max_system_pending=64,
        keep_report=True,
    )
    assert res.rejected > 0
    assert res.completed + res.rejected == res.submitted
    assert res.report.rejected == res.rejected
    assert 0 < res.reject_fraction < 1


# ------------------------------------------------- gateway admission unit
def test_gateway_global_cap_weighted_share():
    gw = Gateway(target=8, deadline=10.0, lanes=8, max_system_pending=4)
    gw.register_client("heavy", weight=1.0)
    gw.register_client("light", weight=1.0)
    for i in range(4):
        gw.submit("heavy", "k", None, now=0.0)
    # system at cap and heavy above its share (2 = 4 * 1/2): shed
    with pytest.raises(Backpressure, match="admission cap"):
        gw.submit("heavy", "k", None, now=0.0)
    # the light tenant holds none of the cap: share floor keeps it live
    gw.submit("light", "k", None, now=0.0)
    assert gw.telemetry.tenants["heavy"].rejected == 1
    assert gw.telemetry.tenants["light"].rejected == 0


def test_gateway_cap_counts_in_flight():
    """Outstanding = queued + in flight: dequeuing into the coalescer must
    not free admission headroom."""
    gw = Gateway(target=100, deadline=10.0, lanes=100, max_system_pending=3)
    gw.register_client("a", weight=1.0)
    for _ in range(3):
        gw.submit("a", "k", None, now=0.0)
    gw.pump(0.0)  # queue drains into the coalescer -> in flight
    with pytest.raises(Backpressure, match="admission cap"):
        gw.submit("a", "k", None, now=0.0)


def test_heap_scheduler_matches_reference_scan():
    """The O(log T) heap dequeue must reproduce the reference O(T) scan's
    order exactly — priority tier, then vpass, then client id."""
    def reference_order(tenants_spec, submits):
        state = {
            cid: dict(vpass=0.0, queue=0, prio=p, weight=w)
            for cid, (p, w) in tenants_spec.items()
        }
        for cid in submits:
            state[cid]["queue"] += 1
        order = []
        while True:
            avail = [
                (s["prio"], s["vpass"], cid)
                for cid, s in state.items() if s["queue"]
            ]
            if not avail:
                return order
            _, _, cid = min(avail)
            s = state[cid]
            s["queue"] -= 1
            s["vpass"] += 1.0 / s["weight"]
            order.append(cid)

    tenants_spec = {
        "a": (0, 4.0), "b": (1, 1.0), "c": (1, 2.0),
        "d": (1, 1.0), "e": (2, 0.5),
    }
    rng = np.random.default_rng(2)
    submits = [
        list(tenants_spec)[i]
        for i in rng.integers(0, len(tenants_spec), 60)
    ]
    gw = Gateway(target=1, deadline=10.0, lanes=1)
    for cid, (prio, w) in tenants_spec.items():
        gw.register_client(cid, priority=prio, weight=w)
    for cid in submits:
        gw.submit(cid, "k", None, now=0.0)
    batches = gw.pump(0.0)  # target=1 lane -> one batch per dequeue, in order
    got = [b.members[0].client_id for b in batches]
    assert got == reference_order(tenants_spec, submits)


# ----------------------------------------------------------- knee finding
def point(load, offered, achieved, att, p99=1.0):
    return SweepPoint(
        load=load, n_tenants=10, offered_cps=offered, achieved_cps=achieved,
        p99_latency_s=p99, slo_attainment=att, reject_fraction=0.0,
        queue_depth_p99=None, coalesce_wait_share=None, makespan_s=10.0,
    )


def test_find_knee_locates_last_healthy_point():
    pts = [
        point(1, 100, 98, 1.0),
        point(2, 200, 190, 1.0),
        point(3, 300, 270, 0.995),
        point(4, 400, 290, 0.90),
    ]
    rep = find_knee(pts, efficiency_floor=0.85, attainment_floor=0.99)
    assert rep.knee.load == 3
    assert rep.cliff.load == 4
    assert rep.saturated
    assert rep.point_near_offered(0.8 * 300).load == 2


def test_find_knee_unsaturated_sweep():
    pts = [point(1, 100, 99, 1.0), point(2, 200, 197, 1.0)]
    rep = find_knee(pts)
    assert not rep.saturated
    assert rep.cliff is None
    assert rep.knee.load == 2  # best point seen: a lower bound only


def test_find_knee_degenerate_and_empty():
    rep = find_knee([point(1, 100, 10, 0.5)])
    assert rep.saturated and rep.knee.load == 1 and rep.cliff.load == 1
    with pytest.raises(ValueError, match="empty sweep"):
        find_knee([])


def test_calibrate_admission():
    p = point(3, 300, 280, 1.0, p99=2.0)
    assert calibrate_admission(p, slack=0.5) == 280  # ceil(280*2*0.5)
    assert calibrate_admission(p, slack=0.5, floor=1000) == 1000
    with pytest.raises(ValueError, match="slack"):
        calibrate_admission(p, slack=0.0)


# ------------------------------------------------------------ ergonomics
def test_cumulative_timer():
    t = iter([0.0, 1.0, 5.0, 7.5])
    timer = CumulativeTimer(clock=lambda: next(t))
    with timer.time("step"):
        pass
    with timer.time("step"):
        pass
    assert timer.total("step") == pytest.approx(3.5)
    assert timer.stats()["step"] == {
        "count": 2, "total_s": 3.5, "mean_s": 1.75,
    }


def test_interval_ticker():
    ticker = IntervalTicker(10.0, clock=lambda: 0.0)
    assert ticker.tick(now=0.0)       # first always fires
    assert not ticker.tick(now=5.0)
    assert ticker.tick(now=10.0)
    assert ticker.ticks == 2
    with pytest.raises(ValueError):
        IntervalTicker(0.0)


def test_config_diff():
    base = {"a": 1, "b": {"c": 2, "d": 3}, "gone": 9}
    cur = {"a": 1, "b": {"c": 5, "d": 3}, "new": 7}
    assert config_diff(base, cur) == [
        "b.c: 2 -> 5",
        "gone: 9 -> removed",
        "new: added -> 7",
    ]


# ------------------------------------------------------- slow: full sweep
@pytest.mark.slow
def test_storm_sweep_finds_knee_deterministically():
    """1k-tenant storm: the sweep crosses the knee (attainment degrades
    past it) and the same seed reproduces the identical curve."""
    spec = WorkloadSpec(
        populations=standard_populations(
            1000, rate_per_tenant=0.4, slo_scale=2.0
        ),
        duration_s=20.0,
        seed=7,
    )
    fleet = default_fleet(1)
    loads = (1.0, 3.0, 4.0)
    pts = sweep(spec, loads, workers=fleet)
    rep = find_knee(pts, efficiency_floor=0.80, attainment_floor=0.99)
    assert rep.saturated
    assert rep.cliff is not None
    assert rep.cliff.slo_attainment < 1.0  # attainment < 100% past the knee
    assert rep.knee.offered_cps >= 1000.0  # 1k tenants saturate past 1k c/s
    pts2 = sweep(spec, loads, workers=fleet)
    assert [p.row() for p in pts] == [p.row() for p in pts2]
