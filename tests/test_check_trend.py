"""benchmarks/check_trend.py: the benchmark-regression gate itself."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import check_trend  # noqa: E402

SCALE = "BENCH_scale.json"


def scale_payload(**overrides):
    """A minimal artifact covering every BENCH_scale.json gate."""
    payload = {
        "knee": {"offered_cps": 1000.0, "achieved_cps": 900.0, "p99_latency_s": 4.0},
        "p99_at_80pct_knee_s": 3.0,
        "attainment_at_knee": 0.999,
        "admission": {"reject_fraction": 0.4, "attainment_admitted": 0.99},
        "determinism": {"repeat_identical": 1},
    }
    for path, value in overrides.items():
        node = payload
        *parents, leaf = path.split(".")
        for p in parents:
            node = node[p]
        if value is None:
            del node[leaf]
        else:
            node[leaf] = value
    return payload


@pytest.fixture()
def dirs(tmp_path):
    emitted, baselines = tmp_path / "emitted", tmp_path / "baselines"
    emitted.mkdir(), baselines.mkdir()
    (baselines / SCALE).write_text(json.dumps(scale_payload()))
    return emitted, baselines


def run_check(emitted, baselines, **payload_overrides):
    (emitted / SCALE).write_text(json.dumps(scale_payload(**payload_overrides)))
    return check_trend.check(
        str(emitted), str(baselines), verbose=False, artifacts=(SCALE,)
    )


def test_in_band_passes(dirs):
    emitted, baselines = dirs
    # small in-band drift in a tolerant direction: still green
    assert run_check(emitted, baselines, **{"knee.offered_cps": 950.0}) == []


def test_out_of_band_regression_fails(dirs):
    emitted, baselines = dirs
    failures = run_check(emitted, baselines, **{"knee.offered_cps": 500.0})
    assert len(failures) == 1
    assert "knee.offered_cps" in failures[0]
    assert "want higher" in failures[0]


def test_lower_direction_gate(dirs):
    emitted, baselines = dirs
    # p99 inflating past the band regresses a "lower" gate
    failures = run_check(emitted, baselines, **{"knee.p99_latency_s": 6.0})
    assert len(failures) == 1 and "p99_latency_s" in failures[0]
    # p99 improving (dropping) never trips it
    assert run_check(emitted, baselines, **{"knee.p99_latency_s": 1.0}) == []


def test_all_regressions_reported_in_one_pass(dirs):
    """Not fail-on-first: every out-of-band metric lands in one report."""
    emitted, baselines = dirs
    failures = run_check(
        emitted,
        baselines,
        **{
            "knee.offered_cps": 100.0,
            "knee.p99_latency_s": 99.0,
            "determinism.repeat_identical": 0,
        },
    )
    text = "\n".join(failures)
    assert len(failures) == 3
    for metric in (
        "knee.offered_cps", "knee.p99_latency_s", "determinism.repeat_identical"
    ):
        assert metric in text


def test_missing_gated_metric_fails(dirs):
    emitted, baselines = dirs
    failures = run_check(emitted, baselines, **{"knee.achieved_cps": None})
    assert len(failures) == 1
    assert "missing from the emitted artifact" in failures[0]


def test_missing_artifact_fails(dirs):
    emitted, baselines = dirs
    failures = check_trend.check(
        str(emitted), str(baselines), verbose=False, artifacts=(SCALE,)
    )
    assert failures and "not emitted" in failures[0]


def test_tolerance_scale_loosens_bands(dirs):
    emitted, baselines = dirs
    (emitted / SCALE).write_text(
        json.dumps(scale_payload(**{"knee.offered_cps": 600.0}))
    )
    assert check_trend.check(
        str(emitted), str(baselines), verbose=False, artifacts=(SCALE,)
    )
    loose = check_trend.check(
        str(emitted),
        str(baselines),
        verbose=False,
        artifacts=(SCALE,),
        tolerance_scale=2.0,
    )
    assert loose == []


def test_update_baselines_roundtrip(tmp_path):
    emitted, baselines = tmp_path / "emitted", tmp_path / "baselines"
    emitted.mkdir()
    (emitted / SCALE).write_text(json.dumps(scale_payload()))
    rc = check_trend.main(
        [
            "--emitted",
            str(emitted),
            "--baselines",
            str(baselines),
            "--artifacts",
            SCALE,
            "--update-baselines",
        ]
    )
    assert rc == 0
    assert json.loads((baselines / SCALE).read_text()) == scale_payload()
    # and the freshly updated baseline gates green
    rc = check_trend.main(
        [
            "--emitted",
            str(emitted),
            "--baselines",
            str(baselines),
            "--artifacts",
            SCALE,
        ]
    )
    assert rc == 0


def test_main_exit_codes(dirs):
    emitted, baselines = dirs
    (emitted / SCALE).write_text(
        json.dumps(scale_payload(**{"knee.offered_cps": 100.0}))
    )
    args = [
        "--emitted", str(emitted), "--baselines", str(baselines), "--artifacts", SCALE
    ]
    assert check_trend.main(args) == 1
    (emitted / SCALE).write_text(json.dumps(scale_payload()))
    assert check_trend.main(args) == 0


def test_unknown_artifact_rejected(dirs, capsys):
    emitted, baselines = dirs
    with pytest.raises(SystemExit):
        check_trend.main(
            [
                "--emitted",
                str(emitted),
                "--baselines",
                str(baselines),
                "--artifacts",
                "BENCH_bogus.json",
            ]
        )


def test_github_step_summary_markdown(dirs, monkeypatch, tmp_path):
    emitted, baselines = dirs
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    failures = run_check(emitted, baselines, **{"knee.offered_cps": 100.0})
    assert failures
    text = summary.read_text()
    assert "## Benchmark trend gate" in text
    assert "| artifact | metric | baseline | current | change | status |" in text
    assert "**REGRESSED**" in text
    assert "`knee.offered_cps`" in text
    # every gated metric appears, not just the regressed one
    assert "`determinism.repeat_identical`" in text


def test_flatten():
    flat = check_trend.flatten(
        {"a": {"b": 1, "skip": True}, "xs": [2.5, {"c": 3}], "s": "str"}
    )
    assert flat == {"a.b": 1.0, "xs.0": 2.5, "xs.1.c": 3.0}
