"""QuClassi circuit construction + SWAP-test fidelity tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core import circuits, fidelity as fid, sim
from repro.core import gates as G


@pytest.mark.parametrize("qc", [3, 5, 7, 9])
def test_registers_layout(qc):
    anc, data_q, train_q = circuits.registers(qc)
    m = (qc - 1) // 2
    assert anc == 0
    assert data_q == list(range(1, 1 + m))
    assert train_q == list(range(1 + m, 1 + 2 * m))
    assert not set(data_q) & set(train_q)


@pytest.mark.parametrize("qc", [2, 4, 1])
def test_registers_reject_bad_counts(qc):
    with pytest.raises(ValueError):
        circuits.registers(qc)


@pytest.mark.parametrize("qc,nl,expect", [
    (5, 1, 4), (5, 2, 6), (5, 3, 8),      # m=2: 2m=4, +2(m-1)=2, +2
    (7, 1, 6), (7, 2, 10), (7, 3, 14),    # m=3: 6, +4, +4
])
def test_n_theta_formula(qc, nl, expect):
    assert circuits.n_theta_for(qc, nl) == expect
    spec = circuits.build_quclassi_circuit(qc, nl)
    assert spec.n_theta == expect
    # every theta index used exactly once
    used = [op.param[1] for op in spec.ops
            if op.param and op.param[0] == "theta"]
    assert sorted(used) == list(range(expect))


@pytest.mark.parametrize("qc", [5, 7])
def test_data_angles(qc):
    m = (qc - 1) // 2
    assert circuits.n_data_angles_for(qc) == 2 * m


def test_layer_sequence():
    assert circuits.layers_for_count(1) == ("single",)
    assert circuits.layers_for_count(2) == ("single", "dual")
    assert circuits.layers_for_count(3) == ("single", "dual", "entangle")
    with pytest.raises(ValueError):
        circuits.layers_for_count(4)


def test_qubit_demand():
    for qc in (5, 7):
        spec = circuits.build_quclassi_circuit(qc, 2)
        assert circuits.qubit_demand(spec) == qc


# ------------------------------------------------------------- fidelity
def _overlap_sq(spec_qc, theta, data):
    """Direct |<phi(data)|psi(theta)>|^2 using separate register circuits."""
    anc, data_q, train_q = circuits.registers(spec_qc)
    m = len(data_q)

    enc_ops, _ = circuits.encoding_ops(list(range(m)))
    enc_spec = sim.CircuitSpec(m, tuple(enc_ops), 0, 2 * m)
    phi = sim.run_circuit(enc_spec, jnp.zeros(0), data)

    var_ops, nt = circuits.variational_ops(list(range(m)),
                                           circuits.layers_for_count(2))
    var_spec = sim.CircuitSpec(m, tuple(var_ops), nt, 0)
    psi = sim.run_circuit(var_spec, theta, jnp.zeros(0))

    a = np.asarray(phi[0]) + 1j * np.asarray(phi[1])
    b = np.asarray(psi[0]) + 1j * np.asarray(psi[1])
    return abs(np.vdot(a, b)) ** 2


@pytest.mark.parametrize("qc", [5, 7])
def test_swap_test_equals_direct_overlap(qc):
    spec = circuits.build_quclassi_circuit(qc, 2)
    key = jax.random.PRNGKey(qc)
    theta = jax.random.uniform(key, (spec.n_theta,)) * np.pi
    data = jax.random.uniform(jax.random.fold_in(key, 1), (spec.n_data,)) * np.pi
    f_swap = float(fid.fidelity(spec, theta, data))
    f_direct = _overlap_sq(qc, theta, data)
    assert abs(f_swap - f_direct) < 1e-5


def test_identical_states_fidelity_one():
    """theta chosen so the trainable register prepares exactly the data state."""
    qc = 5
    spec = circuits.build_quclassi_circuit(qc, 1)
    # encoding = RX(a) RY(b) per qubit; single layer = RY(t) RZ(t') per qubit.
    # Use data angles (0, b): then |phi> = RY(b)|0>, reachable by theta=(b, 0).
    b1, b2 = 0.7, 1.9
    data = jnp.array([0.0, b1, 0.0, b2])
    theta = jnp.array([b1, 0.0, b2, 0.0])
    f = float(fid.fidelity(spec, theta, data))
    assert abs(f - 1.0) < 1e-5


def test_orthogonal_states_fidelity_zero():
    qc = 3  # m=1
    spec = circuits.build_quclassi_circuit(qc, 1)
    data = jnp.array([0.0, 0.0])        # |0>
    theta = jnp.array([jnp.pi, 0.0])    # RY(pi)|0> = |1>
    f = float(fid.fidelity(spec, theta, data))
    assert abs(f) < 1e-5


@given(seed=st.integers(0, 10_000))
def test_fidelity_in_unit_interval(seed):
    spec = circuits.build_quclassi_circuit(5, 3)
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(key, (spec.n_theta,), minval=-np.pi, maxval=np.pi)
    data = jax.random.uniform(jax.random.fold_in(key, 7), (spec.n_data,),
                              minval=0, maxval=np.pi)
    f = float(fid.fidelity(spec, theta, data))
    assert -1e-6 <= f <= 1.0 + 1e-6


def test_fidelity_batch_matches_scalar():
    spec = circuits.build_quclassi_circuit(5, 2)
    key = jax.random.PRNGKey(0)
    theta = jax.random.uniform(key, (6, spec.n_theta)) * np.pi
    data = jax.random.uniform(jax.random.fold_in(key, 1), (6, spec.n_data))
    batch = fid.fidelity_batch(spec, theta, data)
    for i in range(6):
        assert abs(float(batch[i]) - float(fid.fidelity(spec, theta[i], data[i]))) < 1e-6


def test_bce_loss_and_grad_consistent():
    f = jnp.array([0.1, 0.5, 0.9])
    y = jnp.array([0.0, 1.0, 1.0])
    g_auto = jax.vmap(jax.grad(lambda fi, yi: fid.bce_loss(fi, yi)))(f, y)
    g_manual = fid.bce_grad_wrt_fidelity(f, y)
    np.testing.assert_allclose(np.asarray(g_auto), np.asarray(g_manual), atol=1e-5)
