"""Algorithm 2 co-Manager unit tests: registration, heartbeats, eviction,
workload assignment (AR filter + CRU sort), multi- vs single-tenant."""
import pytest

from repro.comanager.manager import CoManager
from repro.comanager.worker import CircuitTask, QuantumWorker, WorkerConfig


def task(tid, demand=5, client="c1", st=1.0):
    return CircuitTask(task_id=tid, client_id=client, demand=demand,
                       service_time=st)


# --------------------------------------------------- (2) worker registration
def test_registration_initial_state():
    m = CoManager()
    v = m.register_worker("w1", 20, cru=0.3, t=0.0)
    assert v.max_qubits == 20          # MR
    assert v.occupied_qubits == 0      # OR = 0   (line 4)
    assert v.available_qubits == 20    # AR = MR  (line 5)
    assert v.cru == 0.3                # CRU      (line 6)
    assert "w1" in m.workers


# ------------------------------------------------ (3) heartbeats + liveness
def test_heartbeat_updates_or_ar_cru():
    m = CoManager()
    m.register_worker("w1", 20, 0.0, t=0.0)
    m.heartbeat({"worker_id": "w1", "active": {101: 5, 102: 7},
                 "completed": set(), "cru": 0.6}, t=5.0)
    v = m.workers["w1"]
    assert v.reported_or == 12                    # lines 8-9: sum of D_c
    assert v.available_qubits == 8                # line 10: AR = MR - OR
    assert v.cru == 0.6                           # line 11


def test_heartbeat_settles_in_flight():
    m = CoManager()
    m.register_worker("w1", 20, 0.0, t=0.0)
    wid = m.assign(task(1, demand=5), t=0.1)
    assert wid == "w1"
    assert m.workers["w1"].available_qubits == 15  # optimistic ledger
    # heartbeat reports the task as active -> moves from in_flight to OR
    m.heartbeat({"worker_id": "w1", "active": {1: 5}, "completed": set(),
                 "cru": 0.2}, t=5.0)
    v = m.workers["w1"]
    assert v.in_flight == {}
    assert v.occupied_qubits == 5


def test_eviction_after_three_missed_heartbeats():
    m = CoManager()
    m.register_worker("w1", 10, 0.0, t=0.0)
    m.register_worker("w2", 10, 0.0, t=0.0)
    m.heartbeat({"worker_id": "w2", "active": {}, "completed": set(),
                 "cru": 0.1}, t=14.0)
    dead = m.liveness_check(t=15.0, period=5.0)    # w1 silent for 3 periods
    assert dead == ["w1"]
    assert "w1" not in m.workers and "w2" in m.workers
    assert m.evictions and m.evictions[0][1] == "w1"


def test_eviction_requeues_lost_circuits():
    m = CoManager()
    m.register_worker("w1", 10, 0.0, t=0.0)
    t1 = task(7, demand=5)
    m.submit(t1)
    m.drain_pending(0.0, lambda task, wid: None)
    assert not m.pending
    m.liveness_check(t=15.0, period=5.0)
    assert [t.task_id for t in m.pending] == [7]


def test_two_missed_heartbeats_not_evicted():
    m = CoManager()
    m.register_worker("w1", 10, 0.0, t=0.0)
    assert m.liveness_check(t=10.0, period=5.0) == []


# ------------------------------------------------- (4) workload assignment
def test_assign_filters_by_available_qubits():
    m = CoManager()
    m.register_worker("w_small", 5, 0.0, t=0)
    m.register_worker("w_big", 10, 0.9, t=0)   # higher CRU but only fit
    wid = m.assign(task(1, demand=7), t=1.0)
    assert wid == "w_big"                      # 5q worker useless to a 7q circuit


def test_assign_exact_fit_allowed():
    """AR >= D (see manager.py note reconciling line 16 with Fig 6 text)."""
    m = CoManager()
    m.register_worker("w1", 5, 0.0, t=0)
    assert m.assign(task(1, demand=5), t=0) == "w1"


def test_assign_prefers_lowest_cru():
    m = CoManager()
    m.register_worker("w1", 10, 0.7, t=0)
    m.register_worker("w2", 10, 0.2, t=0)
    m.register_worker("w3", 10, 0.5, t=0)
    assert m.assign(task(1), t=0) == "w2"      # lines 18-20


def test_assign_ties_broken_deterministically():
    m = CoManager()
    m.register_worker("w2", 10, 0.5, t=0)
    m.register_worker("w1", 10, 0.5, t=0)
    assert m.assign(task(1), t=0) == "w1"


def test_assign_returns_none_when_no_candidate():
    m = CoManager()
    m.register_worker("w1", 5, 0.0, t=0)
    assert m.assign(task(1, demand=9), t=0) is None


def test_optimistic_ledger_prevents_overcommit():
    m = CoManager()
    m.register_worker("w1", 10, 0.0, t=0)
    assert m.assign(task(1, demand=5), t=0) == "w1"
    assert m.assign(task(2, demand=5), t=0) == "w1"
    assert m.assign(task(3, demand=5), t=0) is None  # would exceed MR


def test_complete_frees_capacity_eagerly():
    m = CoManager(eager_completion=True)
    m.register_worker("w1", 5, 0.0, t=0)
    t1 = task(1, demand=5)
    assert m.assign(t1, t=0) == "w1"
    assert m.assign(task(2, demand=5), t=0) is None
    m.complete("w1", t1, t=1.0)
    assert m.assign(task(2, demand=5), t=1.1) == "w1"


def test_multitenant_packs_multiple_circuits():
    """A 20-qubit machine accommodates four 5q circuits (paper Fig 6 setup)."""
    m = CoManager(multi_tenant=True)
    m.register_worker("w20", 20, 0.0, t=0)
    placed = [m.assign(task(i, demand=5, client=f"c{i}"), t=0) for i in range(4)]
    assert placed == ["w20"] * 4
    assert m.assign(task(9, demand=5), t=0) is None


def test_multitenant_mixed_widths():
    """Two 7q + one 5q co-resident on 20 qubits (paper §IV-C2)."""
    m = CoManager(multi_tenant=True)
    m.register_worker("w20", 20, 0.0, t=0)
    assert m.assign(task(1, demand=7, client="a"), t=0) == "w20"
    assert m.assign(task(2, demand=7, client="b"), t=0) == "w20"
    assert m.assign(task(3, demand=5, client="c"), t=0) == "w20"
    assert m.assign(task(4, demand=5, client="d"), t=0) is None  # 19 used


def test_single_tenant_one_circuit_per_machine():
    m = CoManager(multi_tenant=False)
    m.register_worker("w20", 20, 0.0, t=0)
    assert m.assign(task(1, demand=5, client="c1"), t=0) == "w20"
    # same client, machine busy -> wait
    assert m.assign(task(2, demand=5, client="c1"), t=0) is None


def test_single_tenant_machine_owned_by_client():
    m = CoManager(multi_tenant=False)
    m.register_worker("w1", 20, 0.0, t=0)
    t1 = task(1, demand=5, client="c1")
    assert m.assign(t1, t=0) == "w1"
    # c1 still has queued work when its first circuit completes -> the
    # machine stays owned by c1 (single-tenant: others wait in the queue)
    m.submit(task(3, demand=5, client="c1"))
    m.complete("w1", t1, t=1.0)
    assert m.assign(task(2, demand=5, client="c2"), t=1.5) is None
    # c1's own next circuit is fine
    assert m.assign(task(3, demand=5, client="c1"), t=2.0) == "w1"


def test_single_tenant_release_after_drain():
    m = CoManager(multi_tenant=False)
    m.register_worker("w1", 20, 0.0, t=0)
    t1 = task(1, demand=5, client="c1")
    m.assign(t1, t=0)
    m.complete("w1", t1, t=1.0)
    assert m.assign(task(2, demand=5, client="c2"), t=2.0) == "w1"


def test_drain_pending_fifo():
    m = CoManager()
    m.register_worker("w1", 10, 0.0, t=0)
    launched = []
    for i in range(4):
        m.submit(task(i, demand=5))
    placed = m.drain_pending(0.0, lambda t, w: launched.append(t.task_id))
    assert placed == 2 and launched == [0, 1]
    assert [t.task_id for t in m.pending] == [2, 3]


# ----------------------------------------------------------- QuantumWorker
def test_worker_capacity_accounting():
    w = QuantumWorker(WorkerConfig("w1", 10, contention=0.0))
    f1 = w.start(task(1, demand=5, st=2.0), now=0.0)
    assert f1 == 2.0
    assert w.occupied_qubits == 5 and w.available_qubits == 5
    with pytest.raises(RuntimeError):
        w.start(task(2, demand=7), now=0.1)
    w.finish(1, now=2.0)
    assert w.occupied_qubits == 0


def test_worker_contention_scaling():
    w = QuantumWorker(WorkerConfig("w1", 20, contention=0.5))
    w.start(task(1, demand=5, st=2.0), now=0.0)
    f2 = w.start(task(2, demand=5, st=2.0), now=0.0)
    assert f2 == pytest.approx(2.0 * 1.5)  # 1 co-resident circuit


def test_worker_heartbeat_payload():
    w = QuantumWorker(WorkerConfig("w1", 10))
    w.start(task(1, demand=5, st=10.0), now=0.0)
    hb = w.heartbeat_payload(1.0)
    assert hb["active"] == {1: 5}
    assert hb["max_qubits"] == 10
    assert 0.0 <= hb["cru"] <= 1.0
