"""Validate the trip-count-aware HLO analyzer against hand-computable
modules (the thing raw cost_analysis gets wrong for scanned models)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_analyzer as H


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    x = jnp.ones((128, 256), jnp.float32)
    w = jnp.ones((256, 64), jnp.float32)
    cost = H.analyze(compile_text(lambda a, b: a @ b, x, w))
    assert cost.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_scan_multiplies_by_trip_count():
    x = jnp.ones((128, 128), jnp.float32)
    ws = jnp.ones((10, 128, 128), jnp.float32)

    def scanned(x, ws):
        def body(c, w):
            return c @ w, 0
        y, _ = jax.lax.scan(body, x, ws)
        return y

    cost = H.analyze(compile_text(scanned, x, ws))
    one = 2 * 128 * 128 * 128
    assert cost.flops == pytest.approx(10 * one, rel=0.05), \
        f"expected 10x matmul flops, got {cost.flops / one:.1f}x"


def test_nested_scan_trip_counts():
    x = jnp.ones((64, 64), jnp.float32)
    ws = jnp.ones((4, 64, 64), jnp.float32)

    def inner(c, w):
        return c @ w, 0

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, ws)
        return c, 0

    def fn(x, ws):
        y, _ = jax.lax.scan(outer, x, jnp.arange(3))
        return y

    cost = H.analyze(compile_text(fn, x, ws))
    one = 2 * 64 * 64 * 64
    assert cost.flops == pytest.approx(12 * one, rel=0.05)


def test_elementwise_bytes_reasonable():
    x = jnp.ones((1024, 1024), jnp.float32)  # 4 MB
    cost = H.analyze(compile_text(lambda a: a + 1.0, x))
    # read 4MB + write 4MB, give or take fusion bookkeeping
    assert 0.5 * 8e6 <= cost.bytes <= 3 * 8e6, cost.bytes


def test_dot_general_contracting_dims():
    a = jnp.ones((8, 32, 16), jnp.float32)
    b = jnp.ones((8, 16, 64), jnp.float32)
    cost = H.analyze(compile_text(
        lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b))
    assert cost.flops == pytest.approx(2 * 8 * 32 * 16 * 64, rel=0.01)


def test_collective_inside_scan_counted_per_trip():
    """psum inside a scan over 5 steps on a 1-device mesh still lowers to an
    all-reduce op in SPMD mode; verify x5 attribution (shape-based)."""
    import os
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    devs = np.array(jax.devices()[:1]).reshape(1)
    mesh = Mesh(devs, ("d",))

    x = jnp.ones((8, 128), jnp.float32)

    def fn(x):
        def body(c, _):
            s = jax.lax.with_sharding_constraint(c, NamedSharding(mesh, P("d")))
            return s * 1.0001, 0
        y, _ = jax.lax.scan(body, x, jnp.arange(5))
        return y

    # on a single device there are no real collectives; this test just
    # asserts the analyzer does not crash on sharded modules.
    cost = H.analyze(compile_text(fn, x))
    assert cost.bytes > 0


def test_parse_module_structure():
    x = jnp.ones((32, 32), jnp.float32)
    comps = H.parse_module(compile_text(lambda a: (a @ a).sum(), x))
    assert any("main" in n for n in comps)
    entry = next(c for n, c in comps.items() if "main" in n)
    assert entry.root is not None


def test_shape_bytes():
    assert H.shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert H.shape_bytes("bf16[8,4096,1152]{2,1,0}") == 8 * 4096 * 1152 * 2
    assert H.shape_bytes("(f32[4], s32[2])") == 24
    assert H.shape_bytes("pred[]") == 1
