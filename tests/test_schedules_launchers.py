"""LR schedules + launcher smoke tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers, schedules


def test_constant():
    fn = schedules.constant(0.3)
    assert float(fn(jnp.int32(100))) == pytest.approx(0.3)


def test_warmup_cosine_shape():
    fn = schedules.warmup_cosine(1.0, warmup_steps=10, total_steps=110,
                                 final_frac=0.1)
    # linear warmup
    assert float(fn(jnp.int32(5))) == pytest.approx(0.5)
    # peak at end of warmup
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0, abs=1e-6)
    # monotone decay after warmup down to final_frac
    vals = [float(fn(jnp.int32(s))) for s in range(10, 111, 10)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))
    assert vals[-1] == pytest.approx(0.1, abs=1e-6)


def test_inverse_sqrt():
    fn = schedules.inverse_sqrt(1.0, warmup_steps=100)
    assert float(fn(jnp.int32(50))) == pytest.approx(0.5)
    assert float(fn(jnp.int32(100))) == pytest.approx(1.0)
    assert float(fn(jnp.int32(400))) == pytest.approx(0.5)


def test_schedule_drives_optimizer():
    opt = optimizers.make("sgd", schedules.inverse_sqrt(1.0, warmup_steps=4))
    p = {"x": jnp.zeros(1)}
    s = opt.init(p)
    u1, s = opt.update({"x": jnp.ones(1)}, s, p)
    assert float(u1["x"][0]) == pytest.approx(-0.25)   # step 1 of 4 warmup


# ------------------------------------------------------------- launchers
def test_train_launcher_reduced():
    from repro.launch.train import run_reduced
    loss = run_reduced("smollm-360m", steps_n=3, batch=2, seq=16)
    assert np.isfinite(loss)


def test_serve_launcher_reduced(capsys):
    from repro.launch.serve import run_reduced
    run_reduced("smollm-360m", batch=2, prompt_len=4, gen=4)
    out = capsys.readouterr().out
    assert "decode steps" in out
