"""Federated DQL subsystem (repro.federated): round state machine, quorum vs
sync-barrier equivalence, straggler fold-in determinism, crash tolerance,
secure aggregation, DP accounting, and the telemetry/trace plumbing."""
import numpy as np
import pytest

from repro.comanager.faults import FaultSpec
from repro.comanager.worker import WorkerConfig
from repro.federated import (
    FederatedConfig,
    FederatedCoordinator,
    TenantSpec,
    fedavg,
    run_federated,
)
from repro.obs import TraceRecorder


def toy_update_fn(seed):
    def update_fn(tenant, round_idx, params):
        ent = [seed, round_idx] + [ord(c) for c in tenant]
        g = np.random.default_rng(np.random.SeedSequence(ent))
        return {
            k: 0.01 * g.standard_normal(np.shape(v)) for k, v in params.items()
        }

    return update_fn


def fleet():
    return [
        WorkerConfig("w1", 5),
        WorkerConfig("w2", 10),
        WorkerConfig("w3", 15),
        WorkerConfig("w4", 20),
    ]


def fig6_tenants(n_circuits=8):
    return [
        TenantSpec("t5a", qc=5, n_layers=1, n_circuits=n_circuits),
        TenantSpec("t5b", qc=5, n_layers=2, n_circuits=n_circuits),
        TenantSpec("t7a", qc=7, n_layers=1, n_circuits=n_circuits),
        TenantSpec("t7b", qc=7, n_layers=2, n_circuits=n_circuits),
    ]


PARAMS0 = {"theta": np.linspace(-1.0, 1.0, 12).reshape(3, 4), "phi": np.ones(5)}


def fingerprint(report):
    import json

    return (
        json.dumps(report.summary(), sort_keys=True, default=float),
        tuple((k, report.params[k].tobytes()) for k in sorted(report.params)),
    )


# -------------------------------------------------------------------- config
def test_config_validation():
    with pytest.raises(ValueError):
        FederatedConfig(quorum=0.0)
    with pytest.raises(ValueError):
        FederatedConfig(quorum=1.5)
    with pytest.raises(ValueError):
        FederatedConfig(late_policy="maybe")
    with pytest.raises(ValueError):
        FederatedConfig(dp_noise_multiplier=1.0)  # noise needs a clip norm
    with pytest.raises(ValueError):
        TenantSpec("evil@r3")  # '@r' is the round-job-id separator


def test_fedavg_weighted_closed_form():
    u = {"a": {"x": np.array([1.0, 0.0])}, "b": {"x": np.array([0.0, 1.0])}}
    out = fedavg(u, weights={"a": 3.0, "b": 1.0})
    np.testing.assert_allclose(out["x"], [0.75, 0.25])
    plain = fedavg(u)
    np.testing.assert_allclose(plain["x"], [0.5, 0.5])


# --------------------------------------------------- quorum vs barrier modes
def test_quorum_one_degenerates_to_sync_barrier():
    """quorum=1.0 with an unreachable deadline takes the quorum code path
    but must close every round at the same instant, with the same on-time
    set and bit-identical parameters, as the sync barrier."""
    reports = {}
    for mode, kw in (
        ("barrier", dict(barrier=True)),
        ("quorum", dict(barrier=False, quorum=1.0, round_deadline_s=1e5)),
    ):
        cfg = FederatedConfig(n_rounds=3, seed=11, **kw)
        reports[mode] = run_federated(
            cfg, fig6_tenants(), toy_update_fn(11), PARAMS0, fleet(),
            gateway=True,
        )
    b, q = reports["barrier"], reports["quorum"]
    assert [r.closed_at for r in b.rounds] == [r.closed_at for r in q.rounds]
    assert [sorted(r.on_time) for r in b.rounds] == [
        sorted(r.on_time) for r in q.rounds
    ]
    for k in b.params:
        assert b.params[k].tobytes() == q.params[k].tobytes()


def test_crashed_tenant_never_stalls_rounds():
    """One tenant's only capable worker crashes at t=0: its update can never
    arrive, yet every configured round still closes by its deadline and the
    tenant lands in the dropped ledger."""
    workers = [WorkerConfig("w1", 5), WorkerConfig("w2", 10)]
    tenants = [
        TenantSpec("a", qc=5, n_circuits=8),
        TenantSpec("b", qc=5, n_circuits=8),
        TenantSpec("big", qc=7, n_circuits=8),  # only fits the crashed w2
    ]
    cfg = FederatedConfig(n_rounds=3, quorum=0.5, seed=3)
    report = run_federated(
        cfg, tenants, toy_update_fn(3), PARAMS0, workers,
        gateway=True,
        worker_failures={"w2": FaultSpec(kind="crash", at=0.0)},
    )
    assert len(report.rounds) == 3
    for rec in report.rounds:
        assert "big" not in rec.on_time
        assert rec.deadline is None or rec.closed_at <= rec.deadline + 1e-9
    assert report.participation["big"]["dropped"] >= 1
    assert report.participation["big"]["participated"] == 0


def test_late_fold_in_deterministic_double_run():
    """The canonical straggler scenario (slow wide workers) must actually
    exercise the staleness fold-in path AND reproduce bit-identically on a
    same-seed double run."""
    faults = {
        w: FaultSpec(kind="slowdown", at=0.0, factor=10.0)
        for w in ("w2", "w3", "w4")
    }

    def once():
        cfg = FederatedConfig(n_rounds=4, quorum=0.5, seed=7)
        return run_federated(
            cfg, fig6_tenants(16), toy_update_fn(7), PARAMS0, fleet(),
            gateway=True, worker_failures=dict(faults),
        )

    r1, r2 = once(), once()
    assert any(rec.folded for rec in r1.rounds), "no straggler ever folded"
    assert fingerprint(r1) == fingerprint(r2)
    assert sum(c["late"] for c in r1.participation.values()) >= 1


# --------------------------------------------------------- secure agg and DP
def test_masked_aggregation_matches_plain_fedavg():
    rng = np.random.default_rng(0)
    tenants = ["a", "b", "c", "d"]
    updates = {
        t: {k: 0.1 * rng.standard_normal(np.shape(v)) for k, v in PARAMS0.items()}
        for t in tenants
    }
    finals = {}
    for secure in (False, True):
        co = FederatedCoordinator(
            FederatedConfig(n_rounds=1, secure_aggregation=secure, seed=5),
            PARAMS0,
        )
        co.begin_round(0, 0.0, tenants)
        for t in tenants:
            assert co.offer(t, updates[t], 0.5) == "participated"
        co.close_round(1.0)
        finals[secure] = co.params
    for k in PARAMS0:
        assert np.abs(finals[True][k] - finals[False][k]).max() <= 1e-6


def test_dp_noise_perturbs_and_accountant_accumulates():
    upd = {"a": {k: np.ones_like(v) for k, v in PARAMS0.items()}}

    def close_with(noise):
        cfg = FederatedConfig(
            n_rounds=1, dp_noise_multiplier=noise, dp_clip=1.0, seed=9
        )
        co = FederatedCoordinator(cfg, PARAMS0)
        co.begin_round(0, 0.0, ["a"])
        co.offer("a", upd["a"], 0.5)
        co.close_round(1.0)
        return co

    clean, noisy = close_with(0.0), close_with(2.0)
    assert any(
        np.abs(clean.params[k] - noisy.params[k]).max() > 0 for k in PARAMS0
    )
    summary = noisy.accountant.summary(1e-5)
    assert summary["rounds"] == 1
    assert summary["epsilon"] > 0
    assert clean.accountant.rounds == 0  # no noise -> nothing spent


def test_nan_update_never_reaches_aggregate():
    co = FederatedCoordinator(FederatedConfig(n_rounds=1), PARAMS0)
    co.begin_round(0, 0.0, ["good", "bad"])
    poison = {k: np.full(np.shape(v), np.nan) for k, v in PARAMS0.items()}
    assert co.offer("bad", poison, 0.1) == "nan_rejected"
    good = {k: np.ones(np.shape(v)) for k, v in PARAMS0.items()}
    assert co.offer("good", good, 0.2) == "participated"
    rec = co.close_round(1.0)
    assert rec.nan_rejected == ["bad"] and rec.on_time == ["good"]
    assert np.isfinite(co.params["theta"]).all()
    np.testing.assert_allclose(co.params["phi"], PARAMS0["phi"] + 1.0)
    assert co.participation["bad"]["dropped"] == 1


def test_staleness_policy_folds_then_drops():
    cfg = FederatedConfig(n_rounds=3, staleness_alpha=0.5, max_staleness=1)
    co = FederatedCoordinator(cfg, PARAMS0)
    co.begin_round(0, 0.0, ["a", "b"])
    co.offer("a", {k: np.zeros(np.shape(v)) for k, v in PARAMS0.items()}, 0.1)
    co.close_round(1.0)
    upd = {k: np.ones(np.shape(v)) for k, v in PARAMS0.items()}
    # one round late -> folds with the alpha discount into the next close
    assert co.offer_late("b", upd, 1.5, trained_round=0) == "late_folded"
    co.begin_round(1, 2.0, ["a", "b"])
    co.offer("a", {k: np.zeros(np.shape(v)) for k, v in PARAMS0.items()}, 2.1)
    rec = co.close_round(3.0)
    assert rec.folded == ["b"]
    # weights: a at 1.0 with a zero delta, b folded at 0.5 with ones
    np.testing.assert_allclose(
        co.params["phi"], PARAMS0["phi"] + 0.5 / 1.5, atol=1e-12
    )
    # beyond max_staleness -> dropped
    assert co.offer_late("b", upd, 3.5, trained_round=0) == "late_dropped"
    assert co.participation["b"]["late"] == 1
    assert co.participation["b"]["dropped"] == 1


# ------------------------------------------------------- telemetry and trace
def test_coordinator_emits_round_trace_events():
    trace = TraceRecorder()
    co = FederatedCoordinator(
        FederatedConfig(n_rounds=1), PARAMS0, trace=trace
    )
    co.begin_round(0, 0.0, ["a", "b"])
    for t in ("a", "b"):
        co.offer(t, {k: np.zeros(np.shape(v)) for k, v in PARAMS0.items()}, 0.5)
    co.close_round(1.0)
    assert trace.round_counts == {
        "round_start": 1,
        "update_received": 2,
        "round_aggregated": 1,
    }
    with pytest.raises(ValueError):
        trace.round_event(0, "not_a_stage", 0.0)


def test_gateway_telemetry_carries_federated_counters():
    cfg = FederatedConfig(n_rounds=2, quorum=0.75, seed=1)
    report = run_federated(
        cfg, fig6_tenants(), toy_update_fn(1), PARAMS0, fleet(), gateway=True
    )
    gw = report.simulation.gateway_summary
    assert gw["federated_rounds"] == 2
    rows = {row["client"]: row for row in gw["tenants"]}
    fed = rows["t5a"]["federated"]
    assert fed["participated"] >= 1
    assert report.rounds_per_second > 0
    assert 0.0 <= report.quorum_wait_share <= 1.0
