"""Parameter-shift rule tests: bank layout, exactness on single/dual layers,
four-term correction for controlled rotations, gradient assembly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import circuits, fidelity as fid, shift_rule


def _setup(qc, nl, b=3, seed=0):
    spec = circuits.build_quclassi_circuit(qc, nl)
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(key, (spec.n_theta,)) * np.pi
    data = jax.random.uniform(jax.random.fold_in(key, 1), (b, spec.n_data)) * np.pi
    labels = jnp.asarray(np.random.default_rng(seed).integers(0, 2, b), jnp.float32)
    return spec, theta, data, labels


def test_bank_layout():
    spec, theta, data, _ = _setup(5, 2, b=3)
    bank = shift_rule.build_bank(theta, data)
    p, b = spec.n_theta, 3
    assert bank.n_circuits == b * (2 * p + 1)
    # first B rows are unshifted
    np.testing.assert_allclose(np.asarray(bank.theta[:b]),
                               np.tile(np.asarray(theta), (b, 1)))
    # row for (plus-shift, param j, sample i)
    j, i = 2, 1
    row = bank.theta[b + j * b + i]
    expect = np.asarray(theta).copy()
    expect[j] += np.pi / 2
    np.testing.assert_allclose(np.asarray(row), expect, atol=1e-6)
    # data tiled in the same order
    np.testing.assert_allclose(np.asarray(bank.data[b + j * b + i]),
                               np.asarray(data[i]), atol=1e-6)


def test_split_results_roundtrip():
    spec, theta, data, _ = _setup(5, 1, b=4)
    bank = shift_rule.build_bank(theta, data)
    f = jnp.arange(bank.n_circuits, dtype=jnp.float32)
    f0, fp, fm = bank.split_results(f)
    assert f0.shape == (4,)
    assert fp.shape == (spec.n_theta, 4)
    assert fm.shape == (spec.n_theta, 4)
    np.testing.assert_allclose(np.asarray(f0), [0, 1, 2, 3])
    np.testing.assert_allclose(np.asarray(fp[0]), [4, 5, 6, 7])


def test_four_term_bank_size():
    spec, theta, data, _ = _setup(5, 3, b=2)
    bank = shift_rule.build_bank(theta, data, four_term=True)
    assert bank.n_circuits == 2 * (4 * spec.n_theta + 1)


def test_controlled_param_indices():
    spec = circuits.build_quclassi_circuit(5, 3)
    idx = shift_rule.controlled_param_indices(spec)
    # m=2: single(4 params 0-3) + dual(2 params 4-5) + entangle(2 params 6-7)
    assert idx == (6, 7)
    assert shift_rule.controlled_param_indices(
        circuits.build_quclassi_circuit(5, 2)) == ()


@pytest.mark.parametrize("qc,nl", [(5, 1), (5, 2), (7, 1), (7, 2)])
def test_two_term_exact_without_controlled_gates(qc, nl):
    """Exact up to float32: the BCE chain dL/dF = (F-y)/(F(1-F)) amplifies
    fidelity round-off by ~1/F(1-F), hence rtol rather than tight atol."""
    spec, theta, data, labels = _setup(qc, nl)
    _, g_shift, f_shift = shift_rule.parameter_shift_grad(spec, theta, data, labels)
    _, g_auto, f_auto = shift_rule.autodiff_grad(spec, theta, data, labels)
    np.testing.assert_allclose(np.asarray(g_shift), np.asarray(g_auto),
                               rtol=5e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f_shift), np.asarray(f_auto), atol=1e-5)

    def pure_fid_grads(t):
        """Compare dF/dtheta itself (no BCE amplification) tightly."""
        return fid.fidelity_batch(spec, jnp.broadcast_to(t, (data.shape[0],)
                                                         + t.shape), data).sum()
    g_f_auto = jax.grad(pure_fid_grads)(theta)
    bank = shift_rule.build_bank(theta, data)
    fids = shift_rule.default_executor(spec)(bank.theta, bank.data)
    _, fp, fm = bank.split_results(fids)[:3]
    g_f_shift = ((fp - fm) / 2.0).sum(-1)
    np.testing.assert_allclose(np.asarray(g_f_shift), np.asarray(g_f_auto),
                               atol=5e-5)


@pytest.mark.parametrize("qc", [5, 7])
def test_four_term_exact_with_controlled_gates(qc):
    spec, theta, data, labels = _setup(qc, 3)
    _, g4, _ = shift_rule.parameter_shift_grad(spec, theta, data, labels,
                                               exact_controlled=True)
    _, ga, _ = shift_rule.autodiff_grad(spec, theta, data, labels)
    np.testing.assert_allclose(np.asarray(g4), np.asarray(ga), atol=3e-4)


def test_two_term_biased_only_on_controlled_params():
    spec, theta, data, labels = _setup(5, 3)
    _, g2, _ = shift_rule.parameter_shift_grad(spec, theta, data, labels)
    _, ga, _ = shift_rule.autodiff_grad(spec, theta, data, labels)
    err = np.abs(np.asarray(g2) - np.asarray(ga))
    ctrl = set(shift_rule.controlled_param_indices(spec))
    for j in range(spec.n_theta):
        if j not in ctrl:
            assert err[j] < 2e-5, (j, err[j])


def test_executor_hook_receives_full_bank():
    spec, theta, data, labels = _setup(5, 1, b=2)
    seen = {}

    def executor(t, d):
        seen["shape"] = (t.shape, d.shape)
        return fid.fidelity_batch(spec, t, d)

    shift_rule.parameter_shift_grad(spec, theta, data, labels, executor=executor)
    c = 2 * (2 * spec.n_theta + 1)
    assert seen["shape"] == ((c, spec.n_theta), (c, spec.n_data))
