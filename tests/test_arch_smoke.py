"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
variant of each assigned architecture's family (<=2 periods, d_model<=256,
<=4 experts), run one forward/train step and one decode step on CPU, assert
output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfg_base
from repro.launch import steps
from repro.models import multimodal, transformer

ALL_ARCHS = [
    "nemotron-4-340b", "phi-3-vision-4.2b", "granite-34b", "smollm-360m",
    "qwen3-4b", "granite-moe-3b-a800m", "musicgen-large", "xlstm-125m",
    "jamba-v0.1-52b", "deepseek-v3-671b",
]

SEQ, BATCH = 16, 2


@pytest.fixture(scope="module")
def reduced_setups():
    out = {}
    for arch in ALL_ARCHS:
        cfg = cfg_base.get(arch).reduced()
        model = transformer.Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        out[arch] = (cfg, model, params)
    return out


def test_all_archs_registered():
    names = cfg_base.all_names()
    for a in ALL_ARCHS:
        assert a in names, f"missing config for {a}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config carries the exact assigned numbers + citation."""
    spec = {
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
    }[arch]
    cfg = cfg_base.get(arch)
    # MoE archs whose pool spec gives d_ff as the per-expert width
    dff = (cfg.moe.d_ff_expert
           if arch in ("granite-moe-3b-a800m", "deepseek-v3-671b") else cfg.d_ff)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads, dff, cfg.vocab)
    assert got == spec, (arch, got, spec)
    assert cfg.source, f"{arch} missing its pool citation"


def test_family_specifics():
    assert cfg_base.get("nemotron-4-340b").activation == "relu2"
    assert cfg_base.get("qwen3-4b").qk_norm
    assert cfg_base.get("granite-moe-3b-a800m").moe.n_experts == 40
    assert cfg_base.get("granite-moe-3b-a800m").moe.top_k == 8
    assert cfg_base.get("deepseek-v3-671b").moe.n_experts == 256
    assert cfg_base.get("deepseek-v3-671b").moe.n_shared_experts == 1
    assert cfg_base.get("deepseek-v3-671b").mla is not None
    assert cfg_base.get("deepseek-v3-671b").mtp_depth == 1
    jamba = cfg_base.get("jamba-v0.1-52b")
    assert jamba.pattern.count("mamba") == 7 and jamba.pattern.count("attn") == 1
    assert jamba.moe.n_experts == 16 and jamba.moe.top_k == 2
    xl = cfg_base.get("xlstm-125m")
    assert set(xl.pattern) == {"mlstm", "slstm"}
    assert cfg_base.get("musicgen-large").n_codebooks == 4
    assert cfg_base.get("phi-3-vision-4.2b").n_prefix_embeds > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_config_limits(arch):
    cfg = cfg_base.get(arch).reduced()
    assert cfg.n_periods <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch, reduced_setups):
    cfg, model, params = reduced_setups[arch]
    batch = multimodal.batch_for(cfg, BATCH, SEQ)
    logits, aux = model.prefill(params, batch)
    if cfg.n_codebooks:
        assert logits.shape == (BATCH, SEQ, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaN/Inf"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_runs_and_loss_finite(arch, reduced_setups):
    cfg, model, params = reduced_setups[arch]
    train_step, optimizer, _ = steps.make_train_step(cfg, global_batch=BATCH)
    opt_state = optimizer.init(params)
    batch = multimodal.batch_for(cfg, BATCH, SEQ)
    new_params, new_opt, loss = jax.jit(train_step)(params, opt_state, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # parameters actually changed
    deltas = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                          params, new_params)
    assert max(jax.tree.leaves(deltas)) > 0.0
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_shapes(arch, reduced_setups):
    cfg, model, params = reduced_setups[arch]
    caches = model.init_caches(BATCH, SEQ)
    batch = multimodal.decode_batch_for(cfg, BATCH)
    logits, new_caches = model.decode_step(params, batch, caches, jnp.int32(3))
    if cfg.n_codebooks:
        assert logits.shape == (BATCH, 1, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (BATCH, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("arch", ["smollm-360m", "xlstm-125m",
                                  "granite-moe-3b-a800m"])
def test_two_train_steps_reduce_loss(arch, reduced_setups):
    """Loss moves in the right direction on a repeated batch."""
    cfg, model, params = reduced_setups[arch]
    train_step, optimizer, _ = steps.make_train_step(cfg, global_batch=BATCH)
    opt_state = optimizer.init(params)
    batch = multimodal.batch_for(cfg, BATCH, SEQ, seed=7)
    step = jax.jit(train_step)
    losses = []
    for _ in range(3):
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_param_count_sane():
    cfg = cfg_base.get("smollm-360m")
    model = transformer.Model(cfg)
    shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    n = transformer.param_count(shapes)
    assert 3.0e8 < n < 4.5e8, n   # ~360M


def test_moe_active_params_less_than_total():
    cfg = cfg_base.get("granite-moe-3b-a800m")
    model = transformer.Model(cfg)
    shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    total = transformer.param_count(shapes)
    active = transformer.active_param_count(cfg, shapes)
    assert active < total
    assert 2.5e9 < total < 4.0e9, total     # ~3B total
    assert 0.5e9 < active < 1.5e9, active   # ~800M active
