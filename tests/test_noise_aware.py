"""Beyond-paper noise-aware scheduling (the paper's §V limitation #2)."""
import pytest

from repro.comanager import tenancy
from repro.comanager.manager import CoManager
from repro.comanager.simulation import SystemSimulation
from repro.comanager.worker import CircuitTask, QuantumWorker, WorkerConfig


def task(tid, depth=14, demand=5):
    return CircuitTask(task_id=tid, client_id="c", demand=demand,
                       service_time=1.0, depth=depth)


def test_noise_aware_prefers_clean_worker():
    m = CoManager(policy="noise_aware")
    m.register_worker("w_noisy", 10, cru=0.0, t=0, error_rate=0.01)
    m.register_worker("w_clean", 10, cru=0.9, t=0, error_rate=0.001)
    # CRU policy would pick w_noisy (lower CRU); noise-aware picks clean
    assert m.assign(task(1), t=0) == "w_clean"


def test_cru_policy_ignores_noise():
    m = CoManager(policy="cru")
    m.register_worker("w_noisy", 10, cru=0.0, t=0, error_rate=0.01)
    m.register_worker("w_clean", 10, cru=0.9, t=0, error_rate=0.001)
    assert m.assign(task(1), t=0) == "w_noisy"


def test_fidelity_floor_excludes_noisy_machine():
    m = CoManager(policy="noise_aware", fidelity_floor=0.9)
    m.register_worker("w_noisy", 10, cru=0.0, t=0, error_rate=0.02)
    # (1-0.02)^14 = 0.75 < 0.9 -> no candidate, circuit queues
    assert m.assign(task(1, depth=14), t=0) is None
    # a shallow circuit is fine on the same machine: 0.98^4 = 0.92
    assert m.assign(task(2, depth=4), t=0) == "w_noisy"


def test_floor_trades_runtime_for_retention():
    def go(policy, floor):
        jobs = [tenancy.JobSpec("c", 5, 2, 60, service_override=0.5)]
        workers = [WorkerConfig("a_clean", 10, error_rate=0.0005),
                   WorkerConfig("b_noisy", 20, speed=1.5, error_rate=0.015)]
        return SystemSimulation(workers, jobs, policy=policy,
                                fidelity_floor=floor).run()

    base = go("cru", 0.0)
    strict = go("noise_aware", 0.97)
    assert strict.fidelity_retention > base.fidelity_retention
    assert strict.makespan > base.makespan      # the price paid
    assert strict.jobs["c"].n_circuits == 60    # nothing dropped


def test_depolarization_model():
    w = QuantumWorker(WorkerConfig("w", 5, error_rate=0.01))
    lam = w.depolarization(depth=10)
    assert lam == pytest.approx(1 - 0.99 ** 10)
    # P0=1 ideal -> pulled toward 1/2
    assert w.observed_p0(1.0, 10) == pytest.approx(1 - lam / 2)
    # noiseless worker is identity
    w0 = QuantumWorker(WorkerConfig("w0", 5))
    assert w0.observed_p0(0.73, 99) == 0.73


def test_heartbeat_carries_error_rate():
    m = CoManager(policy="noise_aware")
    m.register_worker("w", 10, cru=0.0, t=0)
    w = QuantumWorker(WorkerConfig("w", 10, error_rate=0.007))
    m.heartbeat(w.heartbeat_payload(5.0), t=5.0)
    assert m.workers["w"].error_rate == 0.007
