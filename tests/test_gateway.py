"""Online serving gateway (repro.serve): coalescing policy, weighted-fair
admission, backpressure, co-Manager placement, exactly-once eviction
recovery, and the bank-order equivalence guarantees the gradient math
relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comanager import dataplane
from repro.comanager.simulation import SystemSimulation
from repro.comanager.tenancy import JobSpec
from repro.comanager.worker import WorkerConfig
from repro.core import quclassi
from repro.core.quclassi import QuClassiConfig
from repro.serve import (Backpressure, Coalescer, Gateway, GatewayRuntime,
                         PendingCircuit)


def item(key, cid, seq, arrival=0.0):
    return PendingCircuit(key=key, client_id=cid, seq=seq, arrival=arrival,
                          payload=seq)


# ----------------------------------------------------------------- coalescer
def test_coalescer_size_flush_emits_full_lane_multiples():
    c = Coalescer(target=8, lanes=4, deadline=10.0)
    batches = []
    for i in range(19):
        batches += c.add(item("k", "a", i, arrival=0.0))
    assert [b.n for b in batches] == [8, 8]
    assert c.buffered == 3
    # members preserved in admission order
    assert [m.seq for m in batches[0].members] == list(range(8))


def test_coalescer_deadline_flushes_partial_batches():
    c = Coalescer(target=8, lanes=4, deadline=1.0)
    c.add(item("k", "a", 0, arrival=0.0))
    c.add(item("k", "a", 1, arrival=0.4))
    assert c.flush_due(now=0.5) == []          # oldest is only 0.5s old
    due = c.flush_due(now=1.0)
    assert len(due) == 1 and due[0].n == 2 and due[0].by_deadline
    assert c.buffered == 0


def test_coalescer_keys_do_not_mix():
    c = Coalescer(target=4, lanes=4, deadline=10.0)
    out = []
    for i in range(4):
        out += c.add(item("k5", "a", 2 * i))
        out += c.add(item("k7", "b", 2 * i + 1))
    assert len(out) == 2
    assert {b.key for b in out} == {"k5", "k7"}
    assert all(len(b.clients()) == 1 for b in out)


def test_coalescer_requeue_goes_to_front():
    c = Coalescer(target=4, lanes=4, deadline=1.0)
    (full,) = c.add(item("k", "a", 0)) + c.add(item("k", "a", 1)) + \
              c.add(item("k", "a", 2)) + c.add(item("k", "a", 3))
    c.add(item("k", "a", 4))
    c.requeue(full)
    (again,) = c.flush_due(now=5.0)   # old arrivals -> immediately due
    assert [m.seq for m in again.members] == [0, 1, 2, 3]
    assert c.next_deadline() is not None


# ------------------------------------------------------------------- gateway
def test_weighted_fair_dequeue_respects_weights():
    g = Gateway(target=128, lanes=128, deadline=100.0)
    g.register_client("a", weight=2.0)
    g.register_client("b", weight=1.0)
    for i in range(30):
        g.submit("a", "k", i, now=0.0)
        g.submit("b", "k", 100 + i, now=0.0)
    g.pump(now=0.0)
    order = [m.client_id for m in g.coalescer._buffers["k"]]
    first9 = order[:9]
    assert first9.count("a") == 6 and first9.count("b") == 3


def test_late_joining_tenant_does_not_monopolize():
    """A tenant registering after others have been served starts at the
    current minimum virtual pass, not 0 — no catch-up monopoly."""
    g = Gateway(target=128, lanes=128, deadline=100.0)
    for i in range(40):
        g.submit("a", "k", i, now=0.0)
    g.pump(now=0.0)                      # a's vpass advances to 40
    g.register_client("b")
    for i in range(8):
        g.submit("a", "k", i, now=1.0)
        g.submit("b", "k", i, now=1.0)
    g.pump(now=1.0)
    recent = [m.client_id for m in g.coalescer._buffers["k"]][40:]
    # interleaved service, not 8x b followed by 8x a
    assert recent[:4].count("b") <= 3


def test_backpressure_bounds_tenant_queue():
    g = Gateway(target=128, deadline=100.0, max_pending=4)
    for i in range(4):
        g.submit("a", "k", i, now=0.0)
    with pytest.raises(Backpressure):
        g.submit("a", "k", 99, now=0.0)
    assert g.telemetry.tenants["a"].rejected == 1
    # another tenant's budget is untouched
    g.submit("b", "k", 0, now=0.0)


def test_in_flight_cap_skips_saturated_tenant():
    g = Gateway(target=4, lanes=4, deadline=100.0)
    g.register_client("a", max_in_flight=4)
    g.register_client("b")
    for i in range(8):
        g.submit("a", "k", i, now=0.0)
    (b1,) = g.pump(now=0.0)             # first 4 dequeue and flush by size
    assert b1.n == 4
    assert g.pump(now=0.0) == []        # at cap: nothing more dequeues
    assert len(g.tenants["a"].queue) == 4
    g.complete(b1, None, now=1.0)
    g.submit("b", "k", 100, now=1.0)    # capacity back + a second tenant
    (b2,) = g.pump(now=1.0)
    assert b2.n == 4 and b2.clients() == {"a", "b"}


def test_futures_resolve_in_submission_order():
    g = Gateway(target=4, lanes=4, deadline=100.0)
    futs = [g.submit("a", "k", i, now=0.0) for i in range(4)]
    (batch,) = g.pump(now=0.0)
    g.complete(batch, [10, 11, 12, 13], now=1.0)
    assert [f.value for f in futs] == [10, 11, 12, 13]
    assert all(f.done for f in futs)


# ---------------------------------------------- real data plane equivalence
@pytest.fixture(scope="module")
def bank_setup():
    cfg = QuClassiConfig(qc=5, n_layers=1)
    rng = np.random.default_rng(0)
    n = 70
    theta = jnp.asarray(rng.uniform(0, np.pi, (n, cfg.n_theta)), jnp.float32)
    data = jnp.asarray(rng.uniform(0, np.pi, (n, cfg.n_angles)), jnp.float32)
    return cfg, theta, data


def test_bank_order_equivalence_across_executors(bank_setup):
    """worker_batched / sharded / gateway all return fidelities in bank
    order: the gradient assembly is executor-independent."""
    cfg, theta, data = bank_setup
    assignment = dataplane.round_robin_assignment(theta.shape[0], 3)
    f_worker = dataplane.worker_batched_executor(cfg.spec, assignment, 3)(theta, data)

    from repro.launch.mesh import make_host_mesh
    f_sharded = dataplane.sharded_executor(cfg.spec, make_host_mesh())(theta, data)

    rt = GatewayRuntime(target=128, deadline=0.1)
    f_gateway = rt.executor(cfg.spec, "c1")(theta, data)

    np.testing.assert_allclose(np.asarray(f_worker), np.asarray(f_sharded),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(f_worker), np.asarray(f_gateway),
                               atol=1e-6)


def test_gateway_fidelities_bit_identical_to_worker_batched(bank_setup):
    """Acceptance: gateway-scattered fidelities == worker_batched_executor
    output, bitwise, in bank order (per-lane kernel math is independent of
    batch composition)."""
    cfg, theta, data = bank_setup
    assignment = dataplane.round_robin_assignment(theta.shape[0], 4)
    f_direct = dataplane.worker_batched_executor(cfg.spec, assignment, 4)(theta, data)

    rt = GatewayRuntime(target=128, deadline=0.1)
    # two tenants interleaved: cross-tenant batches, same bit-exact results
    ex_a = rt.executor(cfg.spec, "a")
    f_gw = ex_a(theta, data)
    assert np.array_equal(np.asarray(f_direct), np.asarray(f_gw))


def test_multi_tenant_training_through_shared_gateway(bank_setup):
    """Two training clients share one runtime; both gradients match the
    local executor exactly (within fp tolerance)."""
    cfg, _, _ = bank_setup
    from repro.data import mnist
    x, y = mnist.make_pair_dataset(3, 9, n_per_class=4, seed=0)
    x, y = jnp.asarray(x[:2]), jnp.asarray(y[:2])
    params = quclassi.init_params(cfg, jax.random.PRNGKey(0))

    rt = GatewayRuntime(target=128, deadline=0.2)
    l_ref, g_ref, _ = quclassi.grad_shift(cfg, params, x, y)
    for cid in ("alice", "bob"):
        ex = rt.executor(cfg.spec, cid)
        l_gw, g_gw, _ = quclassi.grad_shift(cfg, params, x, y, executor=ex)
        np.testing.assert_allclose(np.asarray(g_gw["theta"]),
                                   np.asarray(g_ref["theta"]), atol=1e-5)
    assert rt.telemetry.tenants["alice"].completed > 0
    assert rt.telemetry.tenants["bob"].completed > 0


def test_trainer_gateway_kwarg():
    from repro.core import trainer
    from repro.data import mnist
    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(3, 9, n_per_class=6, seed=0)
    split = ((x[:4], y[:4]), (x[4:], y[4:]))
    rt = GatewayRuntime(target=128, deadline=0.2)
    rep = trainer.train(cfg, *split, epochs=1, batch_size=4, lr=0.05,
                        gateway=rt, client_id="t1", seed=0)
    assert len(rep.epochs) == 1
    assert rt.telemetry.tenants["t1"].completed > 0
    with pytest.raises(ValueError):
        trainer.train(cfg, *split, epochs=1, gateway=rt,
                      executor=lambda t, d: t)


# --------------------------------------------------- virtual-clock gateway
def sim_jobs(n=200, st=0.3):
    return [JobSpec(f"c{k}", 5 if k < 2 else 7, 1, n, service_override=st)
            for k in range(4)]


def fig6_workers(contention=0.5):
    return [WorkerConfig(f"w{i+1}", q, contention=contention)
            for i, q in enumerate((5, 10, 15, 20))]


def test_sim_gateway_completes_everything_and_beats_per_circuit():
    base = SystemSimulation(fig6_workers(), sim_jobs(), fair_queue=True,
                            classical_overhead=0.01).run()
    gw = SystemSimulation(fig6_workers(), sim_jobs(), gateway=True,
                          gateway_deadline=1.0, classical_overhead=0.01).run()
    assert gw.total_circuits == 800 and len(gw.jobs) == 4
    for k in range(4):
        assert gw.jobs[f"c{k}"].n_circuits == 200
    assert gw.circuits_per_second > base.circuits_per_second
    s = gw.gateway_summary
    assert s["total_completed"] == 800
    assert 0.0 < s["lane_fill"] <= 1.0


def test_sim_gateway_deadline_bounds_latency_under_light_load():
    """A lone trickle of circuits must not wait for a full lane batch."""
    jobs = [JobSpec("c0", 5, 1, 3, service_override=0.1)]
    rep = SystemSimulation([WorkerConfig("w1", 5)], jobs, gateway=True,
                           gateway_deadline=0.5).run()
    assert rep.jobs["c0"].n_circuits == 3
    # 3 circuits << 128: flushed by deadline, not stuck forever
    assert rep.makespan < 2.0
    assert rep.gateway_summary["deadline_flushes"] >= 1


def test_sim_gateway_poisson_arrivals_stream():
    rng = np.random.default_rng(0)
    jobs = [JobSpec(f"c{k}", 5, 1, 100, service_override=0.1) for k in range(2)]
    arrivals = {f"c{k}": np.cumsum(rng.exponential(1 / 50.0, 100)).tolist()
                for k in range(2)}
    rep = SystemSimulation(fig6_workers(), jobs, gateway=True,
                           gateway_deadline=1.0, arrivals=arrivals).run()
    assert all(rep.jobs[f"c{k}"].n_circuits == 100 for k in range(2))
    s = rep.gateway_summary
    assert s["total_completed"] == 200
    for t in s["tenants"]:
        assert t["p99_latency_s"] >= t["p50_latency_s"] > 0.0


def test_sim_gateway_eviction_requeues_and_recoalesces_exactly_once():
    """Acceptance (satellite): a worker dying mid-batch loses nothing and
    duplicates nothing — its batch members are re-coalesced and complete
    exactly once each."""
    jobs = [JobSpec(f"c{k}", 5, 1, 200, service_override=5.0) for k in range(2)]
    workers = [WorkerConfig("w1", 5), WorkerConfig("w2", 10)]
    sim = SystemSimulation(workers, jobs, gateway=True, gateway_deadline=1.0,
                           worker_failures={"w2": 2.0}, run_until=1e6)
    rep = sim.run()
    assert [wid for _, wid in rep.evictions] == ["w2"]
    # every circuit of every client completed exactly once
    assert rep.jobs["c0"].n_circuits == 200
    assert rep.jobs["c1"].n_circuits == 200
    s = rep.gateway_summary
    assert s["total_completed"] == 400
    for t in s["tenants"]:
        assert t["completed"] == t["submitted"] == 200
    # post-eviction work all lands on the survivor
    late = [wid for (t, _, wid) in rep.assignments if t > 20.0]
    assert late and set(late) == {"w1"}


def test_sim_gateway_deterministic_replay():
    def go():
        rep = SystemSimulation(fig6_workers(), sim_jobs(n=120), gateway=True,
                               gateway_deadline=1.0).run()
        return rep.makespan, tuple(rep.assignments)
    assert go() == go()


def test_two_simulations_have_independent_task_ids():
    """Satellite: no module-global id counter — concurrently constructed
    simulations allocate from isolated id spaces."""
    jobs_a = [JobSpec("a", 5, 1, 5, service_override=0.1)]
    jobs_b = [JobSpec("b", 5, 1, 5, service_override=0.1)]
    s1 = SystemSimulation([WorkerConfig("w1", 5)], jobs_a)
    s2 = SystemSimulation([WorkerConfig("w1", 5)], jobs_b)
    r1, r2 = s1.run(), s2.run()   # interleaved construction, serial runs
    ids1 = sorted(tid for _, tid, _ in r1.assignments)
    ids2 = sorted(tid for _, tid, _ in r2.assignments)
    assert ids1 == list(range(5)) and ids2 == list(range(5))


# ------------------------------------------------------- per-tier admission
def test_tier_cap_sheds_saturated_tier_weighted_fair():
    g = Gateway(target=128, lanes=128, deadline=100.0,
                max_pending_per_tier={1: 4})
    g.register_client("a", priority=1)
    g.register_client("b", priority=1)
    for i in range(4):
        g.submit("a", "k", i, now=0.0)
    # tier full and a holds double its share (4 > 4 * 1/2 = 2)
    with pytest.raises(Backpressure, match="tier 1 at admission cap"):
        g.submit("a", "k", 99, now=0.0)
    assert g.telemetry.tenants["a"].rejected == 1
    # b is below its within-tier share: the floor-at-one rule keeps it live
    g.submit("b", "k", 100, now=0.0)


def test_tier_cap_does_not_leak_across_tiers():
    """A saturated low tier never consumes a high tier's headroom (and a
    tier with no cap configured is never shed)."""
    g = Gateway(target=128, lanes=128, deadline=100.0,
                max_pending_per_tier={1: 2})
    g.register_client("lo", priority=1)
    g.register_client("hi", priority=0)
    g.submit("lo", "k", 0, now=0.0)
    g.submit("lo", "k", 1, now=0.0)
    with pytest.raises(Backpressure):
        g.submit("lo", "k", 2, now=0.0)
    for i in range(10):                      # tier 0: uncapped
        g.submit("hi", "k", 100 + i, now=0.0)


def test_tier_cap_frees_headroom_on_completion():
    g = Gateway(target=4, lanes=4, deadline=100.0,
                max_pending_per_tier={1: 4})
    g.register_client("a", priority=1)
    for i in range(4):
        g.submit("a", "k", i, now=0.0)
    with pytest.raises(Backpressure):
        g.submit("a", "k", 98, now=0.0)
    (batch,) = g.pump(now=0.0)
    # dequeued-but-in-flight circuits still hold their tier slots
    with pytest.raises(Backpressure):
        g.submit("a", "k", 99, now=0.0)
    g.complete(batch, None, now=1.0)
    g.submit("a", "k", 100, now=1.0)
